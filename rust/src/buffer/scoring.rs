//! The Fig 4 scoring policy, mirrored bit-for-bit by the Pallas
//! `score_update` kernel (python/compile/kernels/score.py).
//!
//! Access ⇒ `score += 1`.  Not accessed during the minibatch-sampling epoch
//! ⇒ `score *= 0.95`.  `score < 0.95` ⇒ the node is **stale** (evictable).
//! More aggressive than LFU: long-unused items decay geometrically instead
//! of persisting on historical counts (the paper's anti-cache-pollution
//! argument, §2.1).

pub const DECAY: f32 = 0.95;
pub const STALE_THRESHOLD: f32 = 0.95;
/// Score granted to a freshly inserted node (one access).
pub const INITIAL_SCORE: f32 = 1.0;

/// Apply one round of the policy to dense score/accessed columns.
/// Returns the number of stale slots.  `live[i] == false` slots are skipped.
pub fn apply_round(scores: &mut [f32], accessed: &mut [bool], live: &[bool]) -> usize {
    debug_assert_eq!(scores.len(), accessed.len());
    debug_assert_eq!(scores.len(), live.len());
    let mut stale = 0usize;
    for i in 0..scores.len() {
        if !live[i] {
            continue;
        }
        if accessed[i] {
            scores[i] += 1.0;
            accessed[i] = false;
        } else {
            scores[i] *= DECAY;
        }
        if scores[i] < STALE_THRESHOLD {
            stale += 1;
        }
    }
    stale
}

/// Alternative policies for the replacement-strategy ablation (Fig 3 bench).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's frequency-decay policy.
    FreqDecay,
    /// Classic LFU: counts only grow; eviction picks the minimum count.
    Lfu,
    /// LRU: evict the least-recently-accessed slot.
    Lru,
}

impl Policy {
    pub fn parse(s: &str) -> crate::error::Result<Policy> {
        match s {
            "freq_decay" | "rudder" => Ok(Policy::FreqDecay),
            "lfu" => Ok(Policy::Lfu),
            "lru" => Ok(Policy::Lru),
            _ => crate::bail!("unknown scoring policy '{s}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessed_increment_unaccessed_decay() {
        let mut scores = vec![1.0, 1.0, 2.0];
        let mut accessed = vec![true, false, false];
        let live = vec![true, true, true];
        let stale = apply_round(&mut scores, &mut accessed, &live);
        assert_eq!(scores, vec![2.0, 0.95, 1.9]);
        assert_eq!(stale, 0); // 0.95 is not < 0.95
        assert_eq!(accessed, vec![false, false, false]);
    }

    #[test]
    fn stale_detection_matches_kernel_semantics() {
        // Mirror python/tests/test_kernels.py::test_score_update_semantics.
        let mut scores = vec![1.0, 1.0, 0.99, 10.0];
        let mut accessed = vec![true, false, false, false];
        let live = vec![true; 4];
        let stale = apply_round(&mut scores, &mut accessed, &live);
        assert!((scores[2] - 0.9405).abs() < 1e-6);
        assert_eq!(stale, 1);
    }

    #[test]
    fn two_idle_rounds_to_stale_from_fresh() {
        let mut scores = vec![INITIAL_SCORE];
        let mut accessed = vec![false];
        let live = vec![true];
        assert_eq!(apply_round(&mut scores, &mut accessed, &live), 0);
        assert_eq!(apply_round(&mut scores, &mut accessed, &live), 1);
    }

    #[test]
    fn dead_slots_skipped() {
        let mut scores = vec![0.5, 0.5];
        let mut accessed = vec![false, false];
        let live = vec![false, true];
        let stale = apply_round(&mut scores, &mut accessed, &live);
        assert_eq!(stale, 1);
        assert_eq!(scores[0], 0.5); // untouched
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("rudder").unwrap(), Policy::FreqDecay);
        assert_eq!(Policy::parse("lfu").unwrap(), Policy::Lfu);
        assert!(Policy::parse("fifo").is_err());
    }
}
