//! The persistent buffer: Rudder's per-trainer cache of remote-node features.
//!
//! Fixed capacity (`pct × |halo|`, paper §5.1), starts empty, and is
//! refreshed by *replacement rounds*: evict stale nodes (score < 0.95 under
//! the [`scoring`] policy) and admit recently sampled remote nodes that
//! missed.  The *when* of those rounds is the controller's decision (LLM
//! agent / ML classifier / fixed / never); the *what* is decided here.
//!
//! Layout is SoA (ids / scores / accessed / live columns) so the
//! per-minibatch score pass is a linear sweep — the same access pattern the
//! `score_update` Pallas kernel implements for the XLA path.

pub mod scoring;

use crate::util::fasthash::FastMap;

use scoring::{Policy, DECAY, INITIAL_SCORE, STALE_THRESHOLD};

/// Result of a buffer lookup for one minibatch.
#[derive(Debug, Clone, Default)]
pub struct LookupResult {
    pub hits: usize,
    pub misses: usize,
    /// Missed node ids (to fetch remotely this minibatch).
    pub missed_nodes: Vec<u32>,
}

impl LookupResult {
    /// The paper's %-Hits metric: sampled remote nodes found in the buffer.
    pub fn hits_pct(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            100.0
        } else {
            self.hits as f64 / total as f64 * 100.0
        }
    }
}

/// Outcome of a replacement round.
#[derive(Debug, Clone, Default)]
pub struct ReplaceOutcome {
    pub evicted: usize,
    pub inserted: usize,
    /// Nodes newly admitted (their features must be fetched).
    pub fetched_nodes: Vec<u32>,
    /// Nodes evicted this round (their cached features can be dropped —
    /// the cluster runtime uses this to bound its feature store).
    pub evicted_nodes: Vec<u32>,
    /// True when no stale node existed, so replacement was skipped.
    pub skipped: bool,
}

#[derive(Debug, Clone)]
pub struct PersistentBuffer {
    capacity: usize,
    policy: Policy,
    /// node id per slot (u32::MAX = free).
    ids: Vec<u32>,
    scores: Vec<f32>,
    accessed: Vec<bool>,
    live: Vec<bool>,
    /// LRU clock per slot (policy == Lru).
    last_used: Vec<u64>,
    clock: u64,
    index: FastMap<u32, u32>,
    free: Vec<u32>,
    /// Decayed miss-frequency of remote nodes (admission candidates).
    miss_freq: FastMap<u32, f32>,
    rounds: u64,
}

impl PersistentBuffer {
    pub fn new(capacity: usize, policy: Policy) -> PersistentBuffer {
        PersistentBuffer {
            capacity,
            policy,
            ids: vec![u32::MAX; capacity],
            scores: vec![0.0; capacity],
            accessed: vec![false; capacity],
            live: vec![false; capacity],
            last_used: vec![0; capacity],
            clock: 0,
            index: FastMap::with_capacity_and_hasher(capacity, Default::default()),
            free: (0..capacity as u32).rev().collect(),
            miss_freq: FastMap::default(),
            rounds: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn occupancy(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.len() as f64 / self.capacity as f64
        }
    }

    pub fn contains(&self, node: u32) -> bool {
        self.index.contains_key(&node)
    }

    /// Look up this minibatch's sampled remote nodes; marks hits accessed
    /// and records misses as admission candidates.
    pub fn lookup(&mut self, remote_nodes: &[u32]) -> LookupResult {
        self.clock += 1;
        let mut res = LookupResult::default();
        for &v in remote_nodes {
            match self.index.get(&v) {
                Some(&slot) => {
                    res.hits += 1;
                    self.accessed[slot as usize] = true;
                    self.last_used[slot as usize] = self.clock;
                }
                None => {
                    res.misses += 1;
                    res.missed_nodes.push(v);
                    *self.miss_freq.entry(v).or_insert(0.0) += 1.0;
                }
            }
        }
        res
    }

    /// End-of-minibatch score pass (the Fig 4 policy / Pallas kernel).
    /// Returns the number of stale slots.
    pub fn end_round(&mut self) -> usize {
        self.rounds += 1;
        let stale = match self.policy {
            Policy::FreqDecay => {
                scoring::apply_round(&mut self.scores, &mut self.accessed, &self.live)
            }
            Policy::Lfu => {
                // Counts only grow; staleness = anything (eviction ranks).
                for i in 0..self.capacity {
                    if self.live[i] && self.accessed[i] {
                        self.scores[i] += 1.0;
                        self.accessed[i] = false;
                    }
                }
                self.len()
            }
            Policy::Lru => self.len(),
        };
        // Decay miss-frequency so admission prefers *recent* misses.
        self.miss_freq.retain(|_, f| {
            *f *= DECAY;
            *f > 0.05
        });
        stale
    }

    /// Stale slot count without mutating (controller metric).
    pub fn stale_count(&self) -> usize {
        match self.policy {
            Policy::FreqDecay => (0..self.capacity)
                .filter(|&i| self.live[i] && self.scores[i] < STALE_THRESHOLD)
                .count(),
            _ => self.len(),
        }
    }

    /// Execute a replacement round: evict stale slots, admit the
    /// highest-miss-frequency candidates (paper: "recently sampled remote
    /// nodes").  No stale nodes ⇒ skipped (when the buffer is full).
    pub fn replace(&mut self) -> ReplaceOutcome {
        let mut out = ReplaceOutcome::default();
        // 1. Evict.
        match self.policy {
            Policy::FreqDecay => {
                for slot in 0..self.capacity {
                    if self.live[slot] && self.scores[slot] < STALE_THRESHOLD {
                        out.evicted_nodes.push(self.ids[slot]);
                        self.evict_slot(slot as u32);
                        out.evicted += 1;
                    }
                }
            }
            Policy::Lfu | Policy::Lru => {
                // Evict the bottom quartile by count / recency.
                let mut liveslots: Vec<u32> = (0..self.capacity as u32)
                    .filter(|&s| self.live[s as usize])
                    .collect();
                let keyfn = |s: &u32| match self.policy {
                    Policy::Lfu => self.scores[*s as usize] as u64,
                    _ => self.last_used[*s as usize],
                };
                liveslots.sort_by_key(keyfn);
                let evict_n = liveslots.len() / 4;
                for &s in &liveslots[..evict_n] {
                    out.evicted_nodes.push(self.ids[s as usize]);
                    self.evict_slot(s);
                    out.evicted += 1;
                }
            }
        }
        if out.evicted == 0 && self.free.is_empty() {
            out.skipped = true;
            return out;
        }
        // 2. Admit by descending miss frequency.
        let mut candidates: Vec<(u32, f32)> = self
            .miss_freq
            .iter()
            .filter(|(v, _)| !self.index.contains_key(v))
            .map(|(&v, &f)| (v, f))
            .collect();
        candidates.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for (v, _) in candidates {
            let Some(slot) = self.free.pop() else { break };
            self.insert_at(slot, v);
            out.inserted += 1;
            out.fetched_nodes.push(v);
            self.miss_freq.remove(&v);
        }
        out
    }

    /// Sorted node ids currently resident (the cluster runtime warms its
    /// feature store with this after a prepopulated start).
    pub fn resident_nodes(&self) -> Vec<u32> {
        let mut nodes: Vec<u32> = self.index.keys().copied().collect();
        nodes.sort_unstable();
        nodes
    }

    /// Pre-populate (MassiveGNN-style warm start); fills up to capacity.
    pub fn prepopulate(&mut self, nodes: &[u32]) -> usize {
        let mut inserted = 0;
        for &v in nodes {
            if self.index.contains_key(&v) {
                continue;
            }
            let Some(slot) = self.free.pop() else { break };
            self.insert_at(slot, v);
            inserted += 1;
        }
        inserted
    }

    fn insert_at(&mut self, slot: u32, node: u32) {
        let s = slot as usize;
        self.ids[s] = node;
        self.scores[s] = INITIAL_SCORE;
        self.accessed[s] = false;
        self.live[s] = true;
        self.last_used[s] = self.clock;
        self.index.insert(node, slot);
    }

    fn evict_slot(&mut self, slot: u32) {
        let s = slot as usize;
        debug_assert!(self.live[s]);
        self.index.remove(&self.ids[s]);
        self.ids[s] = u32::MAX;
        self.live[s] = false;
        self.scores[s] = 0.0;
        self.accessed[s] = false;
        self.free.push(slot);
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.index.len() + self.free.len() != self.capacity {
            return Err(format!(
                "index {} + free {} != capacity {}",
                self.index.len(),
                self.free.len(),
                self.capacity
            ));
        }
        for (&node, &slot) in &self.index {
            let s = slot as usize;
            if !self.live[s] || self.ids[s] != node {
                return Err(format!("index broken for node {node} slot {slot}"));
            }
        }
        for &slot in &self.free {
            if self.live[slot as usize] {
                return Err(format!("free slot {slot} is live"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(cap: usize) -> PersistentBuffer {
        PersistentBuffer::new(cap, Policy::FreqDecay)
    }

    #[test]
    fn starts_empty_all_misses() {
        let mut b = buf(8);
        let r = b.lookup(&[1, 2, 3]);
        assert_eq!(r.hits, 0);
        assert_eq!(r.misses, 3);
        assert_eq!(r.hits_pct(), 0.0);
        assert!(b.is_empty());
    }

    #[test]
    fn replace_admits_missed_nodes() {
        let mut b = buf(4);
        b.lookup(&[10, 11, 12]);
        b.end_round();
        let out = b.replace();
        assert_eq!(out.inserted, 3);
        assert!(!out.skipped);
        assert_eq!(out.fetched_nodes.len(), 3);
        let r = b.lookup(&[10, 11, 12]);
        assert_eq!(r.hits, 3);
        assert_eq!(r.hits_pct(), 100.0);
        b.check_invariants().unwrap();
    }

    #[test]
    fn admission_prefers_frequent_misses() {
        let mut b = buf(1);
        b.lookup(&[5]);
        b.lookup(&[5]);
        b.lookup(&[9]);
        b.end_round();
        let out = b.replace();
        assert_eq!(out.inserted, 1);
        assert!(b.contains(5), "5 missed twice, 9 once");
    }

    #[test]
    fn skip_when_no_stale_and_full() {
        let mut b = buf(2);
        b.lookup(&[1, 2]);
        b.end_round();
        b.replace();
        // Keep both hot.
        b.lookup(&[1, 2, 3]);
        b.end_round();
        let out = b.replace();
        assert!(out.skipped);
        assert_eq!(out.inserted, 0);
        assert!(b.contains(1) && b.contains(2));
    }

    #[test]
    fn stale_nodes_evicted_after_decay() {
        let mut b = buf(2);
        b.lookup(&[1, 2]);
        b.end_round();
        b.replace();
        // Node 1 stays hot; node 2 idles for two rounds -> stale.
        for _ in 0..2 {
            b.lookup(&[1, 7]);
            b.end_round();
        }
        assert_eq!(b.stale_count(), 1);
        let out = b.replace();
        assert_eq!(out.evicted, 1);
        assert_eq!(out.evicted_nodes, vec![2]);
        assert!(!b.contains(2));
        assert!(b.contains(7), "recent miss admitted");
        b.check_invariants().unwrap();
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut b = buf(3);
        for round in 0..20u32 {
            let nodes: Vec<u32> = (round * 5..round * 5 + 5).collect();
            b.lookup(&nodes);
            b.end_round();
            b.replace();
            assert!(b.len() <= 3);
            b.check_invariants().unwrap();
        }
    }

    #[test]
    fn prepopulate_fills_to_capacity() {
        let mut b = buf(3);
        assert_eq!(b.prepopulate(&[1, 2, 3, 4, 5]), 3);
        assert_eq!(b.len(), 3);
        assert!(b.contains(1) && b.contains(2) && b.contains(3));
        assert_eq!(b.resident_nodes(), vec![1, 2, 3]);
        assert_eq!(b.prepopulate(&[9]), 0);
        b.check_invariants().unwrap();
    }

    #[test]
    fn hits_pct_empty_lookup_is_100() {
        let mut b = buf(2);
        assert_eq!(b.lookup(&[]).hits_pct(), 100.0);
    }

    #[test]
    fn lru_policy_evicts_oldest() {
        let mut b = PersistentBuffer::new(4, Policy::Lru);
        b.lookup(&[1, 2, 3, 4]);
        b.end_round();
        b.replace();
        // Touch 2,3,4 but not 1.
        b.lookup(&[2, 3, 4]);
        b.end_round();
        b.lookup(&[5]);
        b.end_round();
        let out = b.replace();
        assert!(out.evicted >= 1);
        assert!(!b.contains(1), "LRU must evict node 1 first");
    }

    #[test]
    fn zero_capacity_buffer_is_inert() {
        let mut b = buf(0);
        let r = b.lookup(&[1, 2]);
        assert_eq!(r.misses, 2);
        b.end_round();
        let out = b.replace();
        assert_eq!(out.inserted, 0);
        assert!(out.skipped);
        b.check_invariants().unwrap();
    }
}
