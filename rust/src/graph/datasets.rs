//! Dataset registry: scaled stand-ins for the paper's Table 1a graphs.
//!
//! Each spec preserves the *relative* characteristics that drive prefetching
//! behaviour — average degree, degree skew (R-MAT `a`), feature width (comm
//! bytes per node), and train-set fraction — at 20×–2000× reduced node
//! counts so experiments run on one machine.  DESIGN.md §2 records the
//! substitution rationale.

use crate::graph::csr::Csr;
use crate::graph::labels::propagate_labels;
use crate::graph::rmat::{densify_isolated, generate, RmatParams};
use crate::util::rng::{derive_seed, Pcg32};

/// Static description of a dataset stand-in.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Paper-reported size (for reporting only).
    pub paper_nodes: &'static str,
    pub paper_edges: &'static str,
    /// Stand-in scale.
    pub num_nodes: usize,
    pub num_edges: usize,
    /// R-MAT top-left quadrant probability (skew; b = c = (1-a-d)/2).
    pub skew_a: f64,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Fraction of nodes in the training split.
    pub train_frac: f64,
    /// Excluded from classifier offline training (§5.4 unseen studies).
    pub unseen: bool,
}

impl DatasetSpec {
    fn rmat(&self, scale: f64) -> RmatParams {
        let a = self.skew_a;
        let rest = (1.0 - a) / 3.0;
        RmatParams {
            a,
            b: rest,
            c: rest,
            num_nodes: ((self.num_nodes as f64 * scale) as usize).max(64),
            num_edges: ((self.num_edges as f64 * scale) as usize).max(256),
            permute: true,
        }
    }
}

/// All seven datasets of Table 1a.
pub const ALL: &[DatasetSpec] = &[
    DatasetSpec {
        name: "products",
        paper_nodes: "2.4M", paper_edges: "61.85M",
        num_nodes: 60_000, num_edges: 770_000,
        skew_a: 0.57, feat_dim: 100, num_classes: 47,
        train_frac: 0.08, unseen: false,
    },
    DatasetSpec {
        name: "reddit",
        paper_nodes: "0.23M", paper_edges: "114.61M",
        num_nodes: 12_000, num_edges: 600_000,
        skew_a: 0.55, feat_dim: 602, num_classes: 41,
        train_frac: 0.66, unseen: false,
    },
    DatasetSpec {
        name: "papers100M",
        paper_nodes: "111M", paper_edges: "1.6B",
        num_nodes: 200_000, num_edges: 1_600_000,
        skew_a: 0.59, feat_dim: 128, num_classes: 64,
        train_frac: 0.01, unseen: false,
    },
    DatasetSpec {
        name: "orkut",
        paper_nodes: "3.07M", paper_edges: "117.18M",
        num_nodes: 75_000, num_edges: 1_400_000,
        skew_a: 0.55, feat_dim: 8, num_classes: 32,
        train_frac: 0.05, unseen: false,
    },
    DatasetSpec {
        name: "friendster",
        paper_nodes: "65.6M", paper_edges: "1.8B",
        num_nodes: 150_000, num_edges: 1_500_000,
        skew_a: 0.60, feat_dim: 128, num_classes: 32,
        train_frac: 0.005, unseen: false,
    },
    DatasetSpec {
        name: "yelp",
        paper_nodes: "716K", paper_edges: "13.9M",
        num_nodes: 35_000, num_edges: 680_000,
        skew_a: 0.54, feat_dim: 300, num_classes: 100,
        train_frac: 0.5, unseen: true,
    },
    DatasetSpec {
        name: "ogbn-arxiv",
        paper_nodes: "169K", paper_edges: "1.1M",
        num_nodes: 17_000, num_edges: 110_000,
        skew_a: 0.55, feat_dim: 128, num_classes: 40,
        train_frac: 0.54, unseen: true,
    },
];

pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
    ALL.iter().find(|d| d.name == name)
}

/// A fully materialized dataset: graph + labels + train split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub csr: Csr,
    pub labels: Vec<u16>,
    pub train_nodes: Vec<u32>,
    /// Seed for feature synthesis ([`crate::graph::features`]).
    pub feature_seed: u64,
}

impl Dataset {
    /// Build a dataset at `scale` (1.0 = the registry stand-in size; tests
    /// use ~0.02).  Deterministic in `(name, scale, seed)`.
    pub fn build(spec: &DatasetSpec, scale: f64, seed: u64) -> Dataset {
        let root = derive_seed(seed, &[spec.name.len() as u64, (scale * 1e6) as u64]);
        let mut rng = Pcg32::new(root);
        let csr = generate(&spec.rmat(scale), &mut rng);
        let csr = densify_isolated(&csr, &mut rng);
        let n = csr.num_nodes();
        let classes = spec.num_classes.min(u16::MAX as usize);
        let labels = propagate_labels(&csr, classes, 3, derive_seed(root, &[1]));
        let train_count = ((n as f64 * spec.train_frac) as usize).clamp(1, n);
        let mut ids: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut ids);
        let mut train_nodes: Vec<u32> = ids[..train_count].to_vec();
        train_nodes.sort_unstable();
        Dataset {
            spec: spec.clone(),
            csr,
            labels,
            train_nodes,
            feature_seed: derive_seed(root, &[2]),
        }
    }

    pub fn build_by_name(name: &str, scale: f64, seed: u64) -> crate::error::Result<Dataset> {
        let spec = by_name(name)
            .ok_or_else(|| crate::err!("unknown dataset '{name}' (try: {})", names()))?;
        Ok(Self::build(spec, scale, seed))
    }
}

pub fn names() -> String {
    ALL.iter().map(|d| d.name).collect::<Vec<_>>().join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_complete() {
        assert_eq!(ALL.len(), 7);
        for spec in ALL {
            assert!(spec.num_nodes >= 10_000);
            assert!(spec.num_edges > spec.num_nodes);
            assert!(spec.feat_dim >= 8);
            assert!((0.0..=1.0).contains(&spec.train_frac));
        }
        assert_eq!(ALL.iter().filter(|d| d.unseen).count(), 2);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("reddit").unwrap().feat_dim, 602);
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn builds_scaled_dataset() {
        let ds = Dataset::build(by_name("ogbn-arxiv").unwrap(), 0.05, 1);
        assert!(ds.csr.num_nodes() >= 64);
        assert_eq!(ds.labels.len(), ds.csr.num_nodes());
        assert!(!ds.train_nodes.is_empty());
        assert!(ds.train_nodes.windows(2).all(|w| w[0] < w[1]));
        assert!(ds.train_nodes.iter().all(|&v| (v as usize) < ds.csr.num_nodes()));
    }

    #[test]
    fn deterministic_build() {
        let spec = by_name("products").unwrap();
        let a = Dataset::build(spec, 0.02, 9);
        let b = Dataset::build(spec, 0.02, 9);
        assert_eq!(a.csr.targets, b.csr.targets);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.train_nodes, b.train_nodes);
        assert_eq!(a.feature_seed, b.feature_seed);
    }

    #[test]
    fn no_isolated_nodes_after_build() {
        let ds = Dataset::build(by_name("yelp").unwrap(), 0.02, 3);
        assert!((0..ds.csr.num_nodes() as u32).all(|v| ds.csr.degree(v) > 0));
    }

    #[test]
    fn unknown_name_errors() {
        assert!(Dataset::build_by_name("bogus", 1.0, 0).is_err());
    }
}
