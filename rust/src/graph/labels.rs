//! Pseudo-label assignment via label propagation.
//!
//! The paper's SNAP graphs lack node labels; the authors run node2vec and
//! assign pseudo-labels from the top-5000 communities.  The equivalent here
//! (cheap and deterministic): seed every node with a hashed label and run a
//! few synchronous majority-propagation rounds — labels become locally
//! smooth over the graph, i.e. structurally learnable by a GNN, which is the
//! property node classification training needs.

use crate::graph::csr::Csr;
use crate::util::rng::derive_seed;

/// Assign one label in `[0, num_classes)` per node.
pub fn propagate_labels(csr: &Csr, num_classes: usize, rounds: usize, seed: u64) -> Vec<u16> {
    assert!(num_classes > 0 && num_classes <= u16::MAX as usize);
    let n = csr.num_nodes();
    let mut labels: Vec<u16> = (0..n as u32)
        .map(|v| (derive_seed(seed, &[v as u64]) % num_classes as u64) as u16)
        .collect();
    let mut counts = vec![0u32; num_classes];
    let mut next = labels.clone();
    for round in 0..rounds {
        for v in 0..n as u32 {
            let neigh = csr.neighbors(v);
            if neigh.is_empty() {
                continue;
            }
            for c in counts.iter_mut() {
                *c = 0;
            }
            for &u in neigh {
                counts[labels[u as usize] as usize] += 1;
            }
            // Keep own label sticky to avoid label collapse.
            counts[labels[v as usize] as usize] += 2;
            let mut best = labels[v as usize];
            let mut best_count = counts[best as usize];
            for (c, &cnt) in counts.iter().enumerate() {
                // Deterministic tie-break by (count, class id, round parity).
                if cnt > best_count || (cnt == best_count && (c as u16) < best && round % 2 == 0)
                {
                    best = c as u16;
                    best_count = cnt;
                }
            }
            next[v as usize] = best;
        }
        std::mem::swap(&mut labels, &mut next);
    }
    labels
}

/// Fraction of edges whose endpoints share a label (homophily).
pub fn homophily(csr: &Csr, labels: &[u16]) -> f64 {
    let mut same = 0u64;
    let mut total = 0u64;
    for v in 0..csr.num_nodes() as u32 {
        for &u in csr.neighbors(v) {
            total += 1;
            if labels[v as usize] == labels[u as usize] {
                same += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::util::rng::Pcg32;

    fn test_graph() -> Csr {
        let params = RmatParams {
            a: 0.57, b: 0.19, c: 0.19, num_nodes: 2000, num_edges: 12000, permute: true,
        };
        generate(&params, &mut Pcg32::new(11))
    }

    #[test]
    fn labels_in_range() {
        let g = test_graph();
        let labels = propagate_labels(&g, 16, 3, 1);
        assert_eq!(labels.len(), g.num_nodes());
        assert!(labels.iter().all(|&l| l < 16));
    }

    #[test]
    fn deterministic() {
        let g = test_graph();
        assert_eq!(propagate_labels(&g, 8, 3, 5), propagate_labels(&g, 8, 3, 5));
    }

    #[test]
    fn propagation_raises_homophily() {
        let g = test_graph();
        let random = propagate_labels(&g, 16, 0, 1);
        let smooth = propagate_labels(&g, 16, 4, 1);
        let h0 = homophily(&g, &random);
        let h1 = homophily(&g, &smooth);
        // Random labels: homophily ≈ 1/16. Propagated: noticeably higher.
        assert!(h1 > h0 * 2.0, "h0 {h0} h1 {h1}");
    }

    #[test]
    fn all_classes_survive() {
        let g = test_graph();
        let labels = propagate_labels(&g, 8, 3, 2);
        let mut seen = [false; 8];
        for &l in &labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 4, "label collapse");
    }
}
