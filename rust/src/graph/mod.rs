//! Graph substrate: CSR storage, R-MAT generation, the Table 1a dataset
//! registry, synthesized features, and pseudo-labels.

pub mod csr;
pub mod datasets;
pub mod features;
pub mod labels;
pub mod rmat;

pub use csr::Csr;
pub use datasets::{Dataset, DatasetSpec};
