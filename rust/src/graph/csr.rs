//! Compressed-sparse-row graph storage.
//!
//! The whole pipeline (generation → partitioning → sampling) runs on this
//! structure.  Node ids are `u32` (the scaled stand-in datasets stay under
//! 4B nodes by a wide margin); adjacency is a flat `offsets`/`targets` pair
//! so neighbor walks are cache-linear — the sampler's hot path.

/// A directed graph in CSR form (undirected graphs store both arcs).
#[derive(Debug, Clone)]
pub struct Csr {
    /// `offsets[v]..offsets[v+1]` indexes `targets` for v's out-neighbors.
    pub offsets: Vec<u64>,
    pub targets: Vec<u32>,
}

impl Csr {
    /// Build from an edge list (dedup + self-loop removal optional).
    pub fn from_edges(num_nodes: usize, edges: &[(u32, u32)]) -> Csr {
        let mut degree = vec![0u64; num_nodes];
        for &(s, _) in edges {
            degree[s as usize] += 1;
        }
        let mut offsets = vec![0u64; num_nodes + 1];
        for v in 0..num_nodes {
            offsets[v + 1] = offsets[v] + degree[v];
        }
        let mut cursor = offsets[..num_nodes].to_vec();
        let mut targets = vec![0u32; edges.len()];
        for &(s, t) in edges {
            let c = &mut cursor[s as usize];
            targets[*c as usize] = t;
            *c += 1;
        }
        Csr { offsets, targets }
    }

    /// Build an *undirected* CSR: every `(s, t)` contributes both arcs;
    /// duplicate arcs and self-loops are removed.
    pub fn undirected_from_edges(num_nodes: usize, edges: &[(u32, u32)]) -> Csr {
        let mut both: Vec<(u32, u32)> = Vec::with_capacity(edges.len() * 2);
        for &(s, t) in edges {
            if s == t {
                continue;
            }
            both.push((s, t));
            both.push((t, s));
        }
        both.sort_unstable();
        both.dedup();
        Self::from_edges(num_nodes, &both)
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn num_arcs(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    /// Nodes sorted by descending degree (MassiveGNN-style prefetch order).
    pub fn nodes_by_degree_desc(&self) -> Vec<u32> {
        let mut nodes: Vec<u32> = (0..self.num_nodes() as u32).collect();
        nodes.sort_by_key(|&v| std::cmp::Reverse(self.degree(v)));
        nodes
    }

    /// Memory footprint in bytes (offsets + targets).
    pub fn bytes(&self) -> usize {
        self.offsets.len() * 8 + self.targets.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0-1, 0-2, 1-3, 2-3 undirected.
        Csr::undirected_from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn csr_construction() {
        let g = diamond();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_arcs(), 8);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[1, 2]);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn removes_self_loops_and_duplicates() {
        let g = Csr::undirected_from_edges(3, &[(0, 0), (0, 1), (1, 0), (0, 1)]);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.num_arcs(), 2);
    }

    #[test]
    fn directed_preserves_multiplicity_order() {
        let g = Csr::from_edges(3, &[(0, 2), (0, 1), (2, 0)]);
        assert_eq!(g.neighbors(0), &[2, 1]);
        assert_eq!(g.neighbors(1), &[] as &[u32]);
        assert_eq!(g.neighbors(2), &[0]);
    }

    #[test]
    fn degree_ordering() {
        let g = Csr::undirected_from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2)]);
        let order = g.nodes_by_degree_desc();
        assert_eq!(order[0], 0);
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(0, &[]);
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_arcs(), 0);
    }

    #[test]
    fn isolated_nodes() {
        let g = Csr::undirected_from_edges(5, &[(0, 1)]);
        assert_eq!(g.degree(4), 0);
        assert_eq!(g.neighbors(4), &[] as &[u32]);
    }
}
