//! R-MAT / Kronecker-style graph generator.
//!
//! Real-world GNN benchmark graphs (Table 1a) are heavy-tailed; R-MAT with
//! partition probabilities (a, b, c, d) reproduces the degree skew that
//! drives prefetching dynamics: a small hot set of high-degree nodes that is
//! repeatedly sampled (worth persisting) and a long tail of cold nodes
//! (cache pollution if kept).  Each dataset stand-in in
//! [`crate::graph::datasets`] picks its own (a, b, c, d) + edge factor.

use crate::graph::csr::Csr;
use crate::util::rng::Pcg32;

/// R-MAT parameters.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    /// Quadrant probabilities; must sum to ~1.  `a` >> rest ⇒ heavier skew.
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Number of nodes is rounded up to the next power of two internally,
    /// then mapped back down so ids stay `< num_nodes`.
    pub num_nodes: usize,
    pub num_edges: usize,
    /// Shuffle node ids so partitioning cannot exploit generation order.
    pub permute: bool,
}

impl RmatParams {
    pub fn d(&self) -> f64 {
        1.0 - self.a - self.b - self.c
    }
}

/// Generate an undirected CSR graph.
pub fn generate(params: &RmatParams, rng: &mut Pcg32) -> Csr {
    assert!(params.num_nodes > 1, "need at least 2 nodes");
    assert!(
        params.d() > 0.0 && params.a > 0.0 && params.b >= 0.0 && params.c >= 0.0,
        "bad quadrant probabilities"
    );
    let scale = (params.num_nodes as f64).log2().ceil() as u32;
    let side = 1usize << scale;

    // Optional id permutation (identity when disabled).
    let perm: Vec<u32> = if params.permute {
        let mut p: Vec<u32> = (0..params.num_nodes as u32).collect();
        rng.shuffle(&mut p);
        p
    } else {
        (0..params.num_nodes as u32).collect()
    };

    let mut edges = Vec::with_capacity(params.num_edges);
    let mut attempts = 0usize;
    let max_attempts = params.num_edges * 8 + 1024;
    while edges.len() < params.num_edges && attempts < max_attempts {
        attempts += 1;
        let (mut s, mut t) = (0usize, 0usize);
        let mut span = side;
        while span > 1 {
            span /= 2;
            // Noise the quadrant probabilities slightly per level (standard
            // smoothed R-MAT to avoid exact-power-law artifacts).
            let jitter = 0.9 + 0.2 * rng.f64();
            let a = params.a * jitter;
            let r = rng.f64() * (a + params.b + params.c + params.d());
            if r < a {
                // top-left: nothing to add
            } else if r < a + params.b {
                t += span;
            } else if r < a + params.b + params.c {
                s += span;
            } else {
                s += span;
                t += span;
            }
        }
        // Map the power-of-two grid back into [0, num_nodes).
        let s = s % params.num_nodes;
        let t = t % params.num_nodes;
        if s == t {
            continue;
        }
        edges.push((perm[s], perm[t]));
    }
    Csr::undirected_from_edges(params.num_nodes, &edges)
}

/// Ensure no isolated training nodes: link each zero-degree node to a random
/// neighbor (GNN samplers require ≥1 neighbor to make progress).
pub fn densify_isolated(csr: &Csr, rng: &mut Pcg32) -> Csr {
    let n = csr.num_nodes();
    let mut extra = Vec::new();
    for v in 0..n as u32 {
        if csr.degree(v) == 0 {
            let mut t = rng.below(n as u64) as u32;
            if t == v {
                t = (t + 1) % n as u32;
            }
            extra.push((v, t));
        }
    }
    if extra.is_empty() {
        return csr.clone();
    }
    // Rebuild from the union of arcs.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(csr.num_arcs() / 2 + extra.len());
    for v in 0..n as u32 {
        for &t in csr.neighbors(v) {
            if v < t {
                edges.push((v, t));
            }
        }
    }
    edges.extend(extra);
    Csr::undirected_from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> RmatParams {
        RmatParams { a: 0.57, b: 0.19, c: 0.19, num_nodes: 1000, num_edges: 8000, permute: true }
    }

    #[test]
    fn generates_requested_scale() {
        let mut rng = Pcg32::new(1);
        let g = generate(&small(), &mut rng);
        assert_eq!(g.num_nodes(), 1000);
        // Undirected dedup loses some arcs; expect most of them.
        assert!(g.num_arcs() > 8000, "arcs {}", g.num_arcs());
        assert!(g.num_arcs() <= 16000);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&small(), &mut Pcg32::new(7));
        let b = generate(&small(), &mut Pcg32::new(7));
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.targets, b.targets);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&small(), &mut Pcg32::new(1));
        let b = generate(&small(), &mut Pcg32::new(2));
        assert_ne!(a.targets, b.targets);
    }

    #[test]
    fn skewed_params_make_heavy_tail() {
        let mut rng = Pcg32::new(3);
        let skewed = RmatParams { a: 0.7, b: 0.12, c: 0.12, ..small() };
        let g = generate(&skewed, &mut rng);
        let max_deg = (0..g.num_nodes() as u32).map(|v| g.degree(v)).max().unwrap();
        let mean_deg = g.num_arcs() as f64 / g.num_nodes() as f64;
        assert!(
            max_deg as f64 > 6.0 * mean_deg,
            "max {max_deg} mean {mean_deg}: degree distribution not heavy-tailed"
        );
    }

    #[test]
    fn all_ids_in_range() {
        let mut rng = Pcg32::new(5);
        let g = generate(&small(), &mut rng);
        assert!(g.targets.iter().all(|&t| (t as usize) < g.num_nodes()));
    }

    #[test]
    fn densify_removes_isolation() {
        let mut rng = Pcg32::new(9);
        let sparse = RmatParams {
            a: 0.6, b: 0.15, c: 0.15, num_nodes: 500, num_edges: 300, permute: true,
        };
        let g = generate(&sparse, &mut rng);
        let d = densify_isolated(&g, &mut rng);
        assert!((0..d.num_nodes() as u32).all(|v| d.degree(v) > 0));
    }
}
