//! Node-feature synthesis.
//!
//! The paper's datasets carry dense float features (Table 1a: 8–602 dims).
//! Storing features for millions of nodes is pointless in a simulator whose
//! compute path only needs *deterministic, node-identified* vectors — so
//! features are synthesized on demand from a hash PRNG keyed by
//! `(dataset_seed, node_id)`.  The same node always yields the same vector,
//! which is what the persistent buffer semantics (and the XLA compute path)
//! require; communication accounting uses `feat_bytes` for volume.

use crate::util::rng::{derive_seed, splitmix64};

/// Deterministic feature vector for a node; values roughly N(0, 1) via CLT.
pub fn fill_features(dataset_seed: u64, node: u32, out: &mut [f32]) {
    let mut state = derive_seed(dataset_seed, &[node as u64]);
    for (i, slot) in out.iter_mut().enumerate() {
        // Sum of 4 uniforms, centered/scaled: cheap approximate Gaussian.
        let mut acc = 0.0f32;
        for _ in 0..4 {
            let bits = splitmix64(&mut state);
            acc += (bits >> 40) as f32 / (1u64 << 24) as f32;
        }
        // Mix a class-correlated component in dim 0..8 so labels are
        // learnable (labels are also seeded by the node hash).
        let base = (acc - 2.0) * (3.0f32).sqrt(); // var(U_sum of 4) = 4/12
        *slot = if i < 8 {
            base + ((derive_seed(dataset_seed, &[node as u64, 77]) >> (i * 4)) & 0xF) as f32
                * 0.1
        } else {
            base
        };
    }
}

/// Feature payload size in bytes for one node (f32 features).
#[inline]
pub fn feat_bytes(feat_dim: usize) -> u64 {
    (feat_dim * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_node() {
        let mut a = vec![0.0; 32];
        let mut b = vec![0.0; 32];
        fill_features(42, 17, &mut a);
        fill_features(42, 17, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn distinct_nodes_distinct_features() {
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        fill_features(42, 1, &mut a);
        fill_features(42, 2, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn distinct_datasets_distinct_features() {
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        fill_features(1, 5, &mut a);
        fill_features(2, 5, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn roughly_standardized() {
        let mut buf = vec![0.0f32; 64];
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        let n = 500usize;
        for node in 0..n as u32 {
            fill_features(7, node, &mut buf);
            for &x in &buf[8..] {
                sum += x as f64;
                sum2 += (x as f64) * (x as f64);
            }
        }
        let cnt = (n * 56) as f64;
        let mean = sum / cnt;
        let var = sum2 / cnt - mean * mean;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn bytes_accounting() {
        assert_eq!(feat_bytes(100), 400);
        assert_eq!(feat_bytes(602), 2408);
    }
}
