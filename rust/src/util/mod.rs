//! Shared substrates: deterministic RNG, JSON, TOML-subset configs,
//! statistics, and the property-testing micro-framework.
//!
//! Everything here is hand-rolled because the build sandbox mirrors no
//! crates beyond the `xla` closure (DESIGN.md §2).

pub mod fasthash;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tomlite;
