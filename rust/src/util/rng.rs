//! Deterministic PRNG substrate.
//!
//! Every stochastic choice in the system (graph generation, sampling,
//! simulated-LLM noise, classifier init) flows from [`Pcg32`] streams derived
//! with [`derive_seed`], keyed by semantic tuples like
//! `(experiment, dataset, trainer, epoch, minibatch)` — so any run is
//! bit-reproducible and any component can be replayed in isolation.

/// SplitMix64: used for seeding / key derivation (passes BigCrush as a mixer).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive a child seed from a parent seed and a list of stream labels.
pub fn derive_seed(parent: u64, labels: &[u64]) -> u64 {
    let mut s = parent ^ 0xA076_1D64_78BD_642F;
    let mut out = splitmix64(&mut s);
    for &l in labels {
        s ^= l.wrapping_mul(0xE703_7ED1_A0B4_28DB);
        out ^= splitmix64(&mut s).rotate_left((l % 63) as u32 + 1);
    }
    out
}

/// PCG32 (XSH-RR): small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Child RNG on an independent stream, labelled for reproducibility.
    pub fn fork(&mut self, label: u64) -> Pcg32 {
        let seed = derive_seed(self.next_u64(), &[label]);
        Pcg32::with_stream(seed, label.wrapping_mul(2).wrapping_add(1))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `0..n` (Floyd's algorithm), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        let mut out: Vec<usize> = chosen.into_iter().collect();
        out.sort_unstable();
        out
    }

    /// Pick one element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg32::new(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg32::new(11);
        for _ in 0..100 {
            let k = rng.below(20) as usize;
            let s = rng.sample_indices(50, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn sample_indices_k_ge_n() {
        let mut rng = Pcg32::new(1);
        assert_eq!(rng.sample_indices(5, 9), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn derive_seed_sensitivity() {
        let a = derive_seed(1, &[1, 2, 3]);
        let b = derive_seed(1, &[1, 2, 4]);
        let c = derive_seed(2, &[1, 2, 3]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, derive_seed(1, &[1, 2, 3]));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg32::new(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
