//! TOML-subset config parser (sections, key/value, arrays, comments).
//!
//! Experiment configs (`configs/*.toml`) and the calibration file use this.
//! Supported grammar — the practical subset:
//!
//! ```toml
//! top_level = 1            # comments
//! [section]
//! s = "string"
//! n = 42
//! f = 1.5
//! b = true
//! xs = [1, 2, 3]
//! names = ["a", "b"]
//! [section.sub]            # dotted sections
//! ```

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Debug, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse into a [`Json`] object tree (sections become nested objects).
pub fn parse(src: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();
    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(ln, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(ln, "empty section name"));
            }
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            // Materialize the section path.
            ensure_path(&mut root, &section, ln)?;
        } else {
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err(ln, "expected 'key = value'"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err(ln, "empty key"));
            }
            let value = parse_value(val.trim(), ln)?;
            insert_at(&mut root, &section, key, value, ln)?;
        }
    }
    Ok(Json::Obj(root))
}

/// Parse a file from disk.
pub fn parse_file(path: &std::path::Path) -> crate::error::Result<Json> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| crate::err!("reading {}: {e}", path.display()))?;
    Ok(parse(&src)?)
}

fn err(line: usize, msg: &str) -> TomlError {
    TomlError { line: line + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, ln: usize) -> Result<Json, TomlError> {
    if s.is_empty() {
        return Err(err(ln, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(ln, "unterminated string"))?;
        return Ok(Json::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(ln, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), ln)?);
        }
        return Ok(Json::Arr(items));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(ln, &format!("cannot parse value '{s}'")))
}

/// Split on commas that are not inside quotes (arrays of strings).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn ensure_path(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    ln: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for seg in path {
        let entry = cur
            .entry(seg.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(err(ln, &format!("'{seg}' is not a section"))),
        }
    }
    Ok(())
}

fn insert_at(
    root: &mut BTreeMap<String, Json>,
    section: &[String],
    key: &str,
    value: Json,
    ln: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for seg in section {
        let entry = cur
            .entry(seg.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(err(ln, &format!("'{seg}' is not a section"))),
        }
    }
    if cur.insert(key.to_string(), value).is_some() {
        return Err(err(ln, &format!("duplicate key '{key}'")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let src = r#"
# experiment config
name = "fig12"       # inline comment
trainers = [16, 32, 64]
[dataset]
kind = "products"
scale = 0.05
[dataset.partition]
method = "metis"
parts = 4
enabled = true
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("fig12"));
        assert_eq!(v.at("dataset.kind").unwrap().as_str(), Some("products"));
        assert_eq!(v.at("dataset.partition.parts").unwrap().as_i64(), Some(4));
        assert_eq!(v.at("dataset.partition.enabled").unwrap().as_bool(), Some(true));
        let tr = v.get("trainers").unwrap().as_arr().unwrap();
        assert_eq!(tr.len(), 3);
        assert_eq!(tr[2].as_i64(), Some(64));
    }

    #[test]
    fn string_arrays_with_commas() {
        let v = parse(r#"xs = ["a,b", "c"]"#).unwrap();
        let xs = v.get("xs").unwrap().as_arr().unwrap();
        assert_eq!(xs[0].as_str(), Some("a,b"));
        assert_eq!(xs[1].as_str(), Some("c"));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let v = parse(r##"k = "a#b""##).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = what").is_err());
        assert!(parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn empty_and_comment_only() {
        let v = parse("\n# just a comment\n\n").unwrap();
        assert_eq!(v, Json::Obj(Default::default()));
    }

    #[test]
    fn negative_and_float_numbers() {
        let v = parse("a = -4\nb = 2.75\nc = 1e2").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(-4));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.75));
        assert_eq!(v.get("c").unwrap().as_f64(), Some(100.0));
    }
}
