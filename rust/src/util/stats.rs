//! Statistics substrate: summary stats, percentiles, confidence intervals.
//!
//! The paper reports mean epoch times, p99 communication volumes (Fig 14),
//! medians-over-configurations (Fig 13), and 95% CIs "computed via
//! chi-square distribution" on Pass@1 proportions (Table 4).  All of that
//! lives here.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile in `[0, 100]` with linear interpolation (NIST method).
///
/// NaN samples are excluded before ranking (a NaN wall-clock delta must
/// not poison the whole summary, and `partial_cmp`-based sorting would
/// panic on one); an input that is all-NaN or empty yields 0.0.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    if v.len() == 1 {
        return v[0];
    }
    let rank = p.clamp(0.0, 100.0) / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// 95% chi-square quantile for 1 degree of freedom.
pub const CHI2_95_DF1: f64 = 3.841458820694124;

/// Wilson score interval on a proportion, driven by the chi-square(1)
/// 95% quantile (z² = χ²₁,₀.₉₅) — this is the "95% CI per run, computed via
/// chi-square distribution" of Table 4.  Returns `(lo_delta, hi_delta)` as
/// positive offsets below/above the point estimate, in percent units when
/// `successes/trials` is interpreted as a rate.
pub fn wilson_ci95(successes: u64, trials: u64) -> (f64, f64) {
    if trials == 0 {
        return (0.0, 0.0);
    }
    let n = trials as f64;
    let p = successes as f64 / n;
    let z2 = CHI2_95_DF1;
    let denom = 1.0 + z2 / n;
    let center = (p + z2 / (2.0 * n)) / denom;
    let half = (z2 / n * (p * (1.0 - p) + z2 / (4.0 * n))).sqrt() / denom;
    let lo = (center - half).max(0.0);
    let hi = (center + half).min(1.0);
    ((p - lo) * 100.0, (hi - p) * 100.0)
}

/// Exponential moving average helper.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Online mean/min/max/count accumulator (Welford for variance).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Simple fixed-bucket histogram for trajectory summaries.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Self { lo, hi, buckets: vec![0; n], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Streaming log-bucketed latency histogram with percentile extraction.
///
/// Fixed log-spaced buckets from [`LogHistogram::MIN_SECS`] (1 µs), four
/// buckets per octave (`2^(1/4)` ratio ⇒ ±~9% bucket resolution), 128
/// buckets ⇒ ~4300 s of range.  Samples below range land in bucket 0,
/// above range in the last bucket.  All state is integer counts, so the
/// type derives `Eq`, merges exactly, and costs O(1) per sample — built
/// for always-on latency recording (per-link fetch round trips) where
/// keeping every sample would not fly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: vec![0; Self::BUCKETS], total: 0 }
    }
}

impl LogHistogram {
    pub const BUCKETS: usize = 128;
    /// Lower edge of bucket 0.
    pub const MIN_SECS: f64 = 1e-6;
    /// Buckets per octave (factor-of-two span).
    pub const PER_OCTAVE: f64 = 4.0;

    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(secs: f64) -> usize {
        if secs.is_nan() || secs <= Self::MIN_SECS {
            return 0;
        }
        let idx = ((secs / Self::MIN_SECS).log2() * Self::PER_OCTAVE) as usize;
        idx.min(Self::BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the representative value
    /// percentile extraction reports.
    fn bucket_mid(i: usize) -> f64 {
        Self::MIN_SECS * ((i as f64 + 0.5) / Self::PER_OCTAVE).exp2()
    }

    pub fn push(&mut self, secs: f64) {
        self.counts[Self::bucket_of(secs)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Raw bucket counts (serialization support; pairs with
    /// [`LogHistogram::from_bucket_counts`]).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Rebuild from raw bucket counts (the serialization inverse of
    /// [`LogHistogram::bucket_counts`]).
    pub fn from_bucket_counts(counts: Vec<u64>) -> crate::error::Result<LogHistogram> {
        crate::ensure!(
            counts.len() == Self::BUCKETS,
            "log histogram: {} buckets (want {})",
            counts.len(),
            Self::BUCKETS
        );
        let total = counts.iter().sum();
        Ok(LogHistogram { counts, total })
    }

    /// Exact bucket-wise merge (histograms are additive).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Percentile in `[0, 100]`: the geometric midpoint of the first
    /// bucket whose cumulative count reaches `p`% of the samples
    /// (0.0 when empty).  Resolution is one bucket (±~9%).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0 * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(Self::BUCKETS - 1)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // Mirrors `log_histogram_edge_samples`: a NaN wall-clock delta must
        // not panic or poison the summary.  Pre-fix this panicked inside
        // `sort_by(partial_cmp.unwrap())`.
        assert!((percentile(&[1.0, f64::NAN, 3.0], 50.0) - 2.0).abs() < 1e-12);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 99.0), 0.0);
        assert!((median(&[f64::NAN, 5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn p99_order_insensitive() {
        let mut xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        xs.reverse();
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.1);
    }

    #[test]
    fn wilson_ci_sane() {
        // 80/100: CI roughly (71%, 87%).
        let (lo, hi) = wilson_ci95(80, 100);
        assert!(lo > 5.0 && lo < 12.0, "lo {lo}");
        assert!(hi > 5.0 && hi < 12.0, "hi {hi}");
        // Extreme proportions stay in [0, 100].
        let (lo0, hi0) = wilson_ci95(0, 10);
        assert_eq!(lo0, 0.0);
        assert!(hi0 > 0.0);
        let (lo1, hi1) = wilson_ci95(10, 10);
        assert!(lo1 > 0.0);
        assert_eq!(hi1, 0.0);
        assert_eq!(wilson_ci95(5, 0), (0.0, 0.0));
    }

    #[test]
    fn wilson_ci_narrows_with_n() {
        let (lo_small, _) = wilson_ci95(8, 10);
        let (lo_big, _) = wilson_ci95(800, 1000);
        assert!(lo_big < lo_small);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.get(), None);
        e.push(0.0);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5];
        let mut acc = Accumulator::default();
        for &x in &xs {
            acc.push(x);
        }
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(acc.min, 1.0);
        assert_eq!(acc.max, 5.5);
    }

    #[test]
    fn log_histogram_percentiles_track_distribution() {
        let mut h = LogHistogram::new();
        // 99 samples near 1 ms, one outlier at 1 s.
        for _ in 0..99 {
            h.push(1e-3);
        }
        h.push(1.0);
        assert_eq!(h.count(), 100);
        let p50 = h.p50();
        assert!(p50 > 0.5e-3 && p50 < 2e-3, "p50 {p50}");
        let p99 = h.p99();
        assert!(p99 > 0.5e-3 && p99 < 2e-3, "p99 {p99} (99th sample is still ~1ms)");
        let p100 = h.percentile(100.0);
        assert!(p100 > 0.5 && p100 < 2.0, "max {p100}");
    }

    #[test]
    fn log_histogram_merge_is_additive() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        for i in 1..=50 {
            let x = i as f64 * 1e-4;
            a.push(x);
            all.push(x);
        }
        for i in 1..=50 {
            let x = i as f64 * 1e-2;
            b.push(x);
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a, all);
        assert_eq!(a.count(), 100);
    }

    #[test]
    fn log_histogram_edge_samples() {
        let mut h = LogHistogram::new();
        h.push(0.0);
        h.push(-1.0);
        h.push(f64::NAN);
        h.push(1e9); // beyond range clamps to last bucket
        assert_eq!(h.count(), 4);
        assert!(h.p50() > 0.0);
        assert!(LogHistogram::new().is_empty());
        assert_eq!(LogHistogram::new().percentile(50.0), 0.0);
    }

    #[test]
    fn log_histogram_bucket_counts_round_trip() {
        let mut h = LogHistogram::new();
        for i in 1..=20 {
            h.push(i as f64 * 3e-4);
        }
        let back = LogHistogram::from_bucket_counts(h.bucket_counts().to_vec()).unwrap();
        assert_eq!(back, h);
        assert!(LogHistogram::from_bucket_counts(vec![0; 3]).is_err(), "wrong bucket count");
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert!(h.buckets.iter().all(|&b| b == 1));
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}
