//! Minimal JSON substrate (parser + writer).
//!
//! The sandbox mirrors no serde, so the agent prompt/response path, the
//! artifact manifest, and trace files all go through this hand-rolled
//! implementation.  It is a full JSON parser (strings with escapes, numbers,
//! nested containers) with helpful error positions; the writer emits both
//! compact and pretty forms.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects use a `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors -----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- accessors --------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Dotted-path lookup: `j.at("a.b.c")`.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.get(seg)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- parsing ----------------------------------------------------------
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Extract the first balanced JSON object embedded in arbitrary text.
    ///
    /// LLM responses wrap JSON in prose/markdown fences; this scans for a
    /// `{`, tracks string/escape state, and parses the balanced span.
    pub fn extract_object(text: &str) -> Option<Json> {
        let b = text.as_bytes();
        let mut start = None;
        let mut depth = 0usize;
        let mut in_str = false;
        let mut esc = false;
        for (i, &c) in b.iter().enumerate() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == b'\\' {
                    esc = true;
                } else if c == b'"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                b'"' if start.is_some() => in_str = true,
                b'{' => {
                    if start.is_none() {
                        start = Some(i);
                    }
                    depth += 1;
                }
                b'}' if start.is_some() => {
                    depth -= 1;
                    if depth == 0 {
                        return Json::parse(&text[start.unwrap()..=i]).ok();
                    }
                }
                _ => {}
            }
        }
        None
    }

    // -- writing ----------------------------------------------------------
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * level) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn nested_structures() {
        let src = r#"{"a": [1, 2, {"b": null}], "c": {"d": [true, "x\n"]}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at("c.d").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.at("a").unwrap().as_arr().unwrap()[0].as_i64(), Some(1));
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA");
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["{", "[1,", "tru", "{\"a\" 1}", "1 2", "", "{\"a\":}"] {
            assert!(Json::parse(src).is_err(), "{src}");
        }
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn extract_object_from_prose() {
        let text = "Sure! Here is my decision:\n```json\n{\"action\": \"replace\", \"n\": 3}\n``` hope that helps";
        let v = Json::extract_object(text).unwrap();
        assert_eq!(v.get("action").unwrap().as_str(), Some("replace"));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
    }

    #[test]
    fn extract_object_handles_braces_in_strings() {
        let text = "x {\"a\": \"} trap {\", \"b\": 1} y";
        let v = Json::extract_object(text).unwrap();
        assert_eq!(v.get("b").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn extract_object_none_when_absent() {
        assert_eq!(Json::extract_object("no json here"), None);
        assert_eq!(Json::extract_object("{broken"), None);
    }

    #[test]
    fn deterministic_serialization() {
        let a = Json::obj(vec![("z", Json::num(1)), ("a", Json::num(2))]);
        assert_eq!(a.to_string_compact(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn numbers_render_integers_cleanly() {
        assert_eq!(Json::num(3.0).to_string_compact(), "3");
        assert_eq!(Json::num(3.5).to_string_compact(), "3.5");
    }
}
