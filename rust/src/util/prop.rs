//! Property-testing micro-framework (proptest is not mirrored offline).
//!
//! Size-driven random case generation with automatic shrinking: cases are
//! generated from a [`Pcg32`] whose "size budget" grows over the run, so the
//! first failures are naturally small; on failure the runner retries the
//! failing case at progressively smaller sizes and reports the smallest
//! size + seed that still fails (rerunnable by construction).
//!
//! ```ignore
//! prop_check("buffer never exceeds capacity", 200, |g| {
//!     let cap = g.usize(1, 64);
//!     /* ... build case from g, return Err(msg) on violation ... */
//!     Ok(())
//! });
//! ```

use crate::util::rng::{derive_seed, Pcg32};

/// Case-generation handle: a seeded RNG plus a size budget.
pub struct G {
    pub rng: Pcg32,
    pub size: usize,
}

impl G {
    /// Integer in `[lo, hi]`, biased toward the low end by the size budget.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = (hi - lo).min(self.size.max(1));
        lo + self.rng.below(span as u64 + 1) as usize
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.usize(lo as usize, hi as usize) as u64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// A vector of length `[0, max_len]` built by `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut G) -> T) -> Vec<T> {
        let len = self.usize(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let i = self.rng.below(xs.len() as u64) as usize;
        &xs[i]
    }
}

/// Outcome of a property run.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`; panic with a reproducible report on
/// the first (shrunk) failure.  Seed comes from `RUDDER_PROP_SEED` if set so
/// failures can be replayed exactly.
pub fn prop_check(name: &str, cases: u32, mut prop: impl FnMut(&mut G) -> PropResult) {
    let base_seed = std::env::var("RUDDER_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FF_EE00_u64);

    for case in 0..cases {
        // Size ramps 1 -> 100 across the run.
        let size = 1 + (case as usize * 100) / cases.max(1) as usize;
        let seed = derive_seed(base_seed, &[name.len() as u64, case as u64]);
        if let Err(msg) = run_case(&mut prop, seed, size) {
            // Shrink: retry the same seed at smaller sizes, keep the
            // smallest size that still fails.
            let mut fail_size = size;
            let mut fail_msg = msg;
            let mut s = size;
            while s > 1 {
                s /= 2;
                match run_case(&mut prop, seed, s) {
                    Err(m) => {
                        fail_size = s;
                        fail_msg = m;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, size {fail_size}):\n  {fail_msg}\n\
                 replay with RUDDER_PROP_SEED={base_seed}"
            );
        }
    }
}

fn run_case(
    prop: &mut impl FnMut(&mut G) -> PropResult,
    seed: u64,
    size: usize,
) -> PropResult {
    let mut g = G { rng: Pcg32::new(seed), size };
    prop(&mut g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check("reverse twice is identity", 50, |g| {
            let v = g.vec(32, |g| g.u64(0, 1000));
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err(format!("{v:?} != {r:?}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        prop_check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn shrinks_to_small_size() {
        // The failing size reported must be small for a property that fails
        // whenever the vector is non-empty.
        let result = std::panic::catch_unwind(|| {
            prop_check("fails on non-empty", 100, |g| {
                let v = g.vec(64, |g| g.u64(0, 9));
                if v.is_empty() {
                    Ok(())
                } else {
                    Err("non-empty".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Shrinker halves size; a size-1 failure must be found.
        assert!(msg.contains("size 1"), "{msg}");
    }

    #[test]
    fn generator_bounds_respected() {
        prop_check("usize in bounds", 100, |g| {
            let lo = g.usize(0, 10);
            let hi = lo + g.usize(0, 10);
            let x = g.usize(lo, hi);
            if x >= lo && x <= hi {
                Ok(())
            } else {
                Err(format!("{x} not in [{lo}, {hi}]"))
            }
        });
    }
}
