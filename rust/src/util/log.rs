//! Role-prefixed, level-filtered diagnostic logging on stderr.
//!
//! `RUDDER_LOG=debug|info|off` (default `off` when unset; unknown values
//! mean `info`) selects the level once per process.  Every line carries a
//! `[trainer-3]`-style prefix: the role set via [`set_role`], else the
//! current thread's name with its `rudder-` prefix stripped — so the
//! in-process cluster threads label themselves for free and multiproc
//! workers (whose role loops run on `main`) call [`set_role`] at startup.
//! A hung TCP run is then debuggable from interleaved stderr alone:
//! `RUDDER_LOG=debug rudder cluster --transport tcp ...`.
//!
//! Use through the crate-level macros:
//!
//! ```ignore
//! crate::log_info!("drain timed out after {timeout:?}");
//! crate::log_debug!("frame on closed channel {ch}");
//! ```

use std::cell::RefCell;
use std::sync::OnceLock;

/// Verbosity, ordered so `Level::Off < Level::Info < Level::Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off,
    Info,
    Debug,
}

static LEVEL: OnceLock<Level> = OnceLock::new();

thread_local! {
    static ROLE: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// The process-wide level, resolved from `RUDDER_LOG` on first use.
pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("RUDDER_LOG").ok().as_deref() {
        None | Some("off") | Some("0") | Some("none") => Level::Off,
        Some("debug") => Level::Debug,
        Some(_) => Level::Info,
    })
}

/// Would a message at `l` be printed?  (The macros check this before
/// formatting, so disabled logging costs one comparison.)
pub fn enabled(l: Level) -> bool {
    l != Level::Off && l <= level()
}

/// Set this thread's log prefix (multiproc workers: the role loop runs on
/// `main`, whose thread name says nothing useful).
pub fn set_role(role: &str) {
    ROLE.with(|r| *r.borrow_mut() = Some(role.to_string()));
}

fn prefix() -> String {
    if let Some(r) = ROLE.with(|r| r.borrow().clone()) {
        return r;
    }
    match std::thread::current().name() {
        Some(n) => n.strip_prefix("rudder-").unwrap_or(n).to_string(),
        None => "rudder".to_string(),
    }
}

/// Emit one line (already level-checked by the macros).
pub fn write(args: std::fmt::Arguments<'_>) {
    eprintln!("[{}] {}", prefix(), args);
}

/// Info-level diagnostic: lifecycle milestones and recoverable anomalies
/// (drain timeouts, unexpected frames) a user should see by default.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::write(format_args!($($arg)*));
        }
    };
}

/// Debug-level diagnostic: per-frame/per-connection chatter for hunting
/// hangs (`RUDDER_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::write(format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Off < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn enabled_respects_off() {
        // `enabled(Off)` is never true regardless of the env level.
        assert!(!enabled(Level::Off));
    }

    #[test]
    fn prefix_prefers_set_role() {
        std::thread::Builder::new()
            .name("rudder-server-7".into())
            .spawn(|| {
                assert_eq!(prefix(), "server-7");
                set_role("trainer-3");
                assert_eq!(prefix(), "trainer-3");
            })
            .unwrap()
            .join()
            .unwrap();
    }

    #[test]
    fn macros_compile_at_both_levels() {
        crate::log_info!("info message {}", 1);
        crate::log_debug!("debug message {}", 2);
    }
}
