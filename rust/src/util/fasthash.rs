//! Fast non-cryptographic hasher for integer keys (std's SipHash costs
//! ~10× more on the buffer's u32-keyed hot maps — §Perf item L3-2).
//!
//! Multiplicative (Fibonacci) hashing: `h = x * 2^64/φ`, finalized with an
//! xor-shift.  Keys here are node ids (already well-spread by the R-MAT
//! permutation), so this is collision-safe in practice and ~1ns per hash.

use std::hash::{BuildHasherDefault, Hasher};

#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        let mut x = self.state;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        x ^= x >> 33;
        x
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = self
                .state
                .rotate_left(8)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(b as u64);
        }
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.state = self
            .state
            .wrapping_add(x as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.state = self.state.wrapping_add(x).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }
}

pub type FastBuild = BuildHasherDefault<FastHasher>;
pub type FastMap<K, V> = std::collections::HashMap<K, V, FastBuild>;
pub type FastSet<K> = std::collections::HashSet<K, FastBuild>;

/// FNV-1a over a byte slice: the stable content digest of the
/// content-addressed feature chunk store.  Unlike [`FastHasher`] this is a
/// *published* wire value (trainers and servers must agree across
/// processes and releases), so it uses the textbook FNV-1a constants and
/// nothing host-dependent.
pub fn digest_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a over an f32 slice (little-endian bytes), the row-payload form
/// used for feature chunks.
pub fn digest_f32(vals: &[f32]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for v in vals {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_for_node_ids() {
        let mut m: FastMap<u32, u32> = FastMap::default();
        for i in 0..10_000u32 {
            m.insert(i * 7 + 1, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u32 {
            assert_eq!(m.get(&(i * 7 + 1)), Some(&i));
        }
        assert_eq!(m.get(&0), None);
    }

    #[test]
    fn digest_is_stable_fnv1a() {
        // Published FNV-1a test vectors: the digest is a wire value, so it
        // must never drift.
        assert_eq!(digest_bytes(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(digest_bytes(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(digest_bytes(b"foobar"), 0x85944171F73967E8);
        // f32 form matches the byte form over the same little-endian bytes.
        let vals = [1.5f32, -2.25, 0.0];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(digest_f32(&vals), digest_bytes(&bytes));
        assert_ne!(digest_f32(&[1.0, 2.0]), digest_f32(&[2.0, 1.0]));
    }

    #[test]
    fn hashes_spread() {
        use std::hash::{BuildHasher, Hash};
        let b = FastBuild::default();
        let mut buckets = [0u32; 64];
        for i in 0..64_000u32 {
            let mut h = b.build_hasher();
            i.hash(&mut h);
            buckets[(h.finish() % 64) as usize] += 1;
        }
        for &c in &buckets {
            assert!((600..1400).contains(&c), "{buckets:?}");
        }
    }
}
