//! Fast non-cryptographic hasher for integer keys (std's SipHash costs
//! ~10× more on the buffer's u32-keyed hot maps — §Perf item L3-2).
//!
//! Multiplicative (Fibonacci) hashing: `h = x * 2^64/φ`, finalized with an
//! xor-shift.  Keys here are node ids (already well-spread by the R-MAT
//! permutation), so this is collision-safe in practice and ~1ns per hash.

use std::hash::{BuildHasherDefault, Hasher};

#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        let mut x = self.state;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        x ^= x >> 33;
        x
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = self
                .state
                .rotate_left(8)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(b as u64);
        }
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.state = self
            .state
            .wrapping_add(x as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.state = self.state.wrapping_add(x).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }
}

pub type FastBuild = BuildHasherDefault<FastHasher>;
pub type FastMap<K, V> = std::collections::HashMap<K, V, FastBuild>;
pub type FastSet<K> = std::collections::HashSet<K, FastBuild>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_for_node_ids() {
        let mut m: FastMap<u32, u32> = FastMap::default();
        for i in 0..10_000u32 {
            m.insert(i * 7 + 1, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u32 {
            assert_eq!(m.get(&(i * 7 + 1)), Some(&i));
        }
        assert_eq!(m.get(&0), None);
    }

    #[test]
    fn hashes_spread() {
        use std::hash::{BuildHasher, Hash};
        let b = FastBuild::default();
        let mut buckets = [0u32; 64];
        for i in 0..64_000u32 {
            let mut h = b.build_hasher();
            i.hash(&mut h);
            buckets[(h.finish() % 64) as usize] += 1;
        }
        for &c in &buckets {
            assert!((600..1400).contains(&c), "{buckets:?}");
        }
    }
}
