//! Config system: TOML files → [`RunConfig`] (plus CLI overrides).
//!
//! Example (`configs/quickstart.toml`):
//!
//! ```toml
//! dataset = "products"
//! scale = 0.1
//! trainers = 8
//! batch_size = 256
//! buffer_pct = 0.25
//! epochs = 6
//! controller = "llm:gemma3-4b"
//! mode = "async"
//! [net]
//! alpha = 0.001
//! [compute]
//! base_overhead = 0.1
//! ```

use std::path::Path;

use crate::buffer::scoring::Policy;
use crate::partition::Method;
use crate::sim::{ControllerSpec, Mode, RunConfig};
use crate::util::json::Json;
use crate::util::tomlite;

/// Apply a parsed TOML document over a base config.
pub fn apply(doc: &Json, mut cfg: RunConfig) -> crate::error::Result<RunConfig> {
    let gets = |k: &str| doc.get(k).and_then(Json::as_str);
    let getf = |k: &str| doc.get(k).and_then(Json::as_f64);
    let getu = |k: &str| doc.get(k).and_then(Json::as_usize);
    if let Some(v) = gets("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = getf("scale") {
        cfg.scale = v;
    }
    if let Some(v) = getu("seed") {
        cfg.seed = v as u64;
    }
    if let Some(v) = getu("trainers") {
        crate::ensure!(v >= 1, "trainers must be >= 1");
        cfg.num_trainers = v;
    }
    if let Some(v) = getu("batch_size") {
        cfg.batch_size = v;
    }
    if let Some(v) = getu("fanout1") {
        cfg.fanout1 = v;
    }
    if let Some(v) = getu("fanout2") {
        cfg.fanout2 = v;
    }
    if let Some(v) = getf("buffer_pct") {
        crate::ensure!((0.0..=1.0).contains(&v), "buffer_pct in [0,1]");
        cfg.buffer_pct = v;
    }
    if let Some(v) = getu("epochs") {
        cfg.epochs = v;
    }
    if let Some(v) = getu("hidden") {
        cfg.hidden = v;
    }
    if let Some(v) = gets("controller") {
        cfg.controller = ControllerSpec::parse(v)?;
    }
    if let Some(v) = gets("mode") {
        cfg.mode = Mode::parse(v)?;
    }
    if let Some(v) = gets("partition") {
        cfg.partition_method = Method::parse(v)?;
    }
    if let Some(v) = gets("buffer_policy") {
        cfg.buffer_policy = Policy::parse(v)?;
    }
    if let Some(v) = getu("chunk_rows") {
        crate::ensure!(v >= 1, "chunk_rows must be >= 1");
        cfg.chunk_rows = v;
    }
    if let Some(v) = getu("chunk_cache_bytes") {
        cfg.chunk_cache_bytes = v as u64;
    }
    if let Some(net) = doc.get("net") {
        let f = |k: &str, d: f64| net.get(k).and_then(Json::as_f64).unwrap_or(d);
        cfg.net.alpha = f("alpha", cfg.net.alpha);
        cfg.net.beta = f("beta", cfg.net.beta);
        cfg.net.contention = f("contention", cfg.net.contention);
        cfg.net.beta_allreduce = f("beta_allreduce", cfg.net.beta_allreduce);
        cfg.net.alpha_allreduce = f("alpha_allreduce", cfg.net.alpha_allreduce);
    }
    if let Some(c) = doc.get("compute") {
        let f = |k: &str, d: f64| c.get(k).and_then(Json::as_f64).unwrap_or(d);
        cfg.compute.device_flops = f("device_flops", cfg.compute.device_flops);
        cfg.compute.base_overhead = f("base_overhead", cfg.compute.base_overhead);
        cfg.compute.train_multiplier = f("train_multiplier", cfg.compute.train_multiplier);
    }
    Ok(cfg)
}

/// Load a TOML config file over the defaults.
pub fn load(path: &Path) -> crate::error::Result<RunConfig> {
    let doc = tomlite::parse_file(path)?;
    apply(&doc, RunConfig::default())
}

/// Parse a TOML config from an in-memory string over the defaults — the
/// wire-delivery twin of [`load`], used when the orchestrator ships the
/// resolved config inline ([`crate::cluster::Frame::Config`]) instead of
/// through a shared file.
pub fn from_toml_str(toml: &str) -> crate::error::Result<RunConfig> {
    let doc = tomlite::parse(toml)?;
    apply(&doc, RunConfig::default())
}

/// Serialize a fully-resolved [`RunConfig`] to the TOML dialect [`apply`]
/// reads, covering *every* field — `load(to_toml(cfg)) == cfg` — so the
/// multi-process cluster runtime can hand workers an exact copy of the
/// orchestrator's config (same seeds ⇒ same graph, partition, and
/// schedule in every process).
pub fn to_toml(cfg: &RunConfig) -> crate::error::Result<String> {
    use std::fmt::Write as _;
    // Numbers travel as f64 text: exact for finite values printed with
    // Rust's shortest-roundtrip formatter, but integers above 2^53 and
    // non-finite floats would be silently mangled — refuse those.
    crate::ensure!(
        cfg.seed <= (1u64 << 53),
        "config: seed {} too large to serialize exactly",
        cfg.seed
    );
    crate::ensure!(
        cfg.chunk_cache_bytes <= (1u64 << 53),
        "config: chunk_cache_bytes {} too large to serialize exactly",
        cfg.chunk_cache_bytes
    );
    let f = |v: f64| -> crate::error::Result<String> {
        crate::ensure!(v.is_finite(), "config: non-finite value {v} not serializable");
        Ok(format!("{v:?}"))
    };
    let mode = match cfg.mode {
        Mode::Async => "async",
        Mode::Sync => "sync",
    };
    let partition = match cfg.partition_method {
        Method::MetisLike => "metis",
        Method::Ldg => "ldg",
        Method::Random => "random",
    };
    let policy = match cfg.buffer_policy {
        Policy::FreqDecay => "freq_decay",
        Policy::Lfu => "lfu",
        Policy::Lru => "lru",
    };
    let mut s = String::new();
    let _ = writeln!(s, "# generated by config::to_toml — worker-process run config");
    let _ = writeln!(s, "dataset = \"{}\"", cfg.dataset);
    let _ = writeln!(s, "scale = {}", f(cfg.scale)?);
    let _ = writeln!(s, "seed = {}", cfg.seed);
    let _ = writeln!(s, "trainers = {}", cfg.num_trainers);
    let _ = writeln!(s, "batch_size = {}", cfg.batch_size);
    let _ = writeln!(s, "fanout1 = {}", cfg.fanout1);
    let _ = writeln!(s, "fanout2 = {}", cfg.fanout2);
    let _ = writeln!(s, "buffer_pct = {}", f(cfg.buffer_pct)?);
    let _ = writeln!(s, "epochs = {}", cfg.epochs);
    let _ = writeln!(s, "hidden = {}", cfg.hidden);
    let _ = writeln!(s, "controller = \"{}\"", cfg.controller.spec());
    let _ = writeln!(s, "mode = \"{mode}\"");
    let _ = writeln!(s, "partition = \"{partition}\"");
    let _ = writeln!(s, "buffer_policy = \"{policy}\"");
    let _ = writeln!(s, "chunk_rows = {}", cfg.chunk_rows);
    let _ = writeln!(s, "chunk_cache_bytes = {}", cfg.chunk_cache_bytes);
    let _ = writeln!(s, "[net]");
    let _ = writeln!(s, "alpha = {}", f(cfg.net.alpha)?);
    let _ = writeln!(s, "beta = {}", f(cfg.net.beta)?);
    let _ = writeln!(s, "contention = {}", f(cfg.net.contention)?);
    let _ = writeln!(s, "beta_allreduce = {}", f(cfg.net.beta_allreduce)?);
    let _ = writeln!(s, "alpha_allreduce = {}", f(cfg.net.alpha_allreduce)?);
    let _ = writeln!(s, "[compute]");
    let _ = writeln!(s, "device_flops = {}", f(cfg.compute.device_flops)?);
    let _ = writeln!(s, "base_overhead = {}", f(cfg.compute.base_overhead)?);
    let _ = writeln!(s, "train_multiplier = {}", f(cfg.compute.train_multiplier)?);
    Ok(s)
}

/// Does a calibration document apply to the runtime backend this build
/// would select?  Untagged (pre-tagging) files are accepted; a mismatched
/// tag means the constants were measured on a different backend and must
/// not be silently mixed in.
pub fn calibration_matches_backend(doc: &Json, active: &str) -> bool {
    match doc.get("backend").and_then(Json::as_str) {
        Some(tag) => tag == active,
        None => true,
    }
}

/// Load calibration constants (written by `rudder calibrate`) if present
/// and measured on the currently active runtime backend.
pub fn load_calibration(cfg: &mut RunConfig) {
    let path = Path::new("configs/calibration.toml");
    if let Ok(doc) = tomlite::parse_file(path) {
        let active = crate::runtime::active_backend_name();
        if !calibration_matches_backend(&doc, active) {
            crate::log_info!(
                "warning: ignoring configs/calibration.toml — measured on backend '{}' \
                 but this build runs '{active}'; re-run `rudder calibrate`",
                doc.get("backend").and_then(Json::as_str).unwrap_or("?"),
            );
            return;
        }
        if let Ok(updated) = apply(&doc, cfg.clone()) {
            *cfg = updated;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn applies_overrides() {
        let doc = tomlite::parse(
            r#"
dataset = "reddit"
trainers = 16
buffer_pct = 0.05
controller = "llm:llama3.2-3b"
mode = "sync"
partition = "ldg"
[net]
alpha = 0.002
[compute]
base_overhead = 0.2
"#,
        )
        .unwrap();
        let cfg = apply(&doc, RunConfig::default()).unwrap();
        assert_eq!(cfg.dataset, "reddit");
        assert_eq!(cfg.num_trainers, 16);
        assert_eq!(cfg.buffer_pct, 0.05);
        assert_eq!(cfg.mode, Mode::Sync);
        assert_eq!(cfg.net.alpha, 0.002);
        assert_eq!(cfg.compute.base_overhead, 0.2);
        assert_eq!(cfg.partition_method, Method::Ldg);
    }

    #[test]
    fn rejects_bad_values() {
        let doc = tomlite::parse("buffer_pct = 1.5").unwrap();
        assert!(apply(&doc, RunConfig::default()).is_err());
        let doc = tomlite::parse("controller = \"llm:nonexistent\"").unwrap();
        assert!(apply(&doc, RunConfig::default()).is_err());
        let doc = tomlite::parse("trainers = 0").unwrap();
        assert!(apply(&doc, RunConfig::default()).is_err());
    }

    #[test]
    fn to_toml_round_trips_every_field() {
        use crate::gnn::ComputeParams;
        use crate::net::NetParams;
        let cfg = RunConfig {
            dataset: "reddit".into(),
            scale: 0.15,
            seed: 1234567,
            num_trainers: 3,
            batch_size: 48,
            fanout1: 7,
            fanout2: 11,
            buffer_pct: 0.05,
            epochs: 9,
            hidden: 96,
            controller: ControllerSpec::parse("clf:rf:finetune=25").unwrap(),
            mode: Mode::Sync,
            partition_method: Method::Ldg,
            buffer_policy: Policy::Lru,
            chunk_rows: 16,
            chunk_cache_bytes: 4 * 1024 * 1024,
            net: NetParams {
                alpha: 0.002,
                beta: 1.0 / 15e6, // exercises exponent formatting
                ..NetParams::default()
            },
            compute: ComputeParams { base_overhead: 0.123456789, ..ComputeParams::default() },
        };
        let toml = to_toml(&cfg).unwrap();
        let back = apply(&tomlite::parse(&toml).unwrap(), RunConfig::default()).unwrap();
        // Field-exact round-trip (Debug covers every field, including the
        // float bit patterns via the shortest-roundtrip formatter).
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"), "\n{toml}");
    }

    #[test]
    fn to_toml_refuses_unserializable_values() {
        use crate::gnn::ComputeParams;
        let cfg = RunConfig { seed: u64::MAX, ..RunConfig::default() };
        assert!(to_toml(&cfg).is_err(), "huge seeds would lose precision");
        let cfg = RunConfig {
            compute: ComputeParams { device_flops: f64::INFINITY, ..ComputeParams::default() },
            ..RunConfig::default()
        };
        assert!(to_toml(&cfg).is_err(), "non-finite floats have no TOML form");
    }

    #[test]
    fn empty_doc_keeps_defaults() {
        let doc = tomlite::parse("").unwrap();
        let cfg = apply(&doc, RunConfig::default()).unwrap();
        assert_eq!(cfg.dataset, "products");
    }

    #[test]
    fn calibration_backend_tag_gates_application() {
        let doc = tomlite::parse(
            "backend = \"pjrt\"\n[compute]\nbase_overhead = 0.5",
        )
        .unwrap();
        assert!(calibration_matches_backend(&doc, "pjrt"));
        assert!(!calibration_matches_backend(&doc, "interpreter"));
        // Untagged legacy files still apply.
        let legacy = tomlite::parse("[compute]\nbase_overhead = 0.5").unwrap();
        assert!(calibration_matches_backend(&legacy, "interpreter"));
        // The tag itself is ignored by `apply` (unknown keys pass through).
        let cfg = apply(&doc, RunConfig::default()).unwrap();
        assert_eq!(cfg.compute.base_overhead, 0.5);
        // The default (zero-dep) build always resolves to the interpreter.
        #[cfg(not(feature = "pjrt"))]
        assert_eq!(crate::runtime::active_backend_name(), "interpreter");
    }
}
