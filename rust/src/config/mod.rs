//! Config system: TOML files → [`RunConfig`] (plus CLI overrides).
//!
//! Example (`configs/quickstart.toml`):
//!
//! ```toml
//! dataset = "products"
//! scale = 0.1
//! trainers = 8
//! batch_size = 256
//! buffer_pct = 0.25
//! epochs = 6
//! controller = "llm:gemma3-4b"
//! mode = "async"
//! [net]
//! alpha = 0.001
//! [compute]
//! base_overhead = 0.1
//! ```

use std::path::Path;

use crate::partition::Method;
use crate::sim::{ControllerSpec, Mode, RunConfig};
use crate::util::json::Json;
use crate::util::tomlite;

/// Apply a parsed TOML document over a base config.
pub fn apply(doc: &Json, mut cfg: RunConfig) -> crate::error::Result<RunConfig> {
    let gets = |k: &str| doc.get(k).and_then(Json::as_str);
    let getf = |k: &str| doc.get(k).and_then(Json::as_f64);
    let getu = |k: &str| doc.get(k).and_then(Json::as_usize);
    if let Some(v) = gets("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = getf("scale") {
        cfg.scale = v;
    }
    if let Some(v) = getu("seed") {
        cfg.seed = v as u64;
    }
    if let Some(v) = getu("trainers") {
        crate::ensure!(v >= 1, "trainers must be >= 1");
        cfg.num_trainers = v;
    }
    if let Some(v) = getu("batch_size") {
        cfg.batch_size = v;
    }
    if let Some(v) = getu("fanout1") {
        cfg.fanout1 = v;
    }
    if let Some(v) = getu("fanout2") {
        cfg.fanout2 = v;
    }
    if let Some(v) = getf("buffer_pct") {
        crate::ensure!((0.0..=1.0).contains(&v), "buffer_pct in [0,1]");
        cfg.buffer_pct = v;
    }
    if let Some(v) = getu("epochs") {
        cfg.epochs = v;
    }
    if let Some(v) = getu("hidden") {
        cfg.hidden = v;
    }
    if let Some(v) = gets("controller") {
        cfg.controller = ControllerSpec::parse(v)?;
    }
    if let Some(v) = gets("mode") {
        cfg.mode = Mode::parse(v)?;
    }
    if let Some(v) = gets("partition") {
        cfg.partition_method = Method::parse(v)?;
    }
    if let Some(net) = doc.get("net") {
        let f = |k: &str, d: f64| net.get(k).and_then(Json::as_f64).unwrap_or(d);
        cfg.net.alpha = f("alpha", cfg.net.alpha);
        cfg.net.beta = f("beta", cfg.net.beta);
        cfg.net.contention = f("contention", cfg.net.contention);
        cfg.net.beta_allreduce = f("beta_allreduce", cfg.net.beta_allreduce);
        cfg.net.alpha_allreduce = f("alpha_allreduce", cfg.net.alpha_allreduce);
    }
    if let Some(c) = doc.get("compute") {
        let f = |k: &str, d: f64| c.get(k).and_then(Json::as_f64).unwrap_or(d);
        cfg.compute.device_flops = f("device_flops", cfg.compute.device_flops);
        cfg.compute.base_overhead = f("base_overhead", cfg.compute.base_overhead);
        cfg.compute.train_multiplier = f("train_multiplier", cfg.compute.train_multiplier);
    }
    Ok(cfg)
}

/// Load a TOML config file over the defaults.
pub fn load(path: &Path) -> crate::error::Result<RunConfig> {
    let doc = tomlite::parse_file(path)?;
    apply(&doc, RunConfig::default())
}

/// Does a calibration document apply to the runtime backend this build
/// would select?  Untagged (pre-tagging) files are accepted; a mismatched
/// tag means the constants were measured on a different backend and must
/// not be silently mixed in.
pub fn calibration_matches_backend(doc: &Json, active: &str) -> bool {
    match doc.get("backend").and_then(Json::as_str) {
        Some(tag) => tag == active,
        None => true,
    }
}

/// Load calibration constants (written by `rudder calibrate`) if present
/// and measured on the currently active runtime backend.
pub fn load_calibration(cfg: &mut RunConfig) {
    let path = Path::new("configs/calibration.toml");
    if let Ok(doc) = tomlite::parse_file(path) {
        let active = crate::runtime::active_backend_name();
        if !calibration_matches_backend(&doc, active) {
            eprintln!(
                "warning: ignoring configs/calibration.toml — measured on backend '{}' \
                 but this build runs '{active}'; re-run `rudder calibrate`",
                doc.get("backend").and_then(Json::as_str).unwrap_or("?"),
            );
            return;
        }
        if let Ok(updated) = apply(&doc, cfg.clone()) {
            *cfg = updated;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn applies_overrides() {
        let doc = tomlite::parse(
            r#"
dataset = "reddit"
trainers = 16
buffer_pct = 0.05
controller = "llm:llama3.2-3b"
mode = "sync"
partition = "ldg"
[net]
alpha = 0.002
[compute]
base_overhead = 0.2
"#,
        )
        .unwrap();
        let cfg = apply(&doc, RunConfig::default()).unwrap();
        assert_eq!(cfg.dataset, "reddit");
        assert_eq!(cfg.num_trainers, 16);
        assert_eq!(cfg.buffer_pct, 0.05);
        assert_eq!(cfg.mode, Mode::Sync);
        assert_eq!(cfg.net.alpha, 0.002);
        assert_eq!(cfg.compute.base_overhead, 0.2);
        assert_eq!(cfg.partition_method, Method::Ldg);
    }

    #[test]
    fn rejects_bad_values() {
        let doc = tomlite::parse("buffer_pct = 1.5").unwrap();
        assert!(apply(&doc, RunConfig::default()).is_err());
        let doc = tomlite::parse("controller = \"llm:nonexistent\"").unwrap();
        assert!(apply(&doc, RunConfig::default()).is_err());
        let doc = tomlite::parse("trainers = 0").unwrap();
        assert!(apply(&doc, RunConfig::default()).is_err());
    }

    #[test]
    fn empty_doc_keeps_defaults() {
        let doc = tomlite::parse("").unwrap();
        let cfg = apply(&doc, RunConfig::default()).unwrap();
        assert_eq!(cfg.dataset, "products");
    }

    #[test]
    fn calibration_backend_tag_gates_application() {
        let doc = tomlite::parse(
            "backend = \"pjrt\"\n[compute]\nbase_overhead = 0.5",
        )
        .unwrap();
        assert!(calibration_matches_backend(&doc, "pjrt"));
        assert!(!calibration_matches_backend(&doc, "interpreter"));
        // Untagged legacy files still apply.
        let legacy = tomlite::parse("[compute]\nbase_overhead = 0.5").unwrap();
        assert!(calibration_matches_backend(&legacy, "interpreter"));
        // The tag itself is ignored by `apply` (unknown keys pass through).
        let cfg = apply(&doc, RunConfig::default()).unwrap();
        assert_eq!(cfg.compute.base_overhead, 0.5);
        // The default (zero-dep) build always resolves to the interpreter.
        #[cfg(not(feature = "pjrt"))]
        assert_eq!(crate::runtime::active_backend_name(), "interpreter");
    }
}
