//! Flight recorder: cluster-wide structured tracing.
//!
//! Every role thread in the cluster runtime (trainer, prefetcher, feature
//! server, allreduce hub, event loop) owns a [`Tracer`] and emits typed
//! [`TraceEvent`] records — minibatch begin/end, fetch issue/response,
//! batch flush, allreduce wait, replacement, stall — carrying both the
//! **virtual clock** (the α–β modelled time the sim reasons in) and a
//! **wall clock** (seconds since the role thread started).  Buffers are
//! collected when each role exits and merged into one [`Trace`] per run;
//! multiproc workers ship theirs back inside the existing `Frame::Result`
//! blobs, so a TCP run still yields a single merged trace.
//!
//! Two serializations with lossless two-way conversion ([`codec`]):
//! human-readable JSON lines and a compact length-prefixed binary framing
//! (`RTRC` magic, `[u32 len][event]` frames — the same shape as the wire
//! format).  `rudder trace dump|stats|diff` operate on either.
//!
//! Determinism contract ([`diff`]): every event kind is classified
//! *virtual* or *wall-only*.  Virtual kinds carry only data derived from
//! config + seed (request ids, node sets, modelled clocks), so same-seed
//! runs must produce **bit-identical** virtual events across the channel,
//! tcp, and event transports — the trace-level generalization of
//! `wire_parity`.  Wall-only kinds (batch flushes, event-loop sweeps,
//! `RoleEnd`) record scheduling reality and are excluded from the diff.
//!
//! Integer fields are bounded to 2^53 and floats are finite with `-0.0`
//! normalized to `0.0`, so every field round-trips bit-exactly through
//! both codecs (JSON numbers are IEEE doubles; the writer emits shortest
//! round-trip decimals).
//!
//! Replay flow ([`crate::replay`]): traces are also the *input* of the
//! offline policy evaluator.  [`TraceMeta::config`] embeds the full run
//! config (TOML), and each trainer stream carries one
//! [`EventKind::SampleDemand`] per active minibatch — the sampled demand
//! (target count, sampled-node count, remote want-set) that
//! `rudder replay` feeds back into the sim state machine to re-drive the
//! run without a cluster, either under the same config (bit-identity
//! check via [`diff`]) or a what-if variant (different controller /
//! buffer / chunk-cache settings).
//!
//! These invariants are machine-enforced: `rudder audit`
//! ([`crate::audit`]) rejects wall clocks feeding virtual fields, bare
//! narrowing casts in [`codec`], and magic literals outside
//! [`crate::magic`]; the clippy lints below harden the rest.

#![warn(
    clippy::cast_possible_truncation,
    clippy::cast_sign_loss,
    clippy::unwrap_used
)]

pub mod codec;
pub mod diff;
pub mod stats;

use std::time::Instant;

use crate::error::Result;

/// Which role thread emitted an event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Role {
    Trainer,
    Prefetcher,
    Server,
    Hub,
    EventLoop,
}

impl Role {
    pub const ALL: [Role; 5] =
        [Role::Trainer, Role::Prefetcher, Role::Server, Role::Hub, Role::EventLoop];

    pub fn tag(self) -> u8 {
        match self {
            Role::Trainer => 1,
            Role::Prefetcher => 2,
            Role::Server => 3,
            Role::Hub => 4,
            Role::EventLoop => 5,
        }
    }

    pub fn from_tag(t: u8) -> Option<Role> {
        Role::ALL.into_iter().find(|r| r.tag() == t)
    }

    pub fn name(self) -> &'static str {
        match self {
            Role::Trainer => "trainer",
            Role::Prefetcher => "prefetcher",
            Role::Server => "server",
            Role::Hub => "hub",
            Role::EventLoop => "eventloop",
        }
    }

    pub fn from_name(s: &str) -> Option<Role> {
        Role::ALL.into_iter().find(|r| r.name() == s)
    }
}

/// A typed trace record.  Fields under each variant are the *payload*;
/// the envelope (role, id, seq, clocks) lives on [`TraceEvent`].
///
/// Kinds are classified by [`EventKind::is_virtual`]: virtual kinds carry
/// only seed-deterministic data and participate in [`diff`]; wall-only
/// kinds record scheduling/timing reality.  `wall_secs`-style fields
/// inside virtual kinds are measured durations and are excluded from the
/// canonical projection ([`diff::canonical`]).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Trainer: a minibatch step begins (before sampling + fetch wait).
    MinibatchBegin { epoch: u32, mb: u32 },
    /// Trainer: the step completed; `step_vsecs` is the virtual-clock
    /// advance the step cost (deterministic).
    MinibatchEnd { epoch: u32, mb: u32, step_vsecs: f64 },
    /// Trainer: blocked on `nodes` remote features for `wall_secs`.
    FetchWait { nodes: u64, wall_secs: f64 },
    /// Trainer: forward/backward compute — `virtual_secs` modelled,
    /// `wall_secs` measured (sleep or real SageRunner).
    Compute { virtual_secs: f64, wall_secs: f64 },
    /// Trainer: a buffer replacement round (admitted/evicted node counts).
    Replacement { admitted: u64, evicted: u64 },
    /// Trainer: blocked on the DDP allreduce barrier.
    AllreduceWait { round: u64, wall_secs: f64 },
    /// Prefetcher: one FetchReq frame created for `owner`'s server.
    FetchIssue { req_id: u64, owner: u32, nodes: u64, bytes: u64 },
    /// Prefetcher: a FetchResp frame arrived and was admitted.
    FetchResponse { req_id: u64, nodes: u64, bytes: u64 },
    /// Prefetcher: evicted `nodes` from the feature store.
    Evict { nodes: u64 },
    /// Prefetcher: one coalesced burst handed to the transport
    /// (wall-only: burst boundaries depend on thread scheduling).
    BatchFlush { owner: u32, frames: u64, bytes: u64 },
    /// Server: served one FetchReq from trainer `from`.
    FetchServe { req_id: u64, from: u32, nodes: u64, bytes: u64 },
    /// Hub: one allreduce round reduced and broadcast.
    AllreduceRound { round: u64, vclock_max: f64, trainers: u32 },
    /// Event loop: one write sweep flushed a batch on connection `conn`
    /// (wall-only).
    LinkFlush { conn: u32, frames: u64, bytes: u64 },
    /// Event loop: a multiplexed channel half-closed (wall-only).
    ChannelClose { conn: u32, channel: u32 },
    /// Final event of every role: `emitted` counts the events before it,
    /// so a collector can prove nothing was dropped at shutdown.
    RoleEnd { emitted: u64 },
    /// Prefetcher: `nodes` of one fetch command served straight from the
    /// chunk cache for `owner` (no wire traffic).  Cache decisions are
    /// command-time-only, so this is virtual and diff-gated.
    CacheHit { owner: u32, nodes: u64 },
    /// Prefetcher: `nodes` of one fetch command missed the chunk cache for
    /// `owner`, admitting `chunks` new chunks (virtual, diff-gated).
    CacheMiss { owner: u32, chunks: u64, nodes: u64 },
    /// Trainer: the sampled demand of one active minibatch — target
    /// count, total sampled nodes, and the deduplicated remote want-set.
    /// This is the record [`crate::replay`] re-drives the sim from;
    /// sampling is seed-deterministic, so it is virtual and diff-gated.
    SampleDemand { epoch: u32, mb: u32, targets: u64, sampled: u64, remote: Vec<u32> },
}

impl EventKind {
    pub fn tag(&self) -> u8 {
        match self {
            EventKind::MinibatchBegin { .. } => 1,
            EventKind::MinibatchEnd { .. } => 2,
            EventKind::FetchWait { .. } => 3,
            EventKind::Compute { .. } => 4,
            EventKind::Replacement { .. } => 5,
            EventKind::AllreduceWait { .. } => 6,
            EventKind::FetchIssue { .. } => 7,
            EventKind::FetchResponse { .. } => 8,
            EventKind::Evict { .. } => 9,
            EventKind::BatchFlush { .. } => 10,
            EventKind::FetchServe { .. } => 11,
            EventKind::AllreduceRound { .. } => 12,
            EventKind::LinkFlush { .. } => 13,
            EventKind::ChannelClose { .. } => 14,
            EventKind::RoleEnd { .. } => 15,
            EventKind::CacheHit { .. } => 16,
            EventKind::CacheMiss { .. } => 17,
            EventKind::SampleDemand { .. } => 18,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EventKind::MinibatchBegin { .. } => "minibatch_begin",
            EventKind::MinibatchEnd { .. } => "minibatch_end",
            EventKind::FetchWait { .. } => "fetch_wait",
            EventKind::Compute { .. } => "compute",
            EventKind::Replacement { .. } => "replacement",
            EventKind::AllreduceWait { .. } => "allreduce_wait",
            EventKind::FetchIssue { .. } => "fetch_issue",
            EventKind::FetchResponse { .. } => "fetch_response",
            EventKind::Evict { .. } => "evict",
            EventKind::BatchFlush { .. } => "batch_flush",
            EventKind::FetchServe { .. } => "fetch_serve",
            EventKind::AllreduceRound { .. } => "allreduce_round",
            EventKind::LinkFlush { .. } => "link_flush",
            EventKind::ChannelClose { .. } => "channel_close",
            EventKind::RoleEnd { .. } => "role_end",
            EventKind::CacheHit { .. } => "cache_hit",
            EventKind::CacheMiss { .. } => "cache_miss",
            EventKind::SampleDemand { .. } => "sample_demand",
        }
    }

    /// Virtual kinds carry only config+seed-deterministic payloads and
    /// must be bit-identical across transports; wall-only kinds depend on
    /// scheduling and are excluded from [`diff`].
    pub fn is_virtual(&self) -> bool {
        !matches!(
            self,
            EventKind::BatchFlush { .. }
                | EventKind::LinkFlush { .. }
                | EventKind::ChannelClose { .. }
                | EventKind::RoleEnd { .. }
        )
    }
}

/// One trace record: envelope + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub role: Role,
    /// Role instance id: trainer/prefetcher/server partition id, 0 for
    /// the hub, connection-set id for the event loop.
    pub id: u32,
    /// Per-(role, id) emission counter, assigned by the [`Tracer`].
    pub seq: u64,
    /// Virtual clock at emission (0.0 for roles without one).
    pub vclock: f64,
    /// Seconds since the emitting role thread started.
    pub wall: f64,
    pub kind: EventKind,
}

/// Normalize a float for the trace domain: `-0.0` becomes `0.0` so the
/// JSONL codec (which writes shortest round-trip decimals through the
/// integral fast path) stays bit-lossless.
pub(crate) fn norm_f64(v: f64) -> f64 {
    if v == 0.0 {
        0.0
    } else {
        v
    }
}

/// Per-role-thread event buffer.  Cheap no-op when disabled (one branch
/// per emit); collects into a `Vec` otherwise — no locks, the buffer is
/// handed over wholesale when the role exits ([`Tracer::finish`]).
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    role: Role,
    id: u32,
    start: Instant,
    seq: u64,
    events: Vec<TraceEvent>,
}

impl Tracer {
    pub fn new(enabled: bool, role: Role, id: u32) -> Tracer {
        // audit:allow(wall-clock-in-virtual-path) anchors the wall field only; vclock stays virtual
        Tracer { enabled, role, id, start: Instant::now(), seq: 0, events: Vec::new() }
    }

    /// A disabled tracer (every emit is a no-op, `finish` yields nothing).
    pub fn off() -> Tracer {
        Tracer::new(false, Role::Trainer, 0)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event at virtual time `vclock`.
    pub fn emit(&mut self, vclock: f64, kind: EventKind) {
        if !self.enabled {
            return;
        }
        self.events.push(TraceEvent {
            role: self.role,
            id: self.id,
            seq: self.seq,
            vclock: norm_f64(vclock),
            wall: self.start.elapsed().as_secs_f64(),
            kind,
        });
        self.seq += 1;
    }

    /// Close the buffer: emits the terminal [`EventKind::RoleEnd`] (whose
    /// `emitted` payload counts every prior event, the drop-detection
    /// anchor for [`Trace::verify_complete`]) and returns all events.
    pub fn finish(mut self) -> Vec<TraceEvent> {
        if !self.enabled {
            return Vec::new();
        }
        let emitted = self.seq;
        self.emit(0.0, EventKind::RoleEnd { emitted });
        self.events
    }
}

/// Run-level metadata stamped into every trace file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceMeta {
    pub label: String,
    pub seed: u64,
    pub transport: String,
    pub compute: String,
    /// The full run config as TOML ([`crate::config::to_toml`]), so a
    /// trace is a self-contained replay input.  Empty when the recorder
    /// predates replay or the producer had no config to stamp.
    pub config: String,
}

/// A complete (possibly merged) run trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub meta: TraceMeta,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new(meta: TraceMeta) -> Trace {
        Trace { meta, events: Vec::new() }
    }

    /// Canonical merged order: role tag, then instance id, then seq —
    /// independent of collection/arrival order.
    pub fn sort_canonical(&mut self) {
        self.events.sort_by_key(|e| (e.role.tag(), e.id, e.seq));
    }

    /// Events of one role instance, in seq order (assumes
    /// [`Trace::sort_canonical`] ran).
    pub fn role_events(&self, role: Role, id: u32) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter().filter(move |e| e.role == role && e.id == id)
    }

    /// Drop-detection audit: every (role, id) stream must end with exactly
    /// one `RoleEnd` whose `emitted` count matches the events collected
    /// before it, and seqs must be gapless.
    pub fn verify_complete(&self) -> Result<()> {
        use std::collections::BTreeMap;
        let mut streams: BTreeMap<(u8, u32), Vec<&TraceEvent>> = BTreeMap::new();
        for e in &self.events {
            streams.entry((e.role.tag(), e.id)).or_default().push(e);
        }
        for ((tag, id), mut evs) in streams {
            let role = Role::from_tag(tag).expect("valid role tag");
            let who = format!("{}-{id}", role.name());
            evs.sort_by_key(|e| e.seq);
            for (i, e) in evs.iter().enumerate() {
                crate::ensure!(
                    e.seq == i as u64,
                    "trace stream {who}: seq gap at {} (expected {i}) — events dropped",
                    e.seq
                );
            }
            let last = evs.last().expect("non-empty stream");
            match last.kind {
                EventKind::RoleEnd { emitted } => {
                    crate::ensure!(
                        emitted == evs.len() as u64 - 1,
                        "trace stream {who}: RoleEnd says {emitted} events emitted but {} \
                         collected — events dropped at shutdown",
                        evs.len() - 1
                    );
                }
                _ => crate::bail!("trace stream {who}: missing terminal RoleEnd event"),
            }
            let ends = evs.iter().filter(|e| matches!(e.kind, EventKind::RoleEnd { .. })).count();
            crate::ensure!(ends == 1, "trace stream {who}: {ends} RoleEnd events (want 1)");
        }
        Ok(())
    }

    /// Write to `path`: `.jsonl` extension selects the JSON-lines text
    /// form, anything else the compact binary framing.
    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        let bytes = if path.extension().and_then(|e| e.to_str()) == Some("jsonl") {
            codec::to_jsonl(self)?.into_bytes()
        } else {
            codec::encode_binary(self)?
        };
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Read either serialization back (sniffs the binary magic).
    pub fn read_file(path: &std::path::Path) -> Result<Trace> {
        let bytes = std::fs::read(path)?;
        if bytes.starts_with(codec::MAGIC) {
            codec::decode_binary(&bytes)
        } else {
            let text = String::from_utf8(bytes).map_err(|_| {
                crate::err!("{}: neither RTRC binary nor utf-8 jsonl", path.display())
            })?;
            codec::from_jsonl(&text)
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;

    #[test]
    fn role_tags_round_trip() {
        for r in Role::ALL {
            assert_eq!(Role::from_tag(r.tag()), Some(r));
            assert_eq!(Role::from_name(r.name()), Some(r));
        }
        assert_eq!(Role::from_tag(0), None);
        assert_eq!(Role::from_name("nope"), None);
    }

    #[test]
    fn tracer_disabled_is_silent() {
        let mut t = Tracer::off();
        t.emit(1.0, EventKind::Evict { nodes: 3 });
        assert!(t.finish().is_empty());
    }

    #[test]
    fn tracer_seq_and_role_end() {
        let mut t = Tracer::new(true, Role::Prefetcher, 2);
        t.emit(0.5, EventKind::Evict { nodes: 1 });
        t.emit(1.5, EventKind::Evict { nodes: 2 });
        let evs = t.finish();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].seq, 0);
        assert_eq!(evs[1].seq, 1);
        assert_eq!(evs[2].kind, EventKind::RoleEnd { emitted: 2 });
        assert_eq!(evs[2].role, Role::Prefetcher);
        assert_eq!(evs[2].id, 2);
        assert!(evs[1].wall >= evs[0].wall);
    }

    #[test]
    fn verify_complete_accepts_finished_stream() {
        let mut tr = Trace::default();
        let mut t = Tracer::new(true, Role::Trainer, 0);
        t.emit(0.0, EventKind::MinibatchBegin { epoch: 0, mb: 0 });
        tr.events.extend(t.finish());
        tr.verify_complete().unwrap();
    }

    #[test]
    fn verify_complete_detects_drops() {
        let mut t = Tracer::new(true, Role::Trainer, 0);
        t.emit(0.0, EventKind::MinibatchBegin { epoch: 0, mb: 0 });
        t.emit(0.0, EventKind::MinibatchEnd { epoch: 0, mb: 0, step_vsecs: 1.0 });
        let mut evs = t.finish();
        // Losing a mid-stream event must be caught (seq gap).
        evs.remove(1);
        let tr = Trace { meta: TraceMeta::default(), events: evs.clone() };
        assert!(tr.verify_complete().is_err());
        // Losing the tail (RoleEnd) must be caught too.
        let mut t = Tracer::new(true, Role::Server, 1);
        t.emit(0.0, EventKind::Evict { nodes: 1 });
        let mut evs = t.finish();
        evs.pop();
        let tr = Trace { meta: TraceMeta::default(), events: evs };
        assert!(tr.verify_complete().is_err());
    }

    #[test]
    fn canonical_sort_is_role_major() {
        let ev = |role: Role, id: u32, seq: u64| TraceEvent {
            role,
            id,
            seq,
            vclock: 0.0,
            wall: 0.0,
            kind: EventKind::Evict { nodes: 0 },
        };
        let mut tr = Trace::default();
        tr.events = vec![ev(Role::Hub, 0, 0), ev(Role::Trainer, 1, 0), ev(Role::Trainer, 0, 1)];
        tr.sort_canonical();
        let order: Vec<(Role, u32)> = tr.events.iter().map(|e| (e.role, e.id)).collect();
        assert_eq!(order, vec![(Role::Trainer, 0), (Role::Trainer, 1), (Role::Hub, 0)]);
    }

    #[test]
    fn minus_zero_normalized() {
        let mut t = Tracer::new(true, Role::Hub, 0);
        t.emit(-0.0, EventKind::AllreduceRound { round: 0, vclock_max: 0.0, trainers: 2 });
        let evs = t.finish();
        assert_eq!(evs[0].vclock.to_bits(), 0.0f64.to_bits());
    }
}
