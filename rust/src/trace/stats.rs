//! Trace analytics behind `rudder trace stats`: per-phase wall-latency
//! percentiles, the per-trainer fetch-blocked breakdown, and per-link
//! fetch timelines reconstructed from issue→response pairs.

use std::collections::HashMap;

use crate::eval::report::{fmt_count, fmt_secs, Table};
use crate::util::stats::percentile;

use super::{EventKind, Role, Trace};

/// Summary of one latency population.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseStats {
    pub count: u64,
    pub total: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl PhaseStats {
    pub fn from_samples(xs: &[f64]) -> PhaseStats {
        PhaseStats {
            count: xs.len() as u64,
            total: xs.iter().sum(),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
        }
    }
}

/// Wall-time samples per phase, pooled across all role instances.
///
/// * `fetch_wait` / `compute` / `allreduce_wait` — the trainer's measured
///   in-step durations.
/// * `minibatch` — wall delta between each `minibatch_begin`/`_end` pair.
/// * `fetch_rtt` — prefetcher `fetch_issue` → `fetch_response` wall delta
///   per request id (the transport's round trip as the trainer's
///   prefetcher saw it).
/// * `serve` — per-request service marks on the feature servers (counted;
///   durations are not spanned server-side).
pub fn phase_samples(t: &Trace) -> Vec<(&'static str, Vec<f64>)> {
    let mut fetch_wait = Vec::new();
    let mut compute = Vec::new();
    let mut allreduce = Vec::new();
    let mut minibatch = Vec::new();
    let mut rtt = Vec::new();
    let mut begins: HashMap<(u32, u32, u32), f64> = HashMap::new();
    let mut issues: HashMap<(u32, u64), f64> = HashMap::new();
    for e in &t.events {
        match e.kind {
            EventKind::FetchWait { wall_secs, .. } => fetch_wait.push(wall_secs),
            EventKind::Compute { wall_secs, .. } => compute.push(wall_secs),
            EventKind::AllreduceWait { wall_secs, .. } => allreduce.push(wall_secs),
            EventKind::MinibatchBegin { epoch, mb } => {
                begins.insert((e.id, epoch, mb), e.wall);
            }
            EventKind::MinibatchEnd { epoch, mb, .. } => {
                if let Some(w0) = begins.remove(&(e.id, epoch, mb)) {
                    minibatch.push((e.wall - w0).max(0.0));
                }
            }
            EventKind::FetchIssue { req_id, .. } if e.role == Role::Prefetcher => {
                issues.insert((e.id, req_id), e.wall);
            }
            EventKind::FetchResponse { req_id, .. } if e.role == Role::Prefetcher => {
                if let Some(w0) = issues.remove(&(e.id, req_id)) {
                    rtt.push((e.wall - w0).max(0.0));
                }
            }
            _ => {}
        }
    }
    vec![
        ("fetch_wait", fetch_wait),
        ("compute", compute),
        ("allreduce_wait", allreduce),
        ("minibatch", minibatch),
        ("fetch_rtt", rtt),
    ]
}

/// Per-phase percentile summaries keyed by phase name.
pub fn phase_stats(t: &Trace) -> Vec<(&'static str, PhaseStats)> {
    phase_samples(t)
        .into_iter()
        .map(|(name, xs)| (name, PhaseStats::from_samples(&xs)))
        .collect()
}

/// `rudder trace stats` table 1: wall-latency percentiles per phase.
pub fn phase_table(t: &Trace) -> Table {
    let mut tab = Table::new(
        "per-phase wall latency (all role instances pooled)",
        &["phase", "count", "total", "p50", "p95", "p99"],
    );
    for (name, s) in phase_stats(t) {
        tab.row(vec![
            name.to_string(),
            s.count.to_string(),
            fmt_secs(s.total),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            fmt_secs(s.p99),
        ]);
    }
    tab
}

/// `rudder trace stats` table 2: where each trainer's wall time went,
/// and what fraction of it was blocked on remote features.
pub fn breakdown_table(t: &Trace) -> Table {
    #[derive(Default)]
    struct Acc {
        minibatches: u64,
        fetch: f64,
        compute: f64,
        barrier: f64,
        stalls: u64,
    }
    let mut per: std::collections::BTreeMap<u32, Acc> = std::collections::BTreeMap::new();
    for e in &t.events {
        if e.role != Role::Trainer {
            continue;
        }
        let a = per.entry(e.id).or_default();
        match e.kind {
            EventKind::MinibatchEnd { .. } => a.minibatches += 1,
            EventKind::FetchWait { wall_secs, .. } => {
                a.fetch += wall_secs;
                a.stalls += 1;
            }
            EventKind::Compute { wall_secs, .. } => a.compute += wall_secs,
            EventKind::AllreduceWait { wall_secs, .. } => a.barrier += wall_secs,
            _ => {}
        }
    }
    let mut tab = Table::new(
        "fetch-blocked breakdown per trainer",
        &["trainer", "minibatches", "stalls", "fetch_blocked", "compute", "barrier", "blocked%"],
    );
    for (id, a) in per {
        let busy = a.fetch + a.compute + a.barrier;
        let pct = if busy > 0.0 { 100.0 * a.fetch / busy } else { 0.0 };
        tab.row(vec![
            id.to_string(),
            a.minibatches.to_string(),
            a.stalls.to_string(),
            fmt_secs(a.fetch),
            fmt_secs(a.compute),
            fmt_secs(a.barrier),
            format!("{pct:.1}"),
        ]);
    }
    tab
}

/// `rudder trace stats` table 3: one row per prefetcher×owner link —
/// request/response traffic and the observed round-trip percentiles.
pub fn link_timeline_table(t: &Trace) -> Table {
    #[derive(Default)]
    struct Link {
        issues: u64,
        nodes: u64,
        req_bytes: u64,
        responses: u64,
        resp_bytes: u64,
        rtts: Vec<f64>,
        first: f64,
        last: f64,
    }
    let mut links: std::collections::BTreeMap<(u32, u32), Link> = std::collections::BTreeMap::new();
    let mut owner_of: HashMap<(u32, u64), u32> = HashMap::new();
    let mut issue_wall: HashMap<(u32, u64), f64> = HashMap::new();
    for e in &t.events {
        if e.role != Role::Prefetcher {
            continue;
        }
        match e.kind {
            EventKind::FetchIssue { req_id, owner, nodes, bytes } => {
                let l = links.entry((e.id, owner)).or_default();
                if l.issues == 0 {
                    l.first = e.wall;
                }
                l.issues += 1;
                l.nodes += nodes;
                l.req_bytes += bytes;
                l.last = l.last.max(e.wall);
                owner_of.insert((e.id, req_id), owner);
                issue_wall.insert((e.id, req_id), e.wall);
            }
            EventKind::FetchResponse { req_id, bytes, .. } => {
                if let Some(owner) = owner_of.remove(&(e.id, req_id)) {
                    let l = links.entry((e.id, owner)).or_default();
                    l.responses += 1;
                    l.resp_bytes += bytes;
                    l.last = l.last.max(e.wall);
                    if let Some(w0) = issue_wall.remove(&(e.id, req_id)) {
                        l.rtts.push((e.wall - w0).max(0.0));
                    }
                }
            }
            _ => {}
        }
    }
    let mut tab = Table::new(
        "per-link fetch timeline (prefetcher -> owner server)",
        &[
            "trainer",
            "owner",
            "reqs",
            "resps",
            "nodes",
            "req_bytes",
            "resp_bytes",
            "rtt_p50",
            "rtt_p99",
            "span",
        ],
    );
    for ((id, owner), l) in links {
        tab.row(vec![
            id.to_string(),
            owner.to_string(),
            l.issues.to_string(),
            l.responses.to_string(),
            fmt_count(l.nodes),
            fmt_count(l.req_bytes),
            fmt_count(l.resp_bytes),
            fmt_secs(percentile(&l.rtts, 50.0)),
            fmt_secs(percentile(&l.rtts, 99.0)),
            fmt_secs((l.last - l.first).max(0.0)),
        ]);
    }
    tab
}

/// Everything `rudder trace stats` prints, in order.
pub fn render_all(t: &Trace) -> Vec<Table> {
    vec![phase_table(t), breakdown_table(t), link_timeline_table(t)]
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;
    use crate::trace::{TraceEvent, TraceMeta};

    fn ev(role: Role, id: u32, seq: u64, wall: f64, kind: EventKind) -> TraceEvent {
        TraceEvent { role, id, seq, vclock: 0.0, wall, kind }
    }

    fn sample() -> Trace {
        Trace {
            meta: TraceMeta::default(),
            events: vec![
                ev(Role::Trainer, 0, 0, 0.00, EventKind::MinibatchBegin { epoch: 0, mb: 0 }),
                ev(Role::Trainer, 0, 1, 0.01, EventKind::FetchWait {
                    nodes: 8,
                    wall_secs: 0.004,
                }),
                ev(Role::Trainer, 0, 2, 0.02, EventKind::Compute {
                    virtual_secs: 1.0,
                    wall_secs: 0.010,
                }),
                ev(Role::Trainer, 0, 3, 0.03, EventKind::AllreduceWait {
                    round: 0,
                    wall_secs: 0.002,
                }),
                ev(Role::Trainer, 0, 4, 0.05, EventKind::MinibatchEnd {
                    epoch: 0,
                    mb: 0,
                    step_vsecs: 1.5,
                }),
                ev(Role::Prefetcher, 0, 0, 0.001, EventKind::FetchIssue {
                    req_id: 1,
                    owner: 1,
                    nodes: 8,
                    bytes: 64,
                }),
                ev(Role::Prefetcher, 0, 1, 0.006, EventKind::FetchResponse {
                    req_id: 1,
                    nodes: 8,
                    bytes: 640,
                }),
            ],
        }
    }

    #[test]
    fn phase_stats_extracts_all_phases() {
        let stats = phase_stats(&sample());
        let get = |name: &str| stats.iter().find(|(n, _)| *n == name).unwrap().1.clone();
        assert_eq!(get("fetch_wait").count, 1);
        assert!((get("fetch_wait").total - 0.004).abs() < 1e-12);
        assert_eq!(get("compute").count, 1);
        assert_eq!(get("minibatch").count, 1);
        assert!((get("minibatch").p50 - 0.05).abs() < 1e-12);
        assert_eq!(get("fetch_rtt").count, 1);
        assert!((get("fetch_rtt").p99 - 0.005).abs() < 1e-12);
    }

    #[test]
    fn breakdown_has_blocked_pct() {
        let tab = breakdown_table(&sample());
        assert_eq!(tab.rows.len(), 1);
        // fetch 0.004 / (0.004 + 0.010 + 0.002) = 25%
        assert_eq!(tab.rows[0].last().unwrap(), "25.0");
    }

    #[test]
    fn link_timeline_pairs_requests() {
        let tab = link_timeline_table(&sample());
        assert_eq!(tab.rows.len(), 1);
        assert_eq!(tab.rows[0][0], "0");
        assert_eq!(tab.rows[0][1], "1");
        assert_eq!(tab.rows[0][2], "1");
        assert_eq!(tab.rows[0][3], "1");
    }

    #[test]
    fn render_all_three_tables() {
        assert_eq!(render_all(&sample()).len(), 3);
    }
}
