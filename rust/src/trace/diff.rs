//! Deterministic trace comparison — the trace-level generalization of
//! `wire_parity`.
//!
//! Same-seed runs must produce **bit-identical virtual-time data** on
//! every transport.  Virtual events (see [`EventKind::is_virtual`]) are
//! projected to a canonical string — kind, virtual clock as raw IEEE
//! bits, and every seed-deterministic payload field; measured
//! `wall_secs`-style fields are dropped — and compared as **multisets
//! per (role, id) stream**.  Multisets, not sequences: response arrival
//! order (prefetcher `fetch_response`, server `fetch_serve`) is
//! scheduling-dependent even though each event's *content* is exact, and
//! a per-role `seq` would encode that arrival order.  Wall-only kinds
//! (batch/link flushes, closes, `RoleEnd`) are excluded entirely.

use std::collections::BTreeMap;

use super::{EventKind, Role, Trace, TraceEvent};

/// How many concrete examples a mismatch report carries.
const MAX_EXAMPLES: usize = 8;

fn bits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Canonical projection of one event: `None` for wall-only kinds, else a
/// string whose byte equality ⇔ virtual-field bit equality.
pub fn canonical(e: &TraceEvent) -> Option<String> {
    if !e.kind.is_virtual() {
        return None;
    }
    let payload = match e.kind {
        EventKind::MinibatchBegin { epoch, mb } => format!("epoch={epoch} mb={mb}"),
        EventKind::MinibatchEnd { epoch, mb, step_vsecs } => {
            format!("epoch={epoch} mb={mb} step_vsecs={}", bits(step_vsecs))
        }
        EventKind::FetchWait { nodes, .. } => format!("nodes={nodes}"),
        EventKind::Compute { virtual_secs, .. } => format!("virtual_secs={}", bits(virtual_secs)),
        EventKind::Replacement { admitted, evicted } => {
            format!("admitted={admitted} evicted={evicted}")
        }
        EventKind::AllreduceWait { round, .. } => format!("round={round}"),
        EventKind::FetchIssue { req_id, owner, nodes, bytes } => {
            format!("req_id={req_id} owner={owner} nodes={nodes} bytes={bytes}")
        }
        EventKind::FetchResponse { req_id, nodes, bytes } => {
            format!("req_id={req_id} nodes={nodes} bytes={bytes}")
        }
        EventKind::Evict { nodes } => format!("nodes={nodes}"),
        EventKind::FetchServe { req_id, from, nodes, bytes } => {
            format!("req_id={req_id} from={from} nodes={nodes} bytes={bytes}")
        }
        EventKind::AllreduceRound { round, vclock_max, trainers } => {
            format!("round={round} vclock_max={} trainers={trainers}", bits(vclock_max))
        }
        EventKind::CacheHit { owner, nodes } => format!("owner={owner} nodes={nodes}"),
        EventKind::CacheMiss { owner, chunks, nodes } => {
            format!("owner={owner} chunks={chunks} nodes={nodes}")
        }
        EventKind::SampleDemand { epoch, mb, targets, sampled, ref remote } => {
            // The want-set itself can be thousands of ids; project it to
            // its length + order-sensitive FNV-1a digest, which is still
            // sensitive to any single-id or single-position change.
            let mut bytes = Vec::with_capacity(remote.len() * 4);
            for n in remote {
                bytes.extend_from_slice(&n.to_le_bytes());
            }
            let digest = crate::util::fasthash::digest_bytes(&bytes);
            format!(
                "epoch={epoch} mb={mb} targets={targets} sampled={sampled} \
                 remote_len={} remote_fnv={digest:016x}",
                remote.len()
            )
        }
        EventKind::BatchFlush { .. }
        | EventKind::LinkFlush { .. }
        | EventKind::ChannelClose { .. }
        | EventKind::RoleEnd { .. } => unreachable!("wall-only kinds filtered above"),
    };
    Some(format!("{} vclock={} {payload}", e.kind.name(), bits(e.vclock)))
}

/// Outcome of a trace comparison.
#[derive(Debug, Default)]
pub struct DiffReport {
    /// (role, id) streams seen across both traces.
    pub streams: usize,
    /// Virtual events compared (max of the two sides).
    pub events: usize,
    /// Human-readable mismatch descriptions; empty ⇔ virtual-identical.
    pub mismatches: Vec<String>,
}

impl DiffReport {
    pub fn identical(&self) -> bool {
        self.mismatches.is_empty()
    }

    pub fn render(&self) -> String {
        if self.identical() {
            format!(
                "traces identical in all virtual-time fields \
                 ({} events across {} role streams)",
                self.events, self.streams
            )
        } else {
            let mut out = format!(
                "traces DIFFER ({} mismatches across {} role streams):\n",
                self.mismatches.len(),
                self.streams
            );
            for m in &self.mismatches {
                out.push_str("  ");
                out.push_str(m);
                out.push('\n');
            }
            out
        }
    }
}

type StreamKey = (u8, u32);

fn multisets(t: &Trace) -> BTreeMap<StreamKey, BTreeMap<String, u64>> {
    let mut by_stream: BTreeMap<StreamKey, BTreeMap<String, u64>> = BTreeMap::new();
    for e in &t.events {
        if let Some(c) = canonical(e) {
            *by_stream.entry((e.role.tag(), e.id)).or_default().entry(c).or_insert(0) += 1;
        }
    }
    by_stream
}

fn stream_name(k: StreamKey) -> String {
    let role = Role::from_tag(k.0).map(Role::name).unwrap_or("?");
    format!("{role}-{}", k.1)
}

/// Compare two traces over their virtual projections.  Metadata besides
/// the seed (label, transport, compute) may legitimately differ — that is
/// the point of cross-transport diffing — and is not compared.
pub fn diff(a: &Trace, b: &Trace) -> DiffReport {
    let mut report = DiffReport::default();
    if a.meta.seed != b.meta.seed {
        report
            .mismatches
            .push(format!("seed differs: {} vs {}", a.meta.seed, b.meta.seed));
    }
    let ma = multisets(a);
    let mb = multisets(b);
    let keys: std::collections::BTreeSet<StreamKey> =
        ma.keys().chain(mb.keys()).copied().collect();
    report.streams = keys.len();
    let empty = BTreeMap::new();
    for k in keys {
        let sa = ma.get(&k).unwrap_or(&empty);
        let sb = mb.get(&k).unwrap_or(&empty);
        let ca: u64 = sa.values().sum();
        let cb: u64 = sb.values().sum();
        report.events += usize::try_from(ca.max(cb)).unwrap_or(usize::MAX);
        if sa == sb {
            continue;
        }
        let who = stream_name(k);
        if ca != cb {
            report.mismatches.push(format!("{who}: {ca} vs {cb} virtual events"));
        }
        let mut examples = 0usize;
        let mut extra = 0usize;
        for (ev, &na) in sa {
            let nb = sb.get(ev).copied().unwrap_or(0);
            if na != nb {
                if examples < MAX_EXAMPLES {
                    report.mismatches.push(format!("{who}: [{ev}] ×{na} vs ×{nb}"));
                    examples += 1;
                } else {
                    extra += 1;
                }
            }
        }
        for (ev, &nb) in sb {
            if !sa.contains_key(ev) {
                if examples < MAX_EXAMPLES {
                    report.mismatches.push(format!("{who}: [{ev}] ×0 vs ×{nb}"));
                    examples += 1;
                } else {
                    extra += 1;
                }
            }
        }
        if extra > 0 {
            report.mismatches.push(format!("{who}: ... and {extra} more differing events"));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;
    use crate::trace::TraceMeta;

    fn ev(role: Role, id: u32, seq: u64, vclock: f64, kind: EventKind) -> TraceEvent {
        TraceEvent { role, id, seq, vclock, wall: seq as f64 * 0.01, kind }
    }

    fn base() -> Trace {
        Trace {
            meta: TraceMeta { seed: 7, ..TraceMeta::default() },
            events: vec![
                ev(Role::Trainer, 0, 0, 1.0, EventKind::MinibatchBegin { epoch: 0, mb: 0 }),
                ev(Role::Prefetcher, 0, 0, 0.0, EventKind::FetchIssue {
                    req_id: 1,
                    owner: 1,
                    nodes: 4,
                    bytes: 32,
                }),
                ev(Role::Prefetcher, 0, 1, 0.0, EventKind::FetchResponse {
                    req_id: 1,
                    nodes: 4,
                    bytes: 32,
                }),
                ev(Role::Prefetcher, 0, 2, 0.0, EventKind::RoleEnd { emitted: 2 }),
            ],
        }
    }

    #[test]
    fn identical_traces_diff_clean() {
        let r = diff(&base(), &base());
        assert!(r.identical(), "{}", r.render());
        assert!(r.events >= 3);
        assert!(r.render().contains("identical"));
    }

    #[test]
    fn wall_fields_ignored() {
        let mut b = base();
        for e in &mut b.events {
            e.wall += 123.0;
        }
        // Wall-only kinds may differ arbitrarily.
        b.events.push(ev(Role::Prefetcher, 0, 3, 0.0, EventKind::BatchFlush {
            owner: 0,
            frames: 9,
            bytes: 9,
        }));
        assert!(diff(&base(), &b).identical());
    }

    #[test]
    fn reordered_responses_still_identical() {
        let mut b = base();
        b.events.swap(1, 2);
        // seqs re-assigned in arrival order, as a real prefetcher would.
        b.events[1].seq = 0;
        b.events[2].seq = 1;
        assert!(diff(&base(), &b).identical());
    }

    #[test]
    fn virtual_field_change_detected() {
        let mut b = base();
        b.events[1].kind =
            EventKind::FetchIssue { req_id: 1, owner: 1, nodes: 5, bytes: 32 };
        let r = diff(&base(), &b);
        assert!(!r.identical());
        assert!(r.render().contains("prefetcher-0"), "{}", r.render());
    }

    #[test]
    fn vclock_bit_change_detected() {
        let mut b = base();
        b.events[0].vclock = f64::from_bits(b.events[0].vclock.to_bits() + 1);
        assert!(!diff(&base(), &b).identical());
    }

    #[test]
    fn missing_stream_detected() {
        let mut b = base();
        b.events.retain(|e| e.role != Role::Trainer);
        let r = diff(&base(), &b);
        assert!(!r.identical());
        assert!(r.render().contains("trainer-0"));
    }

    #[test]
    fn seed_mismatch_detected() {
        let mut b = base();
        b.meta.seed = 8;
        assert!(!diff(&base(), &b).identical());
    }
}
