//! Trace serializations: compact binary framing ⇄ JSON lines.
//!
//! Both forms carry the identical information and convert losslessly in
//! both directions (`rudder trace dump`):
//!
//! * **Binary** — `RTRC` magic, `u32` version, run metadata, `u64` event
//!   count, then one `[u32 len][payload]` frame per event (the wire-format
//!   pattern).  Floats travel as raw IEEE bits; truncated or corrupt
//!   prefixes decode to a clean error, never a panic.
//! * **JSONL** — one header object (`"format": "rudder-trace/v1"`) then
//!   one flat object per event.  Integer fields are bounded to 2^53 and
//!   floats are finite (enforced at encode), so JSON numbers — shortest
//!   round-trip decimals — reproduce every bit.

use crate::cluster::wire::{len_u32, put_u32, put_u64, Reader};
use crate::error::Result;
use crate::util::json::Json;

use super::{norm_f64, EventKind, Role, Trace, TraceEvent, TraceMeta};

/// Binary trace magic (also the sniff key in [`Trace::read_file`]),
/// resolved through the central [`crate::magic`] registry.
pub const MAGIC: &[u8] = crate::magic::TRACE;
/// Binary format version.
pub const VERSION: u32 = 1;
/// JSONL header `format` value.
pub const JSONL_FORMAT: &str = "rudder-trace/v1";

/// Sanity cap on one encoded event (a corrupt length prefix must not
/// drive a huge allocation).  Large enough for a [`EventKind::SampleDemand`]
/// want-set of ~4M node ids; still small enough to bound a bad alloc.
const MAX_EVENT_BYTES: u32 = 1 << 24;
/// Integer fields must fit in an IEEE double exactly.
const MAX_SAFE_INT: u64 = 1 << 53;

// ---------------------------------------------------------------- binary

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    put_u32(out, len_u32(s.len(), "trace string")?);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn get_str(r: &mut Reader<'_>) -> Result<String> {
    let n = r.u32()? as usize;
    crate::ensure!(n <= MAX_EVENT_BYTES as usize, "trace string length {n} too large");
    let b = r.take(n)?;
    Ok(std::str::from_utf8(b).map_err(|_| crate::err!("trace string not utf-8"))?.to_string())
}

fn encode_kind(out: &mut Vec<u8>, k: &EventKind) -> Result<()> {
    out.push(k.tag());
    match *k {
        EventKind::MinibatchBegin { epoch, mb } => {
            put_u32(out, epoch);
            put_u32(out, mb);
        }
        EventKind::MinibatchEnd { epoch, mb, step_vsecs } => {
            put_u32(out, epoch);
            put_u32(out, mb);
            put_f64(out, step_vsecs);
        }
        EventKind::FetchWait { nodes, wall_secs } => {
            put_u64(out, nodes);
            put_f64(out, wall_secs);
        }
        EventKind::Compute { virtual_secs, wall_secs } => {
            put_f64(out, virtual_secs);
            put_f64(out, wall_secs);
        }
        EventKind::Replacement { admitted, evicted } => {
            put_u64(out, admitted);
            put_u64(out, evicted);
        }
        EventKind::AllreduceWait { round, wall_secs } => {
            put_u64(out, round);
            put_f64(out, wall_secs);
        }
        EventKind::FetchIssue { req_id, owner, nodes, bytes } => {
            put_u64(out, req_id);
            put_u32(out, owner);
            put_u64(out, nodes);
            put_u64(out, bytes);
        }
        EventKind::FetchResponse { req_id, nodes, bytes } => {
            put_u64(out, req_id);
            put_u64(out, nodes);
            put_u64(out, bytes);
        }
        EventKind::Evict { nodes } => put_u64(out, nodes),
        EventKind::BatchFlush { owner, frames, bytes } => {
            put_u32(out, owner);
            put_u64(out, frames);
            put_u64(out, bytes);
        }
        EventKind::FetchServe { req_id, from, nodes, bytes } => {
            put_u64(out, req_id);
            put_u32(out, from);
            put_u64(out, nodes);
            put_u64(out, bytes);
        }
        EventKind::AllreduceRound { round, vclock_max, trainers } => {
            put_u64(out, round);
            put_f64(out, vclock_max);
            put_u32(out, trainers);
        }
        EventKind::LinkFlush { conn, frames, bytes } => {
            put_u32(out, conn);
            put_u64(out, frames);
            put_u64(out, bytes);
        }
        EventKind::ChannelClose { conn, channel } => {
            put_u32(out, conn);
            put_u32(out, channel);
        }
        EventKind::RoleEnd { emitted } => put_u64(out, emitted),
        EventKind::CacheHit { owner, nodes } => {
            put_u32(out, owner);
            put_u64(out, nodes);
        }
        EventKind::CacheMiss { owner, chunks, nodes } => {
            put_u32(out, owner);
            put_u64(out, chunks);
            put_u64(out, nodes);
        }
        EventKind::SampleDemand { epoch, mb, targets, sampled, ref remote } => {
            put_u32(out, epoch);
            put_u32(out, mb);
            put_u64(out, targets);
            put_u64(out, sampled);
            put_u32(out, len_u32(remote.len(), "sample_demand remote set")?);
            for &n in remote {
                put_u32(out, n);
            }
        }
    }
    Ok(())
}

fn decode_kind(r: &mut Reader<'_>) -> Result<EventKind> {
    let tag = r.u8()?;
    Ok(match tag {
        1 => EventKind::MinibatchBegin { epoch: r.u32()?, mb: r.u32()? },
        2 => EventKind::MinibatchEnd { epoch: r.u32()?, mb: r.u32()?, step_vsecs: r.f64()? },
        3 => EventKind::FetchWait { nodes: r.u64()?, wall_secs: r.f64()? },
        4 => EventKind::Compute { virtual_secs: r.f64()?, wall_secs: r.f64()? },
        5 => EventKind::Replacement { admitted: r.u64()?, evicted: r.u64()? },
        6 => EventKind::AllreduceWait { round: r.u64()?, wall_secs: r.f64()? },
        7 => EventKind::FetchIssue {
            req_id: r.u64()?,
            owner: r.u32()?,
            nodes: r.u64()?,
            bytes: r.u64()?,
        },
        8 => EventKind::FetchResponse { req_id: r.u64()?, nodes: r.u64()?, bytes: r.u64()? },
        9 => EventKind::Evict { nodes: r.u64()? },
        10 => EventKind::BatchFlush { owner: r.u32()?, frames: r.u64()?, bytes: r.u64()? },
        11 => EventKind::FetchServe {
            req_id: r.u64()?,
            from: r.u32()?,
            nodes: r.u64()?,
            bytes: r.u64()?,
        },
        12 => EventKind::AllreduceRound {
            round: r.u64()?,
            vclock_max: r.f64()?,
            trainers: r.u32()?,
        },
        13 => EventKind::LinkFlush { conn: r.u32()?, frames: r.u64()?, bytes: r.u64()? },
        14 => EventKind::ChannelClose { conn: r.u32()?, channel: r.u32()? },
        15 => EventKind::RoleEnd { emitted: r.u64()? },
        16 => EventKind::CacheHit { owner: r.u32()?, nodes: r.u64()? },
        17 => EventKind::CacheMiss { owner: r.u32()?, chunks: r.u64()?, nodes: r.u64()? },
        18 => {
            let epoch = r.u32()?;
            let mb = r.u32()?;
            let targets = r.u64()?;
            let sampled = r.u64()?;
            let n = r.u32()?;
            crate::ensure!(n <= MAX_EVENT_BYTES / 4, "sample_demand remote set too large ({n})");
            let mut remote = Vec::with_capacity(n as usize);
            for _ in 0..n {
                remote.push(r.u32()?);
            }
            EventKind::SampleDemand { epoch, mb, targets, sampled, remote }
        }
        t => crate::bail!("unknown trace event tag {t}"),
    })
}

/// Encode one event as a binary `[u32 len][payload]` frame.  Shared by
/// the full-trace form below and the multiproc result blobs
/// ([`crate::cluster::ipc`]).  Errors outside the trace domain.
pub(crate) fn put_event(out: &mut Vec<u8>, e: &TraceEvent) -> Result<()> {
    check_domain(e)?;
    let mut buf = Vec::with_capacity(64);
    buf.push(e.role.tag());
    put_u32(&mut buf, e.id);
    put_u64(&mut buf, e.seq);
    put_f64(&mut buf, e.vclock);
    put_f64(&mut buf, e.wall);
    encode_kind(&mut buf, &e.kind)?;
    put_u32(out, len_u32(buf.len(), "trace event")?);
    out.extend_from_slice(&buf);
    Ok(())
}

/// Decode one `[u32 len][payload]` event frame (inverse of
/// [`put_event`]).
pub(crate) fn get_event(r: &mut Reader<'_>) -> Result<TraceEvent> {
    let len = r.u32()?;
    crate::ensure!(len <= MAX_EVENT_BYTES, "trace event oversized ({len} bytes)");
    let payload = r.take(len as usize)?;
    event_from_payload(payload)
}

fn event_from_payload(payload: &[u8]) -> Result<TraceEvent> {
    let mut er = Reader::new(payload);
    let role_tag = er.u8()?;
    let role = Role::from_tag(role_tag)
        .ok_or_else(|| crate::err!("trace event: unknown role tag {role_tag}"))?;
    let ev = TraceEvent {
        role,
        id: er.u32()?,
        seq: er.u64()?,
        vclock: er.f64()?,
        wall: er.f64()?,
        kind: decode_kind(&mut er)?,
    };
    crate::ensure!(er.remaining() == 0, "trace event: {} trailing bytes", er.remaining());
    // Decode-side domain check too: corrupt payload bytes that still
    // parse structurally (e.g. a mangled counter) must not produce an
    // out-of-domain trace that the JSONL codec would then mangle.
    check_domain(&ev)?;
    Ok(ev)
}

/// Encode a full trace to the binary form.  Errors on non-finite floats
/// or integers above 2^53 (outside the declared trace domain).
pub fn encode_binary(t: &Trace) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(64 + t.events.len() * 48);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_str(&mut out, &t.meta.label)?;
    put_u64(&mut out, t.meta.seed);
    put_str(&mut out, &t.meta.transport)?;
    put_str(&mut out, &t.meta.compute)?;
    put_str(&mut out, &t.meta.config)?;
    put_u64(&mut out, t.events.len() as u64);
    for e in &t.events {
        put_event(&mut out, e)?;
    }
    Ok(out)
}

/// Decode the binary form.  Truncated or corrupt input yields an error
/// naming what broke — never a panic, never a silently partial trace.
pub fn decode_binary(bytes: &[u8]) -> Result<Trace> {
    crate::ensure!(bytes.len() >= 8, "trace blob too short for header");
    crate::ensure!(&bytes[..4] == MAGIC, "bad trace magic (want RTRC)");
    let mut r = Reader::new(&bytes[4..]);
    let version = r.u32()?;
    crate::ensure!(version == VERSION, "unsupported trace version {version} (have {VERSION})");
    let meta = TraceMeta {
        label: get_str(&mut r)?,
        seed: r.u64()?,
        transport: get_str(&mut r)?,
        compute: get_str(&mut r)?,
        config: get_str(&mut r)?,
    };
    let count = r.u64()?;
    let mut events = Vec::new();
    for i in 0..count {
        let len = r
            .u32()
            .map_err(|_| crate::err!("trace truncated at event {i}/{count} (length prefix)"))?;
        crate::ensure!(len <= MAX_EVENT_BYTES, "trace event {i} oversized ({len} bytes)");
        let payload =
            r.take(len as usize).map_err(|_| crate::err!("trace truncated at event {i}/{count}"))?;
        let ev = event_from_payload(payload).map_err(|e| crate::err!("trace event {i}: {e}"))?;
        events.push(ev);
    }
    crate::ensure!(r.remaining() == 0, "trace blob has {} trailing bytes", r.remaining());
    Ok(Trace { meta, events })
}

fn check_domain(e: &TraceEvent) -> Result<()> {
    let fin = |v: f64, what: &str| -> Result<()> {
        crate::ensure!(v.is_finite(), "non-finite {what} in trace event (seq {})", e.seq);
        Ok(())
    };
    let int = |v: u64, what: &str| -> Result<()> {
        crate::ensure!(v <= MAX_SAFE_INT, "{what} {v} exceeds 2^53 trace integer domain");
        Ok(())
    };
    fin(e.vclock, "vclock")?;
    fin(e.wall, "wall")?;
    int(e.seq, "seq")?;
    match e.kind {
        EventKind::MinibatchEnd { step_vsecs, .. } => fin(step_vsecs, "step_vsecs")?,
        EventKind::FetchWait { nodes, wall_secs } => {
            int(nodes, "nodes")?;
            fin(wall_secs, "wall_secs")?;
        }
        EventKind::Compute { virtual_secs, wall_secs } => {
            fin(virtual_secs, "virtual_secs")?;
            fin(wall_secs, "wall_secs")?;
        }
        EventKind::Replacement { admitted, evicted } => {
            int(admitted, "admitted")?;
            int(evicted, "evicted")?;
        }
        EventKind::AllreduceWait { round, wall_secs } => {
            int(round, "round")?;
            fin(wall_secs, "wall_secs")?;
        }
        EventKind::FetchIssue { req_id, nodes, bytes, .. } => {
            int(req_id, "req_id")?;
            int(nodes, "nodes")?;
            int(bytes, "bytes")?;
        }
        EventKind::FetchResponse { req_id, nodes, bytes } => {
            int(req_id, "req_id")?;
            int(nodes, "nodes")?;
            int(bytes, "bytes")?;
        }
        EventKind::Evict { nodes } => int(nodes, "nodes")?,
        EventKind::BatchFlush { frames, bytes, .. } => {
            int(frames, "frames")?;
            int(bytes, "bytes")?;
        }
        EventKind::FetchServe { req_id, nodes, bytes, .. } => {
            int(req_id, "req_id")?;
            int(nodes, "nodes")?;
            int(bytes, "bytes")?;
        }
        EventKind::AllreduceRound { round, vclock_max, .. } => {
            int(round, "round")?;
            fin(vclock_max, "vclock_max")?;
        }
        EventKind::LinkFlush { frames, bytes, .. } => {
            int(frames, "frames")?;
            int(bytes, "bytes")?;
        }
        EventKind::RoleEnd { emitted } => int(emitted, "emitted")?,
        EventKind::CacheHit { nodes, .. } => int(nodes, "nodes")?,
        EventKind::CacheMiss { chunks, nodes, .. } => {
            int(chunks, "chunks")?;
            int(nodes, "nodes")?;
        }
        EventKind::SampleDemand { targets, sampled, ref remote, .. } => {
            int(targets, "targets")?;
            int(sampled, "sampled")?;
            int(remote.len() as u64, "remote set size")?;
        }
        EventKind::MinibatchBegin { .. } | EventKind::ChannelClose { .. } => {}
    }
    Ok(())
}

// ----------------------------------------------------------------- jsonl

fn ju(v: u64) -> Json {
    Json::Num(v as f64)
}

fn jf(v: f64) -> Json {
    Json::Num(norm_f64(v))
}

fn kind_fields(k: &EventKind) -> Vec<(&'static str, Json)> {
    match *k {
        EventKind::MinibatchBegin { epoch, mb } => {
            vec![("epoch", ju(epoch as u64)), ("mb", ju(mb as u64))]
        }
        EventKind::MinibatchEnd { epoch, mb, step_vsecs } => vec![
            ("epoch", ju(epoch as u64)),
            ("mb", ju(mb as u64)),
            ("step_vsecs", jf(step_vsecs)),
        ],
        EventKind::FetchWait { nodes, wall_secs } => {
            vec![("nodes", ju(nodes)), ("wall_secs", jf(wall_secs))]
        }
        EventKind::Compute { virtual_secs, wall_secs } => {
            vec![("virtual_secs", jf(virtual_secs)), ("wall_secs", jf(wall_secs))]
        }
        EventKind::Replacement { admitted, evicted } => {
            vec![("admitted", ju(admitted)), ("evicted", ju(evicted))]
        }
        EventKind::AllreduceWait { round, wall_secs } => {
            vec![("round", ju(round)), ("wall_secs", jf(wall_secs))]
        }
        EventKind::FetchIssue { req_id, owner, nodes, bytes } => vec![
            ("req_id", ju(req_id)),
            ("owner", ju(owner as u64)),
            ("nodes", ju(nodes)),
            ("bytes", ju(bytes)),
        ],
        EventKind::FetchResponse { req_id, nodes, bytes } => {
            vec![("req_id", ju(req_id)), ("nodes", ju(nodes)), ("bytes", ju(bytes))]
        }
        EventKind::Evict { nodes } => vec![("nodes", ju(nodes))],
        EventKind::BatchFlush { owner, frames, bytes } => {
            vec![("owner", ju(owner as u64)), ("frames", ju(frames)), ("bytes", ju(bytes))]
        }
        EventKind::FetchServe { req_id, from, nodes, bytes } => vec![
            ("req_id", ju(req_id)),
            ("from", ju(from as u64)),
            ("nodes", ju(nodes)),
            ("bytes", ju(bytes)),
        ],
        EventKind::AllreduceRound { round, vclock_max, trainers } => vec![
            ("round", ju(round)),
            ("vclock_max", jf(vclock_max)),
            ("trainers", ju(trainers as u64)),
        ],
        EventKind::LinkFlush { conn, frames, bytes } => {
            vec![("conn", ju(conn as u64)), ("frames", ju(frames)), ("bytes", ju(bytes))]
        }
        EventKind::ChannelClose { conn, channel } => {
            vec![("conn", ju(conn as u64)), ("channel", ju(channel as u64))]
        }
        EventKind::RoleEnd { emitted } => vec![("emitted", ju(emitted))],
        EventKind::CacheHit { owner, nodes } => {
            vec![("owner", ju(owner as u64)), ("nodes", ju(nodes))]
        }
        EventKind::CacheMiss { owner, chunks, nodes } => {
            vec![("owner", ju(owner as u64)), ("chunks", ju(chunks)), ("nodes", ju(nodes))]
        }
        EventKind::SampleDemand { epoch, mb, targets, sampled, ref remote } => vec![
            ("epoch", ju(epoch as u64)),
            ("mb", ju(mb as u64)),
            ("targets", ju(targets)),
            ("sampled", ju(sampled)),
            ("remote", Json::Arr(remote.iter().map(|&n| ju(n as u64)).collect())),
        ],
    }
}

/// Encode to JSON lines: one header object, then one object per event.
pub fn to_jsonl(t: &Trace) -> Result<String> {
    let mut out = String::new();
    let header = Json::obj(vec![
        ("format", Json::str(JSONL_FORMAT)),
        ("label", Json::str(t.meta.label.clone())),
        ("seed", ju(t.meta.seed)),
        ("transport", Json::str(t.meta.transport.clone())),
        ("compute", Json::str(t.meta.compute.clone())),
        ("config", Json::str(t.meta.config.clone())),
        ("events", ju(t.events.len() as u64)),
    ]);
    out.push_str(&header.to_string_compact());
    out.push('\n');
    for e in &t.events {
        check_domain(e)?;
        let mut fields = vec![
            ("role", Json::str(e.role.name())),
            ("id", ju(e.id as u64)),
            ("seq", ju(e.seq)),
            ("vclock", jf(e.vclock)),
            ("wall", jf(e.wall)),
            ("kind", Json::str(e.kind.name())),
        ];
        fields.extend(kind_fields(&e.kind));
        out.push_str(&Json::obj(fields).to_string_compact());
        out.push('\n');
    }
    Ok(out)
}

// The one intentional float→int narrowing: the ensure above pins `n` to
// a non-negative integral value ≤ 2^53, so the cast is exact.
#[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)]
fn want_u64(j: &Json, key: &str) -> Result<u64> {
    let n = j
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| crate::err!("trace jsonl: missing numeric field '{key}'"))?;
    crate::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= MAX_SAFE_INT as f64,
        "trace jsonl: field '{key}' = {n} is not a trace integer"
    );
    Ok(n as u64)
}

fn want_u32(j: &Json, key: &str) -> Result<u32> {
    let v = want_u64(j, key)?;
    u32::try_from(v).map_err(|_| crate::err!("trace jsonl: field '{key}' = {v} exceeds u32"))
}

fn want_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| crate::err!("trace jsonl: missing numeric field '{key}'"))
}

fn want_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| crate::err!("trace jsonl: missing string field '{key}'"))
}

/// An array of trace integers that each fit in a `u32` (node ids).
#[allow(clippy::cast_possible_truncation, clippy::cast_sign_loss)] // ensure below pins the domain
fn want_u32_arr(j: &Json, key: &str) -> Result<Vec<u32>> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| crate::err!("trace jsonl: missing array field '{key}'"))?;
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let n = v
            .as_f64()
            .ok_or_else(|| crate::err!("trace jsonl: non-numeric entry in '{key}'"))?;
        crate::ensure!(
            n >= 0.0 && n.fract() == 0.0 && n <= f64::from(u32::MAX),
            "trace jsonl: entry {n} in '{key}' is not a u32"
        );
        out.push(n as u32);
    }
    Ok(out)
}

fn kind_from_json(name: &str, j: &Json) -> Result<EventKind> {
    Ok(match name {
        "minibatch_begin" => {
            EventKind::MinibatchBegin { epoch: want_u32(j, "epoch")?, mb: want_u32(j, "mb")? }
        }
        "minibatch_end" => EventKind::MinibatchEnd {
            epoch: want_u32(j, "epoch")?,
            mb: want_u32(j, "mb")?,
            step_vsecs: want_f64(j, "step_vsecs")?,
        },
        "fetch_wait" => EventKind::FetchWait {
            nodes: want_u64(j, "nodes")?,
            wall_secs: want_f64(j, "wall_secs")?,
        },
        "compute" => EventKind::Compute {
            virtual_secs: want_f64(j, "virtual_secs")?,
            wall_secs: want_f64(j, "wall_secs")?,
        },
        "replacement" => EventKind::Replacement {
            admitted: want_u64(j, "admitted")?,
            evicted: want_u64(j, "evicted")?,
        },
        "allreduce_wait" => EventKind::AllreduceWait {
            round: want_u64(j, "round")?,
            wall_secs: want_f64(j, "wall_secs")?,
        },
        "fetch_issue" => EventKind::FetchIssue {
            req_id: want_u64(j, "req_id")?,
            owner: want_u32(j, "owner")?,
            nodes: want_u64(j, "nodes")?,
            bytes: want_u64(j, "bytes")?,
        },
        "fetch_response" => EventKind::FetchResponse {
            req_id: want_u64(j, "req_id")?,
            nodes: want_u64(j, "nodes")?,
            bytes: want_u64(j, "bytes")?,
        },
        "evict" => EventKind::Evict { nodes: want_u64(j, "nodes")? },
        "batch_flush" => EventKind::BatchFlush {
            owner: want_u32(j, "owner")?,
            frames: want_u64(j, "frames")?,
            bytes: want_u64(j, "bytes")?,
        },
        "fetch_serve" => EventKind::FetchServe {
            req_id: want_u64(j, "req_id")?,
            from: want_u32(j, "from")?,
            nodes: want_u64(j, "nodes")?,
            bytes: want_u64(j, "bytes")?,
        },
        "allreduce_round" => EventKind::AllreduceRound {
            round: want_u64(j, "round")?,
            vclock_max: want_f64(j, "vclock_max")?,
            trainers: want_u32(j, "trainers")?,
        },
        "link_flush" => EventKind::LinkFlush {
            conn: want_u32(j, "conn")?,
            frames: want_u64(j, "frames")?,
            bytes: want_u64(j, "bytes")?,
        },
        "channel_close" => EventKind::ChannelClose {
            conn: want_u32(j, "conn")?,
            channel: want_u32(j, "channel")?,
        },
        "role_end" => EventKind::RoleEnd { emitted: want_u64(j, "emitted")? },
        "cache_hit" => {
            EventKind::CacheHit { owner: want_u32(j, "owner")?, nodes: want_u64(j, "nodes")? }
        }
        "cache_miss" => EventKind::CacheMiss {
            owner: want_u32(j, "owner")?,
            chunks: want_u64(j, "chunks")?,
            nodes: want_u64(j, "nodes")?,
        },
        "sample_demand" => EventKind::SampleDemand {
            epoch: want_u32(j, "epoch")?,
            mb: want_u32(j, "mb")?,
            targets: want_u64(j, "targets")?,
            sampled: want_u64(j, "sampled")?,
            remote: want_u32_arr(j, "remote")?,
        },
        other => crate::bail!("trace jsonl: unknown event kind '{other}'"),
    })
}

/// Parse the JSON-lines form back into a [`Trace`].
pub fn from_jsonl(text: &str) -> Result<Trace> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, head) = lines.next().ok_or_else(|| crate::err!("trace jsonl: empty input"))?;
    let h = Json::parse(head).map_err(|e| crate::err!("trace jsonl header: {e}"))?;
    let format = want_str(&h, "format")?;
    crate::ensure!(
        format == JSONL_FORMAT,
        "trace jsonl: unsupported format '{format}' (have {JSONL_FORMAT})"
    );
    let meta = TraceMeta {
        label: want_str(&h, "label")?.to_string(),
        seed: want_u64(&h, "seed")?,
        transport: want_str(&h, "transport")?.to_string(),
        compute: want_str(&h, "compute")?.to_string(),
        config: want_str(&h, "config")?.to_string(),
    };
    let declared = want_u64(&h, "events")?;
    let mut events = Vec::new();
    for (lineno, line) in lines {
        let j = Json::parse(line)
            .map_err(|e| crate::err!("trace jsonl line {}: {e}", lineno + 1))?;
        let kind_name = want_str(&j, "kind")?;
        let role_name = want_str(&j, "role")?;
        let role = Role::from_name(role_name).ok_or_else(|| {
            crate::err!("trace jsonl line {}: unknown role '{role_name}'", lineno + 1)
        })?;
        events.push(TraceEvent {
            role,
            id: want_u32(&j, "id")?,
            seq: want_u64(&j, "seq")?,
            vclock: want_f64(&j, "vclock")?,
            wall: want_f64(&j, "wall")?,
            kind: kind_from_json(kind_name, &j)?,
        });
    }
    crate::ensure!(
        events.len() as u64 == declared,
        "trace jsonl: header declares {declared} events, found {}",
        events.len()
    );
    Ok(Trace { meta, events })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;

    fn sample() -> Trace {
        let meta = TraceMeta {
            label: "demo".into(),
            seed: 7,
            transport: "channel".into(),
            compute: "emulated".into(),
            config: "seed = 7\n".into(),
        };
        let ev = |role, id, seq, vclock, kind| TraceEvent {
            role,
            id,
            seq,
            vclock,
            wall: 0.000123,
            kind,
        };
        Trace {
            meta,
            events: vec![
                ev(Role::Trainer, 0, 0, 0.5, EventKind::MinibatchBegin { epoch: 0, mb: 0 }),
                ev(Role::Trainer, 0, 1, 0.75, EventKind::FetchWait {
                    nodes: 12,
                    wall_secs: 0.001,
                }),
                ev(Role::Prefetcher, 0, 0, 0.0, EventKind::FetchIssue {
                    req_id: 1,
                    owner: 1,
                    nodes: 12,
                    bytes: 96,
                }),
                ev(Role::Hub, 0, 0, 1.25, EventKind::AllreduceRound {
                    round: 0,
                    vclock_max: 1.25,
                    trainers: 2,
                }),
                ev(Role::Trainer, 0, 2, 0.0, EventKind::RoleEnd { emitted: 2 }),
            ],
        }
    }

    #[test]
    fn binary_round_trips() {
        let t = sample();
        let bytes = encode_binary(&t).unwrap();
        let t2 = decode_binary(&bytes).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn jsonl_round_trips() {
        let t = sample();
        let text = to_jsonl(&t).unwrap();
        assert!(text.starts_with("{\"compute\":"), "header first: {text}");
        let t2 = from_jsonl(&text).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn binary_jsonl_binary_lossless() {
        let t = sample();
        let b1 = encode_binary(&t).unwrap();
        let text = to_jsonl(&decode_binary(&b1).unwrap()).unwrap();
        let b2 = encode_binary(&from_jsonl(&text).unwrap()).unwrap();
        assert_eq!(b1, b2);
    }

    #[test]
    fn truncated_binary_errors_cleanly() {
        let bytes = encode_binary(&sample()).unwrap();
        for cut in [0, 3, 4, 7, bytes.len() / 2, bytes.len() - 1] {
            let e = decode_binary(&bytes[..cut]);
            assert!(e.is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn corrupt_binary_errors_cleanly() {
        let mut bytes = encode_binary(&sample()).unwrap();
        bytes[0] = b'X'; // magic
        assert!(decode_binary(&bytes).is_err());
        let mut bytes = encode_binary(&sample()).unwrap();
        bytes[5] = 99; // version
        assert!(decode_binary(&bytes).is_err());
        let mut bytes = encode_binary(&sample()).unwrap();
        let n = bytes.len();
        bytes.truncate(n - 4);
        bytes.extend_from_slice(&[0xFF; 4]); // trailing garbage via mangled tail
        assert!(decode_binary(&bytes).is_err());
    }

    #[test]
    fn jsonl_rejects_bad_input() {
        assert!(from_jsonl("").is_err());
        assert!(from_jsonl("{\"format\":\"nope\"}").is_err());
        let t = sample();
        let text = to_jsonl(&t).unwrap();
        // Dropping an event line breaks the declared count.
        let short: Vec<&str> = text.lines().take(t.events.len()).collect();
        assert!(from_jsonl(&short.join("\n")).is_err());
    }

    #[test]
    fn non_finite_rejected_at_encode() {
        let mut t = sample();
        t.events[0].vclock = f64::NAN;
        assert!(encode_binary(&t).is_err());
        assert!(to_jsonl(&t).is_err());
    }

    #[test]
    fn oversized_int_rejected_at_encode() {
        let mut t = sample();
        t.events[0].seq = u64::MAX;
        assert!(encode_binary(&t).is_err());
    }
}
