//! `rudder` — leader entrypoint + CLI.
//!
//! See `rudder help` (or [`rudder::cli::USAGE`]) for the command surface.

use std::sync::Arc;

use rudder::cli::{Args, USAGE};
use rudder::eval::report::{fmt_count, fmt_pct, fmt_secs, Table};
use rudder::eval::{harness, pass_at_1, Quality};
use rudder::gnn::SageRunner;
use rudder::graph::datasets;
use rudder::partition::{self, Method};
use rudder::runtime::Engine;
use rudder::sampler::Sampler;
use rudder::sim::{build_cluster, run_on, trace_only, ControllerSpec, Mode, RunConfig};
use rudder::util::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "experiment" => cmd_experiment(&args),
        "trace" => cmd_trace(&args),
        "calibrate" => cmd_calibrate(&args),
        "datasets" => cmd_datasets(),
        "models" => cmd_models(),
        "partition-stats" => cmd_partition_stats(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn config_from_args(args: &Args) -> rudder::error::Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.opt("config") {
        cfg = rudder::config::load(std::path::Path::new(path))?;
    }
    rudder::config::load_calibration(&mut cfg);
    if let Some(v) = args.opt("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = args.opt_parse::<f64>("scale")? {
        cfg.scale = v;
    }
    if let Some(v) = args.opt_parse::<usize>("trainers")? {
        cfg.num_trainers = v;
    }
    if let Some(v) = args.opt_parse::<usize>("batch")? {
        cfg.batch_size = v;
    }
    if let Some(v) = args.opt_parse::<f64>("buffer")? {
        cfg.buffer_pct = v;
    }
    if let Some(v) = args.opt_parse::<usize>("epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = args.opt_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.opt("controller") {
        cfg.controller = ControllerSpec::parse(v)?;
    }
    if let Some(v) = args.opt("mode") {
        cfg.mode = Mode::parse(v)?;
    }
    if let Some(v) = args.opt("partition") {
        cfg.partition_method = Method::parse(v)?;
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> rudder::error::Result<()> {
    let cfg = config_from_args(args)?;
    println!(
        "rudder train: {} scale={} trainers={} buffer={:.0}% epochs={} controller={} mode={:?}",
        cfg.dataset,
        cfg.scale,
        cfg.num_trainers,
        cfg.buffer_pct * 100.0,
        cfg.epochs,
        cfg.controller.label(),
        cfg.mode,
    );
    let (ds, part) = build_cluster(&cfg)?;
    println!(
        "graph: {} nodes, {} edges; partition cut={}",
        ds.csr.num_nodes(),
        ds.csr.num_arcs() / 2,
        part.edge_cut(&ds.csr)
    );
    let offline = if matches!(cfg.controller, ControllerSpec::Classifier { .. }) {
        println!("collecting offline classifier traces...");
        Some(harness::offline_training_set(Quality::Quick))
    } else {
        None
    };
    let r = run_on(&ds, &part, &cfg, offline.as_ref());
    let p = pass_at_1(&r.per_trainer);
    let mut t = Table::new("run summary", &["metric", "value"]);
    t.row(vec!["variant".into(), r.label.clone()]);
    t.row(vec!["mean epoch time".into(), fmt_secs(r.mean_epoch_time)]);
    t.row(vec!["steady %-hits".into(), fmt_pct(r.steady_hits_pct)]);
    t.row(vec!["total comm (nodes)".into(), fmt_count(r.total_comm_nodes)]);
    t.row(vec!["total comm (bytes)".into(), fmt_count(r.total_comm_bytes)]);
    t.row(vec!["p99 comm/mb (nodes)".into(), format!("{:.0}", r.p99_comm_nodes)]);
    t.row(vec!["replacement interval r".into(), format!("{:.1}", r.replacement_interval)]);
    t.row(vec![
        "valid responses".into(),
        format!("{:.0}%", r.valid_response_pct),
    ]);
    if p.trials > 0 {
        t.row(vec!["Pass@1 %-hits".into(), p.format()]);
    }
    t.emit("train_summary");
    if args.flag("debug-decisions") {
        for d in &r.per_trainer[0].decisions {
            println!(
                "mb={:<4} act={:<7} pred={:?} hits {:.1} -> {:?} lat={:.2}",
                d.minibatch,
                if d.replace { "replace" } else { "skip" },
                d.prediction,
                d.hits_before,
                d.hits_after,
                d.latency
            );
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> rudder::error::Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let q = if args.flag("full") { Quality::Full } else { Quality::Quick };
    let ids: Vec<&str> = if id == "all" {
        harness::EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        println!("\n### experiment {id} ({q:?}) ###");
        let t0 = std::time::Instant::now();
        for table in harness::run_experiment_id(id, q)? {
            table.emit(&format!("{id}_{}", sanitize(&table.title)));
        }
        println!("[{id} done in {:.1}s]", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .take(48)
        .collect()
}

fn cmd_trace(args: &Args) -> rudder::error::Result<()> {
    let cfg = config_from_args(args)?;
    let (ds, part) = build_cluster(&cfg)?;
    let set = trace_only(&ds, &part, &cfg);
    println!(
        "trace-only: {} labelled examples, positive rate {:.2}, collection cost {:.1}s",
        set.len(),
        set.positive_rate(),
        set.collection_cost
    );
    if let Some(out) = args.opt("out") {
        let examples: Vec<Json> = set
            .xs
            .iter()
            .zip(&set.ys)
            .map(|(x, &y)| {
                Json::obj(vec![
                    (
                        "x",
                        Json::Arr(x.iter().map(|&v| Json::num(v as f64)).collect()),
                    ),
                    ("y", Json::Bool(y)),
                ])
            })
            .collect();
        std::fs::write(out, Json::Arr(examples).to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_calibrate(_args: &Args) -> rudder::error::Result<()> {
    let Some(engine) = Engine::try_load_default() else {
        rudder::bail!(
            "requested artifacts are unusable — fix or remove ./artifacts (or \
             $RUDDER_ARTIFACTS), or rebuild them with `python -m compile.aot`"
        );
    };
    let engine = Arc::new(engine);
    println!("platform: {}", engine.platform());
    // Measure the real sage_train_step on a synthetic minibatch.
    let cfg = RunConfig { scale: 0.05, ..Default::default() };
    let (ds, part) = build_cluster(&cfg)?;
    let c = engine.manifest.config.clone();
    let sampler = Sampler::new(0, c.batch, c.fanout1, c.fanout2, 1);
    let train = part.train_nodes_of(0, &ds.train_nodes);
    let order = sampler.epoch_order(&train, 0);
    let mut runner = SageRunner::new(engine.clone(), 7, 0.05);
    let mut times = Vec::new();
    for mb in 0..5 {
        let b = sampler.sample(&ds.csr, &part, &order, 0, mb % 2);
        if b.targets.is_empty() {
            break;
        }
        let (loss, dt) = runner.train_step(&b, ds.feature_seed, &ds.labels)?;
        println!("  step {mb}: loss={loss:.4} dt={}", fmt_secs(dt));
        if mb > 0 {
            times.push(dt); // skip compile-inclusive first step
        }
    }
    let mean = rudder::util::stats::mean(&times);
    // Scale measured (artifact batch) step to the simulation batch.
    let body = format!(
        "# written by `rudder calibrate` — measured on {}\n[compute]\nbase_overhead = {:.6}\n",
        engine.platform(),
        mean,
    );
    std::fs::create_dir_all("configs")?;
    std::fs::write("configs/calibration.toml", &body)?;
    println!("wrote configs/calibration.toml (mean step {})", fmt_secs(mean));
    Ok(())
}

fn cmd_datasets() -> rudder::error::Result<()> {
    let mut t = Table::new(
        "datasets (Table 1a stand-ins)",
        &["name", "paper_size", "standin_nodes", "standin_edges", "feat_dim", "classes", "unseen"],
    );
    for d in datasets::ALL {
        t.row(vec![
            d.name.to_string(),
            format!("{} / {}", d.paper_nodes, d.paper_edges),
            d.num_nodes.to_string(),
            d.num_edges.to_string(),
            d.feat_dim.to_string(),
            d.num_classes.to_string(),
            if d.unseen { "yes".into() } else { "".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_models() -> rudder::error::Result<()> {
    for table in harness::fig06(Quality::Quick) {
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_partition_stats(args: &Args) -> rudder::error::Result<()> {
    let cfg = config_from_args(args)?;
    let method = args
        .opt("method")
        .map(Method::parse)
        .transpose()?
        .unwrap_or(cfg.partition_method);
    let ds = rudder::graph::Dataset::build_by_name(&cfg.dataset, cfg.scale, cfg.seed)?;
    let mut t = Table::new(
        &format!("partition quality — {} (scale {})", cfg.dataset, cfg.scale),
        &["method", "parts", "cut%", "imbalance", "mean_halo", "remote_ratio"],
    );
    for m in [method, Method::Ldg, Method::Random] {
        let part = partition::partition(&ds.csr, cfg.num_trainers, m, cfg.seed);
        let s = partition::stats::compute(&ds.csr, &part);
        t.row(vec![
            format!("{m:?}"),
            s.num_parts.to_string(),
            format!("{:.1}", s.cut_fraction * 100.0),
            format!("{:.3}", s.imbalance),
            format!("{:.0}", s.mean_halo),
            format!("{:.2}", s.mean_remote_ratio),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
