//! `rudder` — leader entrypoint + CLI.
//!
//! See `rudder help` (or [`rudder::cli::USAGE`]) for the command surface.

use std::path::PathBuf;
use std::sync::Arc;

use rudder::cli::{Args, USAGE};
use rudder::cluster::multiproc::{
    run_hub_worker, run_server_worker, run_trainer_worker, HubWorkerOpts, ServerWorkerOpts,
    TrainerWorkerOpts,
};
use rudder::cluster::{
    parity_check, run_cluster_multiproc, run_cluster_on, wire_parity, ClusterConfig,
    ClusterResult, ComputeMode, FaultSpec, Transport,
};
use rudder::eval::report::{
    fmt_count, fmt_pct, fmt_secs, link_table, measured_table, wire_table, Table,
};
use rudder::eval::{harness, pass_at_1, Quality};
use rudder::gnn::SageRunner;
use rudder::graph::datasets;
use rudder::partition::{self, Method};
use rudder::replay;
use rudder::runtime::Engine;
use rudder::sampler::Sampler;
use rudder::sim::{build_cluster, run_on, trace_only, ControllerSpec, Mode, RunConfig};
use rudder::trace::Trace;
use rudder::util::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "audit" => cmd_audit(&args),
        "train" => cmd_train(&args),
        "cluster" => cmd_cluster(&args),
        "bench" => cmd_bench(&args),
        "experiment" => cmd_experiment(&args),
        "trace" => cmd_trace(&args),
        "replay" => cmd_replay(&args),
        "calibrate" => cmd_calibrate(&args),
        "datasets" => cmd_datasets(),
        "models" => cmd_models(),
        "partition-stats" => cmd_partition_stats(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// `rudder audit`: self-hosted static analysis (see [`rudder::audit`]).
/// Exits nonzero (via the `Err` return) on any finding.
fn cmd_audit(args: &Args) -> rudder::error::Result<()> {
    use rudder::audit;
    if args.flag("list-rules") {
        for r in audit::RULES {
            println!("{:<28} {}", r.name, r.description);
        }
        return Ok(());
    }
    let all = audit::rule_names();
    let mut enabled: std::collections::BTreeSet<&str> = all.iter().copied().collect();
    let pick = |csv: &str| -> rudder::error::Result<Vec<String>> {
        let names: Vec<String> = csv.split(',').map(|s| s.trim().to_string()).collect();
        for n in &names {
            rudder::ensure!(
                all.contains(&n.as_str()),
                "unknown audit rule '{n}' (see rudder audit --list-rules)"
            );
        }
        Ok(names)
    };
    if let Some(csv) = args.opt("rules") {
        let keep = pick(csv)?;
        enabled.retain(|r| keep.iter().any(|k| k == r));
    }
    if let Some(csv) = args.opt("skip-rules") {
        for n in pick(csv)? {
            enabled.retain(|r| *r != n);
        }
    }
    let root = audit::default_root(args.opt("root"))?;
    let report = audit::run_tree(&root, &enabled)?;
    print!("{}", report.render());
    rudder::ensure!(
        report.findings.is_empty(),
        "audit failed: {} finding(s)",
        report.findings.len()
    );
    Ok(())
}

fn config_from_args(args: &Args) -> rudder::error::Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.opt("config") {
        cfg = rudder::config::load(std::path::Path::new(path))?;
    }
    rudder::config::load_calibration(&mut cfg);
    if let Some(v) = args.opt("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = args.opt_parse::<f64>("scale")? {
        cfg.scale = v;
    }
    if let Some(v) = args.opt_parse::<usize>("trainers")? {
        cfg.num_trainers = v;
    }
    if let Some(v) = args.opt_parse::<usize>("batch")? {
        cfg.batch_size = v;
    }
    if let Some(v) = args.opt_parse::<f64>("buffer")? {
        cfg.buffer_pct = v;
    }
    if let Some(v) = args.opt_parse::<usize>("epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = args.opt_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.opt("controller") {
        cfg.controller = ControllerSpec::parse(v)?;
    }
    if let Some(v) = args.opt("mode") {
        cfg.mode = Mode::parse(v)?;
    }
    if let Some(v) = args.opt_parse::<usize>("chunk-rows")? {
        rudder::ensure!(v >= 1, "--chunk-rows must be >= 1");
        cfg.chunk_rows = v;
    }
    if let Some(v) = args.opt_parse::<u64>("chunk-cache")? {
        cfg.chunk_cache_bytes = v;
    }
    if let Some(v) = args.opt("partition") {
        cfg.partition_method = Method::parse(v)?;
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> rudder::error::Result<()> {
    let cfg = config_from_args(args)?;
    println!(
        "rudder train: {} scale={} trainers={} buffer={:.0}% epochs={} controller={} mode={:?}",
        cfg.dataset,
        cfg.scale,
        cfg.num_trainers,
        cfg.buffer_pct * 100.0,
        cfg.epochs,
        cfg.controller.label(),
        cfg.mode,
    );
    let (ds, part) = build_cluster(&cfg)?;
    println!(
        "graph: {} nodes, {} edges; partition cut={}",
        ds.csr.num_nodes(),
        ds.csr.num_arcs() / 2,
        part.edge_cut(&ds.csr)
    );
    let offline = if matches!(cfg.controller, ControllerSpec::Classifier { .. }) {
        println!("collecting offline classifier traces...");
        Some(harness::offline_training_set(Quality::Quick))
    } else {
        None
    };
    let r = run_on(&ds, &part, &cfg, offline.as_ref());
    let p = pass_at_1(&r.per_trainer);
    let mut t = Table::new("run summary", &["metric", "value"]);
    t.row(vec!["variant".into(), r.label.clone()]);
    t.row(vec!["mean epoch time".into(), fmt_secs(r.mean_epoch_time)]);
    t.row(vec!["steady %-hits".into(), fmt_pct(r.steady_hits_pct)]);
    t.row(vec!["total comm (nodes)".into(), fmt_count(r.total_comm_nodes)]);
    t.row(vec!["total comm (bytes)".into(), fmt_count(r.total_comm_bytes)]);
    t.row(vec!["p99 comm/mb (nodes)".into(), format!("{:.0}", r.p99_comm_nodes)]);
    t.row(vec!["replacement interval r".into(), format!("{:.1}", r.replacement_interval)]);
    t.row(vec![
        "valid responses".into(),
        format!("{:.0}%", r.valid_response_pct),
    ]);
    if p.trials > 0 {
        t.row(vec!["Pass@1 %-hits".into(), p.format()]);
    }
    t.emit("train_summary");
    if args.flag("debug-decisions") {
        for d in &r.per_trainer[0].decisions {
            println!(
                "mb={:<4} act={:<7} pred={:?} hits {:.1} -> {:?} lat={:.2}",
                d.minibatch,
                if d.replace { "replace" } else { "skip" },
                d.prediction,
                d.hits_before,
                d.hits_after,
                d.latency
            );
        }
    }
    Ok(())
}

/// `--role` sub-invocations: this process *is* one worker of a
/// multi-process cluster (spawned by the orchestrator, or by hand for
/// debugging).
fn cmd_cluster_worker(role: &str, args: &Args) -> rudder::error::Result<()> {
    let time_scale = args.opt_parse::<f64>("time-scale")?.unwrap_or(0.0);
    // Results go back over the orchestrator's results link (`--results`)
    // or, for manual runs, into a blob file (`--out`).
    let results = args.opt("results").map(str::to_string);
    let out = args.opt("out").map(PathBuf::from);
    if results.is_none() && out.is_none() {
        rudder::bail!("--results <addr> or --out <file> required with --role");
    }
    // Workers normally pull the run config over the control link
    // (`--results` address, Hello → Config); `--run-config <file>` is the
    // manual-debugging override.
    let config = args.opt("run-config").map(PathBuf::from);
    let part = || -> rudder::error::Result<usize> {
        args.opt_parse::<usize>("part")?
            .ok_or_else(|| rudder::err!("--part <n> required with --role {role}"))
    };
    let fault = args.opt_parse::<FaultSpec>("fault")?;
    // The shim lives on the server→trainer reply links, so only server
    // workers take it; rejecting it elsewhere beats silently ignoring it.
    if role != "server" && fault.is_some() {
        rudder::bail!("--fault applies to server workers only, not --role {role}");
    }
    match role {
        "server" => run_server_worker(&ServerWorkerOpts {
            part: part()?,
            listen: args.opt_or("listen", "127.0.0.1:0"),
            config,
            time_scale,
            fault,
            results,
            out,
            trace: args.flag("record-trace"),
        }),
        "hub" => run_hub_worker(&HubWorkerOpts {
            listen: args.opt_or("listen", "127.0.0.1:0"),
            trainers: args
                .opt_parse::<usize>("trainers")?
                .ok_or_else(|| rudder::err!("--trainers <n> required with --role hub"))?,
            round_sleep: args.opt_parse::<f64>("round-sleep")?.unwrap_or(0.0),
            results,
            out,
            trace: args.flag("record-trace"),
        }),
        "trainer" => run_trainer_worker(&TrainerWorkerOpts {
            part: part()?,
            config,
            servers: args
                .opt("servers")
                .or_else(|| args.opt("connect"))
                .ok_or_else(|| {
                    rudder::err!("--servers/--connect <a1,a2,...> required with --role trainer")
                })?
                .split(',')
                .map(str::to_string)
                .collect(),
            hub: args
                .opt("hub")
                .ok_or_else(|| rudder::err!("--hub <addr> required with --role trainer"))?
                .to_string(),
            compute: worker_compute_mode(args, time_scale)?,
            results,
            out,
            trace: args.flag("record-trace"),
        }),
        other => rudder::bail!("unknown --role '{other}' (trainer|server|hub)"),
    }
}

/// Resolve a worker/orchestrator `--compute` flag plus `--time-scale`
/// into a [`ComputeMode`]: measured ignores the time scale (real compute
/// replaces every sleep), emulated carries it.
fn worker_compute_mode(args: &Args, time_scale: f64) -> rudder::error::Result<ComputeMode> {
    match args.opt_parse::<ComputeMode>("compute")?.unwrap_or(ComputeMode::Emulated(0.0)) {
        ComputeMode::Measured => Ok(ComputeMode::Measured),
        ComputeMode::Emulated(_) => Ok(ComputeMode::Emulated(time_scale)),
    }
}

fn cmd_cluster(args: &Args) -> rudder::error::Result<()> {
    if let Some(role) = args.opt("role") {
        let role = role.to_string();
        return cmd_cluster_worker(&role, args);
    }
    let cfg = config_from_args(args)?;
    let time_scale = args.opt_parse::<f64>("time-scale")?.unwrap_or(0.02);
    let compute = worker_compute_mode(args, time_scale)?;
    let transport = args.opt_parse::<Transport>("transport")?.unwrap_or_default();
    let fault = args.opt_parse::<FaultSpec>("fault")?;
    // `--trace <file>` turns the flight recorder on in every role and
    // writes the merged trace after the run (`.jsonl` = JSON lines,
    // anything else = RTRC binary framing).
    let trace_out = args.opt("trace").map(PathBuf::from);
    let ccfg = ClusterConfig {
        run: cfg.clone(),
        compute,
        transport,
        fault,
        trace: trace_out.is_some(),
    };
    println!(
        "rudder cluster: {} scale={} trainers={} buffer={:.0}% epochs={} controller={} mode={:?} transport={} compute={} time-scale={}",
        cfg.dataset,
        cfg.scale,
        cfg.num_trainers,
        cfg.buffer_pct * 100.0,
        cfg.epochs,
        cfg.controller.label(),
        cfg.mode,
        transport.name(),
        compute.name(),
        compute.time_scale(),
    );
    let (ds, part) = build_cluster(&cfg)?;
    println!(
        "graph: {} nodes, {} edges; partition cut={}",
        ds.csr.num_nodes(),
        ds.csr.num_arcs() / 2,
        part.edge_cut(&ds.csr)
    );
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    // Classifier controllers need offline training data, exactly as in
    // `cmd_train` — for any in-process (channel/event) run and for the
    // parity sim.  A pure TCP run computes nothing here: each trainer
    // worker process re-derives the identical set from the seeds.
    let offline = if matches!(cfg.controller, ControllerSpec::Classifier { .. })
        && (transport != Transport::Tcp || args.flag("parity"))
    {
        println!("collecting offline classifier traces...");
        Some(harness::offline_training_set(Quality::Quick))
    } else {
        None
    };
    // Channel/event = threads in this process; TCP = one process per role.
    let run_variant = |c: &ClusterConfig| -> rudder::error::Result<ClusterResult> {
        match c.transport {
            Transport::Channel | Transport::Event => {
                run_cluster_on(ds.clone(), part.clone(), c, offline.clone())
            }
            Transport::Tcp => run_cluster_multiproc(ds.clone(), part.clone(), c),
        }
    };
    let r = run_variant(&ccfg)?;
    let e = &r.experiment;
    let wire = r.wire_total();
    let fetch_wait: f64 = r.walls.iter().map(|w| w.fetch_wait).sum();
    let compute_wall: f64 = r.walls.iter().map(|w| w.compute).sum();
    let mut t = Table::new("cluster run summary", &["metric", "value"]);
    t.row(vec!["variant".into(), e.label.clone()]);
    t.row(vec!["wall-clock total".into(), fmt_secs(r.wall_total)]);
    t.row(vec!["wall-clock / epoch".into(), fmt_secs(r.mean_epoch_wall())]);
    t.row(vec!["virtual epoch time".into(), fmt_secs(e.mean_epoch_time)]);
    t.row(vec!["steady %-hits".into(), fmt_pct(e.steady_hits_pct)]);
    t.row(vec!["fetched nodes (logical)".into(), fmt_count(e.total_comm_nodes)]);
    t.row(vec!["payload bytes (logical)".into(), fmt_count(e.total_comm_bytes)]);
    t.row(vec![
        "wire bytes req/resp".into(),
        format!("{}/{}", fmt_count(wire.req_bytes), fmt_count(wire.resp_bytes)),
    ]);
    t.row(vec!["wire nodes requested".into(), fmt_count(wire.nodes_requested)]);
    t.row(vec!["wire nodes deduped".into(), fmt_count(wire.nodes_deduped)]);
    t.row(vec![
        "RPC frames req/resp".into(),
        format!("{}/{}", wire.req_frames, wire.resp_frames),
    ]);
    if wire.bad_frames > 0 {
        t.row(vec!["wire BAD frames".into(), fmt_count(wire.bad_frames)]);
    }
    t.row(vec!["allreduce rounds".into(), fmt_count(r.allreduce_rounds)]);
    t.row(vec![
        "Σ fetch-wait / Σ compute".into(),
        format!("{} / {}", fmt_secs(fetch_wait), fmt_secs(compute_wall)),
    ]);
    t.emit("cluster_summary");
    wire_table(&r.wire).emit("cluster_wire");
    link_table(&r.wire).emit("cluster_links");
    if compute.is_measured() {
        measured_table(&r.measured).emit("cluster_measured");
        check_replicas_synced(&r)?;
    }
    if let Some(path) = &trace_out {
        let trace = r
            .trace
            .as_ref()
            .ok_or_else(|| rudder::err!("--trace was set but the run returned no trace"))?;
        trace.verify_complete()?;
        let streams: std::collections::BTreeSet<_> =
            trace.events.iter().map(|e| (e.role.tag(), e.id)).collect();
        trace.write_file(path)?;
        println!(
            "trace: {} events across {} role streams -> {}",
            trace.events.len(),
            streams.len(),
            path.display()
        );
    }

    if args.flag("parity") {
        println!("parity: re-running the virtual-time sim with the same config + seed...");
        let sim_r = run_on(ds.as_ref(), part.as_ref(), &cfg, offline.as_ref());
        match parity_check(&sim_r, &r.experiment) {
            Ok(()) => println!(
                "parity OK: fetched-node / buffer-hit / payload-byte counters identical \
                 across {} trainers",
                cfg.num_trainers
            ),
            Err(diff) => rudder::bail!("traffic parity FAILED: {diff}"),
        }
        if transport != Transport::Channel {
            // The multi-process TCP run / event-loop run must also match
            // the in-process channel transport frame-for-frame and
            // byte-for-byte.
            println!("parity: re-running on the in-process channel transport...");
            let chan = ClusterConfig { transport: Transport::Channel, ..ccfg.clone() };
            let r_chan = run_cluster_on(ds.clone(), part.clone(), &chan, offline.clone())?;
            parity_check(&r_chan.experiment, &r.experiment)
                .map_err(|d| rudder::err!("cross-transport traffic parity FAILED: {d}"))?;
            wire_parity(&r_chan.wire, &r.wire)
                .map_err(|d| rudder::err!("cross-transport wire parity FAILED: {d}"))?;
            println!(
                "cross-transport parity OK: wire frame/byte counters identical \
                 (channel threads vs {})",
                match transport {
                    Transport::Tcp =>
                        format!("{} TCP processes", cfg.num_trainers + cfg.num_trainers + 1),
                    _ => "the event-loop transport".to_string(),
                }
            );
        }
    }

    if args.flag("compare-prefetch") {
        let mut off = ccfg.clone();
        off.run.controller = ControllerSpec::NoPrefetch;
        println!("compare: re-running with prefetching disabled (DistDGL baseline)...");
        let r_off = run_variant(&off)?;
        let on_fetch_wait: f64 = r.walls.iter().map(|w| w.fetch_wait).sum();
        let off_fetch_wait: f64 = r_off.walls.iter().map(|w| w.fetch_wait).sum();
        let mut t = Table::new(
            "prefetch on vs off (wall-clock)",
            &["variant", "wall total", "wall/epoch", "fetch-wait", "fetched nodes"],
        );
        t.row(vec![
            e.label.clone(),
            fmt_secs(r.wall_total),
            fmt_secs(r.mean_epoch_wall()),
            fmt_secs(on_fetch_wait),
            fmt_count(e.total_comm_nodes),
        ]);
        t.row(vec![
            r_off.experiment.label.clone(),
            fmt_secs(r_off.wall_total),
            fmt_secs(r_off.mean_epoch_wall()),
            fmt_secs(off_fetch_wait),
            fmt_count(r_off.experiment.total_comm_nodes),
        ]);
        t.emit("cluster_prefetch_compare");
        if r.wall_total > 0.0 {
            println!("prefetch speedup: {:.2}x (wall-clock)", r_off.wall_total / r.wall_total);
        }
        // With emulated costs the win is structural: the baseline blocks on
        // every remote feature every minibatch, the prefetching run only on
        // its misses.  Gate on the *blocking* component (fetch-wait), which
        // isolates the overlap effect from the compute sleeps and scheduler
        // jitter that dominate total wall on loaded CI machines; totals are
        // reported above.  Without emulation (--time-scale 0), and in
        // measured mode (where `rudder bench` owns the tolerance-gated
        // comparison), only report.
        if compute.time_scale() > 0.0
            && cfg.controller != ControllerSpec::NoPrefetch
            && on_fetch_wait >= off_fetch_wait
        {
            rudder::bail!(
                "prefetching did not reduce fetch blocking ({} vs {} for the no-prefetch \
                 baseline): prefetch/compute overlap regressed",
                fmt_secs(on_fetch_wait),
                fmt_secs(off_fetch_wait)
            );
        }
        if r.wall_total >= r_off.wall_total {
            println!(
                "note: total wall-clock did not improve this run (margin below noise at \
                 time-scale {}); fetch-wait above is the reliable overlap signal",
                compute.time_scale()
            );
        }
    }
    Ok(())
}

/// Measured-mode invariant: after the final allreduce every replica's
/// parameters must be bit-identical (the hub reduces in trainer-id order,
/// trainers apply the same mean delta to the same snapshot).
fn check_replicas_synced(r: &ClusterResult) -> rudder::error::Result<()> {
    let hashes: Vec<u64> = r.measured.iter().map(|m| m.param_hash).collect();
    if let Some(&first) = hashes.first() {
        rudder::ensure!(
            hashes.iter().all(|&h| h == first),
            "measured replicas diverged after DDP: param hashes {hashes:?}"
        );
    }
    Ok(())
}

/// `rudder bench` scale matrix: protocol-bound cluster runs (emulated
/// compute with no sleeps, so wall time is pure transport + protocol cost)
/// across trainer counts × buffer sizes × the in-process stream
/// transports (threaded `tcp` vs the readiness-polled `event` loop).
/// Each point reports best-of-rep wall time and wire throughput; the
/// `event_over_tcp` ratios show how the single event-loop thread scales
/// against one-pump-thread-per-link as the link count grows.
fn bench_scale_matrix(base_seed: u64) -> rudder::error::Result<Json> {
    const TRAINERS: [usize; 3] = [2, 4, 8];
    const BUFFERS: [f64; 2] = [0.15, 0.3];
    const REPS: usize = 3;
    let mut points: Vec<Json> = Vec::new();
    let mut ratios: Vec<Json> = Vec::new();
    for &n in &TRAINERS {
        for &buf in &BUFFERS {
            let cfg = RunConfig {
                dataset: "ogbn-arxiv".into(),
                scale: 0.05,
                seed: base_seed,
                num_trainers: n,
                batch_size: 32,
                fanout1: 5,
                fanout2: 5,
                buffer_pct: buf,
                epochs: 1,
                controller: ControllerSpec::parse("massivegnn:8")?,
                ..RunConfig::default()
            };
            let (ds, part) = build_cluster(&cfg)?;
            let ds = Arc::new(ds);
            let part = Arc::new(part);
            let mut tput = [0.0f64; 2];
            for (i, transport) in [Transport::Tcp, Transport::Event].into_iter().enumerate() {
                let ccfg = ClusterConfig {
                    run: cfg.clone(),
                    compute: ComputeMode::Emulated(0.0),
                    transport,
                    fault: None,
                    trace: false,
                };
                let mut best_wall = f64::INFINITY;
                let mut wire_bytes = 0u64;
                let mut rtt = rudder::util::stats::LogHistogram::new();
                for _ in 0..REPS {
                    let r = run_cluster_on(ds.clone(), part.clone(), &ccfg, None)?;
                    let w = r.wire_total();
                    wire_bytes = w.req_bytes + w.resp_bytes;
                    rtt.merge(&w.fetch_latency_total());
                    best_wall = best_wall.min(r.wall_total);
                }
                tput[i] = if best_wall > 0.0 { wire_bytes as f64 / best_wall } else { 0.0 };
                println!(
                    "bench matrix: trainers={n} buffer={buf} transport={} wall={:.3}s \
                     throughput={:.1} MB/s",
                    transport.name(),
                    best_wall,
                    tput[i] / 1e6,
                );
                points.push(Json::obj(vec![
                    ("trainers", Json::num(n as f64)),
                    ("partitions", Json::num(n as f64)),
                    ("buffer_pct", Json::num(buf)),
                    ("transport", Json::str(transport.name())),
                    ("wall_best_s", Json::num(best_wall)),
                    ("wire_bytes", Json::num(wire_bytes as f64)),
                    ("throughput_bytes_per_s", Json::num(tput[i])),
                    ("fetch_rtt_p50_s", Json::num(rtt.p50())),
                    ("fetch_rtt_p99_s", Json::num(rtt.p99())),
                ]));
            }
            ratios.push(Json::obj(vec![
                ("trainers", Json::num(n as f64)),
                ("buffer_pct", Json::num(buf)),
                (
                    "event_over_tcp",
                    Json::num(if tput[0] > 0.0 { tput[1] / tput[0] } else { 0.0 }),
                ),
            ]));
        }
    }
    Ok(Json::obj(vec![
        ("schema", Json::str("rudder-bench-scale/v1")),
        ("compute", Json::str("emulated")),
        ("time_scale", Json::num(0.0)),
        ("epochs", Json::num(1.0)),
        ("reps", Json::num(REPS as f64)),
        ("points", Json::Arr(points)),
        ("event_over_tcp", Json::Arr(ratios)),
    ]))
}

/// `rudder bench` — the pinned measured-compute cluster benchmark.
///
/// Runs the prefetching cluster and the no-prefetch baseline with real
/// SageRunner compute in every trainer, then writes a schema-stable,
/// machine-readable `BENCH_cluster.json`: wall/epoch times, fetch-blocked
/// time, bytes on the wire, the prefetch-vs-baseline ratios CI gates
/// on (`--min-speedup`, `--max-blocked-ratio`; ratios, not absolute
/// seconds, so the gate tolerates slow shared runners), and a transport
/// scale matrix ([`bench_scale_matrix`]; `--skip-scale-matrix` to omit).
fn cmd_bench(args: &Args) -> rudder::error::Result<()> {
    // Pinned configuration: small enough for CI, real compute throughout.
    // Only seed/scale/epochs are overridable (local experiments); the CI
    // artifact stays comparable run to run.
    let cfg = RunConfig {
        dataset: "ogbn-arxiv".into(),
        scale: args.opt_parse::<f64>("scale")?.unwrap_or(0.15),
        seed: args.opt_parse::<u64>("seed")?.unwrap_or(7),
        num_trainers: 2,
        batch_size: 32,
        fanout1: 5,
        fanout2: 5,
        buffer_pct: 0.25,
        epochs: args.opt_parse::<usize>("epochs")?.unwrap_or(2),
        controller: ControllerSpec::parse("massivegnn:8")?,
        ..RunConfig::default()
    };
    let out_path = args.opt_or("out", "BENCH_cluster.json");
    let min_speedup = args.opt_parse::<f64>("min-speedup")?.unwrap_or(0.0);
    let max_blocked_ratio = args.opt_parse::<f64>("max-blocked-ratio")?.unwrap_or(f64::INFINITY);
    // `--trace-dir <dir>` records a flight-recorder trace of both variants
    // and writes `<dir>/prefetch.trace` + `<dir>/baseline.trace` (binary;
    // `rudder trace dump` converts to JSONL).
    let trace_dir = args.opt("trace-dir").map(PathBuf::from);
    println!(
        "rudder bench: measured-compute cluster, {} scale={} trainers={} epochs={} controller={}",
        cfg.dataset,
        cfg.scale,
        cfg.num_trainers,
        cfg.epochs,
        cfg.controller.label(),
    );
    let (ds, part) = build_cluster(&cfg)?;
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let ccfg = ClusterConfig {
        run: cfg.clone(),
        compute: ComputeMode::Measured,
        transport: Transport::Channel,
        fault: None,
        trace: trace_dir.is_some(),
    };
    let on = run_cluster_on(ds.clone(), part.clone(), &ccfg, None)?;
    check_replicas_synced(&on)?;
    println!("bench: re-running with prefetching disabled (baseline)...");
    let mut off_ccfg = ccfg.clone();
    off_ccfg.run.controller = ControllerSpec::NoPrefetch;
    let off = run_cluster_on(ds.clone(), part.clone(), &off_ccfg, None)?;
    check_replicas_synced(&off)?;
    // Third leg: the prefetching run again, with the content-addressed
    // chunk cache enabled (pinned geometry: 32-row chunks, a 16 MiB
    // per-link budget — generous enough that a partition's hot set never
    // evicts at bench scale).  The v4 artifact carries the cached vs
    // uncached wire-byte delta, and the gate below requires the cache to
    // strictly reduce response traffic.
    const BENCH_CHUNK_ROWS: usize = 32;
    const BENCH_CACHE_BYTES: u64 = 16 * 1024 * 1024;
    println!("bench: re-running prefetch with the chunk cache enabled...");
    let mut cached_ccfg = ccfg.clone();
    cached_ccfg.run.chunk_rows = BENCH_CHUNK_ROWS;
    cached_ccfg.run.chunk_cache_bytes = BENCH_CACHE_BYTES;
    cached_ccfg.trace = false;
    let cached = run_cluster_on(ds, part, &cached_ccfg, None)?;
    check_replicas_synced(&cached)?;
    if let Some(dir) = &trace_dir {
        std::fs::create_dir_all(dir)?;
        for (name, r) in [("prefetch", &on), ("baseline", &off)] {
            let trace = r
                .trace
                .as_ref()
                .ok_or_else(|| rudder::err!("bench {name} run returned no trace"))?;
            trace.verify_complete()?;
            let path = dir.join(format!("{name}.trace"));
            trace.write_file(&path)?;
            println!("bench: wrote {} ({} events)", path.display(), trace.events.len());
        }
    }

    let fetch_blocked = |r: &ClusterResult| -> f64 { r.walls.iter().map(|w| w.fetch_wait).sum() };
    // Per-phase percentile summary (schema v3): every measured minibatch
    // contributes one wall-clock sample per phase, pooled across trainers.
    let phase_json = |samples: &[f64]| -> Json {
        Json::obj(vec![
            ("count", Json::num(samples.len() as f64)),
            ("p50_s", Json::num(rudder::util::stats::percentile(samples, 50.0))),
            ("p95_s", Json::num(rudder::util::stats::percentile(samples, 95.0))),
            ("p99_s", Json::num(rudder::util::stats::percentile(samples, 99.0))),
        ])
    };
    let variant_json = |r: &ClusterResult| -> Json {
        let wire = r.wire_total();
        let losses: Vec<f64> = r.measured.iter().map(|m| m.mean_loss()).collect();
        let minibatches: u64 = r.walls.iter().map(|w| w.minibatches).sum();
        let pool = |pick: fn(&rudder::metrics::MeasuredStats) -> &[f64]| -> Vec<f64> {
            r.measured.iter().flat_map(|m| pick(m).iter().copied()).collect()
        };
        let rtt = wire.fetch_latency_total();
        Json::obj(vec![
            ("label", Json::str(r.experiment.label.clone())),
            ("wall_total_s", Json::num(r.wall_total)),
            ("epoch_wall_s", Json::num(r.mean_epoch_wall())),
            ("fetch_blocked_s", Json::num(fetch_blocked(r))),
            ("compute_s", Json::num(r.walls.iter().map(|w| w.compute).sum::<f64>())),
            ("barrier_s", Json::num(r.walls.iter().map(|w| w.barrier).sum::<f64>())),
            ("minibatches", Json::num(minibatches as f64)),
            ("nodes_fetched", Json::num(r.experiment.total_comm_nodes as f64)),
            ("wire_req_bytes", Json::num(wire.req_bytes as f64)),
            ("wire_resp_bytes", Json::num(wire.resp_bytes as f64)),
            ("mean_loss", Json::num(rudder::util::stats::mean(&losses))),
            (
                "phases",
                Json::obj(vec![
                    ("compute", phase_json(&pool(|m| &m.compute_secs))),
                    ("fetch_wait", phase_json(&pool(|m| &m.fetch_wait_secs))),
                    ("barrier", phase_json(&pool(|m| &m.barrier_secs))),
                    (
                        "fetch_rtt",
                        Json::obj(vec![
                            ("count", Json::num(rtt.count() as f64)),
                            ("p50_s", Json::num(rtt.p50())),
                            ("p95_s", Json::num(rtt.p95())),
                            ("p99_s", Json::num(rtt.p99())),
                        ]),
                    ),
                ]),
            ),
        ])
    };
    let scale_matrix = if args.flag("skip-scale-matrix") {
        None
    } else {
        println!("bench: transport scale matrix (tcp vs event across trainer counts)...");
        Some(bench_scale_matrix(cfg.seed)?)
    };
    let speedup_wall = if on.wall_total > 0.0 { off.wall_total / on.wall_total } else { 1.0 };
    let blocked_ratio = if fetch_blocked(&off) > 0.0 {
        fetch_blocked(&on) / fetch_blocked(&off)
    } else {
        1.0
    };
    let wire_on = on.wire_total();
    let wire_cached = cached.wire_total();
    let resp_delta = wire_on.resp_bytes as i64 - wire_cached.resp_bytes as i64;
    let mut fields = vec![
        ("schema", Json::str("rudder-bench-cluster/v4")),
        (
            "config",
            Json::obj(vec![
                ("dataset", Json::str(cfg.dataset.clone())),
                ("scale", Json::num(cfg.scale)),
                ("seed", Json::num(cfg.seed as f64)),
                ("trainers", Json::num(cfg.num_trainers as f64)),
                ("batch_size", Json::num(cfg.batch_size as f64)),
                ("epochs", Json::num(cfg.epochs as f64)),
                ("controller", Json::str(cfg.controller.spec())),
                ("compute", Json::str("measured")),
                ("transport", Json::str("channel")),
            ]),
        ),
        ("prefetch", variant_json(&on)),
        ("baseline", variant_json(&off)),
        ("prefetch_cached", variant_json(&cached)),
        (
            "cache",
            Json::obj(vec![
                ("chunk_rows", Json::num(BENCH_CHUNK_ROWS as f64)),
                ("cache_bytes", Json::num(BENCH_CACHE_BYTES as f64)),
                ("chunks_hit", Json::num(wire_cached.chunks_hit as f64)),
                ("chunks_fetched", Json::num(wire_cached.chunks_fetched as f64)),
                ("bytes_saved_cache", Json::num(wire_cached.bytes_saved_cache as f64)),
                ("wire_resp_bytes_uncached", Json::num(wire_on.resp_bytes as f64)),
                ("wire_resp_bytes_cached", Json::num(wire_cached.resp_bytes as f64)),
                ("wire_resp_bytes_delta", Json::num(resp_delta as f64)),
            ]),
        ),
        ("speedup_wall", Json::num(speedup_wall)),
        ("fetch_blocked_ratio", Json::num(blocked_ratio)),
        ("replicas_synced", Json::Bool(true)),
    ];
    if let Some(m) = scale_matrix {
        fields.push(("scale_matrix", m));
    }
    let doc = Json::obj(fields);
    std::fs::write(&out_path, doc.to_string_pretty())?;
    println!(
        "bench: wall speedup {speedup_wall:.2}x, fetch-blocked ratio {blocked_ratio:.2} \
         (prefetch / baseline); chunk cache saved {} resp bytes \
         ({} uncached -> {} cached); wrote {out_path}",
        fmt_count(resp_delta.max(0) as u64),
        fmt_count(wire_on.resp_bytes),
        fmt_count(wire_cached.resp_bytes),
    );
    // Gates last: the artifact exists (and is uploadable) even on failure.
    rudder::ensure!(
        wire_cached.resp_bytes < wire_on.resp_bytes,
        "bench gate: chunk cache did not reduce wire response bytes \
         ({} cached vs {} uncached)",
        wire_cached.resp_bytes,
        wire_on.resp_bytes
    );
    rudder::ensure!(
        speedup_wall >= min_speedup,
        "bench gate: wall speedup {speedup_wall:.3} below --min-speedup {min_speedup}"
    );
    rudder::ensure!(
        blocked_ratio <= max_blocked_ratio,
        "bench gate: fetch-blocked ratio {blocked_ratio:.3} above --max-blocked-ratio \
         {max_blocked_ratio}"
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> rudder::error::Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let q = if args.flag("full") { Quality::Full } else { Quality::Quick };
    let ids: Vec<&str> = if id == "all" {
        harness::EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        println!("\n### experiment {id} ({q:?}) ###");
        let t0 = std::time::Instant::now();
        for table in harness::run_experiment_id(id, q)? {
            table.emit(&format!("{id}_{}", sanitize(&table.title)));
        }
        println!("[{id} done in {:.1}s]", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .take(48)
        .collect()
}

/// `rudder trace <dump|stats|diff>` — flight-recorder tooling — or, with
/// no subcommand, the legacy trace-only classifier data collection.
fn cmd_trace(args: &Args) -> rudder::error::Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("dump") => return cmd_trace_dump(args),
        Some("stats") => return cmd_trace_stats(args),
        Some("diff") => return cmd_trace_diff(args),
        Some(other) => {
            rudder::bail!("unknown trace subcommand '{other}' (dump|stats|diff)")
        }
        None => {}
    }
    let cfg = config_from_args(args)?;
    let (ds, part) = build_cluster(&cfg)?;
    let set = trace_only(&ds, &part, &cfg);
    println!(
        "trace-only: {} labelled examples, positive rate {:.2}, collection cost {:.1}s",
        set.len(),
        set.positive_rate(),
        set.collection_cost
    );
    if let Some(out) = args.opt("out") {
        let examples: Vec<Json> = set
            .xs
            .iter()
            .zip(&set.ys)
            .map(|(x, &y)| {
                Json::obj(vec![
                    (
                        "x",
                        Json::Arr(x.iter().map(|&v| Json::num(v as f64)).collect()),
                    ),
                    ("y", Json::Bool(y)),
                ])
            })
            .collect();
        std::fs::write(out, Json::Arr(examples).to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn trace_file_arg(args: &Args, idx: usize, what: &str) -> rudder::error::Result<PathBuf> {
    args.positional
        .get(idx)
        .map(PathBuf::from)
        .ok_or_else(|| rudder::err!("trace {}: missing {what} file", args.positional[0]))
}

/// `rudder trace dump <file> [--out <file>]` — convert a trace between
/// the RTRC binary and JSONL forms (extension of `--out` picks the
/// output codec; no `--out` prints JSONL to stdout).
fn cmd_trace_dump(args: &Args) -> rudder::error::Result<()> {
    let input = trace_file_arg(args, 1, "input trace")?;
    let t = Trace::read_file(&input)?;
    match args.opt("out") {
        Some(out) => {
            t.write_file(std::path::Path::new(out))?;
            println!("wrote {out} ({} events)", t.events.len());
        }
        None => print!("{}", rudder::trace::codec::to_jsonl(&t)?),
    }
    Ok(())
}

/// `rudder trace stats <file>` — per-phase latency percentiles,
/// fetch-blocked breakdown, and per-link timelines from one trace.
fn cmd_trace_stats(args: &Args) -> rudder::error::Result<()> {
    let input = trace_file_arg(args, 1, "input trace")?;
    let t = Trace::read_file(&input)?;
    t.verify_complete()?;
    println!(
        "trace: label={} seed={} transport={} compute={} events={}",
        t.meta.label,
        t.meta.seed,
        t.meta.transport,
        t.meta.compute,
        t.events.len()
    );
    for table in rudder::trace::stats::render_all(&t) {
        println!("{}", table.render());
    }
    Ok(())
}

/// `rudder trace diff <a> <b>` — compare the virtual-time fields of two
/// same-seed traces; exits non-zero on any mismatch.  Wall-clock fields
/// and arrival order are excluded, so same-seed runs on different
/// transports (channel / tcp / event) must diff clean.
fn cmd_trace_diff(args: &Args) -> rudder::error::Result<()> {
    let a_path = trace_file_arg(args, 1, "left trace")?;
    let b_path = trace_file_arg(args, 2, "right trace")?;
    let a = Trace::read_file(&a_path)?;
    let b = Trace::read_file(&b_path)?;
    let report = rudder::trace::diff::diff(&a, &b);
    println!("{}", report.render().trim_end());
    rudder::ensure!(
        report.identical(),
        "trace diff: {} virtual-time mismatches between {} and {}",
        report.mismatches.len(),
        a_path.display(),
        b_path.display()
    );
    Ok(())
}

/// `rudder replay --trace <file>` — re-drive a recorded trace through the
/// sim state machine offline.  `--check` proves the re-emitted virtual
/// streams are bit-identical to the recording; override flags
/// (`--controller`, `--buffer`, `--chunk-rows`, `--chunk-cache`) evaluate
/// a what-if variant against the recorded demand.  `replay sweep` fans
/// one trace across a controller × buffer grid in one process.
fn cmd_replay(args: &Args) -> rudder::error::Result<()> {
    if args.positional.first().map(String::as_str) == Some("sweep") {
        return cmd_replay_sweep(args);
    }
    let (original, setup) = replay_setup(args)?;
    let overrides = replay::Overrides {
        controller: args.opt("controller").map(ControllerSpec::parse).transpose()?,
        buffer_pct: args.opt_parse::<f64>("buffer")?,
        chunk_rows: args.opt_parse::<usize>("chunk-rows")?,
        chunk_cache_bytes: args.opt_parse::<u64>("chunk-cache")?,
    };
    let baseline = if args.flag("check") {
        rudder::ensure!(
            !setup.is_measured(),
            "--check needs an emulated-compute trace: measured runs carry real step \
             durations that replay deliberately re-models (record with --time-scale 0)"
        );
        let (run, report) = replay::check(&setup, &original)?;
        println!("{}", report.render().trim_end());
        rudder::ensure!(
            report.identical(),
            "replay check: {} virtual-time mismatches against the recording",
            report.mismatches.len()
        );
        println!(
            "replay check OK: {} re-emitted events bit-identical to the recording",
            run.trace.events.len()
        );
        run
    } else {
        replay::replay(&setup, &replay::Overrides::default())?
    };
    let variants = if overrides.is_empty() {
        Vec::new()
    } else {
        vec![replay::replay(&setup, &overrides)?]
    };
    replay_table(&baseline, &variants).emit("replay_whatif");
    // A bare replay (no what-if) only writes the report when asked.
    let json_path = args
        .opt("json")
        .map(str::to_string)
        .or_else(|| (!variants.is_empty()).then(|| "REPLAY_whatif.json".to_string()));
    if let Some(path) = json_path {
        let doc = replay::whatif_json(&setup.meta, &baseline, &variants);
        std::fs::write(&path, doc.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `rudder replay sweep --trace <file> --controllers a,b --buffers f1,f2`.
fn cmd_replay_sweep(args: &Args) -> rudder::error::Result<()> {
    let (_, setup) = replay_setup(args)?;
    let controllers = match args.opt("controllers") {
        Some(csv) => csv
            .split(',')
            .map(|s| ControllerSpec::parse(s.trim()))
            .collect::<rudder::error::Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    let buffers = match args.opt("buffers") {
        Some(csv) => csv
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|e| rudder::err!("cannot parse --buffers value '{s}': {e}"))
            })
            .collect::<rudder::error::Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    rudder::ensure!(
        !controllers.is_empty() || !buffers.is_empty(),
        "replay sweep: give at least one axis (--controllers a,b and/or --buffers f1,f2)"
    );
    let spec = replay::SweepSpec {
        controllers,
        buffers,
        chunk_rows: args.opt_parse::<usize>("chunk-rows")?,
        chunk_cache_bytes: args.opt_parse::<u64>("chunk-cache")?,
    };
    let baseline = replay::replay(&setup, &replay::Overrides::default())?;
    let runs = replay::sweep(&setup, &spec)?;
    replay_table(&baseline, &runs).emit("replay_sweep");
    let path = args.opt_or("json", "REPLAY_whatif.json");
    let doc = replay::whatif_json(&setup.meta, &baseline, &runs);
    std::fs::write(&path, doc.to_string_pretty())?;
    println!("wrote {path} ({} variants)", runs.len());
    Ok(())
}

/// Shared front half of both replay forms: read `--trace <file>`, load it,
/// announce the source run.
fn replay_setup(args: &Args) -> rudder::error::Result<(Trace, replay::ReplaySetup)> {
    let path = args
        .opt("trace")
        .ok_or_else(|| rudder::err!("replay: --trace <file> required"))?;
    let original = Trace::read_file(std::path::Path::new(path))?;
    let setup = replay::load(&original)?;
    println!(
        "replay: {} — label={} seed={} transport={} compute={}; {} trainers, \
         {} recorded minibatch demands",
        path,
        setup.meta.label,
        setup.meta.seed,
        setup.meta.transport,
        setup.meta.compute,
        setup.cfg.num_trainers,
        setup.recorded_minibatches,
    );
    if setup.is_measured() {
        println!(
            "note: measured-compute trace; replayed virtual times re-model the \
             recorded step durations (--check unavailable)"
        );
    }
    Ok((original, setup))
}

fn replay_table(baseline: &replay::ReplayRun, variants: &[replay::ReplayRun]) -> Table {
    let mut t = Table::new(
        "replay what-if",
        &["variant", "controller", "buffer", "virt epoch", "steady %-hits", "wire resp", "blocked"],
    );
    let row = |tag: String, r: &replay::ReplayRun| {
        vec![
            tag,
            r.cfg.controller.spec(),
            format!("{:.0}%", r.cfg.buffer_pct * 100.0),
            fmt_secs(r.experiment.mean_epoch_time),
            fmt_pct(r.experiment.steady_hits_pct),
            fmt_count(r.wire.resp_bytes),
            format!("{:.3}", r.fetch_blocked_ratio()),
        ]
    };
    t.row(row("recorded".into(), baseline));
    for (i, v) in variants.iter().enumerate() {
        t.row(row(format!("what-if {}", i + 1), v));
    }
    t
}

fn cmd_calibrate(_args: &Args) -> rudder::error::Result<()> {
    let Some(engine) = Engine::try_load_default() else {
        rudder::bail!(
            "requested artifacts are unusable — fix or remove ./artifacts (or \
             $RUDDER_ARTIFACTS), or rebuild them with `python -m compile.aot`"
        );
    };
    let engine = Arc::new(engine);
    println!("platform: {}", engine.platform());
    // Measure the real sage_train_step on a synthetic minibatch.
    let cfg = RunConfig { scale: 0.05, ..Default::default() };
    let (ds, part) = build_cluster(&cfg)?;
    let c = engine.manifest.config.clone();
    let sampler = Sampler::new(0, c.batch, c.fanout1, c.fanout2, 1);
    let train = part.train_nodes_of(0, &ds.train_nodes);
    let order = sampler.epoch_order(&train, 0);
    let mut runner = SageRunner::new(engine.clone(), 7, 0.05);
    let mut times = Vec::new();
    for mb in 0..5 {
        let b = sampler.sample(&ds.csr, &part, &order, 0, mb % 2);
        if b.targets.is_empty() {
            break;
        }
        let (loss, dt) = runner.train_step(&b, ds.feature_seed, &ds.labels)?;
        println!("  step {mb}: loss={loss:.4} dt={}", fmt_secs(dt));
        if mb > 0 {
            times.push(dt); // skip compile-inclusive first step
        }
    }
    let mean = rudder::util::stats::mean(&times);
    // Scale measured (artifact batch) step to the simulation batch.  The
    // backend tag keeps interpreter- and PJRT-derived constants from ever
    // being silently mixed: `config::load_calibration` refuses a file
    // whose tag does not match the backend the current build would run.
    let body = format!(
        "# written by `rudder calibrate` — measured on {}\nbackend = \"{}\"\n[compute]\nbase_overhead = {:.6}\n",
        engine.platform(),
        engine.backend_name(),
        mean,
    );
    std::fs::create_dir_all("configs")?;
    std::fs::write("configs/calibration.toml", &body)?;
    println!(
        "wrote configs/calibration.toml (mean step {}, backend {})",
        fmt_secs(mean),
        engine.backend_name()
    );
    Ok(())
}

fn cmd_datasets() -> rudder::error::Result<()> {
    let mut t = Table::new(
        "datasets (Table 1a stand-ins)",
        &["name", "paper_size", "standin_nodes", "standin_edges", "feat_dim", "classes", "unseen"],
    );
    for d in datasets::ALL {
        t.row(vec![
            d.name.to_string(),
            format!("{} / {}", d.paper_nodes, d.paper_edges),
            d.num_nodes.to_string(),
            d.num_edges.to_string(),
            d.feat_dim.to_string(),
            d.num_classes.to_string(),
            if d.unseen { "yes".into() } else { "".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_models() -> rudder::error::Result<()> {
    for table in harness::fig06(Quality::Quick) {
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_partition_stats(args: &Args) -> rudder::error::Result<()> {
    let cfg = config_from_args(args)?;
    let method = args
        .opt("method")
        .map(Method::parse)
        .transpose()?
        .unwrap_or(cfg.partition_method);
    let ds = rudder::graph::Dataset::build_by_name(&cfg.dataset, cfg.scale, cfg.seed)?;
    let mut t = Table::new(
        &format!("partition quality — {} (scale {})", cfg.dataset, cfg.scale),
        &["method", "parts", "cut%", "imbalance", "mean_halo", "remote_ratio"],
    );
    for m in [method, Method::Ldg, Method::Random] {
        let part = partition::partition(&ds.csr, cfg.num_trainers, m, cfg.seed);
        let s = partition::stats::compute(&ds.csr, &part);
        t.row(vec![
            format!("{m:?}"),
            s.num_parts.to_string(),
            format!("{:.1}", s.cut_fraction * 100.0),
            format!("{:.3}", s.imbalance),
            format!("{:.0}", s.mean_halo),
            format!("{:.2}", s.mean_remote_ratio),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
