//! Multilevel k-way partitioner in the METIS mold.
//!
//! Three phases (Karypis–Kumar):
//! 1. **Coarsening** — repeated heavy-edge matching merges endpoint pairs of
//!    heavy edges until the graph is small (≤ `COARSE_TARGET` × parts).
//! 2. **Initial partitioning** — greedy BFS region growing on the coarsest
//!    graph, seeded round-robin from high-degree nodes.
//! 3. **Uncoarsening + refinement** — project owners back up; at each level
//!    run boundary refinement (positive-gain moves with balance guardrails,
//!    a light Kernighan–Lin).
//!
//! Not a bit-for-bit METIS clone, but produces the properties the paper's
//! pipeline depends on: low edge cut (≫ better than random/hash) and
//! bounded imbalance, so per-part halos are realistic.

use super::Partition;
use crate::graph::Csr;
use crate::util::rng::Pcg32;

const COARSE_TARGET: usize = 30;
const MAX_IMBALANCE: f64 = 1.1;

/// Weighted graph used during coarsening.
struct WGraph {
    /// adjacency: (neighbor, edge weight)
    adj: Vec<Vec<(u32, u32)>>,
    node_weight: Vec<u32>,
}

impl WGraph {
    fn from_csr(csr: &Csr) -> WGraph {
        let n = csr.num_nodes();
        let mut adj = Vec::with_capacity(n);
        for v in 0..n as u32 {
            adj.push(csr.neighbors(v).iter().map(|&u| (u, 1u32)).collect());
        }
        WGraph { adj, node_weight: vec![1; n] }
    }

    fn len(&self) -> usize {
        self.adj.len()
    }
}

/// One round of heavy-edge matching; returns (coarse graph, fine→coarse map).
fn coarsen(g: &WGraph, rng: &mut Pcg32) -> (WGraph, Vec<u32>) {
    let n = g.len();
    let mut matched = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut coarse_count = 0u32;
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        // Heaviest unmatched neighbor.
        let mut best: Option<(u32, u32)> = None;
        for &(u, w) in &g.adj[v as usize] {
            if u != v && matched[u as usize] == u32::MAX {
                if match best { Some((_, bw)) => w > bw, None => true } {
                    best = Some((u, w));
                }
            }
        }
        let c = coarse_count;
        coarse_count += 1;
        matched[v as usize] = c;
        if let Some((u, _)) = best {
            matched[u as usize] = c;
        }
    }
    // Build coarse adjacency with aggregated weights.
    let cn = coarse_count as usize;
    let mut node_weight = vec![0u32; cn];
    for v in 0..n {
        node_weight[matched[v] as usize] += g.node_weight[v];
    }
    let mut agg: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); cn];
    for v in 0..n {
        let cv = matched[v];
        for &(u, w) in &g.adj[v] {
            let cu = matched[u as usize];
            if cu != cv {
                *agg[cv as usize].entry(cu).or_insert(0) += w;
            }
        }
    }
    let adj = agg
        .into_iter()
        .map(|m| {
            let mut v: Vec<(u32, u32)> = m.into_iter().collect();
            v.sort_unstable();
            v
        })
        .collect();
    (WGraph { adj, node_weight }, matched)
}

/// Greedy BFS region growing on the coarsest graph.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Pcg32) -> Vec<u16> {
    let n = g.len();
    let total_weight: u64 = g.node_weight.iter().map(|&w| w as u64).sum();
    let target = (total_weight as f64 / k as f64).ceil() as u64;
    let mut owner = vec![u16::MAX; n];
    let mut part_weight = vec![0u64; k];

    // Seeds: spread across high-degree nodes.
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_by_key(|&v| std::cmp::Reverse(g.adj[v as usize].len()));

    let mut queues: Vec<std::collections::VecDeque<u32>> =
        (0..k).map(|_| Default::default()).collect();
    let mut seed_iter = by_degree.iter();
    for p in 0..k {
        if let Some(&s) = seed_iter.next() {
            queues[p].push_back(s);
        }
    }
    let mut remaining = n;
    while remaining > 0 {
        let mut progressed = false;
        for p in 0..k {
            if part_weight[p] >= target {
                continue;
            }
            // Pop until an unassigned node appears.
            while let Some(v) = queues[p].pop_front() {
                if owner[v as usize] != u16::MAX {
                    continue;
                }
                owner[v as usize] = p as u16;
                part_weight[p] += g.node_weight[v as usize] as u64;
                remaining -= 1;
                progressed = true;
                for &(u, _) in &g.adj[v as usize] {
                    if owner[u as usize] == u16::MAX {
                        queues[p].push_back(u);
                    }
                }
                break;
            }
            if queues[p].is_empty() {
                // Re-seed from any unassigned node.
                if let Some(v) = (0..n as u32).find(|&v| owner[v as usize] == u16::MAX) {
                    queues[p].push_back(v);
                }
            }
        }
        if !progressed {
            // Assign stragglers to the lightest part.
            for v in 0..n {
                if owner[v] == u16::MAX {
                    let p = (0..k).min_by_key(|&p| part_weight[p]).unwrap();
                    owner[v] = p as u16;
                    part_weight[p] += g.node_weight[v] as u64;
                    remaining -= 1;
                }
            }
        }
        let _ = rng; // seeds only influence shuffle order upstream
    }
    owner
}

/// Boundary refinement: greedy positive-gain moves with balance guardrails.
fn refine(g: &WGraph, owner: &mut [u16], k: usize, passes: usize) {
    let n = g.len();
    let mut part_weight = vec![0u64; k];
    for v in 0..n {
        part_weight[owner[v] as usize] += g.node_weight[v] as u64;
    }
    let total: u64 = part_weight.iter().sum();
    let cap = ((total as f64 / k as f64) * MAX_IMBALANCE).ceil() as u64;

    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n {
            let home = owner[v] as usize;
            // Connectivity to each part.
            let mut conn = vec![0i64; k];
            for &(u, w) in &g.adj[v] {
                conn[owner[u as usize] as usize] += w as i64;
            }
            let mut best = home;
            let mut best_gain = 0i64;
            for p in 0..k {
                if p == home {
                    continue;
                }
                let gain = conn[p] - conn[home];
                let fits = part_weight[p] + g.node_weight[v] as u64 <= cap;
                let frees = part_weight[home] > g.node_weight[v] as u64;
                if gain > best_gain && fits && frees {
                    best = p;
                    best_gain = gain;
                }
            }
            if best != home {
                owner[v] = best as u16;
                part_weight[home] -= g.node_weight[v] as u64;
                part_weight[best] += g.node_weight[v] as u64;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Multilevel k-way partition.
pub fn partition(csr: &Csr, k: usize, seed: u64) -> Partition {
    let n = csr.num_nodes();
    if n == 0 {
        return Partition::from_owner(csr, k, vec![]);
    }
    let mut rng = Pcg32::new(seed ^ 0x4D45_5449_53); // "METIS"
    // Coarsen.
    let mut levels: Vec<(WGraph, Vec<u32>)> = Vec::new();
    let mut cur = WGraph::from_csr(csr);
    while cur.len() > COARSE_TARGET * k && levels.len() < 24 {
        let (coarse, map) = coarsen(&cur, &mut rng);
        if coarse.len() as f64 > cur.len() as f64 * 0.95 {
            // Matching stalled (e.g. star graphs) — stop coarsening.
            levels.push((std::mem::replace(&mut cur, coarse), map));
            break;
        }
        levels.push((std::mem::replace(&mut cur, coarse), map));
    }
    // Initial partition on the coarsest graph.
    let mut owner = initial_partition(&cur, k, &mut rng);
    refine(&cur, &mut owner, k, 10);
    // Uncoarsen with refinement at each level.
    for (fine, map) in levels.iter().rev() {
        let mut fine_owner = vec![0u16; fine.len()];
        for v in 0..fine.len() {
            fine_owner[v] = owner[map[v] as usize];
        }
        owner = fine_owner;
        refine(fine, &mut owner, k, 5);
    }
    Partition::from_owner(csr, k, owner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::partition::{partition as part_by, Method};

    fn g(n: usize, m: usize, seed: u64) -> Csr {
        generate(
            &RmatParams { a: 0.57, b: 0.19, c: 0.19, num_nodes: n, num_edges: m, permute: true },
            &mut Pcg32::new(seed),
        )
    }

    #[test]
    fn produces_valid_partition() {
        let csr = g(2000, 12000, 1);
        let part = partition(&csr, 4, 42);
        assert_eq!(part.num_parts, 4);
        let total: usize = part.local_nodes.iter().map(Vec::len).sum();
        assert_eq!(total, csr.num_nodes());
        assert!(part.local_nodes.iter().all(|l| !l.is_empty()));
    }

    #[test]
    fn beats_random_on_edge_cut() {
        let csr = g(3000, 20000, 2);
        let metis = partition(&csr, 8, 1);
        let random = part_by(&csr, 8, Method::Random, 1);
        let mc = metis.edge_cut(&csr);
        let rc = random.edge_cut(&csr);
        assert!(
            (mc as f64) < 0.85 * rc as f64,
            "metis-like cut {mc} not clearly better than random {rc}"
        );
    }

    #[test]
    fn respects_balance() {
        let csr = g(2500, 15000, 3);
        for k in [2, 4, 8] {
            let part = partition(&csr, k, 5);
            assert!(part.imbalance() < 1.4, "k={k} imbalance {}", part.imbalance());
        }
    }

    #[test]
    fn deterministic() {
        let csr = g(800, 5000, 4);
        let a = partition(&csr, 4, 9);
        let b = partition(&csr, 4, 9);
        assert_eq!(a.owner, b.owner);
    }

    #[test]
    fn handles_tiny_graphs() {
        let csr = g(70, 200, 5);
        let part = partition(&csr, 4, 1);
        let total: usize = part.local_nodes.iter().map(Vec::len).sum();
        assert_eq!(total, csr.num_nodes());
    }
}
