//! Graph partitioning substrate (the paper partitions with METIS).
//!
//! Two partitioners:
//! * [`metis_like`] — multilevel: heavy-edge-matching coarsening, greedy
//!   BFS-grow initial partitioning, boundary Kernighan–Lin-style refinement.
//! * [`streaming`] — Linear Deterministic Greedy (LDG), one pass, used as a
//!   fast baseline and in partitioner ablations.
//!
//! The output [`Partition`] carries everything the distributed runtime
//! needs: per-node owner, each part's local nodes, and the *halo* — the set
//! of remote nodes adjacent to a part, which is the persistent buffer's
//! universe (buffer capacity = pct × halo size, paper §5.1).

pub mod metis_like;
pub mod stats;
pub mod streaming;

use crate::graph::Csr;

/// A k-way node partition of a graph.
#[derive(Debug, Clone)]
pub struct Partition {
    pub num_parts: usize,
    /// `owner[v]` = part id of node v.
    pub owner: Vec<u16>,
    /// Nodes owned by each part (sorted).
    pub local_nodes: Vec<Vec<u32>>,
    /// For each part: sorted remote nodes adjacent to its local nodes.
    pub halo: Vec<Vec<u32>>,
}

impl Partition {
    /// Assemble from an owner vector (computes locals + halos).
    pub fn from_owner(csr: &Csr, num_parts: usize, owner: Vec<u16>) -> Partition {
        assert_eq!(owner.len(), csr.num_nodes());
        let mut local_nodes = vec![Vec::new(); num_parts];
        for (v, &p) in owner.iter().enumerate() {
            assert!((p as usize) < num_parts, "owner out of range");
            local_nodes[p as usize].push(v as u32);
        }
        let mut halo = vec![Vec::new(); num_parts];
        for (p, locals) in local_nodes.iter().enumerate() {
            let h = &mut halo[p];
            for &v in locals {
                for &u in csr.neighbors(v) {
                    if owner[u as usize] as usize != p {
                        h.push(u);
                    }
                }
            }
            h.sort_unstable();
            h.dedup();
        }
        Partition { num_parts, owner, local_nodes, halo }
    }

    #[inline]
    pub fn owner_of(&self, v: u32) -> usize {
        self.owner[v as usize] as usize
    }

    #[inline]
    pub fn is_local(&self, part: usize, v: u32) -> bool {
        self.owner_of(v) == part
    }

    /// The k-hop halo of part `p`: remote nodes reachable within `k` hops
    /// of its local nodes.  With 2-hop sampling (fanout {10, 25}) the
    /// persistent buffer's universe is `halo_k(csr, p, 2)` — every node the
    /// sampler can ever fetch remotely (paper §5.1 sizes buffers as a
    /// percentage of this set).
    pub fn halo_k(&self, csr: &Csr, p: usize, k: usize) -> Vec<u32> {
        let mut frontier: Vec<u32> = self.local_nodes[p].clone();
        let mut seen: std::collections::HashSet<u32> = frontier.iter().copied().collect();
        let mut remote: Vec<u32> = Vec::new();
        for _ in 0..k {
            let mut next = Vec::new();
            for &v in &frontier {
                for &u in csr.neighbors(v) {
                    if seen.insert(u) {
                        next.push(u);
                        if self.owner_of(u) != p {
                            remote.push(u);
                        }
                    }
                }
            }
            frontier = next;
        }
        remote.sort_unstable();
        remote
    }

    /// Edge cut: number of (undirected) edges crossing parts.
    pub fn edge_cut(&self, csr: &Csr) -> u64 {
        let mut cut = 0u64;
        for v in 0..csr.num_nodes() as u32 {
            for &u in csr.neighbors(v) {
                if v < u && self.owner[v as usize] != self.owner[u as usize] {
                    cut += 1;
                }
            }
        }
        cut
    }

    /// Load imbalance: max part size / ideal part size.
    pub fn imbalance(&self) -> f64 {
        let n: usize = self.local_nodes.iter().map(Vec::len).sum();
        if n == 0 {
            return 1.0;
        }
        let ideal = n as f64 / self.num_parts as f64;
        let max = self.local_nodes.iter().map(Vec::len).max().unwrap_or(0) as f64;
        max / ideal
    }

    /// Split a part's train nodes: which training seeds live in part `p`.
    pub fn train_nodes_of(&self, p: usize, train_nodes: &[u32]) -> Vec<u32> {
        train_nodes
            .iter()
            .copied()
            .filter(|&v| self.owner_of(v) == p)
            .collect()
    }
}

/// Partitioning method selector (config-driven).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    MetisLike,
    Ldg,
    /// Hash partition — worst-case locality, used in ablations.
    Random,
}

impl Method {
    pub fn parse(s: &str) -> crate::error::Result<Method> {
        match s {
            "metis" | "metis_like" => Ok(Method::MetisLike),
            "ldg" | "streaming" => Ok(Method::Ldg),
            "random" | "hash" => Ok(Method::Random),
            _ => crate::bail!("unknown partition method '{s}'"),
        }
    }
}

/// Partition `csr` into `num_parts` with the given method.
pub fn partition(csr: &Csr, num_parts: usize, method: Method, seed: u64) -> Partition {
    assert!(num_parts >= 1);
    if num_parts == 1 {
        return Partition::from_owner(csr, 1, vec![0; csr.num_nodes()]);
    }
    match method {
        Method::MetisLike => metis_like::partition(csr, num_parts, seed),
        Method::Ldg => streaming::partition_ldg(csr, num_parts, seed),
        Method::Random => {
            let owner: Vec<u16> = (0..csr.num_nodes() as u32)
                .map(|v| {
                    (crate::util::rng::derive_seed(seed, &[v as u64]) % num_parts as u64)
                        as u16
                })
                .collect();
            Partition::from_owner(csr, num_parts, owner)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::util::rng::Pcg32;

    fn g(n: usize, m: usize) -> Csr {
        generate(
            &RmatParams { a: 0.57, b: 0.19, c: 0.19, num_nodes: n, num_edges: m, permute: true },
            &mut Pcg32::new(3),
        )
    }

    #[test]
    fn from_owner_invariants() {
        let csr = g(500, 3000);
        let part = partition(&csr, 4, Method::Random, 1);
        // Every node in exactly one part.
        let total: usize = part.local_nodes.iter().map(Vec::len).sum();
        assert_eq!(total, csr.num_nodes());
        // Halo nodes are remote and adjacent.
        for (p, h) in part.halo.iter().enumerate() {
            for &v in h {
                assert_ne!(part.owner_of(v), p);
            }
            assert!(h.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn single_part_has_empty_halo() {
        let csr = g(200, 1000);
        let part = partition(&csr, 1, Method::MetisLike, 0);
        assert_eq!(part.halo[0], Vec::<u32>::new());
        assert_eq!(part.edge_cut(&csr), 0);
    }

    #[test]
    fn methods_parse() {
        assert_eq!(Method::parse("metis").unwrap(), Method::MetisLike);
        assert_eq!(Method::parse("ldg").unwrap(), Method::Ldg);
        assert_eq!(Method::parse("random").unwrap(), Method::Random);
        assert!(Method::parse("zzz").is_err());
    }

    #[test]
    fn train_nodes_split() {
        let csr = g(300, 2000);
        let part = partition(&csr, 3, Method::Random, 7);
        let train: Vec<u32> = (0..100).collect();
        let mut count = 0;
        for p in 0..3 {
            let tn = part.train_nodes_of(p, &train);
            assert!(tn.iter().all(|&v| part.owner_of(v) == p));
            count += tn.len();
        }
        assert_eq!(count, 100);
    }
}
