//! Partition quality metrics used by `rudder partition-stats` and the
//! partitioner ablation bench.

use super::Partition;
use crate::graph::Csr;

#[derive(Debug, Clone)]
pub struct PartitionStats {
    pub num_parts: usize,
    pub edge_cut: u64,
    pub cut_fraction: f64,
    pub imbalance: f64,
    pub min_part: usize,
    pub max_part: usize,
    /// Mean halo size across parts (the persistent-buffer universe).
    pub mean_halo: f64,
    pub max_halo: usize,
    /// Mean fraction of a part's sampled frontier expected to be remote —
    /// approximated by halo / (halo + local).
    pub mean_remote_ratio: f64,
}

pub fn compute(csr: &Csr, part: &Partition) -> PartitionStats {
    let edge_cut = part.edge_cut(csr);
    let total_edges = (csr.num_arcs() / 2).max(1) as f64;
    let halos: Vec<usize> = part.halo.iter().map(Vec::len).collect();
    let sizes: Vec<usize> = part.local_nodes.iter().map(Vec::len).collect();
    let remote_ratios: Vec<f64> = (0..part.num_parts)
        .map(|p| {
            let h = halos[p] as f64;
            let l = sizes[p] as f64;
            if h + l == 0.0 {
                0.0
            } else {
                h / (h + l)
            }
        })
        .collect();
    PartitionStats {
        num_parts: part.num_parts,
        edge_cut,
        cut_fraction: edge_cut as f64 / total_edges,
        imbalance: part.imbalance(),
        min_part: sizes.iter().copied().min().unwrap_or(0),
        max_part: sizes.iter().copied().max().unwrap_or(0),
        mean_halo: crate::util::stats::mean(
            &halos.iter().map(|&h| h as f64).collect::<Vec<_>>(),
        ),
        max_halo: halos.iter().copied().max().unwrap_or(0),
        mean_remote_ratio: crate::util::stats::mean(&remote_ratios),
    }
}

impl std::fmt::Display for PartitionStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "parts={} cut={} ({:.1}%) imbalance={:.3} sizes=[{}..{}] halo(mean={:.0}, max={}) remote_ratio={:.2}",
            self.num_parts,
            self.edge_cut,
            self.cut_fraction * 100.0,
            self.imbalance,
            self.min_part,
            self.max_part,
            self.mean_halo,
            self.max_halo,
            self.mean_remote_ratio
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::partition::{partition, Method};
    use crate::util::rng::Pcg32;

    #[test]
    fn stats_consistent() {
        let csr = generate(
            &RmatParams {
                a: 0.57, b: 0.19, c: 0.19, num_nodes: 1000, num_edges: 6000, permute: true,
            },
            &mut Pcg32::new(5),
        );
        let part = partition(&csr, 4, Method::MetisLike, 1);
        let s = compute(&csr, &part);
        assert_eq!(s.num_parts, 4);
        assert!(s.cut_fraction >= 0.0 && s.cut_fraction <= 1.0);
        assert!(s.imbalance >= 1.0);
        assert!(s.min_part <= s.max_part);
        assert!(s.mean_remote_ratio > 0.0 && s.mean_remote_ratio < 1.0);
        let _ = format!("{s}");
    }
}
