//! Linear Deterministic Greedy (LDG) streaming partitioner.
//!
//! One pass over nodes in random order; each node goes to the part with the
//! most already-assigned neighbors, discounted by a fullness penalty
//! `1 - |P|/cap` (Stanton & Kliot).  Fast, decent cut, used as the ablation
//! baseline against the multilevel partitioner.

use super::Partition;
use crate::graph::Csr;
use crate::util::rng::Pcg32;

pub fn partition_ldg(csr: &Csr, k: usize, seed: u64) -> Partition {
    let n = csr.num_nodes();
    let cap = (n as f64 / k as f64 * 1.05).ceil().max(1.0);
    let mut owner = vec![u16::MAX; n];
    let mut sizes = vec![0usize; k];
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = Pcg32::new(seed ^ 0x4C44_47); // "LDG"
    rng.shuffle(&mut order);
    let mut neigh_count = vec![0u32; k];
    for &v in &order {
        for c in neigh_count.iter_mut() {
            *c = 0;
        }
        for &u in csr.neighbors(v) {
            let o = owner[u as usize];
            if o != u16::MAX {
                neigh_count[o as usize] += 1;
            }
        }
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for p in 0..k {
            let penalty = 1.0 - sizes[p] as f64 / cap;
            if penalty <= 0.0 {
                continue;
            }
            let score = (neigh_count[p] as f64 + 1e-9) * penalty;
            if score > best_score {
                best_score = score;
                best = p;
            }
        }
        if best_score == f64::NEG_INFINITY {
            best = (0..k).min_by_key(|&p| sizes[p]).unwrap();
        }
        owner[v as usize] = best as u16;
        sizes[best] += 1;
    }
    Partition::from_owner(csr, k, owner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::partition::{partition as part_by, Method};

    fn g(n: usize, m: usize) -> Csr {
        generate(
            &RmatParams { a: 0.57, b: 0.19, c: 0.19, num_nodes: n, num_edges: m, permute: true },
            &mut Pcg32::new(8),
        )
    }

    #[test]
    fn assigns_everyone_with_balance() {
        let csr = g(2000, 12000);
        let part = partition_ldg(&csr, 4, 1);
        let total: usize = part.local_nodes.iter().map(Vec::len).sum();
        assert_eq!(total, 2000);
        assert!(part.imbalance() <= 1.12, "imbalance {}", part.imbalance());
    }

    #[test]
    fn beats_random_cut() {
        let csr = g(3000, 18000);
        let ldg = partition_ldg(&csr, 8, 2);
        let random = part_by(&csr, 8, Method::Random, 2);
        assert!(ldg.edge_cut(&csr) < random.edge_cut(&csr));
    }

    #[test]
    fn deterministic() {
        let csr = g(500, 3000);
        assert_eq!(partition_ldg(&csr, 4, 7).owner, partition_ldg(&csr, 4, 7).owner);
    }
}
