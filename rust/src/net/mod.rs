//! Network cost model: the simulated Slingshot fabric + DistDGL RPC layer.
//!
//! An α–β (latency–bandwidth) model with a contention term:
//!
//! * **Feature fetch** (DistDGL RPC with sender-side aggregation): one
//!   message per *owner partition* involved (not per node) + payload bytes
//!   at the effective per-trainer bandwidth.  Contention grows with the
//!   number of trainers sharing the fabric (log-factor, matching the
//!   paper's observation that communication rises under strong scaling).
//! * **Gradient allreduce** (DDP sync): ring allreduce, `2(p-1)/p × bytes`.
//!
//! Constants are config-overridable (`[net]` section) and were picked so
//! that scaled-down datasets land in the paper's regime: communication is
//! the dominant term for no-prefetch baselines and shrinks below compute
//! when the buffer absorbs most remote traffic.

/// Seconds, as used by the virtual clock.
pub type SimTime = f64;

#[derive(Debug, Clone)]
pub struct NetParams {
    /// Per-message latency (s) — RPC + transport setup.
    pub alpha: f64,
    /// Per-byte time (s/B) — inverse effective bandwidth per trainer.
    pub beta: f64,
    /// Contention growth per log2(trainers).
    pub contention: f64,
    /// Allreduce per-byte time (s/B) on the NCCL-like path.
    pub beta_allreduce: f64,
    /// Allreduce base latency per round (s).
    pub alpha_allreduce: f64,
}

impl Default for NetParams {
    fn default() -> Self {
        // beta is *scale-compensated*: the stand-in graphs are ~40x smaller
        // than the paper's, so per-minibatch fetches carry ~40x fewer nodes.
        // To preserve the paper's T_COMM/T_DDP ratio (communication
        // comparable to compute for no-prefetch baselines), the effective
        // per-trainer RPC throughput is divided by the same factor:
        // 600 MB/s raw DistDGL-RPC-over-TCP => ~15 MB/s compensated.
        NetParams {
            alpha: 1e-3,         // per aggregated RPC (python RPC stack)
            beta: 1.0 / 15e6,    // scale-compensated effective throughput
            contention: 0.18,
            beta_allreduce: 1.0 / 8e9,
            alpha_allreduce: 25e-6,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Network {
    pub params: NetParams,
    pub num_trainers: usize,
}

impl Network {
    pub fn new(params: NetParams, num_trainers: usize) -> Network {
        assert!(num_trainers >= 1);
        Network { params, num_trainers }
    }

    /// Contention multiplier for the current job size.
    #[inline]
    pub fn contention_factor(&self) -> f64 {
        1.0 + self.params.contention * (self.num_trainers as f64).log2().max(0.0)
    }

    /// Time to fetch `node_count` remote node features spread over
    /// `owner_parts` distinct partitions, each feature `feat_bytes` bytes.
    pub fn fetch_time(&self, node_count: usize, owner_parts: usize, feat_bytes: u64) -> SimTime {
        if node_count == 0 {
            return 0.0;
        }
        let msgs = owner_parts.max(1) as f64;
        let bytes = node_count as f64 * feat_bytes as f64;
        self.params.alpha * msgs + self.params.beta * bytes * self.contention_factor()
    }

    /// Same accounting, but only byte volume (for Fig 14 / Fig 20 series).
    pub fn fetch_bytes(&self, node_count: usize, feat_bytes: u64) -> u64 {
        node_count as u64 * feat_bytes
    }

    /// Ring-allreduce time for one gradient sync of `model_bytes`.
    pub fn allreduce_time(&self, model_bytes: u64) -> SimTime {
        let p = self.num_trainers as f64;
        if self.num_trainers == 1 {
            return 0.0;
        }
        let volume = 2.0 * (p - 1.0) / p * model_bytes as f64;
        let rounds = 2.0 * (p - 1.0);
        self.params.alpha_allreduce * rounds + self.params.beta_allreduce * volume
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(t: usize) -> Network {
        Network::new(NetParams::default(), t)
    }

    #[test]
    fn zero_nodes_zero_time() {
        assert_eq!(net(4).fetch_time(0, 0, 400), 0.0);
    }

    #[test]
    fn fetch_scales_with_nodes_and_bytes() {
        let n = net(4);
        let t1 = n.fetch_time(100, 3, 400);
        let t2 = n.fetch_time(200, 3, 400);
        let t3 = n.fetch_time(100, 3, 800);
        assert!(t2 > t1 && t3 > t1);
        assert!((t2 - t1) > 0.9 * (t3 - t1) && (t2 - t1) < 1.1 * (t3 - t1));
    }

    #[test]
    fn aggregation_beats_per_node_messages() {
        let n = net(4);
        let aggregated = n.fetch_time(1000, 3, 4);
        let per_node = 1000.0 * n.params.alpha + n.fetch_time(1000, 0, 4);
        assert!(aggregated < per_node / 10.0);
    }

    #[test]
    fn contention_grows_with_trainers() {
        assert!(net(64).contention_factor() > net(4).contention_factor());
        assert!((net(1).contention_factor() - 1.0).abs() < 1e-12);
        let t_small = net(4).fetch_time(500, 3, 400);
        let t_big = net(256).fetch_time(500, 3, 400);
        assert!(t_big > t_small);
    }

    #[test]
    fn allreduce_single_trainer_free() {
        assert_eq!(net(1).allreduce_time(1 << 20), 0.0);
    }

    #[test]
    fn allreduce_grows_sublinearly_in_p() {
        let b = 4u64 << 20;
        let t4 = net(4).allreduce_time(b);
        let t64 = net(64).allreduce_time(b);
        assert!(t64 > t4);
        // Volume term saturates at 2×bytes; growth beyond that is the
        // per-round latency term, linear in p: bounded by ~16x here.
        assert!(t64 < t4 * 16.0, "t4={t4} t64={t64}");
    }
}
