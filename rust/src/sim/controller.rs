//! Replacement controllers: the *when*-to-replace policies under test.
//!
//! * [`Controller::NoPrefetch`] — baseline DistDGL (variant 1, §5): no
//!   buffer, every remote node fetched every minibatch.
//! * [`Controller::Fixed`] — DistDGL+fixed (variant 2): replacement at
//!   every minibatch, overlapped.
//! * [`Controller::Agent`] — Rudder with an LLM agent (§4.3).
//! * [`Controller::Classifier`] — Rudder with an ML classifier (§4.4),
//!   optional online finetuning.
//! * [`Controller::MassiveGnn`] — the MassiveGNN comparator (§5.1):
//!   degree-prepopulated buffer + fixed replacement interval.
//! * [`Controller::Random`] — coin-flip controller used by trace-only mode
//!   to diversify offline training labels.

use crate::agent::backend::{LlmBackend, SimulatedLlm};
use crate::agent::decision::DecisionMaker;
use crate::agent::profiles::{self, LlmProfile};
use crate::agent::{Action, AgentStep, Observation};
use crate::classifier::finetune::OnlineFinetuner;
use crate::classifier::{features, DecisionModel, Kind};
use crate::util::rng::Pcg32;

pub enum Controller {
    NoPrefetch,
    Fixed,
    Agent(DecisionMaker),
    Classifier {
        model: Box<dyn DecisionModel>,
        finetuner: Option<OnlineFinetuner>,
    },
    MassiveGnn {
        interval: u64,
    },
    /// Cold-start fixed-interval replacement (Fig 3 cadence ablation) —
    /// MassiveGNN's cadence without its warm start.
    Interval {
        interval: u64,
    },
    Random {
        p: f64,
        rng: Pcg32,
    },
}

/// Controller selection, config-parsable.
#[derive(Debug, Clone, PartialEq)]
pub enum ControllerSpec {
    NoPrefetch,
    Fixed,
    Llm { model: String, cot: bool },
    Classifier { kind: Kind, finetune_interval: Option<usize> },
    MassiveGnn { interval: u64 },
    Interval { interval: u64 },
    Random { p: f64 },
}

impl ControllerSpec {
    /// Parse e.g. "none", "fixed", "llm:gemma3-4b", "clf:mlp",
    /// "clf:mlp:finetune=25", "massivegnn:32", "random:0.5".
    pub fn parse(s: &str) -> crate::error::Result<ControllerSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "none" | "distdgl" => Ok(ControllerSpec::NoPrefetch),
            "fixed" => Ok(ControllerSpec::Fixed),
            "llm" => {
                let model = parts.get(1).copied().unwrap_or("gemma3-4b").to_string();
                crate::ensure!(
                    profiles::by_name(&model).is_some(),
                    "unknown LLM '{model}' (try: {})",
                    profiles::names()
                );
                let cot = parts.contains(&"cot");
                Ok(ControllerSpec::Llm { model, cot })
            }
            "clf" | "classifier" => {
                let kind = Kind::parse(parts.get(1).copied().unwrap_or("mlp"))?;
                let finetune_interval = parts.iter().find_map(|p| {
                    p.strip_prefix("finetune=").and_then(|v| v.parse().ok())
                });
                Ok(ControllerSpec::Classifier { kind, finetune_interval })
            }
            "massivegnn" => {
                let interval = parts.get(1).and_then(|v| v.parse().ok()).unwrap_or(32);
                Ok(ControllerSpec::MassiveGnn { interval })
            }
            "interval" => {
                let interval = parts.get(1).and_then(|v| v.parse().ok()).unwrap_or(32);
                Ok(ControllerSpec::Interval { interval })
            }
            "random" => {
                let p = parts.get(1).and_then(|v| v.parse().ok()).unwrap_or(0.5);
                Ok(ControllerSpec::Random { p })
            }
            other => crate::bail!("unknown controller '{other}'"),
        }
    }

    /// The canonical spec string: `parse(spec()) == self`.  Used to pass
    /// configs to `--role` worker processes losslessly (labels are
    /// display-only and do not round-trip).
    pub fn spec(&self) -> String {
        match self {
            ControllerSpec::NoPrefetch => "none".into(),
            ControllerSpec::Fixed => "fixed".into(),
            ControllerSpec::Llm { model, cot } => {
                if *cot {
                    format!("llm:{model}:cot")
                } else {
                    format!("llm:{model}")
                }
            }
            ControllerSpec::Classifier { kind, finetune_interval } => {
                let base = format!("clf:{}", kind.name().to_ascii_lowercase());
                match finetune_interval {
                    Some(i) => format!("{base}:finetune={i}"),
                    None => base,
                }
            }
            ControllerSpec::MassiveGnn { interval } => format!("massivegnn:{interval}"),
            ControllerSpec::Interval { interval } => format!("interval:{interval}"),
            ControllerSpec::Random { p } => format!("random:{p}"),
        }
    }

    pub fn label(&self) -> String {
        match self {
            ControllerSpec::NoPrefetch => "DistDGL".into(),
            ControllerSpec::Fixed => "DistDGL+fixed".into(),
            ControllerSpec::Llm { model, .. } => format!("Rudder/{model}"),
            ControllerSpec::Classifier { kind, finetune_interval } => match finetune_interval {
                Some(i) => format!("Rudder/{}+F{i}", kind.name()),
                None => format!("Rudder/{}", kind.name()),
            },
            ControllerSpec::MassiveGnn { interval } => format!("MassiveGNN(r={interval})"),
            ControllerSpec::Interval { interval } => format!("Interval(r={interval})"),
            ControllerSpec::Random { p } => format!("Random(p={p})"),
        }
    }

    /// Does this spec use a persistent buffer at all?
    pub fn uses_buffer(&self) -> bool {
        !matches!(self, ControllerSpec::NoPrefetch)
    }

    /// Should the buffer be degree-prepopulated (MassiveGNN warm start)?
    pub fn prepopulates(&self) -> bool {
        matches!(self, ControllerSpec::MassiveGnn { .. })
    }

    /// Instantiate.  `pretrained` supplies the classifier model (offline
    /// training product); untrained classifiers fall back to a fresh model.
    pub fn build(
        &self,
        seed: u64,
        pretrained: Option<Box<dyn DecisionModel>>,
    ) -> Controller {
        match self {
            ControllerSpec::NoPrefetch => Controller::NoPrefetch,
            ControllerSpec::Fixed => Controller::Fixed,
            ControllerSpec::Llm { model, cot } => {
                let profile: &LlmProfile = profiles::by_name(model).expect("validated");
                let backend: Box<dyn LlmBackend> =
                    Box::new(SimulatedLlm::new(profile, seed, *cot));
                Controller::Agent(DecisionMaker::new(backend))
            }
            ControllerSpec::Classifier { kind, finetune_interval } => Controller::Classifier {
                model: pretrained.unwrap_or_else(|| kind.build(seed)),
                finetuner: finetune_interval.map(OnlineFinetuner::new),
            },
            ControllerSpec::MassiveGnn { interval } => {
                Controller::MassiveGnn { interval: *interval }
            }
            ControllerSpec::Interval { interval } => {
                Controller::Interval { interval: *interval }
            }
            ControllerSpec::Random { p } => {
                Controller::Random { p: *p, rng: Pcg32::new(seed) }
            }
        }
    }
}

impl Controller {
    /// Configure outcome-evaluation lag (async: 1, sync: 0) — see
    /// [`crate::agent::context::ContextBuilder::eval_lag`].
    pub fn set_eval_lag(&mut self, lag: usize) {
        if let Controller::Agent(dm) = self {
            dm.context.eval_lag = lag;
        }
    }

    /// Is this controller decision-driven (needs the async request/response
    /// queue machinery), as opposed to unconditional policies?
    pub fn is_inference_driven(&self) -> bool {
        matches!(self, Controller::Agent(_) | Controller::Classifier { .. })
    }

    /// One inference-driven decision.  Only meaningful for agent /
    /// classifier controllers; others decide structurally in the trainer.
    pub fn decide(&mut self, minibatch: u64, obs: &Observation) -> AgentStep {
        match self {
            Controller::Agent(dm) => dm.decide(minibatch, obs),
            Controller::Classifier { model, .. } => {
                let x = features::extract(obs);
                let p = model.predict(&x);
                AgentStep {
                    action: if p > 0.5 { Action::Replace } else { Action::Skip },
                    prediction: None, // classifiers are stateless: no expectation
                    latency: model.latency(),
                    valid_response: true,
                    raw_response: format!("{{\"p_replace\": {p:.4}}}"),
                }
            }
            Controller::Random { p, rng } => {
                let replace = rng.chance(*p);
                AgentStep {
                    action: if replace { Action::Replace } else { Action::Skip },
                    prediction: None,
                    latency: 1e-4,
                    valid_response: true,
                    raw_response: String::new(),
                }
            }
            _ => AgentStep {
                action: Action::Skip,
                prediction: None,
                latency: 0.0,
                valid_response: true,
                raw_response: String::new(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_all_forms() {
        assert_eq!(ControllerSpec::parse("none").unwrap(), ControllerSpec::NoPrefetch);
        assert_eq!(ControllerSpec::parse("fixed").unwrap(), ControllerSpec::Fixed);
        assert_eq!(
            ControllerSpec::parse("llm:gemma3-4b").unwrap(),
            ControllerSpec::Llm { model: "gemma3-4b".into(), cot: false }
        );
        assert_eq!(
            ControllerSpec::parse("llm:llama3.2-3b:cot").unwrap(),
            ControllerSpec::Llm { model: "llama3.2-3b".into(), cot: true }
        );
        assert_eq!(
            ControllerSpec::parse("clf:rf").unwrap(),
            ControllerSpec::Classifier { kind: Kind::RandomForest, finetune_interval: None }
        );
        assert_eq!(
            ControllerSpec::parse("clf:mlp:finetune=25").unwrap(),
            ControllerSpec::Classifier { kind: Kind::Mlp, finetune_interval: Some(25) }
        );
        assert_eq!(
            ControllerSpec::parse("massivegnn:16").unwrap(),
            ControllerSpec::MassiveGnn { interval: 16 }
        );
        assert!(ControllerSpec::parse("llm:gpt5").is_err());
        assert!(ControllerSpec::parse("banana").is_err());
    }

    #[test]
    fn spec_string_round_trips() {
        for s in [
            "none",
            "fixed",
            "llm:gemma3-4b",
            "llm:llama3.2-3b:cot",
            "clf:mlp",
            "clf:rf:finetune=25",
            "clf:tabnet",
            "massivegnn:16",
            "interval:8",
            "random:0.5",
        ] {
            let spec = ControllerSpec::parse(s).unwrap();
            let back = ControllerSpec::parse(&spec.spec()).unwrap();
            assert_eq!(spec, back, "spec '{s}' must round-trip through spec()");
        }
    }

    #[test]
    fn labels_are_informative() {
        assert_eq!(ControllerSpec::parse("none").unwrap().label(), "DistDGL");
        assert_eq!(
            ControllerSpec::parse("clf:mlp:finetune=5").unwrap().label(),
            "Rudder/MLP+F5"
        );
        assert!(ControllerSpec::parse("llm:gemma3-4b").unwrap().label().contains("gemma3-4b"));
    }

    #[test]
    fn classifier_controller_decides() {
        let spec = ControllerSpec::parse("clf:lr").unwrap();
        let mut c = spec.build(1, None);
        assert!(c.is_inference_driven());
        let step = c.decide(0, &Observation::default());
        assert!(step.valid_response);
        assert!(step.latency > 0.0);
    }

    #[test]
    fn random_controller_mixes_actions() {
        let mut c = ControllerSpec::parse("random:0.5").unwrap().build(3, None);
        let mut replaces = 0;
        for i in 0..100 {
            if c.decide(i, &Observation::default()).action == Action::Replace {
                replaces += 1;
            }
        }
        assert!((20..=80).contains(&replaces), "{replaces}");
    }

    #[test]
    fn buffer_usage_flags() {
        assert!(!ControllerSpec::NoPrefetch.uses_buffer());
        assert!(ControllerSpec::Fixed.uses_buffer());
        assert!(ControllerSpec::parse("massivegnn").unwrap().prepopulates());
        assert!(!ControllerSpec::Fixed.prepopulates());
    }
}
