//! Discrete-event simulation of the distributed training cluster.
//!
//! Virtual per-trainer clocks advance by the §4.5.3 overlap arithmetic;
//! the DDP allreduce is a per-minibatch barrier; the inference daemon is a
//! single-slot pipeline whose responses materialize after the model's
//! latency ([`queues`]).  Deterministic: same config + seed ⇒ identical
//! results.

pub mod controller;
pub mod queues;
pub mod run;
pub mod trainer;

pub use controller::{Controller, ControllerSpec};
pub use run::{
    build_cluster, build_trainer, max_minibatches_per_epoch, run_experiment, run_on, trace_only,
    ExperimentResult, RunConfig,
};
pub use trainer::{FetchPlan, Mode};
