//! Per-trainer state machine: Algorithm 1 in virtual time.
//!
//! Each minibatch runs the prefetcher path (sample → buffer lookup →
//! decision poll/apply → fetch) and the training path (T_DDP), composing
//! their times per the §4.5.3 overlap model:
//!
//! * async (default): `step = T_DDP + max(0, T_prefetch − T_DDP_prev)` —
//!   the prefetcher prepared this minibatch while the previous one trained;
//!   only the excess is exposed.
//! * sync: the trainer additionally stalls for the in-flight decision every
//!   minibatch (`r = 1`).
//! * no-prefetch baseline: fully serialized `T_sample + T_COMM + T_DDP`.

use crate::agent::{Action, Observation};
use crate::buffer::scoring::Policy;
use crate::buffer::{PersistentBuffer, ReplaceOutcome};
use crate::classifier::labeling::TraceStep;
use crate::classifier::{features, DecisionModel};
use crate::gnn::{AnalyticModel, SageRunner};
use crate::graph::features::feat_bytes;
use crate::graph::Dataset;
use crate::metrics::{DecisionRecord, MinibatchRecord, RunMetrics};
use crate::net::Network;
use crate::partition::Partition;
use crate::sampler::Sampler;
use crate::util::stats::Ema;

use super::controller::Controller;
use super::queues::{InferencePipe, Pending};

/// Sampling cost per sampled node id (CPU neighbor-sampler path).
pub const SAMPLE_COST_PER_NODE: f64 = 1.2e-7;

/// Replacement-round execution cost (paper §2.1's "excessive replacements"
/// penalty): evicting/admitting runs on the trainer host's CPU threads
/// (ThreadPoolExecutor + NUMBA in the paper), contending with the DDP
/// dataloader — so it is charged *unhidden*.  Per-node cost scales with the
/// feature payload copied into the buffer.
pub const REPLACE_BASE_COST: f64 = 6.0e-3;
pub const REPLACE_NODE_COST: f64 = 2.0e-6;
pub const REPLACE_BYTE_COST: f64 = 1.5e-8;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Async,
    Sync,
}

impl Mode {
    pub fn parse(s: &str) -> crate::error::Result<Mode> {
        match s {
            "async" => Ok(Mode::Async),
            "sync" => Ok(Mode::Sync),
            _ => crate::bail!("unknown mode '{s}' (async|sync)"),
        }
    }
}

/// What the in-process cluster runtime ([`crate::cluster`]) must do for one
/// minibatch: the node sets to move over the real RPC path plus the compute
/// time to emulate.  Filled by [`Trainer::step_minibatch`] when
/// `fetch_plan` is armed (`Some`) — virtual-time accounting stays the
/// single source of truth for *what* is fetched (traffic parity by
/// construction); the cluster runtime decides *how* the bytes move.
#[derive(Debug, Clone, Default)]
pub struct FetchPlan {
    /// Unique remote nodes sampled this minibatch (hits + misses): the
    /// cluster trainer blocks until all their features are resident.
    pub unique_remote: Vec<u32>,
    /// Buffer misses — fetched urgently for this minibatch.
    pub missed: Vec<u32>,
    /// Replacement admissions — prefetched asynchronously (overlap).
    pub admitted: Vec<u32>,
    /// Replacement evictions — dropped from the feature store.
    pub evicted: Vec<u32>,
    /// Virtual T_DDP of this minibatch.  This stays the *modelled* cost in
    /// every compute mode — the virtual clock (and with it every decision
    /// and traffic counter) must remain a pure function of config + seed;
    /// measured compute only changes what happens on the wall clock.
    pub t_ddp: f64,
    /// The sampled minibatch itself, captured only when
    /// [`Trainer::capture_minibatch`] is set: the cluster runtime's
    /// measured mode replays it through the real [`SageRunner`].
    pub minibatch: Option<crate::sampler::Minibatch>,
    /// Target-node count of this minibatch (trace capture / replay).
    pub targets: u64,
    /// Total sampled-node count of this minibatch (trace capture / replay).
    pub sampled: u64,
    /// Fetch-blocked time of this step: the part of the prefetch path
    /// (`t_sample + t_comm`) the previous minibatch's compute could not
    /// hide (the whole path for the no-prefetch baseline).
    pub t_exposed: f64,
}

/// One recorded minibatch demand — the payload of a
/// [`crate::trace::EventKind::SampleDemand`] event, fed back into
/// [`Trainer::step_minibatch`] by [`crate::replay`] in place of live
/// sampling.
#[derive(Debug, Clone, Default)]
pub struct DemandRecord {
    pub targets: u64,
    pub sampled: u64,
    pub unique_remote: Vec<u32>,
}

/// Replay-sourced demand for one trainer: records indexed by
/// `epoch * max_mb_per_epoch + mb`; `None` marks a round this trainer sat
/// out (short partition).
#[derive(Debug, Clone, Default)]
pub struct DemandSource {
    pub max_mb_per_epoch: usize,
    pub records: Vec<Option<DemandRecord>>,
}

impl DemandSource {
    pub fn get(&self, epoch: usize, mb: usize) -> Option<&DemandRecord> {
        self.records.get(epoch * self.max_mb_per_epoch + mb).and_then(|r| r.as_ref())
    }
}

/// Where one minibatch's demand came from: live seed-driven sampling, or
/// a replayed [`DemandRecord`].  Both expose the same three quantities
/// the state machine consumes.
enum Demand {
    Sampled(crate::sampler::Minibatch),
    Replayed(DemandRecord),
}

impl Demand {
    fn targets_len(&self) -> usize {
        match self {
            Demand::Sampled(m) => m.targets.len(),
            Demand::Replayed(r) => usize::try_from(r.targets).unwrap_or(usize::MAX),
        }
    }

    fn num_sampled(&self) -> u64 {
        match self {
            Demand::Sampled(m) => m.num_sampled() as u64,
            Demand::Replayed(r) => r.sampled,
        }
    }

    fn unique_remote(&self) -> &[u32] {
        match self {
            Demand::Sampled(m) => &m.unique_remote,
            Demand::Replayed(r) => &r.unique_remote,
        }
    }
}

/// Immutable per-run context shared by all trainers.
pub struct RunCtx<'a> {
    pub ds: &'a Dataset,
    pub part: &'a Partition,
    pub net: Network,
    pub compute: AnalyticModel,
    pub mode: Mode,
    pub epochs_total: usize,
    /// Total planned minibatches for progress-awareness observations.
    pub total_minibatches: u64,
}

/// The MetricsCollector (§4.2): maintains trends and renders observations.
#[derive(Debug)]
pub struct MetricsTracker {
    ema_comm: Ema,
    ema_hits: Ema,
    last_sent_hits: f64,
    last_sent_comm: f64,
    pub last_hits: f64,
    pub last_comm_nodes: u64,
    pub last_replaced_frac: f64,
}

impl Default for MetricsTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsTracker {
    pub fn new() -> MetricsTracker {
        MetricsTracker {
            ema_comm: Ema::new(0.3),
            ema_hits: Ema::new(0.4),
            last_sent_hits: 0.0,
            last_sent_comm: 0.0,
            last_hits: 0.0,
            last_comm_nodes: 0,
            last_replaced_frac: 0.0,
        }
    }

    /// Push the current minibatch's raw %-Hits (called once per minibatch,
    /// right after the buffer lookup).  The controller sees a short EMA —
    /// the MetricsCollector aggregation of §4.2 — damping per-minibatch
    /// sampling noise at the scaled batch sizes (the paper's batch-2000
    /// signal is naturally smooth).
    pub fn push_hits(&mut self, hits: f64) {
        self.last_hits = self.ema_hits.push(hits);
    }

    pub fn end_minibatch(&mut self, comm_nodes: u64, replaced_frac: f64) {
        self.last_comm_nodes = comm_nodes;
        self.ema_comm.push(comm_nodes as f64);
        if replaced_frac > 0.0 {
            self.last_replaced_frac = replaced_frac;
        }
    }

    /// Build the observation sent to the controller; records what was sent
    /// so the next observation carries deltas.
    #[allow(clippy::too_many_arguments)]
    pub fn observe(
        &mut self,
        buffer: &PersistentBuffer,
        ctx: &RunCtx,
        epoch: usize,
        global_mb: u64,
        halo2_len: usize,
        part_id: usize,
    ) -> Observation {
        let obs = Observation {
            hits_pct: self.last_hits,
            buffer_occupancy_pct: buffer.occupancy() * 100.0,
            stale_pct: if buffer.capacity() > 0 {
                buffer.stale_count() as f64 / buffer.capacity() as f64 * 100.0
            } else {
                0.0
            },
            replaced_pct_last: self.last_replaced_frac * 100.0,
            comm_nodes_last: self.last_comm_nodes,
            comm_nodes_ema: self.ema_comm.get().unwrap_or(0.0),
            minibatches_done: global_mb,
            minibatches_pending: ctx.total_minibatches.saturating_sub(global_mb),
            epoch,
            epochs_total: ctx.epochs_total,
            delta_hits: self.last_hits - self.last_sent_hits,
            delta_comm: self.last_comm_nodes as f64 - self.last_sent_comm,
            graph_nodes: ctx.ds.csr.num_nodes() as u64,
            graph_edges: (ctx.ds.csr.num_arcs() / 2) as u64,
            partition_nodes: ctx.part.local_nodes[part_id].len() as u64,
            halo_nodes: halo2_len as u64,
            buffer_capacity: buffer.capacity() as u64,
        };
        self.last_sent_hits = self.last_hits;
        self.last_sent_comm = self.last_comm_nodes as f64;
        obs
    }
}

pub struct Trainer {
    pub part_id: usize,
    pub clock: f64,
    pub buffer: PersistentBuffer,
    pub sampler: Sampler,
    pub controller: Controller,
    pub pipe: InferencePipe,
    pub tracker: MetricsTracker,
    pub metrics: RunMetrics,
    pub train_nodes: Vec<u32>,
    /// Optional measured-compute runner (e2e example / calibration).
    pub runner: Option<SageRunner>,
    /// Optional trace-only recording (classifier offline data).
    pub trace: Option<Vec<TraceStep>>,
    /// When armed (`Some`), each minibatch leaves its I/O choreography
    /// here for the cluster runtime to execute ([`FetchPlan`]).
    pub fetch_plan: Option<FetchPlan>,
    /// Also leave the sampled minibatch in the fetch plan (measured-compute
    /// consumers).  Off by default: the clone is pure overhead otherwise.
    pub capture_minibatch: bool,
    /// When armed, minibatch demand comes from a recorded trace instead of
    /// the live sampler ([`crate::replay`]).  The sampler is never invoked;
    /// the controller/buffer/cost machinery runs unchanged.
    pub demand: Option<DemandSource>,
    pub halo2_len: usize,
    prev_t_ddp: f64,
    global_mb: u64,
    /// Record index of the latest *issued* (pending) decision.
    open_decision: Option<usize>,
    /// Record index of the latest *applied* decision whose outcome has not
    /// been measured yet (closed at the next decision-processing point).
    applied_decision: Option<usize>,
}

impl Trainer {
    pub fn new(
        part_id: usize,
        buffer_capacity: usize,
        halo2_len: usize,
        sampler: Sampler,
        controller: Controller,
        train_nodes: Vec<u32>,
    ) -> Trainer {
        Trainer {
            part_id,
            clock: 0.0,
            buffer: PersistentBuffer::new(buffer_capacity, Policy::FreqDecay),
            sampler,
            controller,
            pipe: InferencePipe::new(),
            tracker: MetricsTracker::new(),
            metrics: RunMetrics::default(),
            train_nodes,
            runner: None,
            trace: None,
            fetch_plan: None,
            capture_minibatch: false,
            demand: None,
            halo2_len,
            prev_t_ddp: 0.0,
            global_mb: 0,
            open_decision: None,
            applied_decision: None,
        }
    }

    pub fn minibatches_per_epoch(&self) -> usize {
        self.sampler.minibatches_per_epoch(self.train_nodes.len())
    }

    fn do_replace(&mut self) -> (bool, ReplaceOutcome, f64) {
        let out = self.buffer.replace();
        let effective = !out.skipped && (out.evicted + out.inserted) > 0;
        let frac = if self.buffer.capacity() > 0 {
            out.inserted as f64 / self.buffer.capacity() as f64
        } else {
            0.0
        };
        (effective, out, frac)
    }

    /// Close the last *applied* decision record with the current smoothed
    /// %-Hits — its action has now had a full decision interval to act.
    fn close_applied(&mut self) {
        if let Some(i) = self.applied_decision.take() {
            self.metrics.decisions[i].hits_after = Some(self.tracker.last_hits);
        }
    }

    fn issue_decision(&mut self, obs: &Observation, epoch_mb: u64, now: f64) -> Pending {
        let step = self.controller.decide(self.global_mb, obs);
        self.metrics.decisions.push(DecisionRecord {
            minibatch: self.global_mb as usize,
            replace: step.action == Action::Replace,
            prediction: step.prediction,
            valid_response: step.valid_response,
            hits_before: obs.hits_pct,
            hits_after: None,
            latency: step.latency,
        });
        self.open_decision = Some(self.metrics.decisions.len() - 1);
        Pending { issued_mb: epoch_mb, issued_at: now, ready_at: now + step.latency, step }
    }

    /// Run one minibatch; returns `false` when this trainer has no work at
    /// this index (short partition).
    pub fn step_minibatch(
        &mut self,
        ctx: &RunCtx,
        epoch: usize,
        mb: usize,
        epoch_order: &[u32],
    ) -> bool {
        let demand = if self.demand.is_some() {
            match self.demand.as_ref().and_then(|s| s.get(epoch, mb)).cloned() {
                Some(rec) => Demand::Replayed(rec),
                None => return false,
            }
        } else {
            let mbatch = self.sampler.sample(&ctx.ds.csr, ctx.part, epoch_order, epoch, mb);
            if mbatch.targets.is_empty() {
                return false;
            }
            Demand::Sampled(mbatch)
        };
        self.global_mb += 1;
        let fb = feat_bytes(ctx.ds.spec.feat_dim);
        let fb_cost = fb as f64 * REPLACE_BYTE_COST;
        let t_sample = SAMPLE_COST_PER_NODE * demand.num_sampled() as f64;

        // --- prefetcher: buffer lookup ---------------------------------
        let lookup = self.buffer.lookup(demand.unique_remote());
        let hits = lookup.hits_pct();
        self.tracker.push_hits(hits);

        // --- decision machinery -----------------------------------------
        let mut replaced = false;
        let mut replace_out = ReplaceOutcome::default();
        let mut replaced_frac = 0.0;
        let mut sync_stall = 0.0;
        enum Kind {
            Inert,
            Fixed,
            MassiveGnn(u64),
            Inference,
        }
        let kind = match &self.controller {
            Controller::NoPrefetch => Kind::Inert,
            Controller::Fixed => Kind::Fixed,
            Controller::MassiveGnn { interval } | Controller::Interval { interval } => {
                Kind::MassiveGnn(*interval)
            }
            Controller::Agent(_) | Controller::Classifier { .. } | Controller::Random { .. } => {
                Kind::Inference
            }
        };
        match kind {
            Kind::Inert => {}
            Kind::Fixed => {
                let (r, out, fr) = self.do_replace();
                replaced = r;
                replace_out = out;
                replaced_frac = fr;
            }
            Kind::MassiveGnn(interval) => {
                if interval > 0 && self.global_mb % interval == 0 {
                    let (r, out, fr) = self.do_replace();
                    replaced = r;
                    replace_out = out;
                    replaced_frac = fr;
                }
            }
            Kind::Inference => match ctx.mode {
                Mode::Sync => {
                    // Trainer waits for the decision every minibatch.  The
                    // previously applied decision's outcome is now visible.
                    self.close_applied();
                    let obs = self.tracker.observe(
                        &self.buffer, ctx, epoch, self.global_mb, self.halo2_len, self.part_id,
                    );
                    let pending = self.issue_decision(&obs, mb as u64, self.clock);
                    sync_stall = pending.step.latency;
                    self.applied_decision = self.open_decision.take();
                    if pending.step.action == Action::Replace {
                        let (r, out, fr) = self.do_replace();
                        replaced = r;
                        replace_out = out;
                        replaced_frac = fr;
                    }
                }
                Mode::Async => {
                    // Non-blocking poll (Algorithm 1 line 12).
                    if let Some(p) = self.pipe.poll(self.clock) {
                        // Outcome of the decision applied at the previous
                        // poll point is now measurable.
                        self.close_applied();
                        if p.step.action == Action::Replace {
                            let (r, out, fr) = self.do_replace();
                            replaced = r;
                            replace_out = out;
                            replaced_frac = fr;
                        }
                        // The polled decision is now applied; measure its
                        // outcome at the next poll.
                        self.applied_decision = self.open_decision.take();
                        // Clear stale requests + notify + fresh metrics
                        // (lines 15-19).
                        let obs = self.tracker.observe(
                            &self.buffer, ctx, epoch, self.global_mb, self.halo2_len, self.part_id,
                        );
                        let pending = self.issue_decision(&obs, mb as u64, self.clock);
                        self.pipe.submit(pending);
                    } else if !self.pipe.busy() {
                        // Bootstrap: first request of the run.
                        let obs = self.tracker.observe(
                            &self.buffer, ctx, epoch, self.global_mb, self.halo2_len, self.part_id,
                        );
                        let pending = self.issue_decision(&obs, mb as u64, self.clock);
                        self.pipe.submit(pending);
                    }
                }
            },
        }

        // Unhidden replacement-processing cost (CPU contention).
        let replace_fetch = replace_out.fetched_nodes.len();
        let t_replace = if replaced {
            REPLACE_BASE_COST
                + replace_fetch as f64 * (REPLACE_NODE_COST + fb_cost)
        } else {
            0.0
        };

        // --- communication ----------------------------------------------
        let fetch_nodes = lookup.missed_nodes.len() + replace_fetch;
        let owners = distinct_owners(ctx.part, self.part_id, &lookup.missed_nodes);
        let t_comm = ctx.net.fetch_time(fetch_nodes, owners.max(1), fb);
        let comm_bytes = ctx.net.fetch_bytes(fetch_nodes, fb);

        // --- training (T_DDP) -------------------------------------------
        // Replayed demand carries no node lists for the runner; replay
        // never arms one, so the analytic model path is always taken.
        let t_ddp = match (self.runner.as_mut(), &demand) {
            (Some(runner), Demand::Sampled(mbatch)) => {
                match runner.train_step(mbatch, ctx.ds.feature_seed, &ctx.ds.labels) {
                    Ok((_loss, dt)) => dt,
                    Err(e) => {
                        crate::log_info!("runtime train step failed ({e}); falling back to model");
                        ctx.compute.step_time(demand.targets_len())
                    }
                }
            }
            _ => ctx.compute.step_time(demand.targets_len()),
        };

        // --- fetch-blocked exposure (§4.5.3) ----------------------------
        let prefetch_path = t_sample + t_comm;
        let t_exposed = match &self.controller {
            Controller::NoPrefetch => prefetch_path,
            _ => (prefetch_path - self.prev_t_ddp).max(0.0),
        };

        // --- cluster I/O choreography (real-runtime consumers) ----------
        if let Some(plan) = self.fetch_plan.as_mut() {
            plan.unique_remote.clear();
            plan.unique_remote.extend_from_slice(demand.unique_remote());
            plan.missed.clone_from(&lookup.missed_nodes);
            plan.admitted.clone_from(&replace_out.fetched_nodes);
            plan.evicted.clone_from(&replace_out.evicted_nodes);
            plan.t_ddp = t_ddp;
            plan.targets = demand.targets_len() as u64;
            plan.sampled = demand.num_sampled();
            plan.t_exposed = t_exposed;
            if self.capture_minibatch {
                if let Demand::Sampled(mbatch) = &demand {
                    plan.minibatch = Some(mbatch.clone());
                }
            }
        }

        // --- online finetuning (classifier option) ----------------------
        let mut finetune_overhead = 0.0;
        if let Controller::Classifier { model, finetuner: Some(ft) } = &mut self.controller {
            let obs_now = Observation {
                hits_pct: hits,
                comm_nodes_last: fetch_nodes as u64,
                ..Default::default()
            };
            let x = features::extract(&obs_now);
            finetune_overhead = ft.observe(
                TraceStep { x, hits_pct: hits, comm_time: t_comm, replaced },
                model.as_mut() as &mut dyn DecisionModel,
            );
        }

        // --- trace-only recording ---------------------------------------
        if let Some(trace) = self.trace.as_mut() {
            // Cheap observation snapshot for offline features.
            let occupancy = self.buffer.occupancy() * 100.0;
            let stale_pct = if self.buffer.capacity() > 0 {
                self.buffer.stale_count() as f64 / self.buffer.capacity() as f64 * 100.0
            } else {
                0.0
            };
            let obs_now = Observation {
                hits_pct: hits,
                buffer_occupancy_pct: occupancy,
                stale_pct,
                comm_nodes_last: fetch_nodes as u64,
                minibatches_done: self.global_mb,
                minibatches_pending: ctx.total_minibatches.saturating_sub(self.global_mb),
                epoch,
                epochs_total: ctx.epochs_total,
                graph_nodes: ctx.ds.csr.num_nodes() as u64,
                halo_nodes: self.halo2_len as u64,
                buffer_capacity: self.buffer.capacity() as u64,
                ..Default::default()
            };
            trace.push(TraceStep {
                x: features::extract(&obs_now),
                hits_pct: hits,
                comm_time: t_comm,
                replaced,
            });
        }

        // --- compose step time (§4.5.3) ---------------------------------
        // `t_exposed` above is the whole prefetch path for the no-prefetch
        // baseline (fully serialized) and only the unhidden excess
        // otherwise, so both arms compose the same way.
        let step_time = match &self.controller {
            Controller::NoPrefetch => t_exposed + t_ddp,
            _ => t_ddp + t_exposed + t_replace + sync_stall + finetune_overhead,
        };
        self.prev_t_ddp = t_ddp;
        self.clock += step_time;

        // --- close out the minibatch ------------------------------------
        let stale = self.buffer.end_round();
        let _ = stale;
        self.tracker.end_minibatch(fetch_nodes as u64, replaced_frac);
        self.metrics.minibatches.push(MinibatchRecord {
            epoch,
            minibatch: self.global_mb as usize,
            trainer: self.part_id,
            hits_pct: hits,
            hits: lookup.hits as u64,
            comm_nodes: fetch_nodes as u64,
            comm_bytes,
            unique_remote: demand.unique_remote().len() as u64,
            buffer_occupancy: self.buffer.occupancy(),
            step_time,
            replaced,
            replaced_frac,
        });
        true
    }
}

/// Number of distinct owner partitions among `nodes` (RPC aggregation).
fn distinct_owners(part: &Partition, me: usize, nodes: &[u32]) -> usize {
    let mut seen = [false; 1024];
    let mut count = 0;
    for &v in nodes {
        let o = part.owner_of(v);
        if o != me && !seen[o % 1024] {
            seen[o % 1024] = true;
            count += 1;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse() {
        assert_eq!(Mode::parse("async").unwrap(), Mode::Async);
        assert_eq!(Mode::parse("sync").unwrap(), Mode::Sync);
        assert!(Mode::parse("semi").is_err());
    }
}
