//! Experiment runner: builds the cluster, steps all trainers epoch by
//! epoch with the per-minibatch DDP barrier, and aggregates results.

use crate::buffer::scoring::Policy;
use crate::classifier::trainer::TrainingSet;
use crate::gnn::{AnalyticModel, ComputeParams, SageShape};
use crate::graph::{datasets, Dataset};
use crate::massivegnn;
use crate::metrics::RunMetrics;
use crate::net::{NetParams, Network};
use crate::partition::{self, Method, Partition};
use crate::sampler::Sampler;
use crate::util::rng::derive_seed;
use crate::util::stats;

use super::controller::ControllerSpec;
use super::trainer::{Mode, RunCtx, Trainer};

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset: String,
    /// Dataset scale multiplier (1.0 = registry stand-in size).
    pub scale: f64,
    pub seed: u64,
    pub num_trainers: usize,
    pub batch_size: usize,
    pub fanout1: usize,
    pub fanout2: usize,
    /// Buffer capacity as a fraction of the 2-hop halo (paper's 5%/25%).
    pub buffer_pct: f64,
    pub epochs: usize,
    pub controller: ControllerSpec,
    pub mode: Mode,
    pub partition_method: Method,
    pub net: NetParams,
    pub compute: ComputeParams,
    pub hidden: usize,
    /// Buffer scoring policy (FreqDecay = the paper's; Lfu/Lru = Fig 4
    /// ablation baselines).
    pub buffer_policy: Policy,
    /// Rows per content-addressed feature chunk (cluster feature plane).
    pub chunk_rows: usize,
    /// Per-link chunk-cache budget in bytes; 0 disables the chunk protocol
    /// entirely (trainers fall back to plain `FetchReq`/`FetchResp`).
    pub chunk_cache_bytes: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "products".into(),
            scale: 0.2,
            seed: 42,
            num_trainers: 4,
            batch_size: 32,
            fanout1: 10,
            fanout2: 25,
            buffer_pct: 0.25,
            epochs: 5,
            controller: ControllerSpec::Fixed,
            mode: Mode::Async,
            partition_method: Method::MetisLike,
            net: NetParams::default(),
            compute: ComputeParams::default(),
            hidden: 128,
            buffer_policy: Policy::FreqDecay,
            chunk_rows: 32,
            chunk_cache_bytes: 0,
        }
    }
}

/// Aggregated outcome of one run.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    pub label: String,
    pub per_trainer: Vec<RunMetrics>,
    pub mean_epoch_time: f64,
    pub mean_hits_pct: f64,
    pub steady_hits_pct: f64,
    pub total_comm_nodes: u64,
    pub total_comm_bytes: u64,
    pub p99_comm_nodes: f64,
    pub replacement_interval: f64,
    pub valid_response_pct: f64,
    pub positive_decision_pct: f64,
}

impl ExperimentResult {
    /// Aggregate per-trainer series into the run-level summary.  Public so
    /// the cluster runtime ([`crate::cluster`]) reports through the same
    /// shape as the virtual-time sim.
    pub fn aggregate(label: String, per_trainer: Vec<RunMetrics>, epoch_times: Vec<f64>) -> Self {
        let mean_hits = stats::mean(
            &per_trainer.iter().map(RunMetrics::mean_hits_pct).collect::<Vec<_>>(),
        );
        let steady = stats::mean(
            &per_trainer.iter().map(RunMetrics::steady_hits_pct).collect::<Vec<_>>(),
        );
        let p99 = stats::mean(
            &per_trainer
                .iter()
                .map(|m| m.comm_nodes_percentile(99.0))
                .collect::<Vec<_>>(),
        );
        let r = stats::mean(
            &per_trainer
                .iter()
                .map(RunMetrics::replacement_interval)
                .collect::<Vec<_>>(),
        );
        let (mut valid, mut invalid) = (0u64, 0u64);
        let mut pos_samples = Vec::new();
        for m in &per_trainer {
            let (v, i) = m.response_counts();
            valid += v;
            invalid += i;
            if v + i > 0 {
                pos_samples.push(m.decision_split().0);
            }
        }
        ExperimentResult {
            label,
            mean_epoch_time: stats::mean(&epoch_times),
            mean_hits_pct: mean_hits,
            steady_hits_pct: steady,
            total_comm_nodes: per_trainer.iter().map(RunMetrics::total_comm_nodes).sum(),
            total_comm_bytes: per_trainer.iter().map(RunMetrics::total_comm_bytes).sum(),
            p99_comm_nodes: p99,
            replacement_interval: r,
            valid_response_pct: if valid + invalid > 0 {
                valid as f64 / (valid + invalid) as f64 * 100.0
            } else {
                100.0
            },
            positive_decision_pct: stats::mean(&pos_samples),
            per_trainer,
        }
    }
}

/// Build (or rebuild) the dataset + partition for a config.  Exposed so
/// harnesses can share one graph across variant sweeps.
pub fn build_cluster(cfg: &RunConfig) -> crate::error::Result<(Dataset, Partition)> {
    let ds = Dataset::build_by_name(&cfg.dataset, cfg.scale, cfg.seed)?;
    let part = partition::partition(
        &ds.csr,
        cfg.num_trainers,
        cfg.partition_method,
        derive_seed(cfg.seed, &[7]),
    );
    Ok((ds, part))
}

/// Run a full experiment (dataset built internally).
pub fn run_experiment(cfg: &RunConfig) -> crate::error::Result<ExperimentResult> {
    let (ds, part) = build_cluster(cfg)?;
    Ok(run_on(&ds, &part, cfg, None))
}

/// Build one trainer exactly as [`run_on`] does.  Shared with the cluster
/// runtime ([`crate::cluster`]) so both runtimes derive identical samplers,
/// controllers, buffers, and per-trainer seeds — the foundation of the
/// traffic-parity guarantee.
pub fn build_trainer(
    cfg: &RunConfig,
    ds: &Dataset,
    part: &Partition,
    p: usize,
    offline: Option<&TrainingSet>,
) -> Trainer {
    let train_nodes = part.train_nodes_of(p, &ds.train_nodes);
    let halo2 = part.halo_k(&ds.csr, p, 2);
    let capacity = if cfg.controller.uses_buffer() {
        ((halo2.len() as f64 * cfg.buffer_pct) as usize).max(1)
    } else {
        0
    };
    let sampler = Sampler::new(
        p,
        cfg.batch_size,
        cfg.fanout1,
        cfg.fanout2,
        derive_seed(cfg.seed, &[p as u64, 0x5A]),
    );
    let pretrained = offline.map(|set| {
        if let ControllerSpec::Classifier { kind, .. } = &cfg.controller {
            let mut model = kind.build(derive_seed(cfg.seed, &[p as u64, 0xC1]));
            if !set.is_empty() {
                model.fit(&set.xs, &set.ys);
            }
            model
        } else {
            crate::classifier::Kind::LogReg.build(0)
        }
    });
    let mut controller = cfg
        .controller
        .build(derive_seed(cfg.seed, &[p as u64, 0xA6]), pretrained);
    controller.set_eval_lag(if cfg.mode == Mode::Async { 1 } else { 0 });
    let mut t = Trainer::new(p, capacity, halo2.len(), sampler, controller, train_nodes);
    t.buffer = crate::buffer::PersistentBuffer::new(capacity, cfg.buffer_policy);
    if cfg.controller.prepopulates() {
        let order = massivegnn::prefetch_order(&ds.csr, part, p, capacity);
        t.buffer.prepopulate(&order);
    }
    t
}

/// Max minibatches-per-epoch across trainers — the number of DDP barrier
/// rounds per epoch.  Shared with the cluster runtime, whose allreduce hub
/// must agree on the round count before spawning threads.
pub fn max_minibatches_per_epoch(cfg: &RunConfig, ds: &Dataset, part: &Partition) -> usize {
    (0..cfg.num_trainers)
        .map(|p| {
            part.train_nodes_of(p, &ds.train_nodes)
                .len()
                .div_ceil(cfg.batch_size)
                .max(1)
        })
        .max()
        .unwrap_or(1)
}

/// Run on a pre-built cluster.  `offline` supplies classifier training
/// data (required for meaningful classifier controllers).
pub fn run_on(
    ds: &Dataset,
    part: &Partition,
    cfg: &RunConfig,
    offline: Option<&TrainingSet>,
) -> ExperimentResult {
    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), cfg.num_trainers);
    let compute = AnalyticModel::new(cfg.compute.clone(), shape);
    let allreduce = net.allreduce_time(shape.param_bytes());

    // Build trainers (shared constructor — see the parity note on it).
    let mut trainers: Vec<Trainer> = (0..cfg.num_trainers)
        .map(|p| build_trainer(cfg, ds, part, p, offline))
        .collect();

    let max_mb_per_epoch = max_minibatches_per_epoch(cfg, ds, part);
    let total_minibatches = (max_mb_per_epoch * cfg.epochs) as u64;
    let ctx = RunCtx {
        ds,
        part,
        net,
        compute,
        mode: cfg.mode,
        epochs_total: cfg.epochs,
        total_minibatches,
    };

    let mut epoch_times: Vec<f64> = Vec::new();
    for epoch in 0..cfg.epochs {
        let orders: Vec<Vec<u32>> = trainers
            .iter()
            .map(|t| t.sampler.epoch_order(&t.train_nodes, epoch))
            .collect();
        let epoch_start: Vec<f64> = trainers.iter().map(|t| t.clock).collect();
        for mb in 0..max_mb_per_epoch {
            let mut any_active = false;
            for (t, order) in trainers.iter_mut().zip(&orders) {
                if t.step_minibatch(&ctx, epoch, mb, order) {
                    any_active = true;
                }
            }
            if !any_active {
                break;
            }
            // DDP gradient sync: barrier + ring allreduce.
            let t_bar = trainers.iter().map(|t| t.clock).fold(0.0f64, f64::max);
            for t in trainers.iter_mut() {
                t.clock = t_bar + allreduce;
            }
        }
        // Epoch time = wall time of the barrier-synchronized epoch.
        let epoch_end = trainers.iter().map(|t| t.clock).fold(0.0f64, f64::max);
        let start = epoch_start.iter().copied().fold(f64::INFINITY, f64::min);
        epoch_times.push(epoch_end - start);
        for t in trainers.iter_mut() {
            t.metrics.epoch_times.push(epoch_end - start);
        }
    }

    let per_trainer: Vec<RunMetrics> = trainers.into_iter().map(|t| t.metrics).collect();
    ExperimentResult::aggregate(cfg.controller.label(), per_trainer, epoch_times)
}

/// Trace-only mode (§4.4 offline phase): run with the Random controller and
/// training disabled (compute reduced to the sampling path), recording
/// labelled examples for classifier pretraining.
pub fn trace_only(ds: &Dataset, part: &Partition, cfg: &RunConfig) -> TrainingSet {
    use crate::classifier::labeling::label_trace;
    let mut tcfg = cfg.clone();
    tcfg.controller = ControllerSpec::Random { p: 0.5 };
    // Training disabled: no backprop/optimizer — compute is sampling only.
    tcfg.compute = ComputeParams {
        device_flops: f64::INFINITY,
        base_overhead: 5e-3,
        train_multiplier: 0.0,
    };
    let shape = SageShape {
        batch: tcfg.batch_size,
        fanout1: tcfg.fanout1,
        fanout2: tcfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: tcfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(tcfg.net.clone(), tcfg.num_trainers);
    let compute = AnalyticModel::new(tcfg.compute.clone(), shape);
    let allreduce = 0.0;

    let mut trainers: Vec<Trainer> = (0..tcfg.num_trainers)
        .map(|p| {
            let train_nodes = part.train_nodes_of(p, &ds.train_nodes);
            let halo2 = part.halo_k(&ds.csr, p, 2);
            let capacity = ((halo2.len() as f64 * tcfg.buffer_pct) as usize).max(1);
            let sampler = Sampler::new(
                p,
                tcfg.batch_size,
                tcfg.fanout1,
                tcfg.fanout2,
                derive_seed(tcfg.seed, &[p as u64, 0x5A]),
            );
            let controller = tcfg
                .controller
                .build(derive_seed(tcfg.seed, &[p as u64, 0xA6]), None);
            let mut t = Trainer::new(p, capacity, halo2.len(), sampler, controller, train_nodes);
            t.trace = Some(Vec::new());
            t
        })
        .collect();

    let max_mb = trainers.iter().map(Trainer::minibatches_per_epoch).max().unwrap_or(1);
    let ctx = RunCtx {
        ds,
        part,
        net,
        compute,
        mode: Mode::Async,
        epochs_total: tcfg.epochs,
        total_minibatches: (max_mb * tcfg.epochs) as u64,
    };
    let mut set = TrainingSet::default();
    for epoch in 0..tcfg.epochs {
        let orders: Vec<Vec<u32>> = trainers
            .iter()
            .map(|t| t.sampler.epoch_order(&t.train_nodes, epoch))
            .collect();
        for mb in 0..max_mb {
            for (t, order) in trainers.iter_mut().zip(&orders) {
                t.step_minibatch(&ctx, epoch, mb, order);
            }
            let _ = allreduce;
        }
    }
    for t in trainers {
        let cost = t.clock;
        if let Some(trace) = t.trace {
            set.push_examples(&label_trace(&trace), cost);
        }
    }
    set
}

/// Convenience: list selectable dataset names (CLI help).
pub fn dataset_names() -> String {
    datasets::names()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(controller: &str) -> RunConfig {
        RunConfig {
            dataset: "ogbn-arxiv".into(),
            scale: 0.1,
            seed: 7,
            num_trainers: 4,
            batch_size: 32,
            fanout1: 5,
            fanout2: 5,
            buffer_pct: 0.25,
            epochs: 5,
            controller: ControllerSpec::parse(controller).unwrap(),
            ..Default::default()
        }
    }

    #[test]
    fn baseline_runs_and_aggregates() {
        let r = run_experiment(&quick("none")).unwrap();
        assert_eq!(r.per_trainer.len(), 4);
        assert!(r.mean_epoch_time > 0.0);
        assert_eq!(r.mean_hits_pct, 0.0, "no buffer -> all misses");
        assert!(r.total_comm_nodes > 0);
    }

    #[test]
    fn fixed_buffer_gets_hits_and_beats_baseline_comm() {
        let base = run_experiment(&quick("none")).unwrap();
        let fixed = run_experiment(&quick("fixed")).unwrap();
        assert!(fixed.mean_hits_pct > 10.0, "hits {}", fixed.mean_hits_pct);
        assert!(
            fixed.total_comm_nodes < base.total_comm_nodes,
            "fixed {} vs base {}",
            fixed.total_comm_nodes,
            base.total_comm_nodes
        );
    }

    #[test]
    fn rudder_llm_runs_with_decisions() {
        let r = run_experiment(&quick("llm:gemma3-4b")).unwrap();
        let decisions: usize = r.per_trainer.iter().map(|m| m.decisions.len()).sum();
        assert!(decisions > 0, "agent must make decisions");
        assert!(r.valid_response_pct > 90.0);
        assert!(r.steady_hits_pct > 10.0, "steady hits {}", r.steady_hits_pct);
    }

    #[test]
    fn sync_mode_slower_than_async() {
        let mut async_cfg = quick("llm:qwen-1.5b");
        async_cfg.epochs = 2;
        let mut sync_cfg = async_cfg.clone();
        sync_cfg.mode = Mode::Sync;
        let a = run_experiment(&async_cfg).unwrap();
        let s = run_experiment(&sync_cfg).unwrap();
        assert!(
            s.mean_epoch_time > 2.0 * a.mean_epoch_time,
            "sync {} vs async {}",
            s.mean_epoch_time,
            a.mean_epoch_time
        );
        // Sync mode decides every minibatch: r == 1.
        assert!(s.replacement_interval <= 2.0, "r={}", s.replacement_interval);
    }

    #[test]
    fn deterministic_runs() {
        let a = run_experiment(&quick("llm:gemma3-4b")).unwrap();
        let b = run_experiment(&quick("llm:gemma3-4b")).unwrap();
        assert_eq!(a.mean_epoch_time, b.mean_epoch_time);
        assert_eq!(a.total_comm_nodes, b.total_comm_nodes);
    }

    #[test]
    fn trace_only_produces_labeled_data() {
        let cfg = quick("fixed");
        let (ds, part) = build_cluster(&cfg).unwrap();
        let set = trace_only(&ds, &part, &cfg);
        assert!(set.len() > 50, "only {} examples", set.len());
        assert!(set.positive_rate() > 0.05 && set.positive_rate() < 0.95);
        assert!(set.collection_cost > 0.0);
    }

    #[test]
    fn classifier_controller_with_offline_data() {
        let cfg = quick("fixed");
        let (ds, part) = build_cluster(&cfg).unwrap();
        let set = trace_only(&ds, &part, &cfg);
        let mut ccfg = quick("clf:lr");
        ccfg.epochs = 2;
        let r = run_on(&ds, &part, &ccfg, Some(&set));
        assert!(r.per_trainer.iter().map(|m| m.decisions.len()).sum::<usize>() > 0);
    }

    #[test]
    fn massivegnn_prepopulates() {
        let r = run_experiment(&quick("massivegnn:8")).unwrap();
        // Warm-started buffer: early minibatches should already hit.
        let first_hits = r.per_trainer[0].minibatches[0].hits_pct;
        assert!(first_hits > 0.0, "prepopulated buffer gave 0 first hits");
    }
}
