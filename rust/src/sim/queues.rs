//! The shared request/response queue discipline of §4.5.1, in virtual time.
//!
//! The inference daemon is modelled as a single-slot pipeline: a submitted
//! request becomes a response at `ready_at = now + T_A/C`.  The prefetcher
//! polls non-blocking (Algorithm 1 line 12); while a request is in flight,
//! newer metrics are dropped (the "stale request" clearing of line 15) —
//! which is what makes the replacement interval `r` emerge from relative
//! latencies instead of being a tuned constant.

use crate::agent::AgentStep;

#[derive(Debug, Clone)]
pub struct Pending {
    pub issued_mb: u64,
    pub issued_at: f64,
    pub ready_at: f64,
    pub step: AgentStep,
}

#[derive(Debug, Default)]
pub struct InferencePipe {
    pending: Option<Pending>,
}

impl InferencePipe {
    pub fn new() -> InferencePipe {
        InferencePipe { pending: None }
    }

    /// Is the daemon busy (a request in flight)?
    pub fn busy(&self) -> bool {
        self.pending.is_some()
    }

    /// Non-blocking poll: take the response if it is ready by `now`.
    pub fn poll(&mut self, now: f64) -> Option<Pending> {
        if self.pending.as_ref().is_some_and(|p| p.ready_at <= now) {
            self.pending.take()
        } else {
            None
        }
    }

    /// Submit a new request (the daemon was notified with fresh metrics).
    /// Panics if one is already in flight — callers must poll first.
    pub fn submit(&mut self, p: Pending) {
        assert!(self.pending.is_none(), "inference pipe already busy");
        self.pending = Some(p);
    }

    /// Sync mode: how long the trainer must stall from `now` until the
    /// in-flight response is ready (0 if idle or already ready).
    pub fn wait_time(&self, now: f64) -> f64 {
        self.pending
            .as_ref()
            .map_or(0.0, |p| (p.ready_at - now).max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::Action;

    fn pending(ready_at: f64) -> Pending {
        Pending {
            issued_mb: 0,
            issued_at: 0.0,
            ready_at,
            step: AgentStep {
                action: Action::Replace,
                prediction: None,
                latency: ready_at,
                valid_response: true,
                raw_response: String::new(),
            },
        }
    }

    #[test]
    fn poll_respects_ready_time() {
        let mut pipe = InferencePipe::new();
        pipe.submit(pending(5.0));
        assert!(pipe.busy());
        assert!(pipe.poll(4.9).is_none());
        assert!(pipe.busy(), "unready response must stay queued");
        let p = pipe.poll(5.0).unwrap();
        assert_eq!(p.ready_at, 5.0);
        assert!(!pipe.busy());
    }

    #[test]
    fn wait_time_for_sync_mode() {
        let mut pipe = InferencePipe::new();
        assert_eq!(pipe.wait_time(1.0), 0.0);
        pipe.submit(pending(3.0));
        assert!((pipe.wait_time(1.0) - 2.0).abs() < 1e-12);
        assert_eq!(pipe.wait_time(7.0), 0.0);
    }

    #[test]
    #[should_panic(expected = "already busy")]
    fn double_submit_panics() {
        let mut pipe = InferencePipe::new();
        pipe.submit(pending(1.0));
        pipe.submit(pending(2.0));
    }
}
