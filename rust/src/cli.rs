//! Hand-rolled CLI argument parser (clap is not mirrored offline).
//!
//! Grammar: `rudder <subcommand> [--key value]... [--flag]... [positional]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> crate::error::Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.iter().peekable();
        if let Some(sub) = iter.next() {
            args.subcommand = sub.clone();
        }
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                crate::ensure!(!key.is_empty(), "empty option name");
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .is_some_and(|n| !n.starts_with("--"))
                {
                    args.options
                        .insert(key.to_string(), iter.next().unwrap().clone());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Parse `--key value` through the value type's [`std::str::FromStr`].
    /// Every typed option — numbers, [`Transport`](crate::cluster::Transport),
    /// compute modes, fault specs — funnels through here, so a bad value
    /// reports the type's own error (which enumerates the valid values for
    /// the enum-like options).
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> crate::error::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| crate::err!("cannot parse --{key} value '{v}': {e}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub const USAGE: &str = "\
rudder — LLM-agent-steered prefetching for distributed GNN training (ICS'26 reproduction)

USAGE: rudder <command> [options]

COMMANDS:
  train        run one training experiment
               --dataset <name> --trainers <n> --buffer <pct 0-1>
               --controller <none|fixed|llm:MODEL|clf:KIND[:finetune=N]|massivegnn[:r]>
               --mode <async|sync> --epochs <n> --batch <n> --scale <f>
               --seed <n> --config <file.toml>
  cluster      run the distributed cluster runtime: real trainer /
               feature-server / allreduce-hub workers, wire-format RPC,
               async prefetching.  Takes every `train` flag, plus:
               --transport <t>    channel = threads + in-process channels
                                  (default); tcp = one OS process per role
                                  over loopback TCP sockets; event = one
                                  readiness-polled event-loop thread over
                                  nonblocking sockets, all of a trainer's
                                  links multiplexed on one connection
               --compute <m>      emulated = sleep time-scale × modelled
                                  costs (default); measured = real
                                  SageRunner fwd/bwd in every trainer +
                                  real gradient allreduce (no sleeps)
               --time-scale <f>   wall seconds slept per modelled virtual
                                  second (default 0.02; 0 = no emulation;
                                  ignored by --compute measured)
               --parity           also run the virtual-time sim (and, for
                                  tcp, the channel transport) and fail
                                  unless traffic counters are identical
                                  (holds in both compute modes)
               --compare-prefetch also run with prefetching disabled and
                                  report the wall-clock delta
               --chunk-cache <b>  per-link chunk-cache budget in bytes;
                                  enables the content-addressed feature
                                  plane (ChunkReq/ChunkResp, FNV-1a
                                  digests, byte-budgeted LRU per server
                                  link).  0 (default) keeps the plain
                                  row protocol
               --chunk-rows <n>   rows per feature chunk (default 32)
               --fault <s[:dup[:delay[:chop]]]>  seeded fault injection on
                                  response links (duplicate/reorder/chop)
               --trace <file>     record a flight-recorder trace in every
                                  role and write the merged trace (.jsonl
                                  extension = JSON lines, else RTRC
                                  binary); inspect with `rudder trace`
               worker mode (spawned by the tcp orchestrator; manual use
               for debugging): --role trainer|server|hub --part <n>
               --listen <addr> | --connect/--servers <a1,a2,..> --hub <a>
               --results <addr> | --out <blob> [--record-trace]; listeners
               announce
               "RUDDER_LISTEN <addr>" on stdout, the run config arrives
               inline over the --results control link (Hello -> Config;
               --run-config <toml> overrides with a local file) and
               results return over the same link (no shared filesystem
               needed; --out writes a local blob instead)
  bench        pinned measured-compute benchmark: prefetch vs no-prefetch
               baseline with real SageRunner compute, plus a chunk-cache
               leg (prefetch re-run with the content-addressed feature
               plane on; the artifact carries the cached-vs-uncached
               wire-byte delta and fails unless the cache strictly
               reduces response bytes), plus a transport
               scale matrix (tcp vs event across trainer counts × buffer
               sizes; --skip-scale-matrix to omit); writes machine-
               readable BENCH_cluster.json (--out <file>, default
               ./BENCH_cluster.json) and exits non-zero if
               --min-speedup <f> / --max-blocked-ratio <f> gates fail
               (--scale/--epochs/--seed override the pinned config);
               --trace-dir <dir> records both variants' flight-recorder
               traces to <dir>/prefetch.trace + <dir>/baseline.trace
  experiment   regenerate a paper table/figure: rudder experiment <id> [--full]
               ids: fig01 fig03 fig06 fig12 fig13 fig14 fig15 fig16 fig17
                    table2 fig18 table4 fig20 fig21 | all
  trace        flight-recorder tooling:
               trace dump <file> [--out <file>]  convert binary <-> JSONL
               trace stats <file>   per-phase p50/p95/p99, fetch-blocked
                                    breakdown, per-link timelines
               trace diff <a> <b>   compare virtual-time fields of two
                                    same-seed traces; non-zero exit on any
                                    mismatch (wall clocks excluded, so
                                    channel/tcp/event runs diff clean)
               with no subcommand: trace-only classifier data collection
               --dataset <name> --out <file.json>
  replay       re-drive a recorded trace through the sim state machine
               offline (no cluster, no threads, no wall clocks):
               --trace <file>       recorded trace (required; the run must
                                    have been recorded by a build that
                                    embeds the config + sample demand)
               --check              replay under the recorded config and
                                    fail unless the re-emitted virtual
                                    streams are bit-identical to the
                                    recording (emulated-compute traces
                                    only; record with --time-scale 0)
               --controller <s> --buffer <f> --chunk-rows <n>
               --chunk-cache <b>    what-if overrides: re-evaluate the
                                    recorded demand under a changed
                                    policy; writes the schema-stable
                                    rudder-replay-whatif/v1 report
               --json <file>        report path (default
                                    REPLAY_whatif.json when a what-if or
                                    sweep runs)
               replay sweep --trace <file> --controllers a,b,..
               --buffers f1,f2,..   fan one trace across a controller ×
                                    buffer grid in one process
  audit        self-hosted static analysis: lex rust/src + rust/tests and
               enforce the repo invariants (wall-clock-free virtual-time
               code, checked codec narrowing, non-panicking cluster locks,
               gated logging, timed condvar waits, central magic registry)
               as named rules with file:line diagnostics; exits non-zero
               on any finding.  Suppress an intentional site inline with
               `// audit:allow(rule) reason` (the reason is mandatory;
               stale or unjustified allows are themselves findings)
               --list-rules         print the rule catalog
               --rules a,b          run only these rules
               --skip-rules a,b     run all but these rules
               --root <dir>         crate root (default: auto-detect)
  calibrate    measure real PJRT step latency, write configs/calibration.toml
  datasets     list dataset stand-ins (Table 1a)
  models       list LLM agent profiles (Table 1b)
  partition-stats  partition quality: --dataset <name> --trainers <n> [--method metis|ldg|random]
  help         this text

ENVIRONMENT:
  RUDDER_LOG=off|info|debug   role-prefixed runtime logging on stderr
                              (default off; [trainer-3]-style prefixes)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse(&["train", "--dataset", "reddit", "--xla", "--epochs=5", "extra"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("dataset"), Some("reddit"));
        assert_eq!(a.opt("epochs"), Some("5"));
        assert!(a.flag("xla"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn opt_parse_types() {
        let a = parse(&["x", "--n", "42", "--f", "0.5"]);
        assert_eq!(a.opt_parse::<usize>("n").unwrap(), Some(42));
        assert_eq!(a.opt_parse::<f64>("f").unwrap(), Some(0.5));
        assert_eq!(a.opt_parse::<usize>("missing").unwrap(), None);
        assert!(a.opt_parse::<usize>("f").is_err());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["x", "--verbose", "--out", "file"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("out"), Some("file"));
    }

    #[test]
    fn defaults() {
        let a = parse(&["y"]);
        assert_eq!(a.opt_or("k", "d"), "d");
        assert!(!a.flag("nope"));
    }
}
