//! PJRT backend (cargo feature `pjrt`): loads AOT HLO-text artifacts and
//! runs them through the XLA PJRT C API.
//!
//! The pattern (from /opt/xla-example/load_hlo):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! One backend owns the client plus a compiled-executable cache keyed by
//! entry name; compilation happens once on first use and the request path
//! is pure execute — Python never runs at runtime.  In this repo the `xla`
//! crate resolves to the vendored `xla-stub` shim so this path type-checks
//! offline; swap in the real xla-rs crate (README.md) for actual PJRT.

use std::collections::HashMap;
use std::sync::Mutex;

use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::EntrySpec;
use super::backend::RuntimeBackend;
use super::tensor::{Tensor, TensorData};
use crate::error::Result;

pub struct PjrtBackend {
    client: PjRtClient,
    /// Lazily compiled executables (interior mutability: callers hold
    /// `&self` from multiple sim components).
    cache: Mutex<HashMap<String, PjRtLoadedExecutable>>,
}

impl PjrtBackend {
    /// Create the PJRT CPU client.  Executables are compiled lazily on
    /// first use (keeps startup fast for sims that only touch one entry).
    pub fn new() -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| crate::err!("pjrt client: {e}"))?;
        Ok(PjrtBackend { client, cache: Mutex::new(HashMap::new()) })
    }

    fn compile_entry(&self, entry: &EntrySpec) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .map_err(|e| crate::err!("parsing {}: {e}", entry.file.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| crate::err!("compiling '{}': {e}", entry.name))
    }
}

/// Pack a host tensor into an XLA literal.
fn to_literal(t: &Tensor) -> Result<Literal> {
    let (ty, bytes): (ElementType, Vec<u8>) = match &t.data {
        TensorData::F32(v) => (
            ElementType::F32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        TensorData::I32(v) => (
            ElementType::S32,
            v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
    };
    Literal::create_from_shape_and_untyped_data(ty, &t.shape, &bytes)
        .map_err(|e| crate::err!("literal pack: {e}"))
}

/// Unpack an XLA literal into a host tensor.
fn from_literal(lit: &Literal) -> Result<Tensor> {
    let shape = lit.dims().to_vec();
    let data = match lit.element_type() {
        ElementType::F32 => TensorData::F32(
            lit.to_vec::<f32>().map_err(|e| crate::err!("literal unpack: {e}"))?,
        ),
        ElementType::S32 => TensorData::I32(
            lit.to_vec::<i32>().map_err(|e| crate::err!("literal unpack: {e}"))?,
        ),
    };
    Ok(Tensor { shape, data })
}

impl RuntimeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        format!("pjrt:{}", self.client.platform_name())
    }

    fn warm(&self, entry: &EntrySpec) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(&entry.name) {
            let exe = self.compile_entry(entry)?;
            cache.insert(entry.name.clone(), exe);
        }
        Ok(())
    }

    fn execute(&self, entry: &EntrySpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.warm(entry)?;
        let literals: Vec<Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = {
            let cache = self.cache.lock().unwrap();
            let exe = cache.get(&entry.name).unwrap();
            let mut bufs = exe
                .execute::<Literal>(&literals)
                .map_err(|e| crate::err!("executing '{}': {e}", entry.name))?;
            bufs.pop()
                .and_then(|mut row| if row.is_empty() { None } else { Some(row.remove(0)) })
                .ok_or_else(|| crate::err!("entry '{}': empty result", entry.name))?
                .to_literal_sync()
                .map_err(|e| crate::err!("fetching '{}' result: {e}", entry.name))?
        };
        let parts = result
            .to_tuple()
            .map_err(|e| crate::err!("untupling '{}': {e}", entry.name))?;
        parts.iter().map(from_literal).collect()
    }
}

// PJRT CPU client usage here is externally synchronized via the Mutex-held
// executable cache; literals are host buffers.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = crate::runtime::tensor::lit_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn backend_reports_unavailable_without_real_pjrt() {
        // Against the vendored stub, client construction fails loudly; with
        // the real crate patched in, it succeeds — both are acceptable here.
        match PjrtBackend::new() {
            Ok(b) => assert!(b.platform().starts_with("pjrt:")),
            Err(e) => assert!(e.to_string().contains("pjrt client")),
        }
    }
}
