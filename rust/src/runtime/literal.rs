//! Literal packing helpers: Rust buffers ⇄ XLA literals.
//!
//! All artifact tensors are f32 or i32 (see `aot.py`); these helpers pack
//! flat slices into shaped literals (with optional zero-padding up to the
//! artifact's canonical shape) and unpack results.

use xla::{ElementType, Literal};

use super::artifacts::{Dtype, TensorSpec};

/// Pack an f32 slice into a literal of `shape` (row-major).
pub fn lit_f32(shape: &[usize], data: &[f32]) -> anyhow::Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(
        data.len() == n,
        "lit_f32: {} elements for shape {shape:?} (want {n})",
        data.len()
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Pack an i32 slice into a literal of `shape`.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> anyhow::Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(
        data.len() == n,
        "lit_i32: {} elements for shape {shape:?} (want {n})",
        data.len()
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Scalar f32 literal (shape `()`).
pub fn lit_scalar_f32(v: f32) -> anyhow::Result<Literal> {
    lit_f32(&[], std::slice::from_ref(&v))
}

/// Pack `data` into `spec`'s shape, zero-padding the leading axis if `data`
/// covers only the first `rows` rows (short minibatches).
pub fn lit_padded_f32(spec: &TensorSpec, data: &[f32]) -> anyhow::Result<Literal> {
    anyhow::ensure!(spec.dtype == Dtype::F32, "{}: expected f32", spec.name);
    let n = spec.num_elements();
    anyhow::ensure!(
        data.len() <= n,
        "{}: {} elements exceed shape {:?}",
        spec.name,
        data.len(),
        spec.shape
    );
    if data.len() == n {
        return lit_f32(&spec.shape, data);
    }
    let mut padded = vec![0.0f32; n];
    padded[..data.len()].copy_from_slice(data);
    lit_f32(&spec.shape, &padded)
}

/// Unpack a literal to `Vec<f32>`.
pub fn to_f32(lit: &Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let lit = lit_f32(&[3, 4], &data).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data: Vec<i32> = vec![-1, 0, 7, 42];
        let lit = lit_i32(&[4], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn scalar() {
        let lit = lit_scalar_f32(2.5).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0; 3]).is_err());
        assert!(lit_i32(&[5], &[1; 4]).is_err());
    }

    #[test]
    fn padded_fills_zeros() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![4, 2],
            dtype: Dtype::F32,
        };
        let lit = lit_padded_f32(&spec, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let v = to_f32(&lit).unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn padded_rejects_overflow() {
        let spec = TensorSpec { name: "x".into(), shape: vec![2], dtype: Dtype::F32 };
        assert!(lit_padded_f32(&spec, &[0.0; 3]).is_err());
    }
}
