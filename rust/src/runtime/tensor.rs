//! Host tensors: the runtime ABI type shared by every backend.
//!
//! All artifact tensors are f32 or i32 (see `python/compile/aot.py`); a
//! [`Tensor`] is a shape plus a flat row-major buffer.  The packing helpers
//! (`lit_f32`, `lit_padded_f32`, …) keep the call-site idiom of the old
//! XLA-literal layer, so swapping backends never touches the compute
//! call sites in `gnn/` and `classifier/`.

use super::artifacts::{Dtype, TensorSpec};
use crate::error::Result;

/// Flat element storage for one tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A shaped host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    /// Borrow as f32 elements; errors on dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(crate::err!("tensor: expected f32, got i32")),
        }
    }

    /// Borrow as i32 elements; errors on dtype mismatch.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(crate::err!("tensor: expected i32, got f32")),
        }
    }
}

/// Pack an f32 slice into a tensor of `shape` (row-major).
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Tensor> {
    let n: usize = shape.iter().product();
    crate::ensure!(
        data.len() == n,
        "lit_f32: {} elements for shape {shape:?} (want {n})",
        data.len()
    );
    Ok(Tensor { shape: shape.to_vec(), data: TensorData::F32(data.to_vec()) })
}

/// Pack an i32 slice into a tensor of `shape`.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Tensor> {
    let n: usize = shape.iter().product();
    crate::ensure!(
        data.len() == n,
        "lit_i32: {} elements for shape {shape:?} (want {n})",
        data.len()
    );
    Ok(Tensor { shape: shape.to_vec(), data: TensorData::I32(data.to_vec()) })
}

/// Scalar f32 tensor (shape `()`).
pub fn lit_scalar_f32(v: f32) -> Result<Tensor> {
    lit_f32(&[], std::slice::from_ref(&v))
}

/// Pack `data` into `spec`'s shape, zero-padding the tail if `data` covers
/// only the leading rows (short minibatches).
pub fn lit_padded_f32(spec: &TensorSpec, data: &[f32]) -> Result<Tensor> {
    crate::ensure!(spec.dtype == Dtype::F32, "{}: expected f32", spec.name);
    let n = spec.num_elements();
    crate::ensure!(
        data.len() <= n,
        "{}: {} elements exceed shape {:?}",
        spec.name,
        data.len(),
        spec.shape
    );
    if data.len() == n {
        return lit_f32(&spec.shape, data);
    }
    let mut padded = vec![0.0f32; n];
    padded[..data.len()].copy_from_slice(data);
    lit_f32(&spec.shape, &padded)
}

/// Unpack a tensor to `Vec<f32>`.
pub fn to_f32(t: &Tensor) -> Result<Vec<f32>> {
    Ok(t.as_f32()?.to_vec())
}

/// Unpack a tensor to `Vec<i32>`.
pub fn to_i32(t: &Tensor) -> Result<Vec<i32>> {
    Ok(t.as_i32()?.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let lit = lit_f32(&[3, 4], &data).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), data);
        assert_eq!(lit.num_elements(), 12);
        assert_eq!(lit.dtype(), Dtype::F32);
    }

    #[test]
    fn i32_roundtrip() {
        let data: Vec<i32> = vec![-1, 0, 7, 42];
        let lit = lit_i32(&[4], &data).unwrap();
        assert_eq!(to_i32(&lit).unwrap(), data);
        assert!(to_f32(&lit).is_err());
    }

    #[test]
    fn scalar() {
        let lit = lit_scalar_f32(2.5).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), vec![2.5]);
        assert_eq!(lit.shape, Vec::<usize>::new());
        assert_eq!(lit.num_elements(), 1);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0; 3]).is_err());
        assert!(lit_i32(&[5], &[1; 4]).is_err());
    }

    #[test]
    fn padded_fills_zeros() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![4, 2],
            dtype: Dtype::F32,
        };
        let lit = lit_padded_f32(&spec, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let v = to_f32(&lit).unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn padded_rejects_overflow() {
        let spec = TensorSpec { name: "x".into(), shape: vec![2], dtype: Dtype::F32 };
        assert!(lit_padded_f32(&spec, &[0.0; 3]).is_err());
    }
}
