//! Pure-Rust interpreter backend: executes the AOT manifest entries with
//! the reference semantics of `python/compile/kernels/ref.py` and
//! `python/compile/model.py`, no external runtime required.
//!
//! This is the default [`RuntimeBackend`]: the coordinator's compute calls
//! (`sage_train_step`, `sage_fwd`, `mlp_infer`, `mlp_train_step`,
//! `score_update`) run as plain f32 loops.  Dimensions come from the
//! (engine-validated) input shapes, so the same code serves any artifact
//! configuration.  The scoring constants are shared with
//! [`crate::buffer::scoring`] — one definition for host policy, kernel
//! oracle, and interpreter.

use super::artifacts::EntrySpec;
use super::backend::RuntimeBackend;
use super::tensor::{lit_f32, lit_scalar_f32, Tensor};
use crate::buffer::scoring::{DECAY, STALE_THRESHOLD};
use crate::error::Result;

/// Stateless interpreter over manifest entries.
#[derive(Debug, Default, Clone, Copy)]
pub struct InterpreterBackend;

impl InterpreterBackend {
    pub fn new() -> InterpreterBackend {
        InterpreterBackend
    }
}

impl RuntimeBackend for InterpreterBackend {
    fn name(&self) -> &'static str {
        "interpreter"
    }

    fn execute(&self, entry: &EntrySpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match entry.name.as_str() {
            "sage_train_step" => sage_train_step(inputs),
            "sage_fwd" => sage_fwd(inputs),
            "mlp_infer" => mlp_infer(inputs),
            "mlp_train_step" => mlp_train_step(inputs),
            "score_update" => score_update(inputs),
            other => Err(crate::err!(
                "interpreter: no implementation for entry '{other}'"
            )),
        }
    }
}

// ---------------------------------------------------------------------------
// dense helpers (all row-major f32)

/// Cache-block sizes for [`mm`]: a `TILE_K × TILE_N` panel of B is
/// 64 KiB — it stays resident in L1/L2 while every row of A streams over
/// it, instead of re-reading all of B per output row as the unblocked
/// i-k-j loop did.  Summation order per output element is unchanged (k
/// strictly ascending), so results are bit-identical to the naive loop —
/// the finite-difference tests below hold without tolerance changes.
const MM_TILE_K: usize = 64;
const MM_TILE_N: usize = 256;

/// `(m, k) @ (k, n)` — i-k-j loop order inside fixed-size (k, n) tiles;
/// the inner loop streams, the zero-skip keeps ReLU-sparse activations
/// cheap.
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + MM_TILE_K).min(k);
        let mut n0 = 0;
        while n0 < n {
            let n1 = (n0 + MM_TILE_N).min(n);
            for i in 0..m {
                let arow = &a[i * k + k0..i * k + k1];
                let orow = &mut out[i * n + n0..i * n + n1];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[(k0 + kk) * n + n0..(k0 + kk) * n + n1];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            n0 = n1;
        }
        k0 = k1;
    }
    out
}

/// Gradient product `x^T @ dy`: `(rows, d)^T @ (rows, h)` accumulated into
/// `out` of shape `(d, h)`.
fn acc_xt_dy(x: &[f32], dy: &[f32], rows: usize, d: usize, h: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), d * h);
    for r in 0..rows {
        let xrow = &x[r * d..(r + 1) * d];
        let dyrow = &dy[r * h..(r + 1) * h];
        for (dd, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let orow = &mut out[dd * h..(dd + 1) * h];
            for (o, &g) in orow.iter_mut().zip(dyrow) {
                *o += xv * g;
            }
        }
    }
}

/// `dy @ w^T`: `(rows, c) @ (h, c)^T` → `(rows, h)`.
fn dy_wt(dy: &[f32], w: &[f32], rows: usize, c: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * h];
    for r in 0..rows {
        let dyrow = &dy[r * c..(r + 1) * c];
        let orow = &mut out[r * h..(r + 1) * h];
        for (hh, o) in orow.iter_mut().enumerate() {
            let wrow = &w[hh * c..(hh + 1) * c];
            let mut acc = 0.0f32;
            for (&g, &wv) in dyrow.iter().zip(wrow) {
                acc += g * wv;
            }
            *o = acc;
        }
    }
    out
}

/// Mean over the middle axis: `(rows, groups, d)` → `(rows, d)` where the
/// input is flat `rows*groups*d`.
fn group_mean(x: &[f32], rows: usize, groups: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    let inv = 1.0 / groups as f32;
    for r in 0..rows {
        let orow = &mut out[r * d..(r + 1) * d];
        for g in 0..groups {
            let xrow = &x[(r * groups + g) * d..(r * groups + g + 1) * d];
            for (o, &v) in orow.iter_mut().zip(xrow) {
                *o += v;
            }
        }
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    out
}

/// Add bias + optional ReLU in place.  Post-activation values are what
/// backprop needs here: with ReLU, `z > 0` is exactly the pre-activation
/// positivity mask.
fn add_bias_relu(z: &mut [f32], bias: &[f32], rows: usize, h: usize, relu: bool) {
    for r in 0..rows {
        let row = &mut z[r * h..(r + 1) * h];
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// Row-wise log-softmax probabilities: returns (softmax, log_softmax).
fn softmax_rows(logits: &[f32], rows: usize, c: usize) -> (Vec<f32>, Vec<f32>) {
    let mut p = vec![0.0f32; rows * c];
    let mut logp = vec![0.0f32; rows * c];
    for r in 0..rows {
        let row = &logits[r * c..(r + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &v in row {
            z += (v - m).exp();
        }
        let lnz = z.ln();
        for i in 0..c {
            logp[r * c + i] = row[i] - m - lnz;
            p[r * c + i] = (row[i] - m).exp() / z;
        }
    }
    (p, logp)
}

fn col_sums(x: &[f32], rows: usize, c: usize, out: &mut [f32]) {
    for r in 0..rows {
        let row = &x[r * c..(r + 1) * c];
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

fn sgd(p: &[f32], g: &[f32], lr: f32) -> Vec<f32> {
    p.iter().zip(g).map(|(&pv, &gv)| pv - lr * gv).collect()
}

// ---------------------------------------------------------------------------
// SAGE forward shared by fwd / train entries

struct SageActs {
    b: usize,
    k1: usize,
    d: usize,
    h: usize,
    c: usize,
    agg2: Vec<f32>,   // (B*K1, D)
    z1f: Vec<f32>,    // (B*K1, H) post-ReLU (ReLU mask == z > 0)
    agg1: Vec<f32>,   // (B, D)
    z1s: Vec<f32>,    // (B, H) post-ReLU
    aggh: Vec<f32>,   // (B, H)
    logits: Vec<f32>, // (B, C)
}

fn sage_forward_acts(inputs: &[Tensor]) -> Result<SageActs> {
    let w1s = inputs[0].as_f32()?;
    let w1n = inputs[1].as_f32()?;
    let b1 = inputs[2].as_f32()?;
    let w2s = inputs[3].as_f32()?;
    let w2n = inputs[4].as_f32()?;
    let b2 = inputs[5].as_f32()?;
    let x_self = inputs[6].as_f32()?;
    let x_h1 = inputs[7].as_f32()?;
    let x_h2 = inputs[8].as_f32()?;
    let (d, h) = (inputs[0].shape[0], inputs[0].shape[1]);
    let c = inputs[3].shape[1];
    let (b, k1, k2) = (inputs[8].shape[0], inputs[8].shape[1], inputs[8].shape[2]);

    // Layer 1 on the hop-1 frontier: each hop-1 node aggregates its K2 set.
    let agg2 = group_mean(x_h2, b * k1, k2, d);
    let mut z1f = mm(x_h1, w1s, b * k1, d, h);
    let t = mm(&agg2, w1n, b * k1, d, h);
    for (z, &v) in z1f.iter_mut().zip(&t) {
        *z += v;
    }
    add_bias_relu(&mut z1f, b1, b * k1, h, true);

    // Layer 1 on the targets: aggregate the hop-1 sample.
    let agg1 = group_mean(x_h1, b, k1, d);
    let mut z1s = mm(x_self, w1s, b, d, h);
    let t = mm(&agg1, w1n, b, d, h);
    for (z, &v) in z1s.iter_mut().zip(&t) {
        *z += v;
    }
    add_bias_relu(&mut z1s, b1, b, h, true);

    // Layer 2: targets aggregate their hidden-space hop-1 frontier.
    let aggh = group_mean(&z1f, b, k1, h);
    let mut logits = mm(&z1s, w2s, b, h, c);
    let t = mm(&aggh, w2n, b, h, c);
    for (z, &v) in logits.iter_mut().zip(&t) {
        *z += v;
    }
    add_bias_relu(&mut logits, b2, b, c, false);

    Ok(SageActs { b, k1, d, h, c, agg2, z1f, agg1, z1s, aggh, logits })
}

fn sage_fwd(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let acts = sage_forward_acts(inputs)?;
    Ok(vec![lit_f32(&[acts.b, acts.c], &acts.logits)?])
}

fn sage_train_step(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let acts = sage_forward_acts(inputs)?;
    let labels = inputs[9].as_i32()?;
    let mask = inputs[10].as_f32()?;
    let lr = inputs[11].as_f32()?[0];
    let (b, k1, d, h, c) = (acts.b, acts.k1, acts.d, acts.h, acts.c);

    // Masked mean cross-entropy (model.py::sage_loss).
    let (p, logp) = softmax_rows(&acts.logits, b, c);
    let denom = mask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; b * c];
    for r in 0..b {
        let y = labels[r] as usize;
        crate::ensure!(y < c, "sage_train_step: label {y} out of range (C={c})");
        loss -= logp[r * c + y] * mask[r] / denom;
        let scale = mask[r] / denom;
        for i in 0..c {
            let target = if i == y { 1.0 } else { 0.0 };
            dlogits[r * c + i] = (p[r * c + i] - target) * scale;
        }
    }

    // Layer-2 gradients.
    let mut gw2s = vec![0.0f32; h * c];
    let mut gw2n = vec![0.0f32; h * c];
    let mut gb2 = vec![0.0f32; c];
    acc_xt_dy(&acts.z1s, &dlogits, b, h, c, &mut gw2s);
    acc_xt_dy(&acts.aggh, &dlogits, b, h, c, &mut gw2n);
    col_sums(&dlogits, b, c, &mut gb2);

    // Into layer 1 (targets branch + frontier branch through the mean).
    let w2s = inputs[3].as_f32()?;
    let w2n = inputs[4].as_f32()?;
    let mut dz1s = dy_wt(&dlogits, w2s, b, c, h);
    for (dz, &z) in dz1s.iter_mut().zip(&acts.z1s) {
        if z <= 0.0 {
            *dz = 0.0;
        }
    }
    let daggh = dy_wt(&dlogits, w2n, b, c, h);
    let inv_k1 = 1.0 / k1 as f32;
    let mut dz1f = vec![0.0f32; b * k1 * h];
    for r in 0..b * k1 {
        let src = &daggh[(r / k1) * h..(r / k1 + 1) * h];
        let dst = &mut dz1f[r * h..(r + 1) * h];
        let zrow = &acts.z1f[r * h..(r + 1) * h];
        for i in 0..h {
            dst[i] = if zrow[i] > 0.0 { src[i] * inv_k1 } else { 0.0 };
        }
    }

    // Layer-1 gradients from both branches.
    let x_self = inputs[6].as_f32()?;
    let x_h1 = inputs[7].as_f32()?;
    let mut gw1s = vec![0.0f32; d * h];
    let mut gw1n = vec![0.0f32; d * h];
    let mut gb1 = vec![0.0f32; h];
    acc_xt_dy(x_self, &dz1s, b, d, h, &mut gw1s);
    acc_xt_dy(&acts.agg1, &dz1s, b, d, h, &mut gw1n);
    col_sums(&dz1s, b, h, &mut gb1);
    acc_xt_dy(x_h1, &dz1f, b * k1, d, h, &mut gw1s);
    acc_xt_dy(&acts.agg2, &dz1f, b * k1, d, h, &mut gw1n);
    col_sums(&dz1f, b * k1, h, &mut gb1);

    Ok(vec![
        lit_f32(&[d, h], &sgd(inputs[0].as_f32()?, &gw1s, lr))?,
        lit_f32(&[d, h], &sgd(inputs[1].as_f32()?, &gw1n, lr))?,
        lit_f32(&[h], &sgd(inputs[2].as_f32()?, &gb1, lr))?,
        lit_f32(&[h, c], &sgd(inputs[3].as_f32()?, &gw2s, lr))?,
        lit_f32(&[h, c], &sgd(inputs[4].as_f32()?, &gw2n, lr))?,
        lit_f32(&[c], &sgd(inputs[5].as_f32()?, &gb2, lr))?,
        lit_scalar_f32(loss)?,
    ])
}

// ---------------------------------------------------------------------------
// MLP decision classifier

struct MlpActs {
    n: usize,
    f: usize,
    hm: usize,
    z1: Vec<f32>,     // (N, HM) post-ReLU
    logits: Vec<f32>, // (N, 2)
}

fn mlp_forward_acts(inputs: &[Tensor]) -> Result<MlpActs> {
    let w1 = inputs[0].as_f32()?;
    let b1 = inputs[1].as_f32()?;
    let w2 = inputs[2].as_f32()?;
    let b2 = inputs[3].as_f32()?;
    let x = inputs[4].as_f32()?;
    let (f, hm) = (inputs[0].shape[0], inputs[0].shape[1]);
    let n = inputs[4].shape[0];
    let mut z1 = mm(x, w1, n, f, hm);
    add_bias_relu(&mut z1, b1, n, hm, true);
    let mut logits = mm(&z1, w2, n, hm, 2);
    add_bias_relu(&mut logits, b2, n, 2, false);
    Ok(MlpActs { n, f, hm, z1, logits })
}

fn mlp_infer(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let acts = mlp_forward_acts(inputs)?;
    let (p, _) = softmax_rows(&acts.logits, acts.n, 2);
    let probs: Vec<f32> = (0..acts.n).map(|r| p[r * 2 + 1]).collect();
    Ok(vec![lit_f32(&[acts.n], &probs)?])
}

fn mlp_train_step(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let acts = mlp_forward_acts(inputs)?;
    let labels = inputs[5].as_i32()?;
    let lr = inputs[6].as_f32()?[0];
    let (n, f, hm) = (acts.n, acts.f, acts.hm);

    let (p, logp) = softmax_rows(&acts.logits, n, 2);
    let inv_n = 1.0 / n.max(1) as f32;
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; n * 2];
    for r in 0..n {
        let y = labels[r] as usize;
        crate::ensure!(y < 2, "mlp_train_step: label {y} out of range");
        loss -= logp[r * 2 + y] * inv_n;
        for i in 0..2 {
            let target = if i == y { 1.0 } else { 0.0 };
            dlogits[r * 2 + i] = (p[r * 2 + i] - target) * inv_n;
        }
    }

    let w2 = inputs[2].as_f32()?;
    let mut gw2 = vec![0.0f32; hm * 2];
    let mut gb2 = vec![0.0f32; 2];
    acc_xt_dy(&acts.z1, &dlogits, n, hm, 2, &mut gw2);
    col_sums(&dlogits, n, 2, &mut gb2);

    let mut dz1 = dy_wt(&dlogits, w2, n, 2, hm);
    for (dz, &z) in dz1.iter_mut().zip(&acts.z1) {
        if z <= 0.0 {
            *dz = 0.0;
        }
    }
    let x = inputs[4].as_f32()?;
    let mut gw1 = vec![0.0f32; f * hm];
    let mut gb1 = vec![0.0f32; hm];
    acc_xt_dy(x, &dz1, n, f, hm, &mut gw1);
    col_sums(&dz1, n, hm, &mut gb1);

    Ok(vec![
        lit_f32(&[f, hm], &sgd(inputs[0].as_f32()?, &gw1, lr))?,
        lit_f32(&[hm], &sgd(inputs[1].as_f32()?, &gb1, lr))?,
        lit_f32(&[hm, 2], &sgd(inputs[2].as_f32()?, &gw2, lr))?,
        lit_f32(&[2], &sgd(inputs[3].as_f32()?, &gb2, lr))?,
        lit_scalar_f32(loss)?,
    ])
}

// ---------------------------------------------------------------------------
// buffer score update (ref.py::score_update_ref)

fn score_update(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let scores = inputs[0].as_f32()?;
    let accessed = inputs[1].as_f32()?;
    let n = scores.len();
    let mut new = vec![0.0f32; n];
    let mut stale = vec![0.0f32; n];
    for i in 0..n {
        new[i] = if accessed[i] > 0.0 { scores[i] + 1.0 } else { scores[i] * DECAY };
        stale[i] = if new[i] < STALE_THRESHOLD { 1.0 } else { 0.0 };
    }
    Ok(vec![
        lit_f32(&inputs[0].shape, &new)?,
        lit_f32(&inputs[0].shape, &stale)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::EntrySpec;
    use crate::runtime::tensor::{lit_i32, to_f32};
    use crate::util::rng::Pcg32;

    fn entry(name: &str) -> EntrySpec {
        EntrySpec {
            name: name.to_string(),
            file: std::path::PathBuf::new(),
            inputs: vec![],
            outputs: vec![],
        }
    }

    fn randn(rng: &mut Pcg32, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    /// Tiny SAGE problem: B=3, K1=2, K2=2, D=4, H=5, C=3.
    fn sage_inputs(lr: f32) -> Vec<Tensor> {
        let (b, k1, k2, d, h, c) = (3usize, 2usize, 2usize, 4usize, 5usize, 3usize);
        let mut rng = Pcg32::new(42);
        vec![
            lit_f32(&[d, h], &randn(&mut rng, d * h, 0.5)).unwrap(),
            lit_f32(&[d, h], &randn(&mut rng, d * h, 0.5)).unwrap(),
            lit_f32(&[h], &randn(&mut rng, h, 0.1)).unwrap(),
            lit_f32(&[h, c], &randn(&mut rng, h * c, 0.5)).unwrap(),
            lit_f32(&[h, c], &randn(&mut rng, h * c, 0.5)).unwrap(),
            lit_f32(&[c], &randn(&mut rng, c, 0.1)).unwrap(),
            lit_f32(&[b, d], &randn(&mut rng, b * d, 1.0)).unwrap(),
            lit_f32(&[b, k1, d], &randn(&mut rng, b * k1 * d, 1.0)).unwrap(),
            lit_f32(&[b, k1, k2, d], &randn(&mut rng, b * k1 * k2 * d, 1.0)).unwrap(),
            lit_i32(&[b], &[0, 2, 1]).unwrap(),
            lit_f32(&[b], &[1.0, 1.0, 0.0]).unwrap(),
            lit_scalar_f32(lr).unwrap(),
        ]
    }

    fn sage_loss_of(inputs: &[Tensor]) -> f32 {
        let mut zero_lr = inputs.to_vec();
        zero_lr[11] = lit_scalar_f32(0.0).unwrap();
        let out = sage_train_step(&zero_lr).unwrap();
        to_f32(&out[6]).unwrap()[0]
    }

    #[test]
    fn mm_matches_hand_product() {
        // (2,3) @ (3,2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let out = mm(&a, &b, 2, 3, 2);
        assert_eq!(out, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn mm_blocked_bitwise_matches_naive_across_tile_edges() {
        // Reference i-k-j loop without tiling; the blocked mm keeps k
        // strictly ascending per output element, so results must be
        // bit-identical, including at sizes that straddle tile borders.
        fn mm_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let av = a[i * k + kk];
                    if av == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        out[i * n + j] += av * b[kk * n + j];
                    }
                }
            }
            out
        }
        let mut rng = Pcg32::new(11);
        for (m, k, n) in [
            (3, MM_TILE_K - 1, MM_TILE_N + 3),
            (2, MM_TILE_K + 1, 5),
            (5, 2 * MM_TILE_K + 7, MM_TILE_N),
            (1, 1, 1),
        ] {
            let mut a = randn(&mut rng, m * k, 1.0);
            a[0] = 0.0; // exercise the zero-skip path
            let b = randn(&mut rng, k * n, 1.0);
            assert_eq!(mm(&a, &b, m, k, n), mm_naive(&a, &b, m, k, n), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn group_mean_averages_middle_axis() {
        // rows=1, groups=2, d=2: mean of [1,2] and [3,4] = [2,3].
        let out = group_mean(&[1.0, 2.0, 3.0, 4.0], 1, 2, 2);
        assert_eq!(out, vec![2.0, 3.0]);
    }

    #[test]
    fn score_update_matches_host_policy() {
        let scores = vec![1.0f32, 1.0, 0.99, 10.0];
        let accessed = vec![1.0f32, 0.0, 0.0, 0.0];
        let out = score_update(&[
            lit_f32(&[4], &scores).unwrap(),
            lit_f32(&[4], &accessed).unwrap(),
        ])
        .unwrap();
        let new = to_f32(&out[0]).unwrap();
        let stale = to_f32(&out[1]).unwrap();
        // Mirror the host-side policy.
        let mut rs = scores.clone();
        let mut ra: Vec<bool> = accessed.iter().map(|&a| a > 0.0).collect();
        let live = vec![true; 4];
        let n_stale = crate::buffer::scoring::apply_round(&mut rs, &mut ra, &live);
        for i in 0..4 {
            assert!((new[i] - rs[i]).abs() < 1e-6, "slot {i}");
        }
        assert_eq!(stale.iter().filter(|&&s| s > 0.5).count(), n_stale);
    }

    #[test]
    fn mlp_infer_matches_host_mlp() {
        use crate::classifier::mlp::MlpWeights;
        use crate::classifier::F;
        let w = MlpWeights::init(3);
        let x: [f32; F] = std::array::from_fn(|i| (i as f32 * 0.37).sin());
        let inputs = vec![
            lit_f32(&[F, 32], &w.w1).unwrap(),
            lit_f32(&[32], &w.b1).unwrap(),
            lit_f32(&[32, 2], &w.w2).unwrap(),
            lit_f32(&[2], &w.b2).unwrap(),
            lit_f32(&[1, F], &x).unwrap(),
        ];
        let out = mlp_infer(&inputs).unwrap();
        let p = to_f32(&out[0]).unwrap()[0] as f64;
        let want = w.replace_prob(&x);
        assert!((p - want).abs() < 1e-5, "interp {p} host {want}");
    }

    #[test]
    fn mlp_train_reduces_loss() {
        let (n, f, hm) = (8usize, 4usize, 6usize);
        let mut rng = Pcg32::new(7);
        let zeros_hm = vec![0.0f32; hm];
        let mut params = vec![
            lit_f32(&[f, hm], &randn(&mut rng, f * hm, 0.5)).unwrap(),
            lit_f32(&[hm], &zeros_hm).unwrap(),
            lit_f32(&[hm, 2], &randn(&mut rng, hm * 2, 0.5)).unwrap(),
            lit_f32(&[2], &[0.0, 0.0]).unwrap(),
        ];
        let x = randn(&mut rng, n * f, 1.0);
        let labels: Vec<i32> = (0..n as i32).map(|i| i % 2).collect();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..200 {
            let mut inputs = params.clone();
            inputs.push(lit_f32(&[n, f], &x).unwrap());
            inputs.push(lit_i32(&[n], &labels).unwrap());
            inputs.push(lit_scalar_f32(0.5).unwrap());
            let out = mlp_train_step(&inputs).unwrap();
            last = to_f32(&out[4]).unwrap()[0];
            first.get_or_insert(last);
            params = out[..4].to_vec();
        }
        let first = first.unwrap();
        assert!(last < first * 0.5, "loss {first} -> {last}");
    }

    #[test]
    fn sage_train_reduces_loss_and_masks_padding() {
        let inputs = sage_inputs(0.1);
        let l0 = sage_loss_of(&inputs);
        assert!(l0 > 0.0 && l0.is_finite());
        // Take repeated steps on the same batch: must overfit.
        let mut params: Vec<Tensor> = inputs[..6].to_vec();
        let mut last = l0;
        for _ in 0..200 {
            let mut step_in = params.clone();
            step_in.extend_from_slice(&inputs[6..]);
            let out = sage_train_step(&step_in).unwrap();
            last = to_f32(&out[6]).unwrap()[0];
            params = out[..6].to_vec();
        }
        assert!(last < l0 * 0.5, "loss {l0} -> {last}");
        // Masked row: flipping its label must not change the loss.
        let mut flipped = inputs.clone();
        flipped[9] = lit_i32(&[3], &[0, 2, 2]).unwrap();
        assert!((sage_loss_of(&flipped) - l0).abs() < 1e-6, "mask leaks");
    }

    #[test]
    fn sage_gradients_match_finite_differences() {
        let lr = 0.05f32;
        let base = sage_inputs(lr);
        let out = sage_train_step(&base).unwrap();
        // g = (old - new) / lr for every parameter tensor.
        let param_names = ["w1_self", "w1_neigh", "b1", "w2_self", "w2_neigh", "b2"];
        for (pi, &pname) in param_names.iter().enumerate() {
            let old = base[pi].as_f32().unwrap().to_vec();
            let new = to_f32(&out[pi]).unwrap();
            // Probe a few coordinates with central differences.
            for probe in [0usize, old.len() / 2, old.len() - 1] {
                let eps = 1e-2f32;
                let mut plus = base.clone();
                let mut minus = base.clone();
                let mut pv = old.clone();
                pv[probe] += eps;
                plus[pi] = lit_f32(&base[pi].shape, &pv).unwrap();
                let mut mv = old.clone();
                mv[probe] -= eps;
                minus[pi] = lit_f32(&base[pi].shape, &mv).unwrap();
                let numeric = (sage_loss_of(&plus) - sage_loss_of(&minus)) / (2.0 * eps);
                let analytic = (old[probe] - new[probe]) / lr;
                assert!(
                    (numeric - analytic).abs() < 2e-2 + 0.05 * numeric.abs().max(1.0),
                    "{pname}[{probe}]: numeric {numeric} analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn unknown_entry_rejected() {
        let b = InterpreterBackend::new();
        assert!(b.execute(&entry("not_an_entry"), &[]).is_err());
    }
}
