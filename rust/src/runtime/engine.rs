//! PJRT execution engine: loads AOT HLO-text artifacts and runs them.
//!
//! The pattern (from /opt/xla-example/load_hlo):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! One engine owns the client plus a compiled-executable cache keyed by
//! entry name; compilation happens once at load (or lazily on first call),
//! and the request path is pure execute — Python never runs at runtime.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::{EntrySpec, Manifest};

pub struct Engine {
    pub manifest: Manifest,
    client: PjRtClient,
    /// Lazily compiled executables (interior mutability: callers hold &self
    /// from multiple sim components).
    cache: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    /// Cumulative execute() wall time per entry (perf accounting).
    timings: Mutex<HashMap<String, (u64, f64)>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Engine({} entries)", self.manifest.entries.len())
    }
}

impl Engine {
    /// Load the manifest and create the PJRT CPU client.  Executables are
    /// compiled lazily on first use (keeps startup fast for sims that only
    /// touch one entry).
    pub fn load(dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            timings: Mutex::new(HashMap::new()),
        })
    }

    /// Load if artifacts exist; `None` otherwise (analytic fallback mode).
    pub fn try_load_default() -> Option<Engine> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            match Engine::load(&dir) {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("warning: artifacts present but unloadable: {err}");
                    None
                }
            }
        } else {
            None
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_entry(&self, entry: &EntrySpec) -> anyhow::Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&entry.file)?;
        let comp = XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Ensure `name` is compiled (warm-up; also used by `rudder calibrate`).
    pub fn warm(&self, name: &str) -> anyhow::Result<()> {
        let entry = self
            .manifest
            .entry(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact entry '{name}'"))?;
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(name) {
            let exe = self.compile_entry(entry)?;
            cache.insert(name.to_string(), exe);
        }
        Ok(())
    }

    /// Execute `name` with positional inputs; returns the output tuple as
    /// individual literals (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let entry = self
            .manifest
            .entry(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact entry '{name}'"))?;
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "entry '{name}': {} inputs given, ABI wants {}",
            inputs.len(),
            entry.inputs.len()
        );
        self.warm(name)?;
        let start = std::time::Instant::now();
        let result = {
            let cache = self.cache.lock().unwrap();
            let exe = cache.get(name).unwrap();
            let mut bufs = exe.execute::<Literal>(inputs)?;
            bufs.pop()
                .and_then(|mut row| if row.is_empty() { None } else { Some(row.remove(0)) })
                .ok_or_else(|| anyhow::anyhow!("entry '{name}': empty result"))?
                .to_literal_sync()?
        };
        let dt = start.elapsed().as_secs_f64();
        {
            let mut t = self.timings.lock().unwrap();
            let e = t.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == entry.outputs.len(),
            "entry '{name}': {} outputs, ABI wants {}",
            parts.len(),
            entry.outputs.len()
        );
        Ok(parts)
    }

    /// (calls, total seconds) per entry since load.
    pub fn timing(&self, name: &str) -> (u64, f64) {
        self.timings
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or((0, 0.0))
    }

    /// Mean execute latency for an entry, if it ever ran.
    pub fn mean_latency(&self, name: &str) -> Option<f64> {
        let (n, total) = self.timing(name);
        if n == 0 {
            None
        } else {
            Some(total / n as f64)
        }
    }
}

// PJRT CPU client usage here is externally synchronized via the Mutex-held
// executable cache; literals are host buffers.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
