//! Execution engine: manifest + pluggable [`RuntimeBackend`].
//!
//! One engine owns the artifact manifest and a backend; every `execute`
//! call is validated against the manifest ABI (arity, dtype, shape) before
//! it reaches the backend, and timed for the perf accounting `rudder
//! calibrate` reports.  The default backend is the zero-dependency
//! [`InterpreterBackend`](super::interp::InterpreterBackend); build with
//! `--features pjrt` for the PJRT/XLA engine (`Engine::load_pjrt`).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use super::artifacts::{ArtifactConfig, EntrySpec, Manifest};
use super::backend::RuntimeBackend;
use super::interp::InterpreterBackend;
use super::tensor::Tensor;
use crate::error::Result;

pub struct Engine {
    pub manifest: Manifest,
    backend: Box<dyn RuntimeBackend>,
    /// Cumulative execute() wall time per entry (perf accounting).
    timings: Mutex<HashMap<String, (u64, f64)>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Engine({} entries, backend {})",
            self.manifest.entries.len(),
            self.backend.name()
        )
    }
}

impl Engine {
    /// Wrap an explicit manifest + backend pair.
    pub fn with_backend(manifest: Manifest, backend: Box<dyn RuntimeBackend>) -> Engine {
        Engine { manifest, backend, timings: Mutex::new(HashMap::new()) }
    }

    /// Load the manifest from `dir` on this build's default backend: the
    /// interpreter, or PJRT when the `pjrt` feature is enabled (on-disk
    /// artifacts are exactly what the PJRT engine compiles).
    pub fn load(dir: &Path) -> Result<Engine> {
        #[cfg(feature = "pjrt")]
        {
            Engine::load_pjrt(dir)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Engine::load_interpreter(dir)
        }
    }

    /// Load the manifest from `dir` and run it on the interpreter backend
    /// regardless of enabled features.
    pub fn load_interpreter(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Ok(Engine::with_backend(manifest, Box::new(InterpreterBackend::new())))
    }

    /// Interpreter engine from the built-in `aot.py` default schema — no
    /// files needed.
    pub fn builtin(config: ArtifactConfig) -> Engine {
        let manifest = Manifest::builtin(&Manifest::default_dir(), config);
        Engine::with_backend(manifest, Box::new(InterpreterBackend::new()))
    }

    /// Load the manifest from `dir` and compile/execute through PJRT.
    #[cfg(feature = "pjrt")]
    pub fn load_pjrt(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let backend = super::pjrt::PjrtBackend::new()?;
        Ok(Engine::with_backend(manifest, Box::new(backend)))
    }

    /// Default engine: artifacts from disk when present (honouring
    /// `$RUDDER_ARTIFACTS`), else the built-in default schema on the
    /// interpreter.  `None` when artifacts were explicitly requested but
    /// are unusable — on-disk artifacts that fail to load, or a
    /// `$RUDDER_ARTIFACTS` directory with no manifest — so the caller
    /// surfaces the problem instead of silently running default shapes.
    pub fn try_load_default() -> Option<Engine> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            match Engine::load(&dir) {
                Ok(e) => Some(e),
                Err(err) => {
                    crate::log_info!("warning: artifacts present but unloadable: {err}");
                    None
                }
            }
        } else if std::env::var_os("RUDDER_ARTIFACTS").is_some() {
            crate::log_info!("warning: $RUDDER_ARTIFACTS={} has no manifest.json", dir.display());
            None
        } else {
            Some(Engine::builtin(ArtifactConfig::default()))
        }
    }

    /// Backend/platform name (reported by `rudder calibrate`).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Short backend identifier ("interpreter", "pjrt") — `rudder
    /// calibrate` stamps this into `configs/calibration.toml` so
    /// constants measured on one backend are never silently applied to
    /// runs on another.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.manifest
            .entry(name)
            .ok_or_else(|| crate::err!("unknown artifact entry '{name}'"))
    }

    /// Ensure `name` is ready (compile caches for JIT-style backends).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.backend.warm(self.entry(name)?)
    }

    /// Execute `name` with positional inputs; returns the output tuple as
    /// individual tensors (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.entry(name)?;
        crate::ensure!(
            inputs.len() == entry.inputs.len(),
            "entry '{name}': {} inputs given, ABI wants {}",
            inputs.len(),
            entry.inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&entry.inputs) {
            crate::ensure!(
                t.dtype() == spec.dtype,
                "entry '{name}', input '{}': dtype {:?} != ABI {:?}",
                spec.name,
                t.dtype(),
                spec.dtype
            );
            crate::ensure!(
                t.shape == spec.shape,
                "entry '{name}', input '{}': shape {:?} != ABI {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
        }
        let start = std::time::Instant::now();
        let outputs = self.backend.execute(entry, inputs)?;
        let dt = start.elapsed().as_secs_f64();
        {
            let mut t = self.timings.lock().unwrap();
            let e = t.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        crate::ensure!(
            outputs.len() == entry.outputs.len(),
            "entry '{name}': {} outputs, ABI wants {}",
            outputs.len(),
            entry.outputs.len()
        );
        Ok(outputs)
    }

    /// (calls, total seconds) per entry since load.
    pub fn timing(&self, name: &str) -> (u64, f64) {
        self.timings
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or((0, 0.0))
    }

    /// Mean execute latency for an entry, if it ever ran.
    pub fn mean_latency(&self, name: &str) -> Option<f64> {
        let (n, total) = self.timing(name);
        if n == 0 {
            None
        } else {
            Some(total / n as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::{lit_f32, lit_i32, to_f32};

    fn small_engine() -> Engine {
        Engine::builtin(ArtifactConfig {
            batch: 4,
            fanout1: 2,
            fanout2: 3,
            feat_dim: 5,
            hidden: 6,
            classes: 3,
            mlp_feats: 4,
            mlp_hidden: 5,
            mlp_batch: 8,
            score_block: 16,
        })
    }

    // Execution-level coverage (ABI rejection details, timing counters,
    // entry parity with the host policy) lives in the integration suite
    // `rust/tests/runtime_artifacts.rs`; these unit tests cover only what
    // is local to the engine facade: builtin construction, validation
    // dtype checks, and warm dispatch.
    #[test]
    fn builtin_engine_executes_and_validates() {
        let e = small_engine();
        assert_eq!(e.platform(), "interpreter");
        let n = e.manifest.config.score_block;
        let scores: Vec<f32> = (0..n).map(|i| i as f32 * 0.2).collect();
        let accessed: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let out = e
            .execute(
                "score_update",
                &[lit_f32(&[n], &scores).unwrap(), lit_f32(&[n], &accessed).unwrap()],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let new = to_f32(&out[0]).unwrap();
        assert_eq!(new[0], 0.0); // not accessed: 0 * 0.95
        assert_eq!(new[1], 1.2); // accessed: 0.2 + 1
        assert!(e.mean_latency("score_update").is_some());
        // Dtype validation is engine-local (backends never see the call).
        let int_zeros = vec![0i32; n];
        let zeros = vec![0.0f32; n];
        let ints = lit_i32(&[n], &int_zeros).unwrap();
        let ok = lit_f32(&[n], &zeros).unwrap();
        assert!(e.execute("score_update", &[ints, ok]).is_err());
        // Warm on a known entry is fine; unknown errors.
        assert!(e.warm("score_update").is_ok());
        assert!(e.warm("nonexistent_entry").is_err());
    }
}
