//! [`RuntimeBackend`]: the seam between the coordinator and whatever
//! actually executes the AOT compute entries.
//!
//! The [`Engine`](super::Engine) owns a manifest plus one backend and does
//! all ABI validation/timing; a backend only has to run a *validated* call.
//! Two implementations ship:
//!
//! * [`interp::InterpreterBackend`](super::interp::InterpreterBackend) —
//!   the default: pure-Rust execution of the entry semantics, mirroring
//!   `python/compile/kernels/ref.py` / `model.py`.  Zero dependencies.
//! * `pjrt::PjrtBackend` (behind the `pjrt` cargo feature) — loads the HLO
//!   text artifacts through the PJRT C API (`xla` crate).

use super::artifacts::EntrySpec;
use super::tensor::Tensor;
use crate::error::Result;

/// Executes manifest entries.  Implementations must be shareable across
/// the sim's trainers (`&self` execution, `Send + Sync`).
pub trait RuntimeBackend: Send + Sync {
    /// Short backend identifier ("interpreter", "pjrt").
    fn name(&self) -> &'static str;

    /// Human-readable platform string (`rudder calibrate` reports it);
    /// device-backed backends override with the real platform name.
    fn platform(&self) -> String {
        self.name().to_string()
    }

    /// Prepare `entry` for execution (compile/warm caches).  Optional.
    fn warm(&self, _entry: &EntrySpec) -> Result<()> {
        Ok(())
    }

    /// Execute one entry.  `inputs` are already validated against the
    /// entry's ABI (arity, shapes, dtypes) by the engine.
    fn execute(&self, entry: &EntrySpec, inputs: &[Tensor]) -> Result<Vec<Tensor>>;
}
