//! AOT artifact manifest: the ABI between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` records, for every lowered HLO module, the
//! positional input tensor specs (name/shape/dtype) and output names.  The
//! engine validates every execute call against these specs — shape bugs
//! surface as errors at the call site instead of garbage numerics.
//!
//! When no artifacts directory exists, [`Manifest::builtin`] reproduces the
//! same schema from `aot.py`'s `DEFAULTS` in code, so the interpreter
//! backend (which needs no HLO files) runs out of the box.

use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            _ => Err(crate::err!("unsupported dtype '{s}'")),
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

/// Shapes baked into the artifacts (aot.py `DEFAULTS`, possibly overridden).
#[derive(Debug, Clone)]
pub struct ArtifactConfig {
    pub batch: usize,
    pub fanout1: usize,
    pub fanout2: usize,
    pub feat_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub mlp_feats: usize,
    pub mlp_hidden: usize,
    pub mlp_batch: usize,
    pub score_block: usize,
}

impl Default for ArtifactConfig {
    /// `aot.py::DEFAULTS` — the canonical artifact shapes.
    fn default() -> ArtifactConfig {
        ArtifactConfig {
            batch: 128,
            fanout1: 10,
            fanout2: 25,
            feat_dim: 100,
            hidden: 128,
            classes: 32,
            mlp_feats: 12,
            mlp_hidden: 32,
            mlp_batch: 64,
            score_block: 4096,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ArtifactConfig,
    pub entries: Vec<EntrySpec>,
}

fn spec(name: &str, shape: &[usize], dtype: Dtype) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            crate::err!(
                "reading {}: {e} (build artifacts with `python -m compile.aot`)",
                path.display()
            )
        })?;
        let root = Json::parse(&src)?;
        let cfg = root
            .get("config")
            .ok_or_else(|| crate::err!("manifest missing 'config'"))?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| crate::err!("manifest config missing '{k}'"))
        };
        let config = ArtifactConfig {
            batch: get("batch")?,
            fanout1: get("fanout1")?,
            fanout2: get("fanout2")?,
            feat_dim: get("feat_dim")?,
            hidden: get("hidden")?,
            classes: get("classes")?,
            mlp_feats: get("mlp_feats")?,
            mlp_hidden: get("mlp_hidden")?,
            mlp_batch: get("mlp_batch")?,
            score_block: get("score_block")?,
        };
        let mut entries = Vec::new();
        let entry_map = root
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| crate::err!("manifest missing 'entries'"))?;
        for (name, e) in entry_map {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| crate::err!("entry '{name}' missing file"))?;
            let mut inputs = Vec::new();
            for inp in e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| crate::err!("entry '{name}' missing inputs"))?
            {
                let iname = inp.get("name").and_then(Json::as_str).unwrap_or("?");
                let dtype = Dtype::parse(
                    inp.get("dtype").and_then(Json::as_str).unwrap_or("float32"),
                )?;
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                inputs.push(TensorSpec { name: iname.to_string(), shape, dtype });
            }
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_default();
            entries.push(EntrySpec {
                name: name.clone(),
                file: dir.join(file),
                inputs,
                outputs,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, entries })
    }

    /// Reproduce `aot.py::build_entries` in code: the same entry names,
    /// positional input specs, and output names, for any shape config.
    /// Backends that do not read HLO files (the interpreter) run from this
    /// alone; `file` points into `dir` for backends that do.
    pub fn builtin(dir: &Path, config: ArtifactConfig) -> Manifest {
        let c = &config;
        let (b, k1, k2) = (c.batch, c.fanout1, c.fanout2);
        let (d, h, cls) = (c.feat_dim, c.hidden, c.classes);
        let (f, hm, mb, sb) = (c.mlp_feats, c.mlp_hidden, c.mlp_batch, c.score_block);
        let sage_params = vec![
            spec("w1_self", &[d, h], Dtype::F32),
            spec("w1_neigh", &[d, h], Dtype::F32),
            spec("b1", &[h], Dtype::F32),
            spec("w2_self", &[h, cls], Dtype::F32),
            spec("w2_neigh", &[h, cls], Dtype::F32),
            spec("b2", &[cls], Dtype::F32),
        ];
        let sage_batch = vec![
            spec("x_self", &[b, d], Dtype::F32),
            spec("x_h1", &[b, k1, d], Dtype::F32),
            spec("x_h2", &[b, k1, k2, d], Dtype::F32),
        ];
        let mlp_params = vec![
            spec("w1", &[f, hm], Dtype::F32),
            spec("b1", &[hm], Dtype::F32),
            spec("w2", &[hm, 2], Dtype::F32),
            spec("b2", &[2], Dtype::F32),
        ];
        let entry = |name: &str, inputs: Vec<TensorSpec>, outputs: &[&str]| EntrySpec {
            name: name.to_string(),
            file: dir.join(format!("{name}.hlo.txt")),
            inputs,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        };
        let mut train_inputs = sage_params.clone();
        train_inputs.extend(sage_batch.clone());
        train_inputs.push(spec("labels", &[b], Dtype::I32));
        train_inputs.push(spec("mask", &[b], Dtype::F32));
        train_inputs.push(spec("lr", &[], Dtype::F32));
        let mut fwd_inputs = sage_params;
        fwd_inputs.extend(sage_batch);
        let mut infer_inputs = mlp_params.clone();
        infer_inputs.push(spec("feats", &[1, f], Dtype::F32));
        let mut mlp_train_inputs = mlp_params;
        mlp_train_inputs.push(spec("feats", &[mb, f], Dtype::F32));
        mlp_train_inputs.push(spec("labels", &[mb], Dtype::I32));
        mlp_train_inputs.push(spec("lr", &[], Dtype::F32));
        const SAGE_TRAIN_OUTPUTS: &[&str] = &[
            "new_w1_self",
            "new_w1_neigh",
            "new_b1",
            "new_w2_self",
            "new_w2_neigh",
            "new_b2",
            "loss",
        ];
        let entries = vec![
            entry("sage_train_step", train_inputs, SAGE_TRAIN_OUTPUTS),
            entry("sage_fwd", fwd_inputs, &["logits"]),
            entry("mlp_infer", infer_inputs, &["replace_prob"]),
            entry(
                "mlp_train_step",
                mlp_train_inputs,
                &["new_w1", "new_b1", "new_w2", "new_b2", "loss"],
            ),
            entry(
                "score_update",
                vec![
                    spec("scores", &[sb], Dtype::F32),
                    spec("accessed", &[sb], Dtype::F32),
                ],
                &["new_scores", "stale_mask"],
            ),
        ];
        Manifest { dir: dir.to_path_buf(), config, entries }
    }

    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Default artifact directory: `$RUDDER_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("RUDDER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rudder-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const SAMPLE: &str = r#"{
      "config": {"batch": 4, "fanout1": 2, "fanout2": 3, "feat_dim": 5,
                 "hidden": 6, "classes": 3, "mlp_feats": 4, "mlp_hidden": 5,
                 "mlp_batch": 8, "score_block": 16},
      "entries": {
        "score_update": {
          "file": "score_update.hlo.txt",
          "inputs": [
            {"name": "scores", "shape": [16], "dtype": "float32"},
            {"name": "accessed", "shape": [16], "dtype": "float32"}
          ],
          "outputs": ["new_scores", "stale_mask"]
        }
      }
    }"#;

    #[test]
    fn loads_manifest() {
        let dir = tmpdir("ok");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.batch, 4);
        assert_eq!(m.config.score_block, 16);
        let e = m.entry("score_update").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![16]);
        assert_eq!(e.inputs[0].dtype, Dtype::F32);
        assert_eq!(e.inputs[0].num_elements(), 16);
        assert_eq!(e.outputs, vec!["new_scores", "stale_mask"]);
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-xyz")).unwrap_err();
        assert!(err.to_string().contains("compile.aot"));
    }

    #[test]
    fn rejects_bad_dtype() {
        let dir = tmpdir("baddtype");
        write_manifest(
            &dir,
            &SAMPLE.replace("\"float32\"", "\"float64\""),
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("bfloat16").is_err());
        assert_eq!(Dtype::F32.size(), 4);
    }

    #[test]
    fn builtin_mirrors_aot_schema() {
        let m = Manifest::builtin(Path::new("artifacts"), ArtifactConfig::default());
        assert_eq!(m.entries.len(), 5);
        let train = m.entry("sage_train_step").unwrap();
        assert_eq!(train.inputs.len(), 12);
        assert_eq!(train.inputs[8].shape, vec![128, 10, 25, 100]);
        assert_eq!(train.inputs[9].dtype, Dtype::I32);
        assert_eq!(train.inputs[11].shape, Vec::<usize>::new());
        assert_eq!(train.outputs.len(), 7);
        let infer = m.entry("mlp_infer").unwrap();
        assert_eq!(infer.inputs.len(), 5);
        assert_eq!(infer.inputs[4].shape, vec![1, 12]);
        assert_eq!(m.entry("score_update").unwrap().inputs[0].shape, vec![4096]);
    }
}
