//! Runtime layer: pluggable execution of the AOT artifact entries.
//!
//! [`Engine`] validates every call against the [`artifacts`] manifest ABI
//! and delegates to a [`backend::RuntimeBackend`]:
//!
//! * [`interp::InterpreterBackend`] (default) — pure-Rust execution of the
//!   reference semantics (`python/compile/kernels/ref.py`, `model.py`);
//!   zero external dependencies, no artifact files needed.
//! * `pjrt::PjrtBackend` (cargo feature `pjrt`) — compiles the HLO *text*
//!   artifacts through the PJRT C API (`xla` crate), per
//!   /opt/xla-example/load_hlo.  HLO text is the interchange format.

pub mod artifacts;
pub mod backend;
pub mod engine;
pub mod interp;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tensor;

pub use artifacts::{ArtifactConfig, Dtype, EntrySpec, Manifest, TensorSpec};
pub use backend::RuntimeBackend;
pub use engine::Engine;
pub use interp::InterpreterBackend;
pub use tensor::{Tensor, TensorData};
