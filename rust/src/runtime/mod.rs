//! Runtime layer: PJRT client wrapper over the AOT artifacts.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, per /opt/xla-example/load_hlo.  HLO *text*
//! is the interchange format (DESIGN.md §3).

pub mod artifacts;
pub mod engine;
pub mod literal;

pub use artifacts::{ArtifactConfig, Dtype, EntrySpec, Manifest, TensorSpec};
pub use engine::Engine;
