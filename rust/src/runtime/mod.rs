//! Runtime layer: pluggable execution of the AOT artifact entries.
//!
//! [`Engine`] validates every call against the [`artifacts`] manifest ABI
//! and delegates to a [`backend::RuntimeBackend`]:
//!
//! * [`interp::InterpreterBackend`] (default) — pure-Rust execution of the
//!   reference semantics (`python/compile/kernels/ref.py`, `model.py`);
//!   zero external dependencies, no artifact files needed.
//! * `pjrt::PjrtBackend` (cargo feature `pjrt`) — compiles the HLO *text*
//!   artifacts through the PJRT C API (`xla` crate), per
//!   /opt/xla-example/load_hlo.  HLO text is the interchange format.

pub mod artifacts;
pub mod backend;
pub mod engine;
pub mod interp;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tensor;

pub use artifacts::{ArtifactConfig, Dtype, EntrySpec, Manifest, TensorSpec};
pub use backend::RuntimeBackend;
pub use engine::Engine;
pub use interp::InterpreterBackend;
pub use tensor::{Tensor, TensorData};

/// The backend [`Engine::try_load_default`] would select in this build
/// and environment, without loading anything: `pjrt` when the feature is
/// on *and* on-disk artifacts exist (mirroring [`Engine::load`]), else
/// the interpreter.  Used to validate that `configs/calibration.toml`
/// constants were measured on the backend that is about to run.
pub fn active_backend_name() -> &'static str {
    #[cfg(feature = "pjrt")]
    {
        if Manifest::default_dir().join("manifest.json").exists() {
            return "pjrt";
        }
    }
    "interpreter"
}
