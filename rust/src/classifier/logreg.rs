//! Logistic regression (SGD, L2) — the simplest §4.4 classifier.

use super::{DecisionModel, FeatureVec, F};

#[derive(Debug, Clone)]
pub struct LogReg {
    pub w: [f64; F],
    pub b: f64,
    pub lr: f64,
    pub l2: f64,
    pub epochs: usize,
}

impl LogReg {
    pub fn new() -> LogReg {
        LogReg { w: [0.0; F], b: 0.0, lr: 0.3, l2: 1e-4, epochs: 60 }
    }

    fn margin(&self, x: &FeatureVec) -> f64 {
        self.b + self.w.iter().zip(x).map(|(w, &v)| w * v as f64).sum::<f64>()
    }

    fn sgd_pass(&mut self, xs: &[FeatureVec], ys: &[bool], lr: f64) {
        for (x, &y) in xs.iter().zip(ys) {
            let p = sigmoid(self.margin(x));
            let g = p - if y { 1.0 } else { 0.0 };
            for (w, &v) in self.w.iter_mut().zip(x) {
                *w -= lr * (g * v as f64 + self.l2 * *w);
            }
            self.b -= lr * g;
        }
    }
}

impl Default for LogReg {
    fn default() -> Self {
        Self::new()
    }
}

#[inline]
pub fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl DecisionModel for LogReg {
    fn name(&self) -> String {
        "LR".into()
    }

    fn predict(&self, x: &FeatureVec) -> f64 {
        sigmoid(self.margin(x))
    }

    fn latency(&self) -> f64 {
        0.3e-3
    }

    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.w = [0.0; F];
        self.b = 0.0;
        for e in 0..self.epochs {
            let lr = self.lr / (1.0 + e as f64 * 0.05);
            self.sgd_pass(xs, ys, lr);
        }
    }

    fn finetune(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.sgd_pass(xs, ys, self.lr * 0.1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    #[test]
    fn learns_synthetic() {
        let (xs, ys) = synthetic(500, 1);
        let mut m = LogReg::new();
        m.fit(&xs, &ys);
        assert!(m.accuracy(&xs, &ys) > 0.8);
    }

    #[test]
    fn untrained_predicts_half() {
        let m = LogReg::new();
        assert!((m.predict(&[0.0; F]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn finetune_shifts_decisions() {
        let (xs, ys) = synthetic(400, 2);
        let mut m = LogReg::new();
        m.fit(&xs, &ys);
        // Finetune on inverted labels nudges predictions.
        let inv: Vec<bool> = ys.iter().map(|&y| !y).collect();
        let before = m.predict(&xs[0]);
        for _ in 0..30 {
            m.finetune(&xs, &inv);
        }
        let after = m.predict(&xs[0]);
        assert!((before - after).abs() > 1e-3);
    }
}
