//! Online finetuning (paper §4.4, §5.4): periodically update the decision
//! head on labels derived from recently observed (state, outcome) pairs,
//! weights otherwise frozen.
//!
//! The finetuner buffers [`labeling::TraceStep`]s as they stream in from
//! the live run, labels consecutive pairs with the S′ rule, and triggers a
//! `finetune` pass every `interval` minibatches — the "5/25/50" selected
//! empirically in §5.4.

use super::labeling::{label_trace, TraceStep};
use super::DecisionModel;

pub struct OnlineFinetuner {
    pub interval: usize,
    pub window: Vec<TraceStep>,
    pub max_window: usize,
    steps_since: usize,
    pub finetune_count: usize,
    /// Cumulative simulated finetune overhead (seconds) charged to the run.
    pub overhead: f64,
    /// Cost per finetune pass (simulated seconds).
    pub pass_cost: f64,
}

impl OnlineFinetuner {
    pub fn new(interval: usize) -> OnlineFinetuner {
        OnlineFinetuner {
            interval,
            window: Vec::new(),
            max_window: 256,
            steps_since: 0,
            finetune_count: 0,
            overhead: 0.0,
            pass_cost: 8e-3,
        }
    }

    /// Feed one observed step; maybe run a finetune pass.  Returns the
    /// simulated overhead incurred now (0 unless a pass triggered).
    pub fn observe(&mut self, step: TraceStep, model: &mut dyn DecisionModel) -> f64 {
        self.window.push(step);
        if self.window.len() > self.max_window {
            let excess = self.window.len() - self.max_window;
            self.window.drain(..excess);
        }
        self.steps_since += 1;
        if self.steps_since < self.interval || self.window.len() < 2 {
            return 0.0;
        }
        self.steps_since = 0;
        let labeled = label_trace(&self.window);
        if labeled.is_empty() {
            return 0.0;
        }
        let xs: Vec<_> = labeled.iter().map(|e| e.x).collect();
        let ys: Vec<_> = labeled.iter().map(|e| e.y).collect();
        model.finetune(&xs, &ys);
        self.finetune_count += 1;
        self.overhead += self.pass_cost;
        self.pass_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::{Kind, F};

    fn step(hits: f64, replaced: bool) -> TraceStep {
        TraceStep { x: [0.1; F], hits_pct: hits, comm_time: 0.05, replaced }
    }

    #[test]
    fn triggers_every_interval() {
        let mut ft = OnlineFinetuner::new(5);
        let mut model = Kind::LogReg.build(1);
        let mut triggered = 0;
        for i in 0..20 {
            let cost = ft.observe(step(40.0 + i as f64, i % 2 == 0), model.as_mut());
            if cost > 0.0 {
                triggered += 1;
            }
        }
        assert_eq!(triggered, 4);
        assert_eq!(ft.finetune_count, 4);
        assert!((ft.overhead - 4.0 * ft.pass_cost).abs() < 1e-12);
    }

    #[test]
    fn window_bounded() {
        let mut ft = OnlineFinetuner::new(1000);
        ft.max_window = 10;
        let mut model = Kind::LogReg.build(1);
        for i in 0..50 {
            ft.observe(step(i as f64, false), model.as_mut());
        }
        assert_eq!(ft.window.len(), 10);
    }

    #[test]
    fn no_trigger_with_single_step() {
        let mut ft = OnlineFinetuner::new(1);
        let mut model = Kind::LogReg.build(1);
        assert_eq!(ft.observe(step(1.0, false), model.as_mut()), 0.0);
        assert!(ft.observe(step(2.0, true), model.as_mut()) > 0.0);
    }
}
