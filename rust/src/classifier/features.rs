//! Observation → fixed feature vector (the classifier input ABI; `F` must
//! match the `mlp_feats` the XLA MLP artifact was built with).

use crate::agent::Observation;

use super::{FeatureVec, F};

/// Normalized, bounded features — stateless, exactly what §4.4 feeds the
/// classifiers (%-Hits, communication latency proxies, buffer occupancy,
//  progress, graph scale).
pub fn extract(o: &Observation) -> FeatureVec {
    let total_mb = (o.minibatches_done + o.minibatches_pending) as f64;
    let progress = if total_mb > 0.0 { o.minibatches_done as f64 / total_mb } else { 0.0 };
    let epoch_frac = if o.epochs_total > 0 {
        o.epoch as f64 / o.epochs_total as f64
    } else {
        0.0
    };
    let halo_frac = if o.graph_nodes > 0 {
        o.halo_nodes as f64 / o.graph_nodes as f64
    } else {
        0.0
    };
    let cap_frac = if o.halo_nodes > 0 {
        o.buffer_capacity as f64 / o.halo_nodes as f64
    } else {
        0.0
    };
    let mut x = [0.0f32; F];
    x[0] = (o.hits_pct / 100.0) as f32;
    x[1] = (o.buffer_occupancy_pct / 100.0) as f32;
    x[2] = (o.stale_pct / 100.0) as f32;
    x[3] = (o.replaced_pct_last / 100.0) as f32;
    x[4] = ((o.comm_nodes_last as f64).ln_1p() / 12.0) as f32;
    x[5] = (o.comm_nodes_ema.max(0.0).ln_1p() / 12.0) as f32;
    x[6] = progress as f32;
    x[7] = (o.delta_hits / 100.0) as f32;
    x[8] = (o.delta_comm.signum() * o.delta_comm.abs().ln_1p() / 12.0) as f32;
    x[9] = halo_frac as f32;
    x[10] = cap_frac.min(1.0) as f32;
    x[11] = epoch_frac as f32;
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs() -> Observation {
        Observation {
            hits_pct: 55.0,
            buffer_occupancy_pct: 80.0,
            stale_pct: 10.0,
            replaced_pct_last: 4.0,
            comm_nodes_last: 1500,
            comm_nodes_ema: 1400.0,
            minibatches_done: 25,
            minibatches_pending: 75,
            epoch: 2,
            epochs_total: 10,
            delta_hits: -2.0,
            delta_comm: 120.0,
            graph_nodes: 60_000,
            graph_edges: 770_000,
            partition_nodes: 15_000,
            halo_nodes: 9_000,
            buffer_capacity: 450,
        }
    }

    #[test]
    fn features_bounded() {
        let x = extract(&obs());
        for (i, &v) in x.iter().enumerate() {
            assert!((-1.0..=1.5).contains(&v), "feature {i} out of range: {v}");
        }
        assert!((x[0] - 0.55).abs() < 1e-6);
        assert!((x[6] - 0.25).abs() < 1e-6);
        assert!((x[11] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn deterministic() {
        assert_eq!(extract(&obs()), extract(&obs()));
    }

    #[test]
    fn zero_observation_safe() {
        let x = extract(&Observation::default());
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn negative_delta_comm_sign_preserved() {
        let mut o = obs();
        o.delta_comm = -120.0;
        let x = extract(&o);
        assert!(x[8] < 0.0);
    }
}
