//! Random forest: bagged CART trees with per-tree feature subsampling.

use super::tree::{Tree, TreeParams};
use super::{DecisionModel, FeatureVec, F};
use crate::util::rng::Pcg32;

pub struct RandomForest {
    pub trees: Vec<Tree>,
    pub n_trees: usize,
    pub params: TreeParams,
    seed: u64,
}

impl RandomForest {
    pub fn new(seed: u64) -> RandomForest {
        RandomForest {
            trees: Vec::new(),
            n_trees: 30,
            params: TreeParams { max_depth: 6, min_leaf: 3, feature_subsample: 6 },
            seed,
        }
    }
}

impl DecisionModel for RandomForest {
    fn name(&self) -> String {
        "RF".into()
    }

    fn predict(&self, x: &FeatureVec) -> f64 {
        if self.trees.is_empty() {
            return 0.5;
        }
        self.trees.iter().map(|t| t.predict(x)).sum::<f64>() / self.trees.len() as f64
    }

    fn latency(&self) -> f64 {
        0.8e-3
    }

    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        let targets: Vec<f64> = ys.iter().map(|&y| if y { 1.0 } else { 0.0 }).collect();
        let mut rng = Pcg32::new(self.seed);
        self.trees.clear();
        for t in 0..self.n_trees {
            let mut tree_rng = rng.fork(t as u64);
            // Bootstrap sample.
            let n = xs.len();
            let (bx, bt): (Vec<FeatureVec>, Vec<f64>) = (0..n)
                .map(|_| {
                    let i = tree_rng.below(n as u64) as usize;
                    (xs[i], targets[i])
                })
                .unzip();
            // Random feature order; tree looks at the first
            // `feature_subsample` entries.
            let mut order: Vec<usize> = (0..F).collect();
            tree_rng.shuffle(&mut order);
            self.trees.push(Tree::fit(&bx, &bt, self.params, &order));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    #[test]
    fn ensemble_beats_chance() {
        let (xs, ys) = synthetic(500, 20);
        let mut m = RandomForest::new(1);
        m.fit(&xs, &ys);
        assert!(m.accuracy(&xs, &ys) > 0.85, "{}", m.accuracy(&xs, &ys));
    }

    #[test]
    fn unfitted_predicts_half() {
        let m = RandomForest::new(1);
        assert_eq!(m.predict(&[0.0; F]), 0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let (xs, ys) = synthetic(200, 21);
        let mut a = RandomForest::new(7);
        let mut b = RandomForest::new(7);
        a.fit(&xs, &ys);
        b.fit(&xs, &ys);
        let x = xs[0];
        assert_eq!(a.predict(&x), b.predict(&x));
    }

    #[test]
    fn averaged_probabilities_in_unit_interval() {
        let (xs, ys) = synthetic(300, 22);
        let mut m = RandomForest::new(2);
        m.fit(&xs, &ys);
        for x in &xs {
            let p = m.predict(x);
            assert!((0.0..=1.0).contains(&p));
        }
    }
}
