//! Offline label assignment (paper §4.4).
//!
//! Execution traces are unlabelled; a replacement instance is labelled
//! "good" iff the %-Hits improvement outweighs the communication-cost
//! increase across successive minibatches:
//!
//! ```text
//! S' = Δ%Hits − ΔT_COMM > 0   →  good (1),  else bad (0)
//! ```
//!
//! The paper notes the scenarios that compromise label integrity (delayed
//! effects, stateless views, undersampled configuration space) — which the
//! classifier evaluation then surfaces as the ~50% accuracies of Table 4.

use super::FeatureVec;

/// A (feature, label) pair assembled from a trace.
#[derive(Debug, Clone)]
pub struct LabeledExample {
    pub x: FeatureVec,
    pub y: bool,
}

/// One raw trace step the labeller consumes.
#[derive(Debug, Clone)]
pub struct TraceStep {
    pub x: FeatureVec,
    pub hits_pct: f64,
    /// Communication *time* for this minibatch (the T_COMM of S').
    pub comm_time: f64,
    /// Was a replacement executed on this step?
    pub replaced: bool,
}

/// Normalisation constant: 1 percentage point of hits is traded against
/// this many seconds of communication.
pub const COMM_WEIGHT: f64 = 100.0;

/// Label every decision point in a trace.  For a step at `t`, compare
/// metrics at `t+1` vs `t`: `Δ%Hits − COMM_WEIGHT × ΔT_comm > 0`.
pub fn label_trace(steps: &[TraceStep]) -> Vec<LabeledExample> {
    let mut out = Vec::new();
    for w in steps.windows(2) {
        let (cur, next) = (&w[0], &w[1]);
        let d_hits = next.hits_pct - cur.hits_pct;
        let d_comm = next.comm_time - cur.comm_time;
        let s_prime = d_hits - COMM_WEIGHT * d_comm;
        // The label answers "was replacing at this state good?".  For steps
        // that replaced, the observed outcome is direct; for steps that
        // skipped, the counterfactual is inverted (skipping was good iff
        // the state did not degrade).
        let y = if cur.replaced { s_prime > 0.0 } else { s_prime <= 0.0 };
        out.push(LabeledExample { x: cur.x, y });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(hits: f64, comm: f64, replaced: bool) -> TraceStep {
        TraceStep { x: [0.0; super::super::F], hits_pct: hits, comm_time: comm, replaced }
    }

    #[test]
    fn replacement_that_helps_is_good() {
        let trace = vec![step(40.0, 0.10, true), step(48.0, 0.10, false)];
        let labels = label_trace(&trace);
        assert_eq!(labels.len(), 1);
        assert!(labels[0].y, "hits +8, comm flat -> good");
    }

    #[test]
    fn replacement_whose_comm_cost_dominates_is_bad() {
        let trace = vec![step(40.0, 0.10, true), step(42.0, 0.15, false)];
        // ΔHits = 2, ΔT_comm = 0.05 × 100 = 5 -> S' < 0.
        assert!(!label_trace(&trace)[0].y);
    }

    #[test]
    fn skip_during_stable_state_is_good() {
        let trace = vec![step(70.0, 0.10, false), step(70.0, 0.10, false)];
        assert!(label_trace(&trace)[0].y);
    }

    #[test]
    fn skip_while_state_improves_anyway_is_bad_label() {
        // Hits rose without a replacement: the labeller credits "replace
        // would have been good" -> skip gets labelled bad.  This is exactly
        // the label-integrity hazard §4.4 describes.
        let trace = vec![step(40.0, 0.10, false), step(55.0, 0.10, false)];
        assert!(!label_trace(&trace)[0].y);
    }

    #[test]
    fn trace_of_n_steps_yields_n_minus_1_labels() {
        let trace: Vec<TraceStep> = (0..10).map(|i| step(i as f64, 0.1, i % 2 == 0)).collect();
        assert_eq!(label_trace(&trace).len(), 9);
        assert!(label_trace(&[]).is_empty());
        assert!(label_trace(&trace[..1]).is_empty());
    }
}
