//! ML-classifier controllers (paper §4.4): stateless discriminative models
//! mapping the current buffer/runtime statistics to a binary
//! replace/skip decision.
//!
//! Six families, all trained offline on labelled traces (the `S'` rule in
//! [`labeling`]) and optionally finetuned online:
//! MLP ([`mlp`], with an XLA-artifact variant running through PJRT), logistic
//! regression ([`logreg`]), CART decision trees ([`tree`]), random forests
//! ([`forest`]), gradient-boosted trees ([`gbdt`], XGBoost-lite), linear SVM
//! ([`svm`]), and a TabNet-lite with sparse feature gating ([`tabnet`]).

pub mod features;
pub mod finetune;
pub mod forest;
pub mod gbdt;
pub mod labeling;
pub mod logreg;
pub mod mlp;
pub mod svm;
pub mod tabnet;
pub mod trainer;
pub mod tree;

/// Input feature dimensionality (must match aot.py `mlp_feats`... the XLA
/// MLP artifact is built for this F).
pub const F: usize = 12;

pub type FeatureVec = [f32; F];

/// A trainable binary decision model.
pub trait DecisionModel: Send {
    fn name(&self) -> String;
    /// Probability that replacing now is beneficial.
    fn predict(&self, x: &FeatureVec) -> f64;
    /// Inference latency in (virtual) seconds.
    fn latency(&self) -> f64;
    /// Full (re)fit on a labelled set.
    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]);
    /// Online finetune on a small fresh batch (default: head refit is a
    /// no-op for models without incremental training).
    fn finetune(&mut self, _xs: &[FeatureVec], _ys: &[bool]) {}
    /// Supervised accuracy on a labelled set.
    fn accuracy(&self, xs: &[FeatureVec], ys: &[bool]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let correct = xs
            .iter()
            .zip(ys)
            .filter(|(x, &y)| (self.predict(x) > 0.5) == y)
            .count();
        correct as f64 / xs.len() as f64
    }
}

/// Classifier selector used by configs and the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Mlp,
    LogReg,
    RandomForest,
    Svm,
    Xgb,
    TabNet,
}

pub const ALL_KINDS: &[Kind] =
    &[Kind::Mlp, Kind::LogReg, Kind::RandomForest, Kind::Svm, Kind::Xgb, Kind::TabNet];

impl Kind {
    pub fn parse(s: &str) -> crate::error::Result<Kind> {
        match s.to_ascii_lowercase().as_str() {
            "mlp" => Ok(Kind::Mlp),
            "lr" | "logreg" => Ok(Kind::LogReg),
            "rf" | "forest" => Ok(Kind::RandomForest),
            "svm" => Ok(Kind::Svm),
            "xgb" | "xgboost" => Ok(Kind::Xgb),
            "tabnet" => Ok(Kind::TabNet),
            _ => crate::bail!("unknown classifier '{s}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kind::Mlp => "MLP",
            Kind::LogReg => "LR",
            Kind::RandomForest => "RF",
            Kind::Svm => "SVM",
            Kind::Xgb => "XGB",
            Kind::TabNet => "TabNet",
        }
    }

    /// Instantiate an untrained model with a deterministic seed.
    pub fn build(&self, seed: u64) -> Box<dyn DecisionModel> {
        match self {
            Kind::Mlp => Box::new(mlp::RustMlp::new(seed)),
            Kind::LogReg => Box::new(logreg::LogReg::new()),
            Kind::RandomForest => Box::new(forest::RandomForest::new(seed)),
            Kind::Svm => Box::new(svm::LinearSvm::new()),
            Kind::Xgb => Box::new(gbdt::Gbdt::new()),
            Kind::TabNet => Box::new(tabnet::TabNetLite::new(seed)),
        }
    }
}

#[cfg(test)]
pub(crate) mod testdata {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Separable-ish synthetic task: replace is beneficial when hits are low
    /// and occupancy below 1 (mirrors the real decision geometry).
    pub fn synthetic(n: usize, seed: u64) -> (Vec<FeatureVec>, Vec<bool>) {
        let mut rng = Pcg32::new(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let mut x = [0.0f32; F];
            for v in x.iter_mut() {
                *v = rng.f32();
            }
            let score = (1.0 - x[0]) + (1.0 - x[1]) * 0.5 + x[2] * 0.3 - 0.9;
            let noisy = score + (rng.f32() - 0.5) * 0.2;
            xs.push(x);
            ys.push(noisy > 0.0);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in ALL_KINDS {
            let parsed = Kind::parse(&k.name().to_ascii_lowercase()).unwrap();
            assert_eq!(parsed, *k);
        }
        assert!(Kind::parse("nope").is_err());
        assert_eq!(Kind::parse("xgboost").unwrap(), Kind::Xgb);
    }

    #[test]
    fn every_kind_learns_the_synthetic_task() {
        let (xs, ys) = testdata::synthetic(600, 42);
        let (txs, tys) = testdata::synthetic(200, 43);
        let base_rate = tys.iter().filter(|&&y| y).count() as f64 / tys.len() as f64;
        let majority = base_rate.max(1.0 - base_rate);
        for kind in ALL_KINDS {
            let mut m = kind.build(1);
            m.fit(&xs, &ys);
            let acc = m.accuracy(&txs, &tys);
            assert!(
                acc > majority.max(0.70),
                "{} only reached {acc:.3} (majority {majority:.3})",
                kind.name()
            );
        }
    }

    #[test]
    fn latencies_are_small_and_positive() {
        for kind in ALL_KINDS {
            let m = kind.build(1);
            let l = m.latency();
            assert!(l > 0.0 && l < 0.05, "{}: {l}", kind.name());
        }
    }
}
