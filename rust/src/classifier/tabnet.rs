//! TabNet-lite: sequential-attention feature selection + a small decision
//! head.
//!
//! Captures the architectural property the paper discusses (§5.3): a
//! *sparse gating mechanism* that hard-selects a feature subset per
//! decision step.  Gates are learned (softmax over feature logits,
//! sharpened to top-k at inference), so useful features can be — and under
//! distribution shift often are — discarded, which is exactly the failure
//! mode Table 2 shows for TabNet in synchronous mode.

use super::logreg::sigmoid;
use super::{DecisionModel, FeatureVec, F};
use crate::util::rng::Pcg32;

pub const STEPS: usize = 2;
pub const TOP_K: usize = 5;

pub struct TabNetLite {
    /// Per-step gate logits over features.
    pub gate_logits: Vec<[f64; F]>,
    /// Per-step linear head on the gated features.
    pub head_w: Vec<[f64; F]>,
    pub head_b: Vec<f64>,
    pub epochs: usize,
    pub lr: f64,
    seed: u64,
}

impl TabNetLite {
    pub fn new(seed: u64) -> TabNetLite {
        let mut rng = Pcg32::new(seed);
        let mut init = || {
            let mut a = [0.0f64; F];
            for v in a.iter_mut() {
                *v = rng.normal() * 0.1;
            }
            a
        };
        TabNetLite {
            gate_logits: (0..STEPS).map(|_| init()).collect(),
            head_w: (0..STEPS).map(|_| init()).collect(),
            head_b: vec![0.0; STEPS],
            epochs: 150,
            lr: 0.25,
            seed,
        }
    }

    /// Soft gates during training; hard top-k at inference.
    fn gates(&self, step: usize, hard: bool) -> [f64; F] {
        let logits = &self.gate_logits[step];
        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut g = [0.0f64; F];
        let mut z = 0.0;
        for i in 0..F {
            g[i] = ((logits[i] - m) / 0.5).exp();
            z += g[i];
        }
        for v in g.iter_mut() {
            *v /= z;
        }
        if hard {
            // Keep top-k gates, renormalized; zero the rest (sparse mask).
            let mut idx: Vec<usize> = (0..F).collect();
            idx.sort_by(|&a, &b| g[b].partial_cmp(&g[a]).unwrap());
            let mut hardg = [0.0f64; F];
            let kept: f64 = idx[..TOP_K].iter().map(|&i| g[i]).sum();
            for &i in &idx[..TOP_K] {
                hardg[i] = g[i] / kept;
            }
            return hardg;
        }
        g
    }

    fn raw(&self, x: &FeatureVec, hard: bool) -> f64 {
        let mut acc = 0.0;
        for s in 0..STEPS {
            let g = self.gates(s, hard);
            let mut dot = self.head_b[s];
            for i in 0..F {
                dot += self.head_w[s][i] * g[i] * x[i] as f64;
            }
            acc += dot;
        }
        acc
    }

    fn sgd_pass(&mut self, xs: &[FeatureVec], ys: &[bool], lr: f64) {
        for (x, &y) in xs.iter().zip(ys) {
            let p = sigmoid(self.raw(x, false));
            let err = p - if y { 1.0 } else { 0.0 };
            for s in 0..STEPS {
                let g = self.gates(s, false);
                for i in 0..F {
                    let xi = x[i] as f64;
                    // Head gradient.
                    let gw = err * g[i] * xi;
                    self.head_w[s][i] -= lr * gw;
                    // Gate gradient (through the softmax, diagonal approx).
                    let ggate = err * self.head_w[s][i] * xi * g[i] * (1.0 - g[i]) / 0.5;
                    self.gate_logits[s][i] -= lr * ggate;
                }
                self.head_b[s] -= lr * err;
            }
        }
    }
}

impl DecisionModel for TabNetLite {
    fn name(&self) -> String {
        "TabNet".into()
    }

    fn predict(&self, x: &FeatureVec) -> f64 {
        sigmoid(self.raw(x, true))
    }

    fn latency(&self) -> f64 {
        1.8e-3
    }

    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        *self = TabNetLite::new(self.seed);
        for e in 0..self.epochs {
            let lr = self.lr / (1.0 + e as f64 * 0.01);
            self.sgd_pass(xs, ys, lr);
        }
    }

    fn finetune(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.sgd_pass(xs, ys, self.lr * 0.05);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    #[test]
    fn learns_synthetic() {
        let (xs, ys) = synthetic(500, 40);
        let mut m = TabNetLite::new(1);
        m.fit(&xs, &ys);
        assert!(m.accuracy(&xs, &ys) > 0.75, "{}", m.accuracy(&xs, &ys));
    }

    #[test]
    fn inference_mask_is_sparse() {
        let (xs, ys) = synthetic(300, 41);
        let mut m = TabNetLite::new(2);
        m.fit(&xs, &ys);
        for s in 0..STEPS {
            let g = m.gates(s, true);
            let nonzero = g.iter().filter(|&&v| v > 0.0).count();
            assert_eq!(nonzero, TOP_K);
            assert!((g.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn gating_selects_informative_features() {
        // Targets depend on features 0..3 only (see testdata::synthetic).
        let (xs, ys) = synthetic(600, 42);
        let mut m = TabNetLite::new(3);
        m.fit(&xs, &ys);
        let g = m.gates(0, true);
        let informative: f64 = g[..3].iter().sum();
        assert!(
            informative > 3.0 * (TOP_K as f64 / F as f64) * 0.5,
            "gates ignore informative features: {g:?}"
        );
    }
}
