//! Linear SVM (hinge loss, SGD / Pegasos-style).

use super::{DecisionModel, FeatureVec, F};

#[derive(Debug, Clone)]
pub struct LinearSvm {
    pub w: [f64; F],
    pub b: f64,
    pub lambda: f64,
    pub epochs: usize,
}

impl LinearSvm {
    pub fn new() -> LinearSvm {
        LinearSvm { w: [0.0; F], b: 0.0, lambda: 1e-3, epochs: 80 }
    }

    fn margin(&self, x: &FeatureVec) -> f64 {
        self.b + self.w.iter().zip(x).map(|(w, &v)| w * v as f64).sum::<f64>()
    }
}

impl Default for LinearSvm {
    fn default() -> Self {
        Self::new()
    }
}

impl DecisionModel for LinearSvm {
    fn name(&self) -> String {
        "SVM".into()
    }

    fn predict(&self, x: &FeatureVec) -> f64 {
        // Squash the margin for a probability-ish output.
        super::logreg::sigmoid(2.0 * self.margin(x))
    }

    fn latency(&self) -> f64 {
        0.3e-3
    }

    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.w = [0.0; F];
        self.b = 0.0;
        let mut t = 1.0f64;
        for _ in 0..self.epochs {
            for (x, &y) in xs.iter().zip(ys) {
                let lr = 1.0 / (self.lambda * t);
                t += 1.0;
                let yy = if y { 1.0 } else { -1.0 };
                let m = yy * self.margin(x);
                for w in self.w.iter_mut() {
                    *w *= 1.0 - lr * self.lambda;
                }
                if m < 1.0 {
                    for (w, &v) in self.w.iter_mut().zip(x) {
                        *w += lr * yy * v as f64;
                    }
                    self.b += lr * yy * 0.1;
                }
            }
        }
    }

    fn finetune(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        for (x, &y) in xs.iter().zip(ys) {
            let yy = if y { 1.0 } else { -1.0 };
            if yy * self.margin(x) < 1.0 {
                for (w, &v) in self.w.iter_mut().zip(x) {
                    *w += 0.01 * yy * v as f64;
                }
                self.b += 0.001 * yy;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    #[test]
    fn learns_synthetic() {
        let (xs, ys) = synthetic(500, 3);
        let mut m = LinearSvm::new();
        m.fit(&xs, &ys);
        assert!(m.accuracy(&xs, &ys) > 0.78, "{}", m.accuracy(&xs, &ys));
    }

    #[test]
    fn margins_translate_to_confidence() {
        let (xs, ys) = synthetic(500, 4);
        let mut m = LinearSvm::new();
        m.fit(&xs, &ys);
        let probs: Vec<f64> = xs.iter().map(|x| m.predict(x)).collect();
        assert!(probs.iter().any(|&p| p > 0.8));
        assert!(probs.iter().any(|&p| p < 0.2));
    }
}
