//! MLP decision classifier.
//!
//! Two implementations sharing the same architecture (F → 32 → 2, ReLU,
//! softmax — mirroring `model.py::MlpParams`):
//!
//! * [`RustMlp`] — native backprop, used for offline training and sweeps.
//! * [`RuntimeMlp`] — runs inference and finetune steps through the
//!   `mlp_infer` / `mlp_train_step` AOT entries on the runtime engine
//!   (interpreter by default, PJRT with `--features pjrt`), proving the
//!   classifier path composes with the runtime (weights live host-side
//!   between calls, exactly like the GNN runner).

use std::sync::Arc;

use super::{DecisionModel, FeatureVec, F};
use crate::runtime::tensor::{self as lit, Tensor};
use crate::runtime::Engine;
use crate::util::rng::Pcg32;

pub const HIDDEN: usize = 32;

/// Shared parameter block (row-major, matching the artifact ABI).
#[derive(Debug, Clone)]
pub struct MlpWeights {
    pub w1: Vec<f32>, // F × HIDDEN
    pub b1: Vec<f32>, // HIDDEN
    pub w2: Vec<f32>, // HIDDEN × 2
    pub b2: Vec<f32>, // 2
}

impl MlpWeights {
    pub fn init(seed: u64) -> MlpWeights {
        let mut rng = Pcg32::new(seed);
        let s1 = (2.0 / F as f64).sqrt();
        let s2 = (2.0 / HIDDEN as f64).sqrt();
        MlpWeights {
            w1: (0..F * HIDDEN).map(|_| (rng.normal() * s1) as f32).collect(),
            b1: vec![0.0; HIDDEN],
            w2: (0..HIDDEN * 2).map(|_| (rng.normal() * s2) as f32).collect(),
            b2: vec![0.0; 2],
        }
    }

    /// Forward pass; returns (hidden activations, logits).
    pub fn forward(&self, x: &FeatureVec) -> ([f32; HIDDEN], [f32; 2]) {
        let mut h = [0.0f32; HIDDEN];
        for j in 0..HIDDEN {
            let mut acc = self.b1[j];
            for i in 0..F {
                acc += x[i] * self.w1[i * HIDDEN + j];
            }
            h[j] = acc.max(0.0);
        }
        let mut logits = [0.0f32; 2];
        for c in 0..2 {
            let mut acc = self.b2[c];
            for j in 0..HIDDEN {
                acc += h[j] * self.w2[j * 2 + c];
            }
            logits[c] = acc;
        }
        (h, logits)
    }

    pub fn replace_prob(&self, x: &FeatureVec) -> f64 {
        let (_, logits) = self.forward(x);
        let m = logits[0].max(logits[1]);
        let e0 = (logits[0] - m).exp();
        let e1 = (logits[1] - m).exp();
        (e1 / (e0 + e1)) as f64
    }

    /// One SGD step on a batch (cross-entropy).  Returns mean loss.
    pub fn sgd_step(&mut self, xs: &[FeatureVec], ys: &[bool], lr: f32) -> f32 {
        let n = xs.len().max(1) as f32;
        let mut gw1 = vec![0.0f32; F * HIDDEN];
        let mut gb1 = vec![0.0f32; HIDDEN];
        let mut gw2 = vec![0.0f32; HIDDEN * 2];
        let mut gb2 = vec![0.0f32; 2];
        let mut loss = 0.0f32;
        for (x, &y) in xs.iter().zip(ys) {
            let (h, logits) = self.forward(x);
            let m = logits[0].max(logits[1]);
            let e0 = (logits[0] - m).exp();
            let e1 = (logits[1] - m).exp();
            let z = e0 + e1;
            let p = [e0 / z, e1 / z];
            let t = [if y { 0.0 } else { 1.0 }, if y { 1.0 } else { 0.0 }];
            loss -= (if y { p[1] } else { p[0] }).max(1e-9).ln();
            let dlogits = [p[0] - t[0], p[1] - t[1]];
            for c in 0..2 {
                gb2[c] += dlogits[c];
                for j in 0..HIDDEN {
                    gw2[j * 2 + c] += h[j] * dlogits[c];
                }
            }
            for j in 0..HIDDEN {
                if h[j] <= 0.0 {
                    continue;
                }
                let dh = dlogits[0] * self.w2[j * 2] + dlogits[1] * self.w2[j * 2 + 1];
                gb1[j] += dh;
                for i in 0..F {
                    gw1[i * HIDDEN + j] += x[i] * dh;
                }
            }
        }
        let step = lr / n;
        for (w, g) in self.w1.iter_mut().zip(&gw1) {
            *w -= step * g;
        }
        for (w, g) in self.b1.iter_mut().zip(&gb1) {
            *w -= step * g;
        }
        for (w, g) in self.w2.iter_mut().zip(&gw2) {
            *w -= step * g;
        }
        for (w, g) in self.b2.iter_mut().zip(&gb2) {
            *w -= step * g;
        }
        loss / n
    }
}

// ---------------------------------------------------------------------------

pub struct RustMlp {
    pub weights: MlpWeights,
    pub epochs: usize,
    pub lr: f32,
    seed: u64,
}

impl RustMlp {
    pub fn new(seed: u64) -> RustMlp {
        RustMlp { weights: MlpWeights::init(seed), epochs: 120, lr: 0.5, seed }
    }
}

impl DecisionModel for RustMlp {
    fn name(&self) -> String {
        "MLP".into()
    }

    fn predict(&self, x: &FeatureVec) -> f64 {
        self.weights.replace_prob(x)
    }

    fn latency(&self) -> f64 {
        1.2e-3
    }

    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.weights = MlpWeights::init(self.seed);
        for e in 0..self.epochs {
            let lr = self.lr / (1.0 + e as f32 * 0.02);
            self.weights.sgd_step(xs, ys, lr);
        }
    }

    fn finetune(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.weights.sgd_step(xs, ys, self.lr * 0.05);
    }
}

// ---------------------------------------------------------------------------

/// Engine-backed MLP: inference via the `mlp_infer` entry, finetuning via
/// `mlp_train_step` (padding/truncating the batch to the artifact's
/// `mlp_batch`).
pub struct RuntimeMlp {
    pub engine: Arc<Engine>,
    pub weights: MlpWeights,
}

impl RuntimeMlp {
    pub fn new(engine: Arc<Engine>, seed: u64) -> crate::error::Result<RuntimeMlp> {
        let c = &engine.manifest.config;
        crate::ensure!(
            c.mlp_feats == F && c.mlp_hidden == HIDDEN,
            "artifact MLP shape ({}, {}) != classifier ({F}, {HIDDEN}); \
             rebuild artifacts",
            c.mlp_feats,
            c.mlp_hidden
        );
        Ok(RuntimeMlp { engine, weights: MlpWeights::init(seed) })
    }

    fn param_tensors(&self) -> crate::error::Result<Vec<Tensor>> {
        Ok(vec![
            lit::lit_f32(&[F, HIDDEN], &self.weights.w1)?,
            lit::lit_f32(&[HIDDEN], &self.weights.b1)?,
            lit::lit_f32(&[HIDDEN, 2], &self.weights.w2)?,
            lit::lit_f32(&[2], &self.weights.b2)?,
        ])
    }

    /// Replace-probability through the runtime path.
    pub fn predict_rt(&self, x: &FeatureVec) -> crate::error::Result<f64> {
        let mut inputs = self.param_tensors()?;
        inputs.push(lit::lit_f32(&[1, F], x)?);
        let out = self.engine.execute("mlp_infer", &inputs)?;
        Ok(lit::to_f32(&out[0])?[0] as f64)
    }

    /// One finetune step through the runtime path; returns the loss.
    pub fn finetune_rt(
        &mut self,
        xs: &[FeatureVec],
        ys: &[bool],
        lr: f32,
    ) -> crate::error::Result<f32> {
        crate::ensure!(
            !xs.is_empty() && xs.len() == ys.len(),
            "finetune_rt: need matching non-empty features/labels ({} vs {})",
            xs.len(),
            ys.len()
        );
        let mb = self.engine.manifest.config.mlp_batch;
        let mut feats = vec![0.0f32; mb * F];
        let mut labels = vec![0i32; mb];
        for i in 0..mb {
            let src = i % xs.len();
            feats[i * F..(i + 1) * F].copy_from_slice(&xs[src]);
            labels[i] = ys[src] as i32;
        }
        let mut inputs = self.param_tensors()?;
        inputs.push(lit::lit_f32(&[mb, F], &feats)?);
        inputs.push(lit::lit_i32(&[mb], &labels)?);
        inputs.push(lit::lit_scalar_f32(lr)?);
        let out = self.engine.execute("mlp_train_step", &inputs)?;
        self.weights.w1 = lit::to_f32(&out[0])?;
        self.weights.b1 = lit::to_f32(&out[1])?;
        self.weights.w2 = lit::to_f32(&out[2])?;
        self.weights.b2 = lit::to_f32(&out[3])?;
        Ok(lit::to_f32(&out[4])?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    #[test]
    fn learns_synthetic() {
        let (xs, ys) = synthetic(500, 7);
        let mut m = RustMlp::new(1);
        m.fit(&xs, &ys);
        assert!(m.accuracy(&xs, &ys) > 0.85, "{}", m.accuracy(&xs, &ys));
    }

    #[test]
    fn sgd_reduces_loss() {
        let (xs, ys) = synthetic(200, 8);
        let mut w = MlpWeights::init(3);
        let first = w.sgd_step(&xs, &ys, 0.5);
        let mut last = first;
        for _ in 0..50 {
            last = w.sgd_step(&xs, &ys, 0.5);
        }
        assert!(last < first * 0.9, "first {first} last {last}");
    }

    #[test]
    fn probabilities_valid() {
        let w = MlpWeights::init(4);
        let (xs, _) = synthetic(50, 9);
        for x in &xs {
            let p = w.replace_prob(x);
            assert!((0.0..=1.0).contains(&p));
        }
    }

    #[test]
    fn deterministic_init() {
        let a = MlpWeights::init(5);
        let b = MlpWeights::init(5);
        assert_eq!(a.w1, b.w1);
    }
}
