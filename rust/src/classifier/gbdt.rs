//! Gradient-boosted decision trees (XGBoost-lite): logistic loss, shallow
//! regression trees on the gradient, shrinkage.

use super::logreg::sigmoid;
use super::tree::{Tree, TreeParams};
use super::{DecisionModel, FeatureVec, F};

pub struct Gbdt {
    pub trees: Vec<Tree>,
    pub base: f64,
    pub n_rounds: usize,
    pub learning_rate: f64,
    pub params: TreeParams,
}

impl Gbdt {
    pub fn new() -> Gbdt {
        Gbdt {
            trees: Vec::new(),
            base: 0.0,
            n_rounds: 40,
            learning_rate: 0.3,
            params: TreeParams { max_depth: 3, min_leaf: 5, feature_subsample: F },
        }
    }

    fn raw(&self, x: &FeatureVec) -> f64 {
        self.base
            + self
                .trees
                .iter()
                .map(|t| self.learning_rate * t.predict(x))
                .sum::<f64>()
    }
}

impl Default for Gbdt {
    fn default() -> Self {
        Self::new()
    }
}

impl DecisionModel for Gbdt {
    fn name(&self) -> String {
        "XGB".into()
    }

    fn predict(&self, x: &FeatureVec) -> f64 {
        sigmoid(self.raw(x))
    }

    fn latency(&self) -> f64 {
        0.6e-3
    }

    fn fit(&mut self, xs: &[FeatureVec], ys: &[bool]) {
        self.trees.clear();
        let n = xs.len().max(1);
        let pos = ys.iter().filter(|&&y| y).count() as f64;
        let p0 = (pos / n as f64).clamp(1e-3, 1.0 - 1e-3);
        self.base = (p0 / (1.0 - p0)).ln();
        let order: Vec<usize> = (0..F).collect();
        let mut raw: Vec<f64> = vec![self.base; n];
        for _ in 0..self.n_rounds {
            // Negative gradient of logloss: y − σ(raw).
            let grad: Vec<f64> = raw
                .iter()
                .zip(ys)
                .map(|(&r, &y)| (if y { 1.0 } else { 0.0 }) - sigmoid(r))
                .collect();
            let tree = Tree::fit(xs, &grad, self.params, &order);
            for (i, x) in xs.iter().enumerate() {
                raw[i] += self.learning_rate * tree.predict(x);
            }
            self.trees.push(tree);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    #[test]
    fn boosting_fits_synthetic() {
        let (xs, ys) = synthetic(500, 30);
        let mut m = Gbdt::new();
        m.fit(&xs, &ys);
        assert!(m.accuracy(&xs, &ys) > 0.85, "{}", m.accuracy(&xs, &ys));
    }

    #[test]
    fn base_rate_respected_before_trees() {
        let xs = vec![[0.0f32; F]; 100];
        let ys: Vec<bool> = (0..100).map(|i| i < 80).collect();
        let mut m = Gbdt::new();
        m.n_rounds = 0;
        m.fit(&xs, &ys);
        assert!((m.predict(&[0.0; F]) - 0.8).abs() < 0.02);
    }

    #[test]
    fn more_rounds_fit_tighter() {
        let (xs, ys) = synthetic(400, 31);
        let mut small = Gbdt::new();
        small.n_rounds = 3;
        small.fit(&xs, &ys);
        let mut big = Gbdt::new();
        big.n_rounds = 40;
        big.fit(&xs, &ys);
        assert!(big.accuracy(&xs, &ys) >= small.accuracy(&xs, &ys));
    }
}
