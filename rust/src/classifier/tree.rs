//! CART decision tree (gini impurity, depth/size-limited) — the base
//! learner for [`super::forest`] and [`super::gbdt`].

use super::{FeatureVec, F};

#[derive(Debug, Clone)]
pub enum Node {
    Leaf {
        /// Mean target (probability for classification, residual for GBDT).
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
}

#[derive(Debug, Clone)]
pub struct Tree {
    pub root: Node,
    pub max_depth: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_leaf: usize,
    /// Features considered per split (`F` = all; smaller for forests).
    pub feature_subsample: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 5, min_leaf: 5, feature_subsample: F }
    }
}

impl Tree {
    /// Fit a regression tree on (xs, targets) minimizing squared error —
    /// with 0/1 targets this is equivalent to gini-driven classification.
    pub fn fit(
        xs: &[FeatureVec],
        targets: &[f64],
        params: TreeParams,
        feature_order: &[usize],
    ) -> Tree {
        let idx: Vec<u32> = (0..xs.len() as u32).collect();
        let root = build(xs, targets, &idx, params, feature_order, 0);
        Tree { root, max_depth: params.max_depth }
    }

    pub fn predict(&self, x: &FeatureVec) -> f64 {
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    node = if x[*feature] <= *threshold { left } else { right };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn d(n: &Node) -> usize {
            match n {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(left).max(d(right)),
            }
        }
        d(&self.root)
    }
}

fn mean(targets: &[f64], idx: &[u32]) -> f64 {
    if idx.is_empty() {
        return 0.0;
    }
    idx.iter().map(|&i| targets[i as usize]).sum::<f64>() / idx.len() as f64
}

fn build(
    xs: &[FeatureVec],
    targets: &[f64],
    idx: &[u32],
    params: TreeParams,
    feature_order: &[usize],
    depth: usize,
) -> Node {
    let m = mean(targets, idx);
    if depth >= params.max_depth || idx.len() < 2 * params.min_leaf {
        return Node::Leaf { value: m };
    }
    // Find the best (feature, threshold) by SSE reduction.
    let mut best: Option<(usize, f32, f64)> = None;
    let base_sse: f64 = idx.iter().map(|&i| (targets[i as usize] - m).powi(2)).sum();
    for &f in feature_order.iter().take(params.feature_subsample) {
        // Candidate thresholds: sorted unique values (sampled).
        let mut vals: Vec<f32> = idx.iter().map(|&i| xs[i as usize][f]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        let step = (vals.len() / 16).max(1);
        for w in vals.windows(2).step_by(step) {
            let thr = (w[0] + w[1]) / 2.0;
            let (mut sl, mut nl, mut sr, mut nr) = (0.0f64, 0usize, 0.0f64, 0usize);
            for &i in idx {
                let t = targets[i as usize];
                if xs[i as usize][f] <= thr {
                    sl += t;
                    nl += 1;
                } else {
                    sr += t;
                    nr += 1;
                }
            }
            if nl < params.min_leaf || nr < params.min_leaf {
                continue;
            }
            // SSE after split = Σ t² − nl·ml² − nr·mr²; Σ t² is constant,
            // so maximize nl·ml² + nr·mr².
            let ml = sl / nl as f64;
            let mr = sr / nr as f64;
            let gain = nl as f64 * ml * ml + nr as f64 * mr * mr;
            if match best { Some((_, _, g)) => gain > g, None => true } {
                best = Some((f, thr, gain));
            }
        }
    }
    let Some((f, thr, gain)) = best else {
        return Node::Leaf { value: m };
    };
    // Require a real improvement over the unsplit node.
    let unsplit_gain = idx.len() as f64 * m * m;
    if gain <= unsplit_gain + 1e-12 && base_sse > 0.0 {
        return Node::Leaf { value: m };
    }
    let (mut li, mut ri) = (Vec::new(), Vec::new());
    for &i in idx {
        if xs[i as usize][f] <= thr {
            li.push(i);
        } else {
            ri.push(i);
        }
    }
    Node::Split {
        feature: f,
        threshold: thr,
        left: Box::new(build(xs, targets, &li, params, feature_order, depth + 1)),
        right: Box::new(build(xs, targets, &ri, params, feature_order, depth + 1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;

    fn to_targets(ys: &[bool]) -> Vec<f64> {
        ys.iter().map(|&y| if y { 1.0 } else { 0.0 }).collect()
    }

    #[test]
    fn fits_separable_data() {
        let (xs, ys) = synthetic(400, 10);
        let order: Vec<usize> = (0..F).collect();
        let t = Tree::fit(&xs, &to_targets(&ys), TreeParams::default(), &order);
        let acc = xs
            .iter()
            .zip(&ys)
            .filter(|(x, &y)| (t.predict(x) > 0.5) == y)
            .count() as f64
            / xs.len() as f64;
        assert!(acc > 0.8, "{acc}");
    }

    #[test]
    fn respects_depth_limit() {
        let (xs, ys) = synthetic(400, 11);
        let order: Vec<usize> = (0..F).collect();
        let params = TreeParams { max_depth: 3, ..Default::default() };
        let t = Tree::fit(&xs, &to_targets(&ys), params, &order);
        assert!(t.depth() <= 3);
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let xs = vec![[0.5f32; F]; 20];
        let targets = vec![1.0; 20];
        let order: Vec<usize> = (0..F).collect();
        let t = Tree::fit(&xs, &targets, TreeParams::default(), &order);
        assert!(matches!(t.root, Node::Leaf { value } if (value - 1.0).abs() < 1e-12));
    }

    #[test]
    fn single_example() {
        let xs = vec![[0.1f32; F]];
        let t = Tree::fit(&xs, &[1.0], TreeParams::default(), &(0..F).collect::<Vec<_>>());
        assert_eq!(t.predict(&[0.9; F]), 1.0);
    }
}
