//! Offline classifier training pipeline (paper §4.4 / Eqn 1).
//!
//! The paper collects execution traces in *trace-only mode* (training
//! disabled) across datasets × partitions × buffer sizes, labels them with
//! the S′ rule, and fits each classifier once — "hundreds to thousands of
//! node-hours" of offline cost that LLM agents avoid (Corollary 2.1).
//! Here, [`OfflineTrainer`] consumes traces produced by
//! `sim::run::trace_only` and manages the train/validation split.

use super::labeling::LabeledExample;
use super::{DecisionModel, FeatureVec, Kind};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Default)]
pub struct TrainingSet {
    pub xs: Vec<FeatureVec>,
    pub ys: Vec<bool>,
    /// Offline collection cost in simulated node-seconds (Eqn 1's
    /// |S| × T_sampling term), accumulated by the trace producer.
    pub collection_cost: f64,
}

impl TrainingSet {
    pub fn push_examples(&mut self, examples: &[LabeledExample], cost: f64) {
        for e in examples {
            self.xs.push(e.x);
            self.ys.push(e.y);
        }
        self.collection_cost += cost;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Deterministic shuffled train/val split.
    pub fn split(&self, val_frac: f64, seed: u64) -> (TrainingSet, TrainingSet) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        Pcg32::new(seed).shuffle(&mut idx);
        let n_val = (self.len() as f64 * val_frac) as usize;
        let mut train = TrainingSet::default();
        let mut val = TrainingSet::default();
        for (i, &j) in idx.iter().enumerate() {
            let dst = if i < n_val { &mut val } else { &mut train };
            dst.xs.push(self.xs[j]);
            dst.ys.push(self.ys[j]);
        }
        train.collection_cost = self.collection_cost;
        (train, val)
    }

    pub fn positive_rate(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.ys.iter().filter(|&&y| y).count() as f64 / self.len() as f64
    }
}

/// Result of one offline fit.
pub struct TrainedClassifier {
    pub kind: Kind,
    pub model: Box<dyn DecisionModel>,
    pub train_accuracy: f64,
    pub val_accuracy: f64,
    /// Simulated training wall time (the T_train(Θ) term of Eqn 1).
    pub train_cost: f64,
}

pub struct OfflineTrainer {
    pub data: TrainingSet,
    pub seed: u64,
}

impl OfflineTrainer {
    pub fn new(data: TrainingSet, seed: u64) -> OfflineTrainer {
        OfflineTrainer { data, seed }
    }

    /// Fit one classifier kind; returns the model plus bookkeeping.
    pub fn train(&self, kind: Kind) -> TrainedClassifier {
        let (train, val) = self.data.split(0.2, self.seed);
        let mut model = kind.build(self.seed);
        let t0 = std::time::Instant::now();
        if !train.is_empty() {
            model.fit(&train.xs, &train.ys);
        }
        let train_cost = t0.elapsed().as_secs_f64();
        let train_accuracy = model.accuracy(&train.xs, &train.ys);
        let val_accuracy = model.accuracy(&val.xs, &val.ys);
        TrainedClassifier { kind, model, train_accuracy, val_accuracy, train_cost }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::testdata::synthetic;
    use crate::classifier::ALL_KINDS;

    fn set(n: usize) -> TrainingSet {
        let (xs, ys) = synthetic(n, 50);
        let mut s = TrainingSet::default();
        for (x, y) in xs.into_iter().zip(ys) {
            s.xs.push(x);
            s.ys.push(y);
        }
        s.collection_cost = 123.0;
        s
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let s = set(100);
        let (train, val) = s.split(0.25, 1);
        assert_eq!(train.len() + val.len(), 100);
        assert_eq!(val.len(), 25);
    }

    #[test]
    fn trains_all_kinds_with_sane_accuracy() {
        let trainer = OfflineTrainer::new(set(500), 2);
        for &kind in ALL_KINDS {
            let out = trainer.train(kind);
            assert!(
                out.val_accuracy > 0.6,
                "{:?} val acc {}",
                kind,
                out.val_accuracy
            );
            assert!(out.train_cost >= 0.0);
        }
    }

    #[test]
    fn empty_training_set_survives() {
        let trainer = OfflineTrainer::new(TrainingSet::default(), 3);
        let out = trainer.train(Kind::LogReg);
        assert_eq!(out.val_accuracy, 0.0);
    }

    #[test]
    fn positive_rate() {
        let mut s = TrainingSet::default();
        s.xs = vec![[0.0; crate::classifier::F]; 4];
        s.ys = vec![true, true, true, false];
        assert!((s.positive_rate() - 0.75).abs() < 1e-12);
    }
}
