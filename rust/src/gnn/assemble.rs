//! Minibatch → tensor packing for the `sage_*` artifacts.
//!
//! The sampler emits padded dense node-id tensors; this module synthesizes
//! the corresponding feature tensors ([`crate::graph::features`]) and packs
//! them (plus labels and the padding mask) into runtime tensors matching
//! the artifact ABI.  Short minibatches zero-pad the batch axis and zero
//! the mask so the loss ignores padding rows (verified against model.py by
//! `python/tests/test_model.py::test_mask_excludes_padding`).

use super::SageShape;
use crate::graph::features::fill_features;
use crate::runtime::tensor::{self as lit, Tensor};
use crate::sampler::Minibatch;

pub struct PackedBatch {
    pub x_self: Tensor,
    pub x_h1: Tensor,
    pub x_h2: Tensor,
    pub labels: Tensor,
    pub mask: Tensor,
}

/// Pack one sampled minibatch, synthesizing every feature row from the
/// dataset seed.  `labels` is the dataset's full label vector (values are
/// taken mod `shape.classes` — the canonical artifact class space,
/// DESIGN.md §2).
pub fn pack_minibatch(
    shape: &SageShape,
    mb: &Minibatch,
    feature_seed: u64,
    labels: &[u16],
) -> crate::error::Result<PackedBatch> {
    pack_minibatch_with(shape, mb, labels, |node, dst| fill_features(feature_seed, node, dst))
}

/// Pack one sampled minibatch with an arbitrary feature source: `fill`
/// writes node `n`'s feature row into `dst` (`shape.feat_dim` floats).
/// The cluster runtime's measured-compute path uses this to gather remote
/// rows from the trainer's [`crate::cluster::FeatureStore`] (what the
/// prefetcher actually fetched) and local rows from the partition shard,
/// instead of re-synthesizing everything.
pub fn pack_minibatch_with<F: FnMut(u32, &mut [f32])>(
    shape: &SageShape,
    mb: &Minibatch,
    labels: &[u16],
    mut fill: F,
) -> crate::error::Result<PackedBatch> {
    let (b, k1, k2, d) = (shape.batch, shape.fanout1, shape.fanout2, shape.feat_dim);
    let rows = mb.targets.len();
    crate::ensure!(rows <= b, "minibatch {rows} rows > artifact batch {b}");
    crate::ensure!(
        mb.fanout1 == k1 && mb.fanout2 == k2,
        "sampler fanout ({}, {}) != artifact fanout ({k1}, {k2})",
        mb.fanout1,
        mb.fanout2
    );
    crate::ensure!(mb.hop1.len() == rows * k1, "hop1 len mismatch");
    crate::ensure!(mb.hop2.len() == rows * k1 * k2, "hop2 len mismatch");

    let mut x_self = vec![0.0f32; b * d];
    for (i, &v) in mb.targets.iter().enumerate() {
        fill(v, &mut x_self[i * d..(i + 1) * d]);
    }
    let mut x_h1 = vec![0.0f32; b * k1 * d];
    for (i, &v) in mb.hop1.iter().enumerate() {
        fill(v, &mut x_h1[i * d..(i + 1) * d]);
    }
    let mut x_h2 = vec![0.0f32; b * k1 * k2 * d];
    for (i, &v) in mb.hop2.iter().enumerate() {
        fill(v, &mut x_h2[i * d..(i + 1) * d]);
    }
    let mut label_ids = vec![0i32; b];
    let mut mask = vec![0.0f32; b];
    for (i, &v) in mb.targets.iter().enumerate() {
        label_ids[i] = (labels[v as usize] as usize % shape.classes) as i32;
        mask[i] = 1.0;
    }
    Ok(PackedBatch {
        x_self: lit::lit_f32(&[b, d], &x_self)?,
        x_h1: lit::lit_f32(&[b, k1, d], &x_h1)?,
        x_h2: lit::lit_f32(&[b, k1, k2, d], &x_h2)?,
        labels: lit::lit_i32(&[b], &label_ids)?,
        mask: lit::lit_f32(&[b], &mask)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_shape() -> SageShape {
        SageShape { batch: 4, fanout1: 2, fanout2: 3, feat_dim: 5, hidden: 6, classes: 3 }
    }

    fn mb(rows: usize) -> Minibatch {
        Minibatch {
            targets: (0..rows as u32).collect(),
            hop1: (0..(rows * 2) as u32).collect(),
            hop2: (0..(rows * 6) as u32).collect(),
            fanout1: 2,
            fanout2: 3,
            unique_remote: vec![],
            unique_local: vec![],
        }
    }

    #[test]
    fn packs_full_batch() {
        let labels = vec![1u16; 64];
        let p = pack_minibatch(&tiny_shape(), &mb(4), 7, &labels).unwrap();
        let xs = lit::to_f32(&p.x_self).unwrap();
        assert_eq!(xs.len(), 4 * 5);
        let m = lit::to_f32(&p.mask).unwrap();
        assert_eq!(m, vec![1.0; 4]);
    }

    #[test]
    fn short_batch_padded_and_masked() {
        let labels = vec![2u16; 64];
        let p = pack_minibatch(&tiny_shape(), &mb(2), 7, &labels).unwrap();
        let m = lit::to_f32(&p.mask).unwrap();
        assert_eq!(m, vec![1.0, 1.0, 0.0, 0.0]);
        let xs = lit::to_f32(&p.x_self).unwrap();
        assert!(xs[2 * 5..].iter().all(|&x| x == 0.0), "padding rows must be zero");
        let l = lit::to_i32(&p.labels).unwrap();
        assert_eq!(l, vec![2, 2, 0, 0]);
    }

    #[test]
    fn labels_mod_classes() {
        let labels = vec![7u16; 64]; // 7 mod 3 = 1
        let p = pack_minibatch(&tiny_shape(), &mb(1), 7, &labels).unwrap();
        assert_eq!(lit::to_i32(&p.labels).unwrap()[0], 1);
    }

    #[test]
    fn custom_fill_matches_seeded_fill() {
        let labels = vec![1u16; 64];
        let a = pack_minibatch(&tiny_shape(), &mb(3), 7, &labels).unwrap();
        let b =
            pack_minibatch_with(&tiny_shape(), &mb(3), &labels, |n, dst| fill_features(7, n, dst))
                .unwrap();
        assert_eq!(lit::to_f32(&a.x_self).unwrap(), lit::to_f32(&b.x_self).unwrap());
        assert_eq!(lit::to_f32(&a.x_h2).unwrap(), lit::to_f32(&b.x_h2).unwrap());
    }

    #[test]
    fn rejects_oversized_minibatch() {
        let labels = vec![0u16; 64];
        assert!(pack_minibatch(&tiny_shape(), &mb(5), 7, &labels).is_err());
    }

    #[test]
    fn rejects_fanout_mismatch() {
        let labels = vec![0u16; 64];
        let mut bad = mb(2);
        bad.fanout1 = 3;
        assert!(pack_minibatch(&tiny_shape(), &bad, 7, &labels).is_err());
    }
}
