//! GNN compute: the T_DDP side of the overlap equation.
//!
//! Two interchangeable runners:
//!
//! * [`SageRunner`] — the real thing: packs the sampled minibatch into
//!   runtime tensors and executes the AOT `sage_train_step` entry through
//!   the [`Engine`]'s backend (pure-Rust interpreter by default, PJRT with
//!   `--features pjrt`).  Used by the e2e example, calibration, and the
//!   runtime integration tests.
//! * [`AnalyticModel`] — a roofline-style cost model (flops / effective
//!   device flops + base overhead) for large parameter sweeps where only
//!   *relative* T_DDP matters.  Its constants are set from `rudder
//!   calibrate` (which measures the real runner) or from the A100-like
//!   defaults in [`ComputeParams`].

pub mod assemble;

use std::sync::Arc;

use crate::runtime::tensor as lit;
use crate::runtime::Engine;
use crate::sampler::Minibatch;
use crate::util::rng::Pcg32;

/// Analytic compute-model constants.
#[derive(Debug, Clone)]
pub struct ComputeParams {
    /// Effective device flops (peak × achievable efficiency).
    pub device_flops: f64,
    /// Fixed per-step overhead (launch, host sync) in seconds.
    pub base_overhead: f64,
    /// fwd+bwd+update multiplier over pure-forward flops.
    pub train_multiplier: f64,
}

impl Default for ComputeParams {
    fn default() -> Self {
        // A100-like: 19.5 TF fp32 peak × ~0.35 achieved on small GNN GEMMs.
        // base_overhead models the DistDGL per-minibatch fixed path (CPU
        // sampling, feature gather, python dataloader, kernel launches) —
        // ~100 ms at batch 2000, which is what makes T_DDP ~ 0.1 s and the
        // paper's replacement intervals (r ≈ 6–40) emerge from real LLM
        // latencies.  `rudder calibrate` refines it from measured runs.
        ComputeParams {
            device_flops: 6.8e12,
            base_overhead: 0.1,
            train_multiplier: 3.0,
        }
    }
}

/// Model-shape constants shared by both runners.
#[derive(Debug, Clone, Copy)]
pub struct SageShape {
    pub batch: usize,
    pub fanout1: usize,
    pub fanout2: usize,
    pub feat_dim: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl SageShape {
    /// Forward flops of the 2-layer SAGE model on a full minibatch.
    pub fn forward_flops(&self) -> f64 {
        let (b, k1, k2) = (self.batch as f64, self.fanout1 as f64, self.fanout2 as f64);
        let (d, h, c) = (self.feat_dim as f64, self.hidden as f64, self.classes as f64);
        let l1_frontier = b * k1 * (k2 * d + 2.0 * 2.0 * d * h); // mean + 2 matmuls
        let l1_self = b * (k1 * d + 2.0 * 2.0 * d * h);
        let l2 = b * (k1 * h + 2.0 * 2.0 * h * c);
        l1_frontier + l1_self + l2
    }

    /// Parameter bytes (for the DDP allreduce volume).
    pub fn param_bytes(&self) -> u64 {
        let n = 2 * self.feat_dim * self.hidden
            + self.hidden
            + 2 * self.hidden * self.classes
            + self.classes;
        (n * 4) as u64
    }
}

#[derive(Debug, Clone)]
pub struct AnalyticModel {
    pub params: ComputeParams,
    pub shape: SageShape,
}

impl AnalyticModel {
    pub fn new(params: ComputeParams, shape: SageShape) -> Self {
        AnalyticModel { params, shape }
    }

    /// T_DDP for a minibatch with `rows` target nodes (≤ shape.batch).
    pub fn step_time(&self, rows: usize) -> f64 {
        let frac = rows as f64 / self.shape.batch.max(1) as f64;
        self.params.base_overhead
            + self.shape.forward_flops() * frac * self.params.train_multiplier
                / self.params.device_flops
    }
}

/// GraphSAGE parameter state held host-side between runtime steps.
#[derive(Debug, Clone)]
pub struct SageState {
    pub w1_self: Vec<f32>,
    pub w1_neigh: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2_self: Vec<f32>,
    pub w2_neigh: Vec<f32>,
    pub b2: Vec<f32>,
    pub shape: SageShape,
}

impl SageState {
    /// Flatten every parameter tensor into one vector, in the fixed order
    /// `w1_self, w1_neigh, b1, w2_self, w2_neigh, b2` — the layout of the
    /// DDP gradient blob (`param_bytes() / 4` floats).
    pub fn flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity((self.shape.param_bytes() / 4) as usize);
        out.extend_from_slice(&self.w1_self);
        out.extend_from_slice(&self.w1_neigh);
        out.extend_from_slice(&self.b1);
        out.extend_from_slice(&self.w2_self);
        out.extend_from_slice(&self.w2_neigh);
        out.extend_from_slice(&self.b2);
        out
    }

    /// Overwrite every parameter from a [`SageState::flat`]-layout vector.
    pub fn set_flat(&mut self, flat: &[f32]) -> crate::error::Result<()> {
        crate::ensure!(
            flat.len() == (self.shape.param_bytes() / 4) as usize,
            "sage state: flat vector has {} params, model has {}",
            flat.len(),
            self.shape.param_bytes() / 4
        );
        let mut pos = 0usize;
        for dst in [
            &mut self.w1_self,
            &mut self.w1_neigh,
            &mut self.b1,
            &mut self.w2_self,
            &mut self.w2_neigh,
            &mut self.b2,
        ] {
            dst.copy_from_slice(&flat[pos..pos + dst.len()]);
            pos += dst.len();
        }
        Ok(())
    }

    /// FNV-1a over the parameters' bit patterns: two replicas share a
    /// fingerprint iff their parameters are bit-identical (the real-DDP
    /// sync invariant the cluster's measured mode asserts).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in self.flat() {
            for byte in v.to_bits().to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// Glorot-ish init (mirrors model.py `sage_init` statistics).
    pub fn init(shape: SageShape, seed: u64) -> SageState {
        let mut rng = Pcg32::new(seed);
        let mut randn = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        let s1 = (2.0 / (shape.feat_dim + shape.hidden) as f64).sqrt();
        let s2 = (2.0 / (shape.hidden + shape.classes) as f64).sqrt();
        SageState {
            w1_self: randn(shape.feat_dim * shape.hidden, s1),
            w1_neigh: randn(shape.feat_dim * shape.hidden, s1),
            b1: vec![0.0; shape.hidden],
            w2_self: randn(shape.hidden * shape.classes, s2),
            w2_neigh: randn(shape.hidden * shape.classes, s2),
            b2: vec![0.0; shape.classes],
            shape,
        }
    }
}

/// Executes real train steps through the runtime engine.
pub struct SageRunner {
    pub engine: Arc<Engine>,
    pub state: SageState,
    pub lr: f32,
    pub losses: Vec<f32>,
}

impl SageRunner {
    pub fn new(engine: Arc<Engine>, seed: u64, lr: f32) -> SageRunner {
        let c = &engine.manifest.config;
        let shape = SageShape {
            batch: c.batch,
            fanout1: c.fanout1,
            fanout2: c.fanout2,
            feat_dim: c.feat_dim,
            hidden: c.hidden,
            classes: c.classes,
        };
        let state = SageState::init(shape, seed);
        SageRunner { engine, state, lr, losses: Vec::new() }
    }

    /// Run one train step on a sampled minibatch, synthesizing features
    /// from the dataset seed.  Returns `(loss, seconds)`.
    pub fn train_step(
        &mut self,
        mb: &Minibatch,
        feature_seed: u64,
        labels: &[u16],
    ) -> crate::error::Result<(f32, f64)> {
        let batch = assemble::pack_minibatch(&self.state.shape, mb, feature_seed, labels)?;
        self.train_step_packed(batch)
    }

    /// Run one train step with an arbitrary feature source (see
    /// [`assemble::pack_minibatch_with`]) — the cluster runtime's
    /// measured-compute entry point.
    pub fn train_step_with<F: FnMut(u32, &mut [f32])>(
        &mut self,
        mb: &Minibatch,
        labels: &[u16],
        fill: F,
    ) -> crate::error::Result<(f32, f64)> {
        let batch = assemble::pack_minibatch_with(&self.state.shape, mb, labels, fill)?;
        self.train_step_packed(batch)
    }

    fn train_step_packed(
        &mut self,
        batch: assemble::PackedBatch,
    ) -> crate::error::Result<(f32, f64)> {
        let s = &self.state;
        let shp = s.shape;
        let inputs = vec![
            lit::lit_f32(&[shp.feat_dim, shp.hidden], &s.w1_self)?,
            lit::lit_f32(&[shp.feat_dim, shp.hidden], &s.w1_neigh)?,
            lit::lit_f32(&[shp.hidden], &s.b1)?,
            lit::lit_f32(&[shp.hidden, shp.classes], &s.w2_self)?,
            lit::lit_f32(&[shp.hidden, shp.classes], &s.w2_neigh)?,
            lit::lit_f32(&[shp.classes], &s.b2)?,
            batch.x_self,
            batch.x_h1,
            batch.x_h2,
            batch.labels,
            batch.mask,
            lit::lit_scalar_f32(self.lr)?,
        ];
        let t0 = std::time::Instant::now();
        let out = self.engine.execute("sage_train_step", &inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        crate::ensure!(out.len() == 7, "sage_train_step: want 7 outputs");
        self.state.w1_self = lit::to_f32(&out[0])?;
        self.state.w1_neigh = lit::to_f32(&out[1])?;
        self.state.b1 = lit::to_f32(&out[2])?;
        self.state.w2_self = lit::to_f32(&out[3])?;
        self.state.w2_neigh = lit::to_f32(&out[4])?;
        self.state.b2 = lit::to_f32(&out[5])?;
        let loss = lit::to_f32(&out[6])?[0];
        self.losses.push(loss);
        Ok((loss, dt))
    }

    /// Forward-only evaluation: fraction of (unpadded) targets predicted
    /// correctly.
    pub fn eval_accuracy(
        &self,
        mb: &Minibatch,
        feature_seed: u64,
        labels: &[u16],
    ) -> crate::error::Result<f64> {
        let batch = assemble::pack_minibatch(&self.state.shape, mb, feature_seed, labels)?;
        let s = &self.state;
        let shp = s.shape;
        let inputs = vec![
            lit::lit_f32(&[shp.feat_dim, shp.hidden], &s.w1_self)?,
            lit::lit_f32(&[shp.feat_dim, shp.hidden], &s.w1_neigh)?,
            lit::lit_f32(&[shp.hidden], &s.b1)?,
            lit::lit_f32(&[shp.hidden, shp.classes], &s.w2_self)?,
            lit::lit_f32(&[shp.hidden, shp.classes], &s.w2_neigh)?,
            lit::lit_f32(&[shp.classes], &s.b2)?,
            batch.x_self,
            batch.x_h1,
            batch.x_h2,
        ];
        let out = self.engine.execute("sage_fwd", &inputs)?;
        let logits = lit::to_f32(&out[0])?;
        let c = shp.classes;
        let mut correct = 0usize;
        for (i, &t) in mb.targets.iter().enumerate() {
            let row = &logits[i * c..(i + 1) * c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == (labels[t as usize] as usize % c) {
                correct += 1;
            }
        }
        Ok(correct as f64 / mb.targets.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> SageShape {
        SageShape { batch: 128, fanout1: 10, fanout2: 25, feat_dim: 100, hidden: 128, classes: 32 }
    }

    #[test]
    fn flops_scale_with_batch() {
        let s = shape();
        let mut s2 = s;
        s2.batch = 256;
        assert!((s2.forward_flops() / s.forward_flops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn analytic_step_time_monotone() {
        let m = AnalyticModel::new(ComputeParams::default(), shape());
        let t_full = m.step_time(128);
        let t_half = m.step_time(64);
        assert!(t_full > t_half);
        assert!(t_half > m.params.base_overhead);
        // A100-scale: full minibatch in the few-ms range.
        assert!(t_full > 0.05 && t_full < 0.5, "t_full {t_full}");
    }

    #[test]
    fn param_bytes_counts_all_tensors() {
        let s = shape();
        let n = 2 * 100 * 128 + 128 + 2 * 128 * 32 + 32;
        assert_eq!(s.param_bytes(), (n * 4) as u64);
    }

    #[test]
    fn flat_roundtrip_and_fingerprint() {
        let a = SageState::init(shape(), 5);
        let f = a.flat();
        assert_eq!(f.len(), (a.shape.param_bytes() / 4) as usize);
        let b = SageState::init(shape(), 6);
        assert_ne!(a.fingerprint(), b.fingerprint(), "different params, different hash");
        let mut c = SageState::init(shape(), 6);
        c.set_flat(&f).unwrap();
        assert_eq!(c.fingerprint(), a.fingerprint(), "set_flat(flat()) clones the params");
        assert_eq!(c.w1_neigh, a.w1_neigh);
        assert!(c.set_flat(&f[1..]).is_err(), "wrong length rejected");
    }

    #[test]
    fn sage_state_init_deterministic() {
        let a = SageState::init(shape(), 5);
        let b = SageState::init(shape(), 5);
        assert_eq!(a.w1_self, b.w1_self);
        let c = SageState::init(shape(), 6);
        assert_ne!(a.w1_self, c.w1_self);
        assert_eq!(a.w1_self.len(), 100 * 128);
        assert!(a.b1.iter().all(|&x| x == 0.0));
    }
}
