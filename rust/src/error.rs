//! Crate-local error type: the zero-dependency replacement for `anyhow` in
//! every fallible path (runtime, config, CLI, sim construction).
//!
//! [`RudderError`] is a message-carrying error — the crate's failures are
//! operator-facing ("unknown dataset", "manifest missing 'config'"), not
//! machine-matched, so a single string-backed type with `From` conversions
//! for the in-tree parser errors keeps every `?` working.  The [`err!`],
//! [`bail!`] and [`ensure!`] macros mirror the `anyhow` idioms call sites
//! were written against.

use std::fmt;

/// The crate-wide error: a human-readable message, optionally prefixed by
/// the layers it bubbled through.
pub struct RudderError {
    msg: String,
}

impl RudderError {
    pub fn new(msg: impl Into<String>) -> RudderError {
        RudderError { msg: msg.into() }
    }

    /// Prefix with context while propagating (`e.context("loading config")`).
    pub fn context(self, what: impl fmt::Display) -> RudderError {
        RudderError { msg: format!("{what}: {}", self.msg) }
    }
}

impl fmt::Display for RudderError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for RudderError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for RudderError {}

pub type Result<T> = std::result::Result<T, RudderError>;

impl From<std::io::Error> for RudderError {
    fn from(e: std::io::Error) -> RudderError {
        RudderError::new(format!("io error: {e}"))
    }
}

impl From<crate::util::json::JsonError> for RudderError {
    fn from(e: crate::util::json::JsonError) -> RudderError {
        RudderError::new(e.to_string())
    }
}

impl From<crate::util::tomlite::TomlError> for RudderError {
    fn from(e: crate::util::tomlite::TomlError) -> RudderError {
        RudderError::new(e.to_string())
    }
}

impl From<String> for RudderError {
    fn from(msg: String) -> RudderError {
        RudderError::new(msg)
    }
}

impl From<&str> for RudderError {
    fn from(msg: &str) -> RudderError {
        RudderError::new(msg)
    }
}

/// Build a [`RudderError`] from a format string: `err!("bad value {v}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::RudderError::new(format!($($arg)*))
    };
}

/// Return early with a formatted [`RudderError`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_compose() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
        let e2: RudderError = crate::err!("count {}", 3);
        assert_eq!(format!("{e2}"), "count 3");
        assert_eq!(format!("{e2:?}"), "count 3");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert!(f().is_err());
    }

    #[test]
    fn io_and_parser_conversions() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent-rudder-xyz")?)
        }
        assert!(read().unwrap_err().to_string().contains("io error"));
        fn parse() -> Result<crate::util::json::Json> {
            Ok(crate::util::json::Json::parse("{bad")?)
        }
        assert!(parse().unwrap_err().to_string().contains("json error"));
    }

    #[test]
    fn context_prefixes() {
        let e = RudderError::new("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
