//! Reference-free functional-correctness evaluation (paper §4.6).
//!
//! Ground truth for "was this replacement decision *correct*" does not
//! exist, so the paper scores the agent by self-consistency: the agent
//! predicts the %-Hits direction; once the environment transitions, the
//! observed movement either matches (pass) or not (fail).  Pass@1 is the
//! pass rate over predicted decisions, reported with the chi-square-driven
//! 95% Wilson interval (Table 4).

use crate::agent::context::HITS_TOLERANCE;
use crate::metrics::{DecisionRecord, RunMetrics};
use crate::util::stats::wilson_ci95;

#[derive(Debug, Clone, Copy)]
pub struct PassAtK {
    pub passes: u64,
    pub trials: u64,
    /// Pass@1 in percent.
    pub score: f64,
    /// 95% CI offsets below/above the score (percentage points).
    pub ci_lo: f64,
    pub ci_hi: f64,
}

impl PassAtK {
    pub fn format(&self) -> String {
        format!("{:.0} (-{:.0}/{:.0})", self.score, self.ci_lo, self.ci_hi)
    }
}

/// Score one decision: did the observed %-Hits movement match the
/// prediction?  Decisions without predictions or outcomes are skipped.
fn judge(d: &DecisionRecord) -> Option<bool> {
    let pred = d.prediction?;
    let after = d.hits_after?;
    Some(pred.matches(after - d.hits_before, HITS_TOLERANCE))
}

/// Pass@1 across all trainers of a run.
pub fn pass_at_1(per_trainer: &[RunMetrics]) -> PassAtK {
    let mut passes = 0u64;
    let mut trials = 0u64;
    for m in per_trainer {
        for d in &m.decisions {
            if let Some(ok) = judge(d) {
                trials += 1;
                if ok {
                    passes += 1;
                }
            }
        }
    }
    let score = if trials > 0 {
        passes as f64 / trials as f64 * 100.0
    } else {
        0.0
    };
    let (ci_lo, ci_hi) = wilson_ci95(passes, trials);
    PassAtK { passes, trials, score, ci_lo, ci_hi }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::HitsPrediction;

    fn dec(pred: Option<HitsPrediction>, before: f64, after: Option<f64>) -> DecisionRecord {
        DecisionRecord {
            minibatch: 0,
            replace: true,
            prediction: pred,
            valid_response: pred.is_some(),
            hits_before: before,
            hits_after: after,
            latency: 0.1,
        }
    }

    #[test]
    fn scores_matching_predictions() {
        let mut m = RunMetrics::default();
        m.decisions.push(dec(Some(HitsPrediction::Increase), 40.0, Some(50.0))); // pass
        m.decisions.push(dec(Some(HitsPrediction::Increase), 40.0, Some(40.0))); // fail
        m.decisions.push(dec(Some(HitsPrediction::Unchanged), 40.0, Some(40.5))); // pass
        m.decisions.push(dec(Some(HitsPrediction::Decrease), 40.0, Some(30.0))); // pass
        let p = pass_at_1(&[m]);
        assert_eq!(p.trials, 4);
        assert_eq!(p.passes, 3);
        assert!((p.score - 75.0).abs() < 1e-9);
        assert!(p.ci_lo > 0.0 && p.ci_hi > 0.0);
    }

    #[test]
    fn skips_unjudgeable_decisions() {
        let mut m = RunMetrics::default();
        m.decisions.push(dec(None, 40.0, Some(50.0)));
        m.decisions.push(dec(Some(HitsPrediction::Increase), 40.0, None));
        let p = pass_at_1(&[m]);
        assert_eq!(p.trials, 0);
        assert_eq!(p.score, 0.0);
    }

    #[test]
    fn aggregates_across_trainers() {
        let mut a = RunMetrics::default();
        a.decisions.push(dec(Some(HitsPrediction::Increase), 0.0, Some(10.0)));
        let mut b = RunMetrics::default();
        b.decisions.push(dec(Some(HitsPrediction::Increase), 10.0, Some(5.0)));
        let p = pass_at_1(&[a, b]);
        assert_eq!(p.trials, 2);
        assert_eq!(p.passes, 1);
    }

    #[test]
    fn format_matches_table4_style() {
        let p = PassAtK { passes: 76, trials: 100, score: 76.0, ci_lo: 9.0, ci_hi: 11.0 };
        assert_eq!(p.format(), "76 (-9/11)");
    }
}
