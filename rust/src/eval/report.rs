//! Paper-style report rendering: aligned tables and CSV series, printed to
//! stdout and optionally persisted under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use crate::metrics::{MeasuredStats, WireStats};

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV form (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print to stdout and save CSV under `results/<name>.csv`.
    pub fn emit(&self, name: &str) {
        println!("{}", self.render());
        let dir = Path::new("results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.to_csv());
        }
    }
}

/// Per-trainer wire-level RPC counters ([`WireStats`]) as a report table,
/// with a cluster-wide total row — the eval-harness surface for what the
/// cluster runtime actually put on the transport (the sim only models
/// logical traffic).
pub fn wire_table(per_trainer: &[WireStats]) -> Table {
    let mut t = Table::new(
        "wire traffic per trainer (RPC frames/bytes on the transport)",
        &[
            "trainer",
            "req_frames",
            "req_bytes",
            "resp_frames",
            "resp_bytes",
            "nodes_req",
            "nodes_dedup",
            "nodes_recv",
            "dup_frames",
            "bad_frames",
            "chunks_hit",
            "chunks_fetched",
            "bytes_saved",
        ],
    );
    let row = |label: String, w: &WireStats| -> Vec<String> {
        vec![
            label,
            w.req_frames.to_string(),
            fmt_count(w.req_bytes),
            w.resp_frames.to_string(),
            fmt_count(w.resp_bytes),
            fmt_count(w.nodes_requested),
            fmt_count(w.nodes_deduped),
            fmt_count(w.nodes_received),
            w.dup_frames.to_string(),
            w.bad_frames.to_string(),
            fmt_count(w.chunks_hit),
            fmt_count(w.chunks_fetched),
            fmt_count(w.bytes_saved_cache),
        ]
    };
    let mut total = WireStats::default();
    for (i, w) in per_trainer.iter().enumerate() {
        total.merge(w);
        t.row(row(i.to_string(), w));
    }
    t.row(row("total".into(), &total));
    t
}

/// Per-link transport counters (one row per trainer×link: feature-server
/// links and the hub link), including TCP connect retries and, for server
/// links, the wall-clock fetch round-trip p50/p99 (issue → admitted, from
/// [`WireStats::fetch_latency`], keyed by the link's channel id = owner
/// partition; the hub link carries no fetches, so it shows "-").
pub fn link_table(per_trainer: &[WireStats]) -> Table {
    let mut t = Table::new(
        "transport links per trainer",
        &[
            "trainer",
            "peer",
            "chan",
            "frames_out",
            "bytes_out",
            "frames_in",
            "bytes_in",
            "reconnects",
            "fetch_p50",
            "fetch_p99",
        ],
    );
    for (i, w) in per_trainer.iter().enumerate() {
        for l in &w.links {
            let lat = if l.peer.starts_with("server:") {
                w.fetch_latency.get(l.channel as usize).filter(|h| !h.is_empty())
            } else {
                None
            };
            let (p50, p99) = match lat {
                Some(h) => (fmt_secs(h.p50()), fmt_secs(h.p99())),
                None => ("-".into(), "-".into()),
            };
            t.row(vec![
                i.to_string(),
                l.peer.clone(),
                l.channel.to_string(),
                l.frames_sent.to_string(),
                fmt_count(l.bytes_sent),
                l.frames_recv.to_string(),
                fmt_count(l.bytes_recv),
                l.reconnects.to_string(),
                p50,
                p99,
            ]);
        }
    }
    t
}

/// Per-trainer measured-compute accounting ([`MeasuredStats`], cluster
/// `--compute measured`): real per-minibatch fwd/bwd time, blocked-on-fetch
/// time, allreduce barrier time, loss, feature-row provenance, and the
/// replica fingerprint (identical across trainers ⇔ DDP kept the replicas
/// in sync).
pub fn measured_table(per_trainer: &[MeasuredStats]) -> Table {
    let mut t = Table::new(
        "measured compute per trainer (real SageRunner fwd/bwd)",
        &[
            "trainer",
            "minibatches",
            "compute",
            "fetch_blocked",
            "fetch_p99",
            "barrier",
            "mean_loss",
            "rows_store",
            "rows_local",
            "grad_bytes",
            "param_hash",
        ],
    );
    for (i, m) in per_trainer.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            m.compute_secs.len().to_string(),
            fmt_secs(m.total_compute()),
            fmt_secs(m.total_fetch_wait()),
            fmt_secs(crate::util::stats::percentile(&m.fetch_wait_secs, 99.0)),
            fmt_secs(m.total_barrier()),
            format!("{:.4}", m.mean_loss()),
            fmt_count(m.rows_from_store),
            fmt_count(m.rows_local),
            fmt_count(m.grad_bytes),
            format!("{:016x}", m.param_hash),
        ]);
    }
    t
}

/// Format helpers shared by benches and the CLI.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}ms", s * 1e3)
    }
}

pub fn fmt_count(n: u64) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}k", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

pub fn fmt_pct(p: f64) -> String {
    format!("{p:.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["model", "pass@1"]);
        t.row(vec!["gemma3-4b".into(), "79".into()]);
        t.row(vec!["llama".into(), "63".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("gemma3-4b  79"));
        let csv = t.to_csv();
        assert!(csv.starts_with("model,pass@1\n"));
        assert!(csv.contains("llama,63\n"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn measured_table_rows() {
        let m = MeasuredStats {
            compute_secs: vec![0.5, 0.5],
            losses: vec![1.0],
            param_hash: 0xAB,
            ..MeasuredStats::default()
        };
        let t = measured_table(&[m]);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][1], "2", "two measured minibatches");
        assert!(t.rows[0].contains(&"00000000000000ab".to_string()));
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0123), "12.3ms");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(12_400), "12.4k");
        assert_eq!(fmt_count(3_200_000), "3.20M");
        assert_eq!(fmt_pct(42.25), "42.2%");
    }
}
