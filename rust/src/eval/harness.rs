//! Experiment harness: one function per paper table/figure (§5).
//!
//! Each function assembles configs, runs the simulation, and returns
//! [`Table`]s shaped like the paper's artifact (same rows/series; our
//! measured numbers).  `Quality::Quick` keeps everything bench-sized;
//! `Quality::Full` runs the larger sweeps for `rudder experiment <id>`.

use crate::classifier::trainer::{OfflineTrainer, TrainingSet};
use crate::classifier::Kind;
use crate::graph::datasets;
use crate::partition::Method;
use crate::sim::{build_cluster, run_on, trace_only, ControllerSpec, Mode, RunConfig};
use crate::util::stats;

use super::passk::pass_at_1;
use super::report::{fmt_count, fmt_pct, fmt_secs, Table};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quality {
    Quick,
    Full,
}

impl Quality {
    pub fn parse(s: &str) -> Quality {
        if s == "full" {
            Quality::Full
        } else {
            Quality::Quick
        }
    }

    fn scale(&self) -> f64 {
        match self {
            Quality::Quick => 0.25,
            Quality::Full => 0.6,
        }
    }

    fn epochs(&self) -> usize {
        match self {
            Quality::Quick => 6,
            Quality::Full => 10,
        }
    }

    fn trainer_counts(&self) -> Vec<usize> {
        match self {
            Quality::Quick => vec![4, 8],
            Quality::Full => vec![4, 8, 16],
        }
    }

    fn datasets(&self) -> Vec<&'static str> {
        match self {
            Quality::Quick => vec!["products", "reddit", "orkut"],
            Quality::Full => vec!["products", "reddit", "papers100M", "orkut", "friendster"],
        }
    }
}

fn base_cfg(q: Quality, dataset: &str, trainers: usize, controller: &str) -> RunConfig {
    RunConfig {
        dataset: dataset.into(),
        scale: q.scale(),
        seed: 42,
        num_trainers: trainers,
        batch_size: 32,
        fanout1: 10,
        fanout2: 25,
        buffer_pct: 0.25,
        epochs: q.epochs(),
        controller: ControllerSpec::parse(controller).expect("valid controller"),
        mode: Mode::Async,
        partition_method: Method::MetisLike,
        ..Default::default()
    }
}

/// Offline classifier training data: traces from the *seen* datasets
/// (yelp and ogbn-arxiv are excluded — the §5.4 unseen protocol).
pub fn offline_training_set(q: Quality) -> TrainingSet {
    let mut set = TrainingSet::default();
    let seen: Vec<&str> = match q {
        Quality::Quick => vec!["products"],
        Quality::Full => vec!["products", "reddit", "orkut"],
    };
    for ds_name in seen {
        for buffer_pct in [0.05, 0.25] {
            let mut cfg = base_cfg(q, ds_name, 4, "random:0.5");
            cfg.buffer_pct = buffer_pct;
            cfg.epochs = q.epochs().min(6);
            if let Ok((ds, part)) = build_cluster(&cfg) {
                let t = trace_only(&ds, &part, &cfg);
                set.push_examples(
                    &t.xs
                        .iter()
                        .zip(&t.ys)
                        .map(|(x, &y)| crate::classifier::labeling::LabeledExample { x: *x, y })
                        .collect::<Vec<_>>(),
                    t.collection_cost,
                );
            }
        }
    }
    set
}

// ---------------------------------------------------------------------------
// Fig 1: declining unique remote nodes

pub fn fig01(q: Quality) -> Vec<Table> {
    let cfg = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg).expect("cluster");
    let r = run_on(&ds, &part, &cfg, None);
    let mut t = Table::new(
        "Fig 1 — unique remote nodes sampled per minibatch (trainer 0)",
        &["minibatch", "unique_remote", "hits_pct"],
    );
    let series = &r.per_trainer[0].minibatches;
    let step = (series.len() / 24).max(1);
    for m in series.iter().step_by(step) {
        t.row(vec![
            m.minibatch.to_string(),
            m.unique_remote.to_string(),
            format!("{:.1}", m.hits_pct),
        ]);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 3: replacement strategies

pub fn fig03(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 3 — %-Hits by replacement strategy (higher is better)",
        &["strategy", "mean_hits", "steady_hits", "comm_nodes"],
    );
    // (label, controller): every-minibatch, infrequent, single, adaptive.
    let variants = [
        ("every-minibatch", "fixed".to_string()),
        ("infrequent (r=64)", "interval:64".to_string()),
        ("single (r=10^6)", "interval:1000000".to_string()),
        ("adaptive (Rudder)", "llm:gemma3-4b".to_string()),
    ];
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for (label, ctl) in variants {
        let mut cfg = cfg0.clone();
        cfg.controller = ControllerSpec::parse(&ctl).unwrap();
        // Cold-start interval controllers: this ablation isolates *cadence*.
        let r = run_on(&ds, &part, &cfg, None);
        t.row(vec![
            label.to_string(),
            fmt_pct(r.mean_hits_pct),
            fmt_pct(r.steady_hits_pct),
            fmt_count(r.total_comm_nodes),
        ]);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 6: LLM characteristics

pub fn fig06(_q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 6 — LLM agent characteristics (spider chart axes)",
        &["model", "type", "quant", "mem_gb", "math500", "ifeval", "decode_tps"],
    );
    for p in crate::agent::profiles::ALL {
        t.row(vec![
            p.name.to_string(),
            format!("{:?}", p.kind),
            p.quant.to_string(),
            format!("{:.1}", p.memory_gb()),
            format!("{:.0}", p.math500),
            format!("{:.0}", p.ifeval),
            format!("{:.0}", p.decode_tps),
        ]);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 12: baseline performance across datasets / trainers / buffers

pub fn fig12(q: Quality) -> Vec<Table> {
    let offline = offline_training_set(q);
    let mut t = Table::new(
        "Fig 12 — mean epoch time + %-Hits (variants × datasets × trainers × buffer)",
        &["dataset", "trainers", "buffer", "variant", "epoch_time", "hits_pct", "comm_nodes"],
    );
    let variants = ["none", "fixed", "llm:gemma3-4b", "clf:mlp"];
    for ds_name in q.datasets() {
        for &trainers in &q.trainer_counts() {
            let cfg0 = base_cfg(q, ds_name, trainers, "none");
            let Ok((ds, part)) = build_cluster(&cfg0) else { continue };
            for buffer_pct in [0.05, 0.25] {
                for v in variants {
                    let mut cfg = cfg0.clone();
                    cfg.buffer_pct = buffer_pct;
                    cfg.controller = ControllerSpec::parse(v).unwrap();
                    let r = run_on(&ds, &part, &cfg, Some(&offline));
                    t.row(vec![
                        ds_name.to_string(),
                        trainers.to_string(),
                        format!("{:.0}%", buffer_pct * 100.0),
                        r.label.clone(),
                        fmt_secs(r.mean_epoch_time),
                        fmt_pct(r.steady_hits_pct),
                        fmt_count(r.total_comm_nodes),
                    ]);
                }
            }
        }
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 13: improvement spectrum over DistDGL+fixed

pub fn fig13(q: Quality) -> Vec<Table> {
    let offline = offline_training_set(q);
    let mut time_gains_llm = Vec::new();
    let mut hits_gains_llm = Vec::new();
    let mut time_gains_clf = Vec::new();
    let mut hits_gains_clf = Vec::new();
    for ds_name in q.datasets() {
        for &trainers in &q.trainer_counts() {
            let cfg0 = base_cfg(q, ds_name, trainers, "fixed");
            let Ok((ds, part)) = build_cluster(&cfg0) else { continue };
            for buffer_pct in [0.05, 0.25] {
                let mut fixed = cfg0.clone();
                fixed.buffer_pct = buffer_pct;
                let rf = run_on(&ds, &part, &fixed, None);
                for (v, tg, hg) in [
                    ("llm:gemma3-4b", &mut time_gains_llm, &mut hits_gains_llm),
                    ("clf:mlp", &mut time_gains_clf, &mut hits_gains_clf),
                ] {
                    let mut cfg = fixed.clone();
                    cfg.controller = ControllerSpec::parse(v).unwrap();
                    let r = run_on(&ds, &part, &cfg, Some(&offline));
                    tg.push((1.0 - r.mean_epoch_time / rf.mean_epoch_time) * 100.0);
                    if rf.steady_hits_pct > 0.0 {
                        hg.push(
                            (r.steady_hits_pct - rf.steady_hits_pct) / rf.steady_hits_pct * 100.0,
                        );
                    }
                }
            }
        }
    }
    let mut t = Table::new(
        "Fig 13 — %-improvement of Rudder over DistDGL+fixed (distribution)",
        &["controller", "metric", "median", "p25", "p75", "min", "max"],
    );
    for (name, xs) in [
        ("LLM (gemma3-4b)", &time_gains_llm),
        ("ML (MLP)", &time_gains_clf),
    ] {
        t.row(vec![
            name.to_string(),
            "epoch-time gain %".into(),
            format!("{:.1}", stats::median(xs)),
            format!("{:.1}", stats::percentile(xs, 25.0)),
            format!("{:.1}", stats::percentile(xs, 75.0)),
            format!("{:.1}", stats::percentile(xs, 0.0)),
            format!("{:.1}", stats::percentile(xs, 100.0)),
        ]);
    }
    for (name, xs) in [
        ("LLM (gemma3-4b)", &hits_gains_llm),
        ("ML (MLP)", &hits_gains_clf),
    ] {
        t.row(vec![
            name.to_string(),
            "hits gain %".into(),
            format!("{:.1}", stats::median(xs)),
            format!("{:.1}", stats::percentile(xs, 25.0)),
            format!("{:.1}", stats::percentile(xs, 75.0)),
            format!("{:.1}", stats::percentile(xs, 0.0)),
            format!("{:.1}", stats::percentile(xs, 100.0)),
        ]);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 14: buffer occupancy + p99 communication volume

pub fn fig14(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 14 — buffer fill + p99 communication per buffer size (gemma3-4b)",
        &["buffer", "trainers", "nodes_in_buffer", "p99_comm_nodes", "comm_per_mb_pct"],
    );
    for &trainers in &q.trainer_counts() {
        let cfg0 = base_cfg(q, "products", trainers, "llm:gemma3-4b");
        let Ok((ds, part)) = build_cluster(&cfg0) else { continue };
        for buffer_pct in [0.05, 0.25] {
            let mut cfg = cfg0.clone();
            cfg.buffer_pct = buffer_pct;
            let r = run_on(&ds, &part, &cfg, None);
            let occupancy: f64 = stats::mean(
                &r.per_trainer
                    .iter()
                    .flat_map(|m| m.minibatches.iter().map(|x| x.buffer_occupancy))
                    .collect::<Vec<_>>(),
            );
            let cap: f64 = stats::mean(
                &(0..part.num_parts)
                    .map(|p| part.halo_k(&ds.csr, p, 2).len() as f64 * buffer_pct)
                    .collect::<Vec<_>>(),
            );
            let sampled: f64 = stats::mean(
                &r.per_trainer
                    .iter()
                    .flat_map(|m| m.minibatches.iter().map(|x| x.unique_remote as f64))
                    .collect::<Vec<_>>(),
            );
            let fetched: f64 = stats::mean(
                &r.per_trainer
                    .iter()
                    .flat_map(|m| m.minibatches.iter().map(|x| x.comm_nodes as f64))
                    .collect::<Vec<_>>(),
            );
            t.row(vec![
                format!("{:.0}%", buffer_pct * 100.0),
                trainers.to_string(),
                format!("{:.0}", occupancy * cap),
                format!("{:.0}", r.p99_comm_nodes),
                format!("{:.1}%", fetched / sampled.max(1.0) * 100.0),
            ]);
        }
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 15: MassiveGNN comparison

pub fn fig15(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 15 — MassiveGNN vs Rudder: comm volume + %-Hits (products)",
        &["variant", "buffer", "comm_nodes", "comm_reduction_vs_DistDGL", "hits_pct"],
    );
    let trainers = *q.trainer_counts().last().unwrap();
    let cfg0 = base_cfg(q, "products", trainers, "none");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for buffer_pct in [0.05, 0.25] {
        let mut base = cfg0.clone();
        base.buffer_pct = buffer_pct;
        let rb = run_on(&ds, &part, &base, None);
        for v in ["massivegnn:32", "llm:gemma3-4b"] {
            let mut cfg = cfg0.clone();
            cfg.buffer_pct = buffer_pct;
            cfg.controller = ControllerSpec::parse(v).unwrap();
            let r = run_on(&ds, &part, &cfg, None);
            let reduction = (1.0 - r.total_comm_nodes as f64 / rb.total_comm_nodes as f64) * 100.0;
            t.row(vec![
                r.label.clone(),
                format!("{:.0}%", buffer_pct * 100.0),
                fmt_count(r.total_comm_nodes),
                format!("{reduction:.1}%"),
                fmt_pct(r.steady_hits_pct),
            ]);
        }
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 16: performance / persistence tradeoff across buffer capacities

pub fn fig16(q: Quality) -> Vec<Table> {
    let offline = offline_training_set(q);
    let mut t = Table::new(
        "Fig 16 — buffer capacity sweep (products): time/comm vs persistence",
        &["buffer", "variant", "epoch_time", "improvement_vs_fixed", "comm_nodes", "hits_pct"],
    );
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for buffer_pct in [0.05, 0.10, 0.15, 0.20, 0.25] {
        let mut fixed = cfg0.clone();
        fixed.buffer_pct = buffer_pct;
        let rf = run_on(&ds, &part, &fixed, None);
        t.row(vec![
            format!("{:.0}%", buffer_pct * 100.0),
            rf.label.clone(),
            fmt_secs(rf.mean_epoch_time),
            "-".into(),
            fmt_count(rf.total_comm_nodes),
            fmt_pct(rf.steady_hits_pct),
        ]);
        for v in ["llm:gemma3-4b", "llm:llama3.2-3b", "llm:smollm2-1.7b", "clf:mlp"] {
            let mut cfg = fixed.clone();
            cfg.controller = ControllerSpec::parse(v).unwrap();
            let r = run_on(&ds, &part, &cfg, Some(&offline));
            let imp = (1.0 - r.mean_epoch_time / rf.mean_epoch_time) * 100.0;
            t.row(vec![
                format!("{:.0}%", buffer_pct * 100.0),
                r.label.clone(),
                fmt_secs(r.mean_epoch_time),
                format!("{imp:+.1}%"),
                fmt_count(r.total_comm_nodes),
                fmt_pct(r.steady_hits_pct),
            ]);
        }
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 17 + Table 2: sync vs async

const T2_MODELS: &[&str] = &[
    "gemma3-4b", "gemma3-1b", "llama3.2-3b", "smollm2-360m", "smollm2-1.7b", "qwen-1.5b",
];
const T2_CLASSIFIERS: &[Kind] = &[
    Kind::Mlp, Kind::TabNet, Kind::LogReg, Kind::RandomForest, Kind::Svm, Kind::Xgb,
];

pub fn table2(q: Quality) -> Vec<Table> {
    let offline = offline_training_set(q);
    let trainer_for_acc = OfflineTrainer::new(offline.clone(), 11);
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    let mut tables = Vec::new();
    for mode in [Mode::Async, Mode::Sync] {
        let mode_name = if mode == Mode::Sync { "Synchronous" } else { "Asynchronous" };
        let mut t = Table::new(
            &format!("Table 2 — {mode_name} evaluation (products)"),
            &["model", "pass@1_or_acc", "interval_r", "valid/invalid_%", "+ve/-ve_%"],
        );
        for m in T2_MODELS {
            let mut cfg = cfg0.clone();
            cfg.mode = mode;
            cfg.controller = ControllerSpec::parse(&format!("llm:{m}")).unwrap();
            let r = run_on(&ds, &part, &cfg, None);
            let p = pass_at_1(&r.per_trainer);
            t.row(vec![
                m.to_string(),
                format!("{:.0}", p.score),
                format!("{:.0}", r.replacement_interval),
                format!(
                    "{:.0}/{:.0}",
                    r.valid_response_pct,
                    100.0 - r.valid_response_pct
                ),
                format!(
                    "{:.0}/{:.0}",
                    r.positive_decision_pct,
                    100.0 - r.positive_decision_pct
                ),
            ]);
        }
        for &kind in T2_CLASSIFIERS {
            let mut cfg = cfg0.clone();
            cfg.mode = mode;
            cfg.controller =
                ControllerSpec::Classifier { kind, finetune_interval: None };
            let r = run_on(&ds, &part, &cfg, Some(&offline));
            // Classifiers report supervised accuracy (§5.3).
            let acc = trainer_for_acc.train(kind).val_accuracy * 100.0;
            t.row(vec![
                kind.name().to_string(),
                format!("{acc:.0}"),
                format!("{:.0}", r.replacement_interval),
                "-".into(),
                format!(
                    "{:.0}/{:.0}",
                    r.positive_decision_pct,
                    100.0 - r.positive_decision_pct
                ),
            ]);
        }
        tables.push(t);
    }
    tables
}

pub fn fig17(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 17 — %-Hits: synchronous vs asynchronous (products)",
        &["model", "sync_hits", "async_hits"],
    );
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for m in ["gemma3-4b", "gemma3-1b", "llama3.2-3b", "smollm2-1.7b"] {
        let mut scores = Vec::new();
        for mode in [Mode::Sync, Mode::Async] {
            let mut cfg = cfg0.clone();
            cfg.mode = mode;
            cfg.controller = ControllerSpec::parse(&format!("llm:{m}")).unwrap();
            let r = run_on(&ds, &part, &cfg, None);
            scores.push(r.steady_hits_pct);
        }
        t.row(vec![
            m.to_string(),
            fmt_pct(scores[0]),
            fmt_pct(scores[1]),
        ]);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Table 3 + Figs 18/19: unseen datasets

pub fn fig18(q: Quality) -> Vec<Table> {
    let offline = offline_training_set(q);
    let mut t = Table::new(
        "Figs 18/19 + Table 3 — unseen datasets (yelp, ogbn-arxiv)",
        &["dataset", "batch", "variant", "epoch_time", "hits_pct", "pass@1_or_acc"],
    );
    for ds_name in ["yelp", "ogbn-arxiv"] {
        for batch in [500usize, 1000, 2000] {
            let mut cfg0 = base_cfg(q, ds_name, 4, "fixed");
            cfg0.batch_size = batch / 40; // scaled stand-ins: shrink batch 40x like nodes
            let Ok((ds, part)) = build_cluster(&cfg0) else { continue };
            let variants = [
                "llm:gemma3-4b".to_string(),
                "clf:mlp".to_string(),
                "clf:mlp:finetune=25".to_string(),
                "clf:tabnet".to_string(),
                "clf:tabnet:finetune=25".to_string(),
                "clf:xgb".to_string(),
                "clf:xgb:finetune=25".to_string(),
            ];
            for v in &variants {
                let mut cfg = cfg0.clone();
                cfg.controller = ControllerSpec::parse(v).unwrap();
                let r = run_on(&ds, &part, &cfg, Some(&offline));
                let p = pass_at_1(&r.per_trainer);
                let score = if p.trials > 0 {
                    p.format()
                } else {
                    "-".to_string()
                };
                t.row(vec![
                    ds_name.to_string(),
                    batch.to_string(),
                    r.label.clone(),
                    fmt_secs(r.mean_epoch_time),
                    fmt_pct(r.steady_hits_pct),
                    score,
                ]);
            }
        }
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Table 4: Pass@1 with CI across datasets

pub fn table4(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Table 4 — Pass@1 %-Hits (+95% CI), async mode",
        &["model", "products", "reddit", "orkut"],
    );
    let cols = ["products", "reddit", "orkut"];
    let mut clusters = Vec::new();
    for ds_name in cols {
        let cfg0 = base_cfg(q, ds_name, 4, "fixed");
        clusters.push((ds_name, build_cluster(&cfg0).expect("cluster"), cfg0));
    }
    for m in T2_MODELS {
        let mut cells = vec![m.to_string()];
        for (_, (ds, part), cfg0) in &clusters {
            let mut cfg = cfg0.clone();
            cfg.controller = ControllerSpec::parse(&format!("llm:{m}")).unwrap();
            let r = run_on(ds, part, &cfg, None);
            cells.push(pass_at_1(&r.per_trainer).format());
        }
        t.row(cells);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Fig 20: replacement trajectories LLM vs MLP

pub fn fig20(q: Quality) -> Vec<Table> {
    let offline = offline_training_set(q);
    let mut tables = Vec::new();
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for v in ["llm:gemma3-4b", "clf:mlp"] {
        let mut cfg = cfg0.clone();
        cfg.controller = ControllerSpec::parse(v).unwrap();
        let r = run_on(&ds, &part, &cfg, Some(&offline));
        let mut t = Table::new(
            &format!("Fig 20 — trajectory ({}), trainer 0", r.label),
            &["minibatch", "hits_pct", "comm_nodes", "replaced"],
        );
        let series = &r.per_trainer[0].minibatches;
        let step = (series.len() / 30).max(1);
        for m in series.iter().step_by(step) {
            t.row(vec![
                m.minibatch.to_string(),
                format!("{:.1}", m.hits_pct),
                m.comm_nodes.to_string(),
                if m.replaced { "R".into() } else { "".into() },
            ]);
        }
        let replacements: usize = r
            .per_trainer
            .iter()
            .map(|m| m.minibatches.iter().filter(|x| x.replaced).count())
            .sum();
        t.row(vec![
            "TOTAL".into(),
            fmt_pct(r.steady_hits_pct),
            fmt_count(r.total_comm_nodes),
            format!("{replacements} replacements"),
        ]);
        tables.push(t);
    }
    tables
}

// ---------------------------------------------------------------------------
// Table 5 + Fig 21: MoE agents

pub fn fig21(q: Quality) -> Vec<Table> {
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    let mut t5 = Table::new(
        "Table 5 — MoE agents (products)",
        &["model", "pass@1", "interval_r", "valid/invalid_%", "+ve/-ve_%"],
    );
    for p in crate::agent::profiles::moe_models() {
        let mut cfg = cfg0.clone();
        cfg.controller = ControllerSpec::parse(&format!("llm:{}", p.name)).unwrap();
        let r = run_on(&ds, &part, &cfg, None);
        let pk = pass_at_1(&r.per_trainer);
        t5.row(vec![
            p.name.to_string(),
            format!("{:.0}", pk.score),
            format!("{:.0}", r.replacement_interval),
            format!("{:.0}/{:.0}", r.valid_response_pct, 100.0 - r.valid_response_pct),
            format!(
                "{:.0}/{:.0}",
                r.positive_decision_pct,
                100.0 - r.positive_decision_pct
            ),
        ]);
    }
    let mut t21 = Table::new(
        "Fig 21 — training times across buffer sizes (MoEs vs gemma3-4b vs fixed)",
        &["buffer", "fixed", "gemma3-4b", "granite3.1-3b", "mixtral-8x7b", "mixtral-8x22b"],
    );
    for buffer_pct in [0.05, 0.10, 0.15, 0.20, 0.25] {
        let mut cells = vec![format!("{:.0}%", buffer_pct * 100.0)];
        for v in [
            "fixed",
            "llm:gemma3-4b",
            "llm:granite3.1-3b",
            "llm:mixtral-8x7b",
            "llm:mixtral-8x22b",
        ] {
            let mut cfg = cfg0.clone();
            cfg.buffer_pct = buffer_pct;
            cfg.controller = ControllerSpec::parse(v).unwrap();
            let r = run_on(&ds, &part, &cfg, None);
            cells.push(fmt_secs(r.mean_epoch_time));
        }
        t21.row(cells);
    }
    vec![t5, t21]
}

// ---------------------------------------------------------------------------
// Ablations (DESIGN.md §5: design choices called out for ablation benches)

/// Scoring-policy ablation: the paper's frequency-decay policy vs classic
/// LFU (cache-pollution-prone, §2.1) and LRU, under the fixed controller.
pub fn abl_policy(q: Quality) -> Vec<Table> {
    use crate::buffer::scoring::Policy;
    let mut t = Table::new(
        "Ablation — buffer scoring policy (fixed cadence, products)",
        &["policy", "mean_hits", "steady_hits", "comm_nodes"],
    );
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for (name, policy) in [
        ("freq-decay (Rudder)", Policy::FreqDecay),
        ("LFU", Policy::Lfu),
        ("LRU", Policy::Lru),
    ] {
        let mut cfg = cfg0.clone();
        cfg.buffer_policy = policy;
        let r = run_on(&ds, &part, &cfg, None);
        t.row(vec![
            name.to_string(),
            fmt_pct(r.mean_hits_pct),
            fmt_pct(r.steady_hits_pct),
            fmt_count(r.total_comm_nodes),
        ]);
    }
    vec![t]
}

/// Chain-of-thought ablation (§4.3.2): CoT raises decision quality at 4–5×
/// response latency — longer replacement intervals, fewer interventions.
pub fn abl_cot(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Ablation — chain-of-thought prompting (gemma3-4b, products)",
        &["variant", "pass@1", "interval_r", "steady_hits", "epoch_time"],
    );
    let cfg0 = base_cfg(q, "products", 4, "fixed");
    let (ds, part) = build_cluster(&cfg0).expect("cluster");
    for (name, spec) in [("zero-shot", "llm:gemma3-4b"), ("CoT", "llm:gemma3-4b:cot")] {
        let mut cfg = cfg0.clone();
        cfg.controller = ControllerSpec::parse(spec).unwrap();
        let r = run_on(&ds, &part, &cfg, None);
        let p = pass_at_1(&r.per_trainer);
        t.row(vec![
            name.to_string(),
            format!("{:.0}", p.score),
            format!("{:.0}", r.replacement_interval),
            fmt_pct(r.steady_hits_pct),
            fmt_secs(r.mean_epoch_time),
        ]);
    }
    vec![t]
}

/// Partitioner ablation: cut quality drives halo size and remote traffic.
pub fn abl_partition(q: Quality) -> Vec<Table> {
    let mut t = Table::new(
        "Ablation — partitioner (fixed cadence, products)",
        &["method", "edge_cut_pct", "mean_halo", "comm_nodes", "steady_hits"],
    );
    for method in [Method::MetisLike, Method::Ldg, Method::Random] {
        let mut cfg = base_cfg(q, "products", 4, "fixed");
        cfg.partition_method = method;
        let (ds, part) = build_cluster(&cfg).expect("cluster");
        let stats = crate::partition::stats::compute(&ds.csr, &part);
        let r = run_on(&ds, &part, &cfg, None);
        t.row(vec![
            format!("{method:?}"),
            format!("{:.1}%", stats.cut_fraction * 100.0),
            format!("{:.0}", stats.mean_halo),
            fmt_count(r.total_comm_nodes),
            fmt_pct(r.steady_hits_pct),
        ]);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// dispatcher

pub const EXPERIMENTS: &[&str] = &[
    "fig01", "fig03", "fig06", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17",
    "table2", "fig18", "table4", "fig20", "fig21",
    "abl_policy", "abl_cot", "abl_partition",
];

pub fn run_experiment_id(id: &str, q: Quality) -> crate::error::Result<Vec<Table>> {
    Ok(match id {
        "fig01" | "fig1" => fig01(q),
        "fig03" | "fig3" => fig03(q),
        "fig06" | "fig6" => fig06(q),
        "fig12" => fig12(q),
        "fig13" => fig13(q),
        "fig14" => fig14(q),
        "fig15" => fig15(q),
        "fig16" => fig16(q),
        "fig17" => fig17(q),
        "table2" | "t2" => table2(q),
        "fig18" | "fig19" | "table3" | "t3" => fig18(q),
        "table4" | "t4" => table4(q),
        "fig20" => fig20(q),
        "fig21" | "table5" | "t5" => fig21(q),
        "abl_policy" => abl_policy(q),
        "abl_cot" => abl_cot(q),
        "abl_partition" => abl_partition(q),
        _ => crate::bail!(
            "unknown experiment '{id}' (available: {})",
            EXPERIMENTS.join(", ")
        ),
    })
}

/// Sanity check used by tests: dataset registry covers all figure needs.
pub fn datasets_available() -> bool {
    ["products", "reddit", "orkut", "yelp", "ogbn-arxiv"]
        .iter()
        .all(|n| datasets::by_name(n).is_some())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_experiments() {
        assert!(datasets_available());
        assert!(EXPERIMENTS.len() >= 17);
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment_id("fig99", Quality::Quick).is_err());
    }

    #[test]
    fn fig06_renders_all_models() {
        let t = &fig06(Quality::Quick)[0];
        assert_eq!(t.rows.len(), crate::agent::profiles::ALL.len());
    }
}
