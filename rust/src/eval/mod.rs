//! Evaluation: Pass@1 scoring (§4.6), the per-figure experiment harness
//! (§5), and paper-style report rendering.

pub mod harness;
pub mod passk;
pub mod report;

pub use harness::{run_experiment_id, Quality, EXPERIMENTS};
pub use passk::{pass_at_1, PassAtK};
pub use report::Table;
