//! MassiveGNN comparator (Sarkar et al., CLUSTER'24 — the paper's §5.1
//! baseline).
//!
//! MassiveGNN prefetches *high-degree remote nodes before training starts*
//! (Rudder starts empty) and replaces on a fixed interval chosen by
//! exhaustive hyperparameter search (the paper uses its best-reported
//! interval, 32).  The replacement candidates use the same scoring policy;
//! only the warm start and the fixed cadence differ.

use crate::graph::Csr;
use crate::partition::Partition;

/// Degree-ordered prefetch candidates for part `p`: its 2-hop halo sorted
/// by descending degree, truncated to `limit`.
pub fn prefetch_order(csr: &Csr, part: &Partition, p: usize, limit: usize) -> Vec<u32> {
    let mut halo = part.halo_k(csr, p, 2);
    halo.sort_by_key(|&v| std::cmp::Reverse(csr.degree(v)));
    halo.truncate(limit);
    halo
}

/// The best-reported fixed replacement interval (paper Fig 15).
pub const DEFAULT_INTERVAL: u64 = 32;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{densify_isolated, generate, RmatParams};
    use crate::partition::{partition, Method};
    use crate::util::rng::Pcg32;

    fn setup() -> (Csr, Partition) {
        let mut rng = Pcg32::new(6);
        let csr = generate(
            &RmatParams {
                a: 0.57, b: 0.19, c: 0.19, num_nodes: 1200, num_edges: 8000, permute: true,
            },
            &mut rng,
        );
        let csr = densify_isolated(&csr, &mut rng);
        let part = partition(&csr, 4, Method::MetisLike, 1);
        (csr, part)
    }

    #[test]
    fn orders_by_degree_desc() {
        let (csr, part) = setup();
        let order = prefetch_order(&csr, &part, 0, 100);
        assert!(order.len() <= 100);
        for w in order.windows(2) {
            assert!(csr.degree(w[0]) >= csr.degree(w[1]));
        }
        // All candidates are remote to part 0.
        assert!(order.iter().all(|&v| part.owner_of(v) != 0));
    }

    #[test]
    fn truncates_to_limit() {
        let (csr, part) = setup();
        assert_eq!(prefetch_order(&csr, &part, 1, 5).len(), 5);
    }
}
