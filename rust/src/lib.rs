//! # Rudder — LLM-agent-steered prefetching for distributed GNN training
//!
//! A from-scratch reproduction of *"Rudder: Steering Prefetching in
//! Distributed GNN Training using LLM Agents"* (ICS 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: a discrete-event simulated
//!   distributed-GNN cluster (partitioned graph, k-hop sampler, RPC network
//!   model, DDP trainers), the persistent buffer with the paper's
//!   frequency-decay scoring policy, the prefetcher/inference task pipeline
//!   of Algorithm 1, the LLM-agent workflow (MetricsCollector →
//!   ContextBuilder → DecisionMaker), the ML-classifier controllers, the
//!   in-process distributed [`cluster`] runtime (real trainer/server
//!   threads, wire-format RPC, async prefetching — traffic-parity-checked
//!   against the sim), and the full evaluation harness (every figure and
//!   table of §5).
//! * **Layer 2** — `python/compile/model.py`: GraphSAGE fwd/bwd + the MLP
//!   decision classifier, AOT-lowered to HLO text.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels (fused SAGE
//!   aggregate+project, tiled matmul, buffer score update).
//!
//! The [`runtime`] module executes the AOT artifact entries through a
//! pluggable [`runtime::RuntimeBackend`]: a zero-dependency pure-Rust
//! interpreter by default, or the PJRT C API (`xla` crate) behind the
//! `pjrt` cargo feature — either way Python never runs on the request path.
//!
//! Start with [`sim::run::run_experiment`] or the `examples/` directory.

// Numeric-kernel style: index loops over multiple parallel buffers are the
// clearest form for the math here.
#![allow(clippy::needless_range_loop)]

pub mod agent;
pub mod audit;
pub mod cli;
pub mod buffer;
pub mod cluster;
pub mod error;
pub mod magic;
pub mod classifier;
pub mod config;
pub mod eval;
pub mod gnn;
pub mod graph;
pub mod metrics;
pub mod massivegnn;
pub mod net;
pub mod partition;
pub mod replay;
pub mod runtime;
pub mod sampler;
pub mod sim;
pub mod trace;
pub mod util;
