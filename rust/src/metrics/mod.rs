//! Metrics: per-minibatch records, run-level series, and the agent-visible
//! observation snapshot.
//!
//! Everything the paper plots flows through [`RunMetrics`]: %-Hits and
//! communication trajectories (Fig 20), epoch times (Fig 12/16/21), p99
//! communication volume (Fig 14), replacement events and intervals
//! (Table 2), and the decision log that Pass@1 (§4.6) is computed from.

use crate::util::stats;

/// One trainer-minibatch observation.
#[derive(Debug, Clone)]
pub struct MinibatchRecord {
    pub epoch: usize,
    pub minibatch: usize,
    pub trainer: usize,
    /// %-Hits: sampled remote nodes found in the persistent buffer.
    pub hits_pct: f64,
    /// Absolute buffer-hit count (traffic-parity checks compare this).
    pub hits: u64,
    /// Remote nodes fetched this minibatch (misses + replacement fetches).
    pub comm_nodes: u64,
    pub comm_bytes: u64,
    /// Unique remote nodes sampled (Fig 1 series).
    pub unique_remote: u64,
    pub buffer_occupancy: f64,
    /// Virtual time this minibatch took (T_DDP + exposed comm + stalls).
    pub step_time: f64,
    /// Was a replacement executed on this minibatch?
    pub replaced: bool,
    /// Nodes replaced as a fraction of buffer capacity.
    pub replaced_frac: f64,
}

/// Decision bookkeeping for Pass@1 (§4.6): the agent predicts the %-Hits
/// direction; we compare against the observed movement at the next
/// evaluation point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HitsPrediction {
    Increase,
    Decrease,
    Unchanged,
}

impl HitsPrediction {
    pub fn parse(s: &str) -> Option<HitsPrediction> {
        match s {
            "increase" | "up" | "improve" => Some(HitsPrediction::Increase),
            "decrease" | "down" | "degrade" => Some(HitsPrediction::Decrease),
            "unchanged" | "same" | "stable" => Some(HitsPrediction::Unchanged),
            _ => None,
        }
    }

    /// Does an observed %-Hits delta match this prediction?
    /// Movements under `tol` percentage points count as "unchanged".
    pub fn matches(&self, delta_hits: f64, tol: f64) -> bool {
        match self {
            HitsPrediction::Increase => delta_hits > tol,
            HitsPrediction::Decrease => delta_hits < -tol,
            HitsPrediction::Unchanged => delta_hits.abs() <= tol,
        }
    }
}

/// One controller decision, enriched once the outcome is observable.
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    pub minibatch: usize,
    /// true = replace, false = skip.
    pub replace: bool,
    pub prediction: Option<HitsPrediction>,
    pub valid_response: bool,
    /// %-Hits at decision time.
    pub hits_before: f64,
    /// %-Hits at the next evaluation point (filled in later).
    pub hits_after: Option<f64>,
    /// Agent/classifier inference latency (virtual seconds).
    pub latency: f64,
}

/// Full per-trainer run series.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub minibatches: Vec<MinibatchRecord>,
    pub decisions: Vec<DecisionRecord>,
    pub epoch_times: Vec<f64>,
}

/// Per-transport-link traffic counters ([`crate::cluster::transport`]):
/// one entry per point-to-point link a trainer owns (one per feature
/// server, plus the allreduce-hub link).  Frames/bytes are counted at the
/// transport layer, so TCP handshake frames and duplicated fault-shim
/// frames appear here even though the protocol-level [`WireStats`]
/// counters exclude them.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Human-readable remote endpoint ("server:2", "hub").
    pub peer: String,
    /// Logical channel id this link rides on.  For the per-connection
    /// transports (channel, tcp) each link has its own physical pipe and
    /// the id just mirrors the link index; the event-loop transport
    /// multiplexes every logical link over one physical connection and
    /// this is the channel tag each frame carries on the wire.
    pub channel: u32,
    pub frames_sent: u64,
    pub bytes_sent: u64,
    pub frames_recv: u64,
    pub bytes_recv: u64,
    /// Connect retries this link needed before it came up (TCP only;
    /// non-zero means the dialer raced the listener and backed off).
    pub reconnects: u64,
}

/// Wire-level traffic counters from the cluster runtime
/// ([`crate::cluster`]): what actually crossed the serialized RPC
/// transport, as opposed to the *logical* per-minibatch fetch accounting
/// in [`MinibatchRecord`].  Coalescing (one frame per owner partition) and
/// want-set dedup make these smaller than the logical counters.  The
/// dedup bookkeeping is driven purely by the trainer's deterministic
/// command sequence, so for a fixed config + seed every counter here is
/// identical across transports (channel vs TCP) and across runs —
/// enforced by `cluster::wire_parity`.
#[derive(Debug, Clone, Default)]
pub struct WireStats {
    /// Request frames / bytes sent (trainer → feature server).
    pub req_frames: u64,
    pub req_bytes: u64,
    /// Response frames / bytes received (feature server → trainer).
    pub resp_frames: u64,
    pub resp_bytes: u64,
    /// Node fetches actually put on the wire.
    pub nodes_requested: u64,
    /// Node fetches suppressed because the feature was already resident or
    /// already expected from an earlier request (the prefetch engine's
    /// dedup).
    pub nodes_deduped: u64,
    /// Node features received on non-duplicate responses.
    pub nodes_received: u64,
    /// Duplicate `FetchResp` frames dropped by req-id dedup (only the
    /// fault-injection shim produces these).
    pub dup_frames: u64,
    /// Frames that failed to decode, had an unexpected kind, or carried a
    /// malformed payload (shape/dim skew, digest mismatch).  Non-zero
    /// means a protocol bug: the nodes of a lost response would stay
    /// outstanding and eventually surface as a feature-wait timeout.
    pub bad_frames: u64,
    /// Chunk-cache counters (content-addressed feature plane; all zero
    /// unless `chunk_cache_bytes > 0`).  `chunks_hit` counts node fetches
    /// served by the per-link chunk cache without a wire request;
    /// `chunks_fetched` counts chunks admitted (their request went on the
    /// wire); `bytes_saved_cache` estimates the response payload bytes
    /// the hits kept off the wire.  All three are command-time counters —
    /// pure functions of config + seed, covered by `wire_parity`.
    pub chunks_hit: u64,
    pub chunks_fetched: u64,
    pub bytes_saved_cache: u64,
    /// Per-link transport counters (feature-server links, then the hub
    /// link).  Timing-independent except for `reconnects`.
    pub links: Vec<LinkStats>,
    /// Per-owner fetch round-trip latency (FetchReq issued → FetchResp
    /// admitted), indexed by owner partition = the server link's channel
    /// id.  Wall-clock data, so it lives here — NOT in [`LinkStats`],
    /// whose `Eq` the cross-transport tests rely on — and is excluded
    /// from `cluster::wire_parity`.
    pub fetch_latency: Vec<crate::util::stats::LogHistogram>,
}

/// Measured-compute accounting from the cluster runtime's
/// `ComputeMode::Measured` ([`crate::cluster`]): what the *real*
/// `SageRunner` did on this trainer, as opposed to the modelled T_DDP the
/// virtual clock charges.  Empty in emulated mode.
///
/// The per-minibatch vectors cover *active* minibatches (short partitions
/// skip trailing indices); `barrier_secs` covers every DDP round, active
/// or not, so its length can exceed the others'.
#[derive(Debug, Clone, Default)]
pub struct MeasuredStats {
    /// Real fwd+bwd wall seconds per active minibatch.
    pub compute_secs: Vec<f64>,
    /// Wall seconds blocked waiting for remote features per active
    /// minibatch (the exposed, un-overlapped part of communication).
    pub fetch_wait_secs: Vec<f64>,
    /// Wall seconds in the DDP allreduce barrier per round.
    pub barrier_secs: Vec<f64>,
    /// Training loss per active minibatch.
    pub losses: Vec<f32>,
    /// Feature rows gathered from the prefetched [`FeatureStore`] (remote
    /// nodes) vs synthesized from the partition-resident shard (local).
    ///
    /// [`FeatureStore`]: crate::cluster::FeatureStore
    pub rows_from_store: u64,
    pub rows_local: u64,
    /// Remote rows *not* found in the store at compute time (re-synthesized
    /// as a fallback).  Non-zero means the assembly barrier has a hole.
    pub rows_fallback: u64,
    /// Gradient payload bytes this trainer sent to the allreduce hub.
    pub grad_bytes: u64,
    /// Fingerprint of the final model parameters
    /// ([`crate::gnn::SageState::fingerprint`]): identical across trainers
    /// iff the real gradient allreduce kept every replica in sync.
    pub param_hash: u64,
}

impl MeasuredStats {
    /// Is there anything here (i.e. did this run measure real compute)?
    pub fn is_populated(&self) -> bool {
        !self.compute_secs.is_empty() || self.param_hash != 0
    }

    pub fn total_compute(&self) -> f64 {
        self.compute_secs.iter().sum()
    }

    pub fn total_fetch_wait(&self) -> f64 {
        self.fetch_wait_secs.iter().sum()
    }

    pub fn total_barrier(&self) -> f64 {
        self.barrier_secs.iter().sum()
    }

    pub fn mean_loss(&self) -> f64 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.losses.iter().map(|&l| l as f64).sum::<f64>() / self.losses.len() as f64
    }
}

impl WireStats {
    /// Accumulate another trainer's counters (cluster-level totals).
    pub fn merge(&mut self, o: &WireStats) {
        self.req_frames += o.req_frames;
        self.req_bytes += o.req_bytes;
        self.resp_frames += o.resp_frames;
        self.resp_bytes += o.resp_bytes;
        self.nodes_requested += o.nodes_requested;
        self.nodes_deduped += o.nodes_deduped;
        self.nodes_received += o.nodes_received;
        self.dup_frames += o.dup_frames;
        self.bad_frames += o.bad_frames;
        self.chunks_hit += o.chunks_hit;
        self.chunks_fetched += o.chunks_fetched;
        self.bytes_saved_cache += o.bytes_saved_cache;
        self.links.extend(o.links.iter().cloned());
        if self.fetch_latency.len() < o.fetch_latency.len() {
            self.fetch_latency.resize_with(o.fetch_latency.len(), Default::default);
        }
        for (mine, theirs) in self.fetch_latency.iter_mut().zip(&o.fetch_latency) {
            mine.merge(theirs);
        }
    }

    /// All owners' fetch latency pooled into one histogram.
    pub fn fetch_latency_total(&self) -> crate::util::stats::LogHistogram {
        let mut all = crate::util::stats::LogHistogram::new();
        for h in &self.fetch_latency {
            all.merge(h);
        }
        all
    }
}

impl RunMetrics {
    pub fn mean_epoch_time(&self) -> f64 {
        stats::mean(&self.epoch_times)
    }

    pub fn mean_hits_pct(&self) -> f64 {
        stats::mean(&self.minibatches.iter().map(|m| m.hits_pct).collect::<Vec<_>>())
    }

    /// Steady-state %-Hits: mean over the last half of the run.
    pub fn steady_hits_pct(&self) -> f64 {
        let n = self.minibatches.len();
        if n == 0 {
            return 0.0;
        }
        let tail: Vec<f64> = self.minibatches[n / 2..].iter().map(|m| m.hits_pct).collect();
        stats::mean(&tail)
    }

    pub fn total_comm_nodes(&self) -> u64 {
        self.minibatches.iter().map(|m| m.comm_nodes).sum()
    }

    /// Total buffer hits across the run (traffic-parity counter).
    pub fn total_hits(&self) -> u64 {
        self.minibatches.iter().map(|m| m.hits).sum()
    }

    pub fn total_comm_bytes(&self) -> u64 {
        self.minibatches.iter().map(|m| m.comm_bytes).sum()
    }

    /// p-th percentile of per-minibatch communication (Fig 14 is p99).
    pub fn comm_nodes_percentile(&self, p: f64) -> f64 {
        stats::percentile(
            &self.minibatches.iter().map(|m| m.comm_nodes as f64).collect::<Vec<_>>(),
            p,
        )
    }

    /// The paper's replacement interval `r` (§4.5.1): mean gap in
    /// minibatches between *processed decisions* (r = 1 in sync mode, the
    /// agent's effective cadence in async mode).  Controllers without an
    /// inference loop (fixed / MassiveGNN) fall back to the gap between
    /// executed replacements.
    pub fn replacement_interval(&self) -> f64 {
        let points: Vec<usize> = if self.decisions.len() >= 2 {
            self.decisions.iter().map(|d| d.minibatch).collect()
        } else {
            self.minibatches
                .iter()
                .filter(|m| m.replaced)
                .map(|m| m.minibatch)
                .collect()
        };
        if points.len() < 2 {
            return self.minibatches.len().max(1) as f64;
        }
        let gaps: Vec<f64> = points.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        stats::mean(&gaps)
    }

    /// (valid, invalid) response counts.
    pub fn response_counts(&self) -> (u64, u64) {
        let valid = self.decisions.iter().filter(|d| d.valid_response).count() as u64;
        (valid, self.decisions.len() as u64 - valid)
    }

    /// (+ve, −ve) decision fractions: replace vs skip among valid decisions.
    pub fn decision_split(&self) -> (f64, f64) {
        let valid: Vec<_> = self.decisions.iter().filter(|d| d.valid_response).collect();
        if valid.is_empty() {
            return (0.0, 0.0);
        }
        let pos = valid.iter().filter(|d| d.replace).count() as f64 / valid.len() as f64;
        (pos * 100.0, (1.0 - pos) * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(mb: usize, hits: f64, comm: u64, replaced: bool) -> MinibatchRecord {
        MinibatchRecord {
            epoch: 0,
            minibatch: mb,
            trainer: 0,
            hits_pct: hits,
            hits: hits as u64,
            comm_nodes: comm,
            comm_bytes: comm * 400,
            unique_remote: comm,
            buffer_occupancy: 0.5,
            step_time: 0.01,
            replaced,
            replaced_frac: if replaced { 0.1 } else { 0.0 },
        }
    }

    #[test]
    fn prediction_parse_and_match() {
        assert_eq!(HitsPrediction::parse("increase"), Some(HitsPrediction::Increase));
        assert_eq!(HitsPrediction::parse("same"), Some(HitsPrediction::Unchanged));
        assert_eq!(HitsPrediction::parse("???"), None);
        assert!(HitsPrediction::Increase.matches(5.0, 1.0));
        assert!(!HitsPrediction::Increase.matches(0.5, 1.0));
        assert!(HitsPrediction::Unchanged.matches(0.5, 1.0));
        assert!(HitsPrediction::Decrease.matches(-3.0, 1.0));
    }

    #[test]
    fn replacement_interval_mean_gap() {
        let mut rm = RunMetrics::default();
        for i in 0..20 {
            rm.minibatches.push(rec(i, 50.0, 10, i % 5 == 0));
        }
        assert!((rm.replacement_interval() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn replacement_interval_degenerate() {
        let mut rm = RunMetrics::default();
        rm.minibatches.push(rec(0, 10.0, 5, false));
        rm.minibatches.push(rec(1, 10.0, 5, true));
        assert_eq!(rm.replacement_interval(), 2.0);
    }

    #[test]
    fn steady_hits_uses_tail() {
        let mut rm = RunMetrics::default();
        for i in 0..10 {
            rm.minibatches.push(rec(i, if i < 5 { 0.0 } else { 80.0 }, 1, false));
        }
        assert!((rm.steady_hits_pct() - 80.0).abs() < 1e-9);
        assert!((rm.mean_hits_pct() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn comm_percentiles() {
        let mut rm = RunMetrics::default();
        for i in 0..100 {
            rm.minibatches.push(rec(i, 50.0, i as u64, false));
        }
        assert!(rm.comm_nodes_percentile(99.0) >= 97.0);
        assert_eq!(rm.total_comm_nodes(), (0..100).sum::<u64>());
    }

    #[test]
    fn measured_stats_aggregates() {
        let mut m = MeasuredStats::default();
        assert!(!m.is_populated());
        m.compute_secs = vec![0.1, 0.3];
        m.fetch_wait_secs = vec![0.01, 0.02];
        m.barrier_secs = vec![0.001, 0.001, 0.001];
        m.losses = vec![2.0, 1.0];
        assert!(m.is_populated());
        assert!((m.total_compute() - 0.4).abs() < 1e-12);
        assert!((m.total_fetch_wait() - 0.03).abs() < 1e-12);
        assert!((m.total_barrier() - 0.003).abs() < 1e-12);
        assert!((m.mean_loss() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn response_counts_and_split() {
        let mut rm = RunMetrics::default();
        for i in 0..10 {
            rm.decisions.push(DecisionRecord {
                minibatch: i,
                replace: i % 2 == 0,
                prediction: None,
                valid_response: i != 9,
                hits_before: 0.0,
                hits_after: None,
                latency: 0.01,
            });
        }
        let (v, inv) = rm.response_counts();
        assert_eq!((v, inv), (9, 1));
    }
}
