//! The audit rule catalog: each repo invariant as a named, toggleable
//! check over a [`SourceModel`].
//!
//! Rules see the *code view* (comments and string contents blanked), so a
//! mention of `Instant::now()` in a doc comment or an error message never
//! fires.  Test-only lines (`#[cfg(test)]` regions, files under `tests/`)
//! are exempt from every rule: the invariants guard production paths, and
//! tests legitimately unwrap, print, and forge stale magics.

use super::lexer::SourceModel;

/// One diagnostic: `path:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// A named rule: whether it applies to a file (by repo-relative path with
/// `/` separators, e.g. `src/cluster/wire.rs`) and the check itself.
pub struct Rule {
    pub name: &'static str,
    pub description: &'static str,
    pub applies: fn(&str) -> bool,
    pub check: fn(&Rule, &str, &SourceModel) -> Vec<Finding>,
}

/// The registry, in reporting order.
pub const RULES: &[Rule] = &[
    Rule {
        name: "wall-clock-in-virtual-path",
        description: "no Instant::now()/SystemTime in virtual-time or prefetch-decision code \
                      (sim/, trace/, replay/, buffer/, massivegnn/, cluster/prefetch.rs)",
        applies: |p| {
            p.starts_with("src/sim/")
                || p.starts_with("src/trace/")
                || p.starts_with("src/replay/")
                || p.starts_with("src/buffer/")
                || p.starts_with("src/massivegnn/")
                || p == "src/cluster/prefetch.rs"
        },
        check: check_wall_clock,
    },
    Rule {
        name: "unchecked-narrowing-in-codec",
        description: "no bare `as u32` / `as u16` casts in the wire/ipc/trace codecs \
                      (use len_u32 / u32::try_from so truncation is an error, not silence)",
        applies: |p| {
            p == "src/cluster/wire.rs" || p == "src/cluster/ipc.rs" || p == "src/trace/codec.rs"
        },
        check: check_narrowing,
    },
    Rule {
        name: "panicking-lock-in-cluster",
        description: "no `.unwrap()` on lock/channel/join results in cluster/ runtime code \
                      (poison-recover, propagate, or `.expect(\"why this cannot fail\")`)",
        applies: |p| p.starts_with("src/cluster/"),
        check: check_panicking_lock,
    },
    Rule {
        name: "printing-outside-log",
        description: "no println!/eprintln! outside util/log.rs, eval/report.rs, main.rs \
                      (runtime roles must log through crate::log_* so output is gated + prefixed)",
        applies: |p| {
            p.starts_with("src/")
                && p != "src/util/log.rs"
                && p != "src/eval/report.rs"
                && p != "src/main.rs"
        },
        check: check_printing,
    },
    Rule {
        name: "untimed-condvar-wait",
        description: "every Condvar wait uses wait_timeout (an untimed wait can hang shutdown \
                      if the matching notify is lost to a panic or a wedged peer)",
        applies: |p| p.starts_with("src/"),
        check: check_condvar,
    },
    Rule {
        name: "ipc-magic-registry",
        description: "RTR*/RSV*/RHB* protocol magics must come from src/magic.rs, not inline \
                      literals (so version bumps cannot drift between encoder and decoder)",
        applies: |p| p.starts_with("src/") && p != "src/magic.rs",
        check: check_magic,
    },
];

pub fn rule_names() -> Vec<&'static str> {
    RULES.iter().map(|r| r.name).collect()
}

/// Byte offsets of every match of `needle` in the code view that starts at
/// a token boundary (previous byte is not an identifier char).
fn token_hits(code: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find(needle) {
        let at = from + p;
        let boundary = at == 0
            || !code.as_bytes()[at - 1].is_ascii_alphanumeric() && code.as_bytes()[at - 1] != b'_';
        if boundary {
            out.push(at);
        }
        from = at + needle.len();
    }
    out
}

fn non_test_hits(
    rule: &Rule,
    path: &str,
    m: &SourceModel,
    needle: &str,
    msg: &str,
) -> Vec<Finding> {
    token_hits(&m.code, needle)
        .into_iter()
        .map(|at| m.line_of(at))
        .filter(|&l| !m.is_test_line(l))
        .map(|line| Finding {
            rule: rule.name,
            path: path.to_string(),
            line,
            message: msg.to_string(),
        })
        .collect()
}

fn check_wall_clock(rule: &Rule, path: &str, m: &SourceModel) -> Vec<Finding> {
    let mut out = non_test_hits(
        rule,
        path,
        m,
        "Instant::now",
        "wall-clock read in virtual-time/decision code",
    );
    out.extend(non_test_hits(rule, path, m, "SystemTime", "SystemTime in virtual-time code"));
    out.sort_by_key(|f| f.line);
    out
}

fn check_narrowing(rule: &Rule, path: &str, m: &SourceModel) -> Vec<Finding> {
    let mut out = Vec::new();
    for needle in ["as u32", "as u16"] {
        for at in token_hits(&m.code, needle) {
            // Only flag the cast operator: require the preceding
            // non-space code to end an expression (`)`, identifier, digit,
            // or `]`), which every `expr as u32` does.
            let before = m.code[..at].trim_end();
            let is_cast = before
                .chars()
                .next_back()
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == ')' || c == ']' || c == '_');
            let line = m.line_of(at);
            if is_cast && !m.is_test_line(line) {
                out.push(Finding {
                    rule: rule.name,
                    path: path.to_string(),
                    line,
                    message: format!("bare `{needle}` narrowing in codec code"),
                });
            }
        }
    }
    out.sort_by_key(|f| f.line);
    out
}

/// Methods whose `Result`/`Option` carries a runtime condition (poisoned
/// lock, hung-up channel, panicked thread) that cluster code must handle
/// or justify — a bare `.unwrap()` turns a peer's failure into a cascade.
const PANICKY_RECEIVERS: &[&str] =
    &["lock", "recv", "try_recv", "recv_timeout", "send", "join", "wait", "wait_timeout"];

fn check_panicking_lock(rule: &Rule, path: &str, m: &SourceModel) -> Vec<Finding> {
    let mut out = Vec::new();
    for at in token_hits(&m.code, ".unwrap()") {
        let line = m.line_of(at);
        if m.is_test_line(line) {
            continue;
        }
        if let Some(recv) = receiver_method(&m.code, at) {
            if PANICKY_RECEIVERS.contains(&recv.as_str()) {
                out.push(Finding {
                    rule: rule.name,
                    path: path.to_string(),
                    line,
                    message: format!(
                        ".{recv}(..).unwrap() can panic on a peer failure — recover or expect"
                    ),
                });
            }
        }
    }
    out.sort_by_key(|f| f.line);
    out
}

/// For `X.method(args).unwrap()` with `.unwrap()` at `at`, walk back over
/// the balanced `(args)` group and return `method`.
fn receiver_method(code: &str, at: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut i = at;
    // Skip whitespace between `)` and `.unwrap` (chained across lines).
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    if i == 0 || b[i - 1] != b')' {
        return None;
    }
    let mut depth = 0i32;
    while i > 0 {
        i -= 1;
        match b[i] {
            b')' => depth += 1,
            b'(' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
    }
    if depth != 0 {
        return None;
    }
    let end = i;
    while i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_') {
        i -= 1;
    }
    if i == end {
        return None;
    }
    Some(code[i..end].to_string())
}

fn check_printing(rule: &Rule, path: &str, m: &SourceModel) -> Vec<Finding> {
    let mut out = Vec::new();
    for mac in ["println!", "eprintln!", "print!", "eprint!"] {
        out.extend(non_test_hits(
            rule,
            path,
            m,
            mac,
            &format!("{mac} outside the logging/report/CLI modules"),
        ));
    }
    out.sort_by_key(|f| f.line);
    out
}

fn check_condvar(rule: &Rule, path: &str, m: &SourceModel) -> Vec<Finding> {
    // Only meaningful in files that actually use a Condvar; `.wait(` on
    // other types (none today) would be noise elsewhere.
    if !m.code.contains("Condvar") {
        return Vec::new();
    }
    token_hits(&m.code, ".wait(")
        .into_iter()
        .map(|at| m.line_of(at))
        .filter(|&l| !m.is_test_line(l))
        .map(|line| Finding {
            rule: rule.name,
            path: path.to_string(),
            line,
            message: "untimed Condvar wait — use wait_timeout so shutdown cannot hang".to_string(),
        })
        .collect()
}

fn check_magic(rule: &Rule, path: &str, m: &SourceModel) -> Vec<Finding> {
    m.strings
        .iter()
        .filter(|s| is_protocol_magic(&s.value))
        .filter(|s| !m.is_test_line(s.line))
        .map(|s| Finding {
            rule: rule.name,
            path: path.to_string(),
            line: s.line,
            message: format!(
                "inline protocol magic \"{}\" — import it from crate::magic instead",
                s.value
            ),
        })
        .collect()
}

/// Exactly the 4-byte `RTR*`/`RSV*`/`RHB*` family (covers `RTRC` too).
fn is_protocol_magic(s: &str) -> bool {
    s.len() == 4
        && (s.starts_with("RTR") || s.starts_with("RSV") || s.starts_with("RHB"))
        && s.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rule_name: &str, path: &str, src: &str) -> Vec<Finding> {
        let rule = RULES.iter().find(|r| r.name == rule_name).unwrap();
        assert!((rule.applies)(path), "{path} must be in scope for {rule_name}");
        let m = SourceModel::lex(src, false);
        (rule.check)(rule, path, &m)
    }

    #[test]
    fn receiver_method_walks_back_over_args() {
        assert_eq!(receiver_method("x.lock().unwrap()", 8).as_deref(), Some("lock"));
        let multi = "q.recv_timeout(Duration::from_secs(1))\n    .unwrap()";
        let at = multi.find(".unwrap").unwrap();
        assert_eq!(receiver_method(multi, at).as_deref(), Some("recv_timeout"));
        // Plain value unwrap: no call group before it.
        assert_eq!(receiver_method("opt.unwrap()", 3), None);
    }

    #[test]
    fn wall_clock_fires_in_scope_only() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(findings("wall-clock-in-virtual-path", "src/sim/run.rs", src).len(), 1);
        let rule = RULES.iter().find(|r| r.name == "wall-clock-in-virtual-path").unwrap();
        assert!(!(rule.applies)("src/cluster/trainer.rs"), "wall stats are wall-clock by design");
    }

    #[test]
    fn narrowing_skips_turbofish_and_types() {
        // `Vec<u32>` or `0u32` must not fire; a real cast must.
        let ok = "fn f(v: Vec<u32>) -> u32 { 0u32 }\n";
        assert!(findings("unchecked-narrowing-in-codec", "src/cluster/wire.rs", ok).is_empty());
        let bad = "fn f(n: usize) -> u32 { n as u32 }\n";
        assert_eq!(
            findings("unchecked-narrowing-in-codec", "src/cluster/wire.rs", bad).len(),
            1
        );
    }

    #[test]
    fn magic_matches_whole_literal_only() {
        let bad = "const M: &[u8; 4] = b\"RTR9\";\n";
        assert_eq!(findings("ipc-magic-registry", "src/cluster/ipc.rs", bad).len(), 1);
        let msg = "fn f() { err(\"bad trace magic (want RTRC)\"); }\n";
        assert!(findings("ipc-magic-registry", "src/trace/codec.rs", msg).is_empty());
    }

    #[test]
    fn condvar_rule_needs_a_condvar_in_scope() {
        let no_cv = "fn f(rx: Receiver<u8>) { rx.wait(); }\n";
        assert!(findings("untimed-condvar-wait", "src/cluster/eventloop.rs", no_cv).is_empty());
        let cv = "use std::sync::Condvar;\nfn f(c: &Condvar, g: G) { let _ = c.wait(g); }\n";
        assert_eq!(findings("untimed-condvar-wait", "src/cluster/eventloop.rs", cv).len(), 1);
    }
}
