//! `rudder audit` — a zero-dependency static-analysis pass over this
//! crate's own sources.
//!
//! The repo's core guarantee — every wire/cache/trace counter is a pure
//! function of config + seed — plus the cluster's shutdown-liveness and
//! diagnosability invariants are enforced here as *named rules* (see
//! [`rules::RULES`]) instead of reviewer discipline.  The pass lexes each
//! file with a comment/string-aware tokenizer ([`lexer::SourceModel`] —
//! no `syn`, no dependencies), runs every applicable rule, and reports
//! `file:line: [rule] message` diagnostics.
//!
//! # Escape hatch
//!
//! A finding that is *intentional* is suppressed with an inline comment
//! that must carry a justification:
//!
//! ```text
//! let rtt_start = Instant::now(); // audit:allow(wall-clock-in-virtual-path) RTT is wall time
//! // audit:allow(printing-outside-log) protocol announce parsed by the orchestrator
//! println!("RUDDER_LISTEN {addr}");
//! ```
//!
//! A trailing comment covers its own line; a comment alone on a line
//! covers the next code line.  An allow with an empty reason, an unknown
//! rule name, or one that suppresses nothing is itself a finding — stale
//! escapes cannot accumulate.
//!
//! # Self-hosting
//!
//! `rudder audit` (CI job `audit`, blocking) runs the pass over
//! `rust/src/` + `rust/tests/` and exits nonzero on any finding; the
//! fixture suite in `rust/tests/audit.rs` pins each rule's fire/quiet/
//! allow behavior.

pub mod lexer;
pub mod rules;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::error::Result;

pub use lexer::SourceModel;
pub use rules::{rule_names, Finding, Rule, RULES};

/// Meta-rule names for directive hygiene (always on; reported alongside
/// the real rules so `--rules`/`--skip-rules` filtering stays simple).
pub const META_UNUSED_ALLOW: &str = "unused-allow";
pub const META_MALFORMED_ALLOW: &str = "malformed-allow";

/// Outcome of auditing one source file (or one fixture snippet).
#[derive(Debug, Default)]
pub struct FileAudit {
    pub findings: Vec<Finding>,
    /// Findings suppressed by a justified `audit:allow`.
    pub suppressed: usize,
}

/// Aggregate report over a tree.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

impl Report {
    /// Human-readable diagnostics + per-rule summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule, f.message));
        }
        let mut by_rule: Vec<(&str, usize)> = Vec::new();
        for f in &self.findings {
            match by_rule.iter_mut().find(|(r, _)| *r == f.rule) {
                Some((_, n)) => *n += 1,
                None => by_rule.push((f.rule, 1)),
            }
        }
        out.push_str(&format!(
            "audit: {} file(s), {} finding(s), {} allowed\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressed
        ));
        for (rule, n) in by_rule {
            out.push_str(&format!("  {n:4}  {rule}\n"));
        }
        out
    }
}

/// Audit one file's source under the enabled rule set.  `path` is the
/// repo-relative path with `/` separators (`src/cluster/wire.rs`,
/// `tests/cluster.rs`) — it selects which rules apply and whether the
/// whole file is test code.
pub fn check_source(path: &str, src: &str, enabled: &BTreeSet<&str>) -> FileAudit {
    let all_test = path.starts_with("tests/");
    let m = SourceModel::lex(src, all_test);
    let mut raw: Vec<Finding> = Vec::new();
    for rule in RULES {
        if enabled.contains(rule.name) && (rule.applies)(path) {
            raw.extend((rule.check)(rule, path, &m));
        }
    }

    let mut out = FileAudit::default();
    let mut used = vec![false; m.allows.len()];
    for f in raw {
        let allow = m.allows.iter().enumerate().find(|(_, a)| {
            a.rule == f.rule && a.target == f.line && !a.reason.is_empty()
        });
        match allow {
            Some((i, _)) => {
                used[i] = true;
                out.suppressed += 1;
            }
            None => out.findings.push(f),
        }
    }

    // Directive hygiene: every allow must be well-formed and, if its rule
    // is enabled and applies to this file, must actually suppress
    // something — otherwise it is stale and gets reported itself.
    let known = rule_names();
    for (i, a) in m.allows.iter().enumerate() {
        if m.is_test_line(a.line) {
            continue;
        }
        if a.rule.is_empty() || !known.contains(&a.rule.as_str()) {
            out.findings.push(Finding {
                rule: META_MALFORMED_ALLOW,
                path: path.to_string(),
                line: a.line,
                message: format!("audit:allow names unknown rule '{}'", a.rule),
            });
            continue;
        }
        if a.reason.is_empty() {
            out.findings.push(Finding {
                rule: META_MALFORMED_ALLOW,
                path: path.to_string(),
                line: a.line,
                message: format!(
                    "audit:allow({}) has no justification — state why the pattern is safe here",
                    a.rule
                ),
            });
            continue;
        }
        let rule = RULES.iter().find(|r| r.name == a.rule.as_str());
        let applicable = rule.is_some_and(|r| enabled.contains(r.name) && (r.applies)(path));
        if applicable && !used[i] {
            out.findings.push(Finding {
                rule: META_UNUSED_ALLOW,
                path: path.to_string(),
                line: a.line,
                message: format!("audit:allow({}) suppresses nothing — remove it", a.rule),
            });
        }
    }
    out.findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Run the pass over a crate tree: every `.rs` under `<root>/src` and
/// `<root>/tests`, in deterministic (sorted) order.
pub fn run_tree(root: &Path, enabled: &BTreeSet<&str>) -> Result<Report> {
    let mut report = Report::default();
    for dir in ["src", "tests"] {
        let base = root.join(dir);
        if !base.is_dir() {
            continue;
        }
        for file in rs_files(&base)? {
            let rel = file
                .strip_prefix(root)
                .unwrap_or(&file)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let src = std::fs::read_to_string(&file)
                .map_err(|e| crate::err!("audit: read {}: {e}", file.display()))?;
            let fa = check_source(&rel, &src, enabled);
            report.files_scanned += 1;
            report.suppressed += fa.suppressed;
            report.findings.extend(fa.findings);
        }
    }
    Ok(report)
}

/// All `.rs` files under `dir`, recursively, sorted by path.
fn rs_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries =
            std::fs::read_dir(&d).map_err(|e| crate::err!("audit: read dir {}: {e}", d.display()))?;
        for entry in entries {
            let path = entry.map_err(|e| crate::err!("audit: read dir entry: {e}"))?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Resolve the crate root to audit: `--root` wins; otherwise look for
/// `rust/src` (repo root cwd) then `src` (crate cwd — how `cargo test`
/// runs).
pub fn default_root(explicit: Option<&str>) -> Result<PathBuf> {
    if let Some(r) = explicit {
        let p = PathBuf::from(r);
        crate::ensure!(p.join("src").is_dir(), "audit: no src/ under --root {r}");
        return Ok(p);
    }
    for cand in ["rust", "."] {
        let p = PathBuf::from(cand);
        if p.join("src").join("lib.rs").is_file() {
            return Ok(p);
        }
    }
    crate::bail!("audit: cannot find the crate root (run from the repo root, or pass --root)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_rules() -> BTreeSet<&'static str> {
        rule_names().into_iter().collect()
    }

    #[test]
    fn allow_with_reason_suppresses_and_is_not_stale() {
        let src = "\
fn f() {
    let t = Instant::now(); // audit:allow(wall-clock-in-virtual-path) RTT is wall-domain
}
";
        let fa = check_source("src/sim/run.rs", src, &all_rules());
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
        assert_eq!(fa.suppressed, 1);
    }

    #[test]
    fn allow_without_reason_still_fires_plus_meta() {
        let src = "fn f() { let t = Instant::now(); } // audit:allow(wall-clock-in-virtual-path)\n";
        let fa = check_source("src/sim/run.rs", src, &all_rules());
        let rules: Vec<&str> = fa.findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"wall-clock-in-virtual-path"), "{rules:?}");
        assert!(rules.contains(&META_MALFORMED_ALLOW), "{rules:?}");
    }

    #[test]
    fn stale_allow_is_reported() {
        let src = "// audit:allow(printing-outside-log) nothing prints here\nfn f() {}\n";
        let fa = check_source("src/cluster/run.rs", src, &all_rules());
        assert_eq!(fa.findings.len(), 1);
        assert_eq!(fa.findings[0].rule, META_UNUSED_ALLOW);
    }

    #[test]
    fn disabled_rule_does_not_fire_and_its_allows_are_not_stale() {
        let src = "fn f() { println!(\"x\"); }\n";
        let only_magic: BTreeSet<&str> = ["ipc-magic-registry"].into_iter().collect();
        let fa = check_source("src/cluster/run.rs", src, &only_magic);
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    }

    #[test]
    fn tests_tree_is_exempt() {
        let src = "fn t() { x.lock().unwrap(); println!(\"y\"); }\n";
        let fa = check_source("tests/cluster.rs", src, &all_rules());
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    }
}
