//! A comment/string-aware lexical model of one Rust source file.
//!
//! This is deliberately *not* a parser (no `syn`, no AST): the audit rules
//! only need to know (a) what the code looks like with comments and string
//! literals blanked out, (b) which string/byte literals appear where,
//! (c) where `// audit:allow(rule) reason` directives sit and which code
//! line each one covers, and (d) which lines are test-only
//! (`#[cfg(test)]` regions, or the whole file under `tests/`).  A single
//! forward scan with a small state machine produces all four, handling
//! nested block comments, raw strings (`r#"…"#`), byte strings, char
//! literals vs. lifetimes, and escapes.

/// One string or byte-string literal: its full contents and the line its
/// opening quote sits on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StrLit {
    pub line: usize,
    pub value: String,
}

/// One `// audit:allow(rule) reason` directive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// Line the comment itself sits on (1-based).
    pub line: usize,
    /// Code line the directive covers: the comment's own line for a
    /// trailing comment, else the next line carrying any code.
    pub target: usize,
    pub rule: String,
    /// Justification text after the closing paren (may be empty — the
    /// driver reports empty reasons as findings).
    pub reason: String,
}

/// Lexical model of one source file.
#[derive(Debug)]
pub struct SourceModel {
    /// The source with every comment and string-literal *content* replaced
    /// by spaces (newlines kept), so byte offsets and line numbers match
    /// the original and naive substring searches cannot be fooled by text
    /// inside strings or comments.
    pub code: String,
    /// All string/byte literals in source order.
    pub strings: Vec<StrLit>,
    /// All `audit:allow` directives.
    pub allows: Vec<Allow>,
    /// `test_lines[l]` (1-based, index 0 unused) — line `l` is inside a
    /// `#[cfg(test)]` region.
    test_lines: Vec<bool>,
}

impl SourceModel {
    /// Lex `src`.  `all_test` marks every line as test code (integration
    /// test files under `tests/`).
    pub fn lex(src: &str, all_test: bool) -> SourceModel {
        let lexed = scan(src);
        let n_lines = src.lines().count() + 2;
        let mut test_lines = vec![all_test; n_lines];
        if !all_test {
            for (start, end) in cfg_test_regions(&lexed.code) {
                let (l0, l1) = (line_of(&lexed.code, start), line_of(&lexed.code, end));
                for flag in test_lines.iter_mut().take(l1.min(n_lines - 1) + 1).skip(l0) {
                    *flag = true;
                }
            }
        }
        let allows = resolve_allows(&lexed.code, lexed.raw_allows);
        SourceModel { code: lexed.code, strings: lexed.strings, allows, test_lines }
    }

    /// Is 1-based line `l` inside test-only code?
    pub fn is_test_line(&self, l: usize) -> bool {
        self.test_lines.get(l).copied().unwrap_or(false)
    }

    /// 1-based line number of byte offset `off` in the code view.
    pub fn line_of(&self, off: usize) -> usize {
        line_of(&self.code, off)
    }
}

fn line_of(s: &str, off: usize) -> usize {
    s.as_bytes()[..off.min(s.len())].iter().filter(|&&b| b == b'\n').count() + 1
}

struct Lexed {
    code: String,
    strings: Vec<StrLit>,
    /// (line, comment text) for every `//` comment.
    raw_allows: Vec<(usize, String)>,
}

/// The forward scan: blank comments and string contents, collect literals
/// and `//` comment text.
fn scan(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut code: Vec<u8> = Vec::with_capacity(b.len());
    let mut strings = Vec::new();
    let mut raw_allows = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    // Push `n` bytes of blank (preserving newlines) while advancing `line`.
    macro_rules! blank {
        ($from:expr, $to:expr) => {
            for k in $from..$to {
                if b[k] == b'\n' {
                    code.push(b'\n');
                    line += 1;
                } else {
                    code.push(b' ');
                }
            }
        };
    }

    while i < b.len() {
        let c = b[i];
        // Line comment.  Doc comments (`///`, `//!`) are documentation,
        // not directives: a rendered `audit:allow` example must not
        // register as a live (and then stale) allow.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let end = memchr_nl(b, i);
            let text = String::from_utf8_lossy(&b[i + 2..end]).into_owned();
            if !text.starts_with('/') && !text.starts_with('!') {
                raw_allows.push((line, text));
            }
            blank!(i, end);
            i = end;
            continue;
        }
        // Block comment (nested).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < b.len() && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank!(i, j);
            i = j;
            continue;
        }
        // Raw (byte) string: r"…", r#"…"#, br##"…"##.
        if c == b'r' || (c == b'b' && i + 1 < b.len() && b[i + 1] == b'r') {
            let r_at = if c == b'r' { i } else { i + 1 };
            if !prev_is_ident(&code) {
                let mut hashes = 0usize;
                let mut j = r_at + 1;
                while j < b.len() && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == b'"' {
                    let open_line = line + count_nl(&b[i..j]);
                    let (content_start, mut k) = (j + 1, j + 1);
                    loop {
                        match b[k..].iter().position(|&x| x == b'"') {
                            Some(p) => {
                                k += p;
                                if b[k + 1..].len() >= hashes
                                    && b[k + 1..k + 1 + hashes].iter().all(|&x| x == b'#')
                                {
                                    break;
                                }
                                k += 1;
                            }
                            None => {
                                k = b.len().saturating_sub(hashes + 1);
                                break;
                            }
                        }
                    }
                    let value = String::from_utf8_lossy(&b[content_start..k]).into_owned();
                    strings.push(StrLit { line: open_line, value });
                    let end = (k + 1 + hashes).min(b.len());
                    blank!(i, end);
                    i = end;
                    continue;
                }
            }
        }
        // Plain (byte) string.
        if c == b'"' || (c == b'b' && i + 1 < b.len() && b[i + 1] == b'"' && !prev_is_ident(&code))
        {
            let open = if c == b'"' { i } else { i + 1 };
            let open_line = line;
            let mut j = open + 1;
            let start = j;
            while j < b.len() {
                match b[j] {
                    b'\\' => j += 2,
                    b'"' => break,
                    _ => j += 1,
                }
            }
            let value = String::from_utf8_lossy(&b[start..j.min(b.len())]).into_owned();
            strings.push(StrLit { line: open_line, value });
            let end = (j + 1).min(b.len());
            blank!(i, end);
            i = end;
            continue;
        }
        // Char literal vs. lifetime: 'x' / '\n' are literals; 'a (no
        // closing quote within two chars) is a lifetime.
        if c == b'\'' {
            let is_char = if i + 1 < b.len() && b[i + 1] == b'\\' {
                true
            } else {
                i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\''
            };
            if is_char {
                let mut j = i + 1;
                if j < b.len() && b[j] == b'\\' {
                    j += 2;
                    while j < b.len() && b[j] != b'\'' {
                        j += 1;
                    }
                } else {
                    j += 1;
                }
                let end = (j + 1).min(b.len());
                blank!(i, end);
                i = end;
                continue;
            }
        }
        if c == b'\n' {
            line += 1;
        }
        code.push(c);
        i += 1;
    }
    Lexed { code: String::from_utf8_lossy(&code).into_owned(), strings, raw_allows }
}

fn memchr_nl(b: &[u8], from: usize) -> usize {
    b[from..].iter().position(|&x| x == b'\n').map_or(b.len(), |p| from + p)
}

fn count_nl(b: &[u8]) -> usize {
    b.iter().filter(|&&x| x == b'\n').count()
}

/// Was the previous code byte part of an identifier (so `r`/`b` here is a
/// suffix of a name like `ptr`, not a raw-string sigil)?
fn prev_is_ident(code: &[u8]) -> bool {
    code.last().is_some_and(|&c| c.is_ascii_alphanumeric() || c == b'_')
}

/// Parse `audit:allow(rule) reason` out of comment texts and resolve each
/// directive's target line against the code view.
fn resolve_allows(code: &str, raw: Vec<(usize, String)>) -> Vec<Allow> {
    let lines: Vec<&str> = code.lines().collect();
    let has_code = |l: usize| lines.get(l - 1).is_some_and(|s| !s.trim().is_empty());
    let mut out = Vec::new();
    for (line, text) in raw {
        let Some(at) = text.find("audit:allow(") else { continue };
        let rest = &text[at + "audit:allow(".len()..];
        let Some(close) = rest.find(')') else {
            out.push(Allow { line, target: line, rule: String::new(), reason: String::new() });
            continue;
        };
        let rule = rest[..close].trim().to_string();
        let reason = rest[close + 1..].trim().trim_start_matches([':', '-']).trim().to_string();
        // Trailing comment → covers its own line; otherwise the next line
        // that carries code (skipping further comment-only lines).
        let target = if has_code(line) {
            line
        } else {
            (line + 1..=lines.len()).find(|&l| has_code(l)).unwrap_or(line)
        };
        out.push(Allow { line, target, rule, reason });
    }
    out
}

/// Byte ranges of `#[cfg(test)]`-gated items in the code view: from the
/// attribute to the matching close brace of the item's body.
fn cfg_test_regions(code: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = code[from..].find("#[cfg(test)]") {
        let at = from + p;
        let Some(open_rel) = code[at..].find('{') else { break };
        let open = at + open_rel;
        let mut depth = 0i32;
        let mut end = code.len();
        for (k, ch) in code[open..].char_indices() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = open + k;
                        break;
                    }
                }
                _ => {}
            }
        }
        out.push((at, end));
        from = end.max(at + 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanks_comments_and_strings() {
        let src = "let a = \"Instant::now()\"; // Instant::now()\nlet b = 1;\n";
        let m = SourceModel::lex(src, false);
        assert!(!m.code.contains("Instant"), "code view: {}", m.code);
        assert_eq!(m.code.len(), src.len());
        assert_eq!(m.strings.len(), 1);
        assert_eq!(m.strings[0].value, "Instant::now()");
        assert_eq!(m.strings[0].line, 1);
    }

    #[test]
    fn raw_and_byte_strings_collected() {
        let src = "let m = b\"RTR4\";\nlet r = r#\"he \"quoted\" re\"#;\nlet p = br\"RSV2\";\n";
        let m = SourceModel::lex(src, false);
        let vals: Vec<&str> = m.strings.iter().map(|s| s.value.as_str()).collect();
        assert_eq!(vals, vec!["RTR4", "he \"quoted\" re", "RSV2"]);
        assert_eq!(m.strings[1].line, 2);
        assert!(!m.code.contains("RTR4"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '\\n'; let q = '\"'; c }\nlet s = \"x\";\n";
        let m = SourceModel::lex(src, false);
        // The '"' char literal must not open a string: the real string on
        // line 2 is still collected as its own literal.
        assert_eq!(m.strings.len(), 1);
        assert_eq!(m.strings[0].line, 2);
        assert!(m.code.contains("fn f<'a>"), "lifetime kept: {}", m.code);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ let x = 1;\n";
        let m = SourceModel::lex(src, false);
        assert!(!m.code.contains("outer"));
        assert!(m.code.contains("let x = 1;"));
    }

    #[test]
    fn allow_directives_trailing_and_preceding() {
        let src = "\
let a = now(); // audit:allow(wall-clock-in-virtual-path) RTT is wall time
// audit:allow(printing-outside-log) protocol announce
println!(\"x\");
";
        let m = SourceModel::lex(src, false);
        assert_eq!(m.allows.len(), 2);
        assert_eq!(m.allows[0].target, 1);
        assert_eq!(m.allows[0].rule, "wall-clock-in-virtual-path");
        assert_eq!(m.allows[0].reason, "RTT is wall time");
        assert_eq!(m.allows[1].target, 3, "own-line allow covers the next code line");
    }

    #[test]
    fn doc_comments_do_not_carry_directives() {
        let src = "\
//! Example: // audit:allow(printing-outside-log) announce line
/// Same here: // audit:allow(wall-clock-in-virtual-path) RTT
fn f() {}
";
        let m = SourceModel::lex(src, false);
        assert!(m.allows.is_empty(), "{:?}", m.allows);
    }

    #[test]
    fn cfg_test_region_marks_lines() {
        let src = "\
fn real() {}
#[cfg(test)]
mod tests {
    fn t() { x.lock().unwrap(); }
}
fn also_real() {}
";
        let m = SourceModel::lex(src, false);
        assert!(!m.is_test_line(1));
        assert!(m.is_test_line(4));
        assert!(!m.is_test_line(6));
    }

    #[test]
    fn all_test_flag_covers_everything() {
        let m = SourceModel::lex("fn t() {}\n", true);
        assert!(m.is_test_line(1));
    }
}
