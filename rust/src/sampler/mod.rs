//! k-hop neighborhood sampler (DistDGL-style, fanout {10, 25}).
//!
//! Each trainer samples minibatches from its partition's training seeds:
//! for every target node draw `fanout[0]` hop-1 neighbors, then `fanout[1]`
//! hop-2 neighbors of each — *with replacement when the degree is short*, so
//! the result is a dense padded tensor matching the AOT artifact shapes
//! (`python/compile/aot.py`).  The sampler also splits the sampled frontier
//! into local vs remote nodes, which drives all communication accounting.
//!
//! Hot path notes (§Perf): neighbor draws use an allocation-free partial
//! Floyd sampler (k ≤ 25, duplicate check is a linear scan over the k
//! already-chosen ids — cache-resident); unique-node extraction uses an
//! epoch-stamped scratch array instead of sorting the full 64k-sample set.
//! Before/after in EXPERIMENTS.md §Perf.

use crate::graph::Csr;
use crate::partition::Partition;
use crate::util::rng::{derive_seed, Pcg32};

/// A sampled 2-hop minibatch, padded to `(batch, fanout1, fanout2)`.
#[derive(Debug, Clone)]
pub struct Minibatch {
    /// Target (seed) nodes; length ≤ batch size (last minibatch is short).
    pub targets: Vec<u32>,
    /// Hop-1 sample, row-major `[targets.len() × fanout1]`.
    pub hop1: Vec<u32>,
    /// Hop-2 sample, row-major `[targets.len() × fanout1 × fanout2]`.
    pub hop2: Vec<u32>,
    pub fanout1: usize,
    pub fanout2: usize,
    /// Unique sampled nodes that are *remote* to this partition (sorted).
    pub unique_remote: Vec<u32>,
    /// Unique sampled nodes that are local (sorted).
    pub unique_local: Vec<u32>,
}

impl Minibatch {
    pub fn num_sampled(&self) -> usize {
        self.targets.len() + self.hop1.len() + self.hop2.len()
    }
}

/// Per-trainer sampler state: the shuffled seed order for the epoch.
#[derive(Debug, Clone)]
pub struct Sampler {
    pub part_id: usize,
    pub batch_size: usize,
    pub fanout1: usize,
    pub fanout2: usize,
    seed: u64,
    /// Epoch-stamped scratch for unique-node extraction (stamp[v] == token
    /// iff v was seen this minibatch) — avoids sorting the full sample.
    stamp: std::cell::RefCell<(Vec<u32>, u32)>,
}

impl Sampler {
    pub fn new(part_id: usize, batch_size: usize, fanout1: usize, fanout2: usize, seed: u64) -> Sampler {
        assert!(batch_size > 0 && fanout1 > 0 && fanout2 > 0);
        Sampler {
            part_id,
            batch_size,
            fanout1,
            fanout2,
            seed,
            stamp: std::cell::RefCell::new((Vec::new(), 0)),
        }
    }

    /// Number of minibatches per epoch for this trainer.
    pub fn minibatches_per_epoch(&self, train_nodes: usize) -> usize {
        train_nodes.div_ceil(self.batch_size).max(1)
    }

    /// Epoch-shuffled training seeds (deterministic in (sampler seed, epoch)).
    pub fn epoch_order(&self, train_nodes: &[u32], epoch: usize) -> Vec<u32> {
        let mut order = train_nodes.to_vec();
        let mut rng = Pcg32::new(derive_seed(self.seed, &[epoch as u64, 0xE0]));
        rng.shuffle(&mut order);
        order
    }

    /// Sample minibatch `mb` of `epoch`.
    pub fn sample(
        &self,
        csr: &Csr,
        part: &Partition,
        epoch_order: &[u32],
        epoch: usize,
        mb: usize,
    ) -> Minibatch {
        let lo = mb * self.batch_size;
        let hi = ((mb + 1) * self.batch_size).min(epoch_order.len());
        let targets: Vec<u32> = if lo < hi {
            epoch_order[lo..hi].to_vec()
        } else {
            Vec::new()
        };
        let mut rng = Pcg32::new(derive_seed(
            self.seed,
            &[epoch as u64, mb as u64, self.part_id as u64],
        ));
        let b = targets.len();
        let mut hop1 = Vec::with_capacity(b * self.fanout1);
        for &t in &targets {
            sample_neighbors(csr, t, self.fanout1, &mut rng, &mut hop1);
        }
        let mut hop2 = Vec::with_capacity(hop1.len() * self.fanout2);
        for &h in &hop1 {
            sample_neighbors(csr, h, self.fanout2, &mut rng, &mut hop2);
        }
        // Unique local/remote split via the epoch-stamped scratch: O(total)
        // with no sort of the full sample (§Perf L3-1).
        let mut guard = self.stamp.borrow_mut();
        let (stamp, token) = &mut *guard;
        if stamp.len() < csr.num_nodes() {
            stamp.resize(csr.num_nodes(), 0);
        }
        *token = token.wrapping_add(1);
        if *token == 0 {
            stamp.iter_mut().for_each(|s| *s = 0);
            *token = 1;
        }
        let tok = *token;
        let (mut unique_local, mut unique_remote) = (Vec::new(), Vec::new());
        let mut visit = |v: u32| {
            let slot = &mut stamp[v as usize];
            if *slot != tok {
                *slot = tok;
                if part.owner_of(v) == self.part_id {
                    unique_local.push(v);
                } else {
                    unique_remote.push(v);
                }
            }
        };
        for &v in &targets {
            visit(v);
        }
        for &v in &hop1 {
            visit(v);
        }
        for &v in &hop2 {
            visit(v);
        }
        drop(guard);
        unique_local.sort_unstable();
        unique_remote.sort_unstable();
        Minibatch {
            targets,
            hop1,
            hop2,
            fanout1: self.fanout1,
            fanout2: self.fanout2,
            unique_remote,
            unique_local,
        }
    }
}

/// Draw `k` neighbors of `v` (without replacement when degree allows,
/// repeating otherwise so the row is always dense).
#[inline]
fn sample_neighbors(csr: &Csr, v: u32, k: usize, rng: &mut Pcg32, out: &mut Vec<u32>) {
    let neigh = csr.neighbors(v);
    let d = neigh.len();
    if d == 0 {
        // Isolated node (shouldn't occur post-densify): self-pad.
        out.extend(std::iter::repeat(v).take(k));
    } else if d <= k {
        // Take all, then pad by cycling.
        for i in 0..k {
            out.push(neigh[i % d]);
        }
    } else {
        // Partial Floyd sampling, allocation-free: duplicate detection is a
        // linear scan over the ≤ k ids already appended this row (k ≤ 25 in
        // every paper config, so the scan stays cache-resident).
        let row_start = out.len();
        for j in (d - k)..d {
            let t = rng.below(j as u64 + 1) as usize;
            let cand = neigh[t];
            if out[row_start..].contains(&cand) {
                out.push(neigh[j]);
            } else {
                out.push(cand);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{densify_isolated, generate, RmatParams};
    use crate::partition::{partition, Method};

    fn setup() -> (Csr, Partition) {
        let mut rng = Pcg32::new(4);
        let csr = generate(
            &RmatParams {
                a: 0.57, b: 0.19, c: 0.19, num_nodes: 1500, num_edges: 9000, permute: true,
            },
            &mut rng,
        );
        let csr = densify_isolated(&csr, &mut rng);
        let part = partition(&csr, 4, Method::MetisLike, 1);
        (csr, part)
    }

    #[test]
    fn dense_padded_shapes() {
        let (csr, part) = setup();
        let s = Sampler::new(0, 32, 5, 7, 9);
        let train = part.local_nodes[0].clone();
        let order = s.epoch_order(&train, 0);
        let mb = s.sample(&csr, &part, &order, 0, 0);
        assert_eq!(mb.targets.len(), 32);
        assert_eq!(mb.hop1.len(), 32 * 5);
        assert_eq!(mb.hop2.len(), 32 * 5 * 7);
    }

    #[test]
    fn short_last_minibatch() {
        let (csr, part) = setup();
        let s = Sampler::new(0, 32, 4, 4, 9);
        let train: Vec<u32> = part.local_nodes[0][..40].to_vec();
        let order = s.epoch_order(&train, 0);
        assert_eq!(s.minibatches_per_epoch(train.len()), 2);
        let mb1 = s.sample(&csr, &part, &order, 0, 1);
        assert_eq!(mb1.targets.len(), 8);
        assert_eq!(mb1.hop1.len(), 8 * 4);
        let mb2 = s.sample(&csr, &part, &order, 0, 2);
        assert!(mb2.targets.is_empty());
    }

    #[test]
    fn sampled_nodes_are_neighbors() {
        let (csr, part) = setup();
        let s = Sampler::new(1, 16, 3, 3, 5);
        let train = part.local_nodes[1].clone();
        let order = s.epoch_order(&train, 2);
        let mb = s.sample(&csr, &part, &order, 2, 0);
        for (i, &t) in mb.targets.iter().enumerate() {
            for j in 0..3 {
                let h = mb.hop1[i * 3 + j];
                assert!(
                    csr.neighbors(t).contains(&h) || h == t,
                    "hop1 {h} not neighbor of {t}"
                );
            }
        }
    }

    #[test]
    fn local_remote_split_correct() {
        let (csr, part) = setup();
        let s = Sampler::new(2, 16, 4, 4, 5);
        let train = part.local_nodes[2].clone();
        let order = s.epoch_order(&train, 0);
        let mb = s.sample(&csr, &part, &order, 0, 0);
        assert!(mb.unique_local.iter().all(|&v| part.owner_of(v) == 2));
        assert!(mb.unique_remote.iter().all(|&v| part.owner_of(v) != 2));
        assert!(mb.unique_remote.windows(2).all(|w| w[0] < w[1]));
        // Remote nodes must be in the partition's 2-hop halo (the buffer
        // universe for 2-hop sampling).
        let halo2 = part.halo_k(&csr, 2, 2);
        for &v in &mb.unique_remote {
            assert!(halo2.binary_search(&v).is_ok(), "{v} not in 2-hop halo");
        }
        assert!(!mb.unique_remote.is_empty(), "expect cross-partition sampling");
    }

    #[test]
    fn deterministic_per_key() {
        let (csr, part) = setup();
        let s = Sampler::new(0, 16, 4, 4, 77);
        let train = part.local_nodes[0].clone();
        let order = s.epoch_order(&train, 1);
        let a = s.sample(&csr, &part, &order, 1, 3);
        let b = s.sample(&csr, &part, &order, 1, 3);
        assert_eq!(a.hop1, b.hop1);
        assert_eq!(a.hop2, b.hop2);
        let c = s.sample(&csr, &part, &order, 1, 4);
        assert_ne!(a.hop1, c.hop1);
    }

    #[test]
    fn epochs_shuffle_differently() {
        let (_, part) = setup();
        let s = Sampler::new(0, 16, 4, 4, 7);
        let train = part.local_nodes[0].clone();
        assert_ne!(s.epoch_order(&train, 0), s.epoch_order(&train, 1));
        assert_eq!(s.epoch_order(&train, 0), s.epoch_order(&train, 0));
    }
}
