//! Protocol magic registry: the single home of every 4-byte `RTR*` /
//! `RSV*` / `RHB*` identification constant.
//!
//! Encoders and decoders must import these — never inline the literal —
//! so a format version bump edits exactly one line and cannot drift
//! between the two sides.  The `ipc-magic-registry` audit rule
//! (`rudder audit`) machine-enforces this: any inline literal matching
//! the family outside this module is a finding.  (Tests that forge stale
//! magics to prove decoders reject them are exempt, as all test code is.)
//!
//! The trailing character is the format version: bump it whenever the
//! payload layout changes so a stale peer fails loudly at the magic
//! check instead of misparsing.

/// Trainer result blob ([`crate::cluster::ipc`]), layout v4.
pub const IPC_TRAINER: &[u8; 4] = b"RTR4";
/// Feature-server result blob ([`crate::cluster::ipc`]), layout v2.
pub const IPC_SERVER: &[u8; 4] = b"RSV2";
/// Allreduce-hub result blob ([`crate::cluster::ipc`]), layout v2.
pub const IPC_HUB: &[u8; 4] = b"RHB2";
/// Binary flight-recorder trace ([`crate::trace::codec`]); versioned by
/// the `u32` that follows it rather than by the magic itself.
pub const TRACE: &[u8; 4] = b"RTRC";
