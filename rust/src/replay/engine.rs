//! The replay drive: re-run the sim state machine from recorded demand
//! and re-enact the cluster protocol synchronously, in one thread.
//!
//! Every counter and virtual event the live cluster produces is a
//! *command-time* function of the message sequence — the want-set dedup,
//! the chunk-cache hit/miss decisions, the per-owner request framing, and
//! the barrier arithmetic all happen when a command is processed, never
//! when a response arrives.  So a single-threaded re-drive that serves
//! each request inline reproduces the live run's virtual streams
//! bit-exactly (the `--check` guarantee), while wall-only events
//! (`batch_flush`, link flushes) simply do not exist offline — the diff
//! projection excludes them anyway.
//!
//! The three live roles map onto three offline models:
//!
//! * trainer thread → the drive loop itself, mirroring
//!   [`crate::cluster::trainer::run_trainer`]'s emission choreography
//!   around [`Trainer::step_minibatch`] with replayed demand;
//! * prefetcher thread → [`PrefetchModel`], mirroring
//!   `spawn_prefetcher`'s command loop (want-set, chunk caches, per-owner
//!   coalescing, req-id counter);
//! * feature server thread → [`ServerModel`], mirroring `server_loop`'s
//!   request accounting and chunk expansion.  No feature rows are
//!   materialized: frame byte lengths are shape-functions only, so
//!   zero-filled payloads of the right dimensions price the wire exactly.

use crate::classifier::trainer::TrainingSet;
use crate::cluster::id_u32;
use crate::cluster::prefetch::{chunk_wire_bytes, ChunkState};
use crate::cluster::wire::{Chunk, Frame};
use crate::cluster::ServerStats;
use crate::error::Result;
use crate::gnn::{AnalyticModel, SageShape};
use crate::graph::Dataset;
use crate::metrics::{RunMetrics, WireStats};
use crate::net::Network;
use crate::partition::Partition;
use crate::sim::trainer::{DemandSource, FetchPlan, RunCtx};
use crate::sim::{self, RunConfig};
use crate::trace::{norm_f64, EventKind, Role, TraceEvent};
use crate::util::fasthash::{FastMap, FastSet};

/// Per-stream event buffer with the same seq/normalization discipline as
/// [`crate::trace::Tracer`], minus its wall clock: replay has no
/// meaningful wall time, so `wall` is recorded as 0 (the diff projection
/// drops it regardless).
struct Emitter {
    role: Role,
    id: u32,
    seq: u64,
    events: Vec<TraceEvent>,
}

impl Emitter {
    fn new(role: Role, id: u32) -> Emitter {
        Emitter { role, id, seq: 0, events: Vec::new() }
    }

    fn emit(&mut self, vclock: f64, kind: EventKind) {
        self.events.push(TraceEvent {
            role: self.role,
            id: self.id,
            seq: self.seq,
            vclock: norm_f64(vclock),
            wall: 0.0,
            kind,
        });
        self.seq += 1;
    }

    /// Close the stream with its terminal `RoleEnd` (so re-emitted traces
    /// pass [`crate::trace::Trace::verify_complete`]) and hand it over.
    fn finish(mut self) -> Vec<TraceEvent> {
        let emitted = self.seq;
        self.emit(0.0, EventKind::RoleEnd { emitted });
        self.events
    }
}

/// Offline stand-in for one feature server: `server_loop`'s accounting
/// and `fetch_serve` events without threads, sockets, or feature rows.
struct ServerModel {
    feat_dim: usize,
    chunk_rows: usize,
    /// Owned node ids in local (row) order — the chunk geometry shared
    /// with `FeatureShard` and the prefetchers' `ChunkLayout`s.
    owned: Vec<u32>,
    local_idx: FastMap<u32, u32>,
    stats: ServerStats,
    ev: Emitter,
}

impl ServerModel {
    fn new(part: &Partition, part_id: usize, feat_dim: usize, chunk_rows: usize) -> ServerModel {
        let owned = part.local_nodes[part_id].clone();
        let mut local_idx = FastMap::default();
        for (i, &n) in owned.iter().enumerate() {
            local_idx.insert(n, id_u32(i));
        }
        ServerModel {
            feat_dim,
            chunk_rows: chunk_rows.max(1),
            owned,
            local_idx,
            stats: ServerStats { part: part_id, ..ServerStats::default() },
            ev: Emitter::new(Role::Server, id_u32(part_id)),
        }
    }

    /// Serve a `FetchReq`: the response echoes the nodes with a row-major
    /// payload whose *shape* prices the wire (values never affect length).
    /// Returns `(nodes in the response, response bytes)`.
    fn serve_rows(&mut self, req_id: u64, from: u32, nodes: &[u32], req_len: u64) -> (u64, u64) {
        let resp = Frame::FetchResp {
            req_id,
            feat_dim: id_u32(self.feat_dim),
            nodes: nodes.to_vec(),
            feats: vec![0.0; nodes.len() * self.feat_dim],
        };
        let served = nodes.len() as u64;
        let out = resp.encoded_len() as u64;
        self.finish_serve(req_id, from, served, out, req_len);
        (served, out)
    }

    /// Serve a `ChunkReq`: expand requested nodes to whole chunks in
    /// first-appearance order, exactly as `FeatureShard::gather_chunks`
    /// (the prefetcher never declares held digests, so nothing is elided).
    fn serve_chunks(&mut self, req_id: u64, from: u32, nodes: &[u32], req_len: u64) -> (u64, u64) {
        let mut seen: FastSet<u32> = FastSet::default();
        let mut chunks: Vec<Chunk> = Vec::new();
        let mut served = 0u64;
        for &n in nodes {
            let Some(&i) = self.local_idx.get(&n) else { continue };
            let c = i as usize / self.chunk_rows;
            if !seen.insert(id_u32(c)) {
                continue;
            }
            let start = c * self.chunk_rows;
            let end = (start + self.chunk_rows).min(self.owned.len());
            served += (end - start) as u64;
            chunks.push(Chunk {
                digest: 0,
                nodes: self.owned[start..end].to_vec(),
                feats: vec![0.0; (end - start) * self.feat_dim],
            });
        }
        let resp = Frame::ChunkResp {
            req_id,
            feat_dim: id_u32(self.feat_dim),
            refs: Vec::new(),
            chunks,
        };
        let out = resp.encoded_len() as u64;
        self.finish_serve(req_id, from, served, out, req_len);
        (served, out)
    }

    fn finish_serve(&mut self, req_id: u64, from: u32, served: u64, out: u64, req_len: u64) {
        self.stats.bytes_in += req_len;
        self.stats.requests += 1;
        self.stats.nodes_served += served;
        self.stats.bytes_out += out;
        self.ev.emit(0.0, EventKind::FetchServe { req_id, from, nodes: served, bytes: out });
    }
}

/// Offline stand-in for one prefetcher: `spawn_prefetcher`'s command-time
/// state — want-set, per-link chunk caches, per-owner coalescing buckets,
/// the single req-id counter — with responses served inline.
struct PrefetchModel {
    trainer_id: usize,
    /// Mirror of `FeatureStore`'s want-set: the only store state that
    /// feeds counters (`begin_fetch` dedup / `evict` removal).
    want: FastSet<u32>,
    chunks: Option<ChunkState>,
    req_id: u64,
    groups: Vec<Vec<u32>>,
    stats: WireStats,
    ev: Emitter,
}

impl PrefetchModel {
    fn new(
        trainer_id: usize,
        part: &Partition,
        feat_dim: usize,
        chunk_rows: usize,
        cache_bytes: u64,
    ) -> PrefetchModel {
        let n = part.num_parts;
        let mut stats = WireStats::default();
        stats.fetch_latency.resize_with(n, Default::default);
        let chunks = (cache_bytes > 0)
            .then(|| ChunkState::build(part, feat_dim, chunk_rows.max(1), cache_bytes));
        PrefetchModel {
            trainer_id,
            want: FastSet::default(),
            chunks,
            req_id: 0,
            groups: vec![Vec::new(); n],
            stats,
            ev: Emitter::new(Role::Prefetcher, id_u32(trainer_id)),
        }
    }

    /// Process one `PrefetchMsg::Fetch` command: dedup against the
    /// want-set, consult the chunk caches, coalesce per owner, issue one
    /// request frame per non-empty owner group, and take the response
    /// round trip inline.
    fn fetch(&mut self, nodes: &[u32], part: &Partition, servers: &mut [ServerModel]) {
        let mut to_req = Vec::new();
        for &n in nodes {
            if self.want.contains(&n) {
                self.stats.nodes_deduped += 1;
            } else {
                self.want.insert(n);
                to_req.push(n);
            }
        }
        match self.chunks.as_mut() {
            Some(cs) => {
                let mut hit_nodes = vec![0u64; servers.len()];
                let mut miss_chunks = vec![0u64; servers.len()];
                for &n in &to_req {
                    let owner = part.owner_of(n);
                    let Some((chunk, _)) = cs.layouts[owner].slot_of(n) else {
                        self.groups[owner].push(n);
                        continue;
                    };
                    if cs.caches[owner].touch(chunk) {
                        hit_nodes[owner] += 1;
                        self.stats.chunks_hit += 1;
                        self.stats.bytes_saved_cache += 4 + 4 * cs.dim as u64;
                    } else {
                        let bytes = chunk_wire_bytes(cs.layouts[owner].rows_in(chunk), cs.dim);
                        cs.caches[owner].admit(chunk, bytes);
                        miss_chunks[owner] += 1;
                        self.stats.chunks_fetched += 1;
                        self.groups[owner].push(n);
                    }
                }
                for owner in 0..servers.len() {
                    if hit_nodes[owner] > 0 {
                        self.ev.emit(0.0, EventKind::CacheHit {
                            owner: id_u32(owner),
                            nodes: hit_nodes[owner],
                        });
                    }
                    if miss_chunks[owner] > 0 {
                        self.ev.emit(0.0, EventKind::CacheMiss {
                            owner: id_u32(owner),
                            chunks: miss_chunks[owner],
                            nodes: self.groups[owner].len() as u64,
                        });
                    }
                }
            }
            None => {
                for &n in &to_req {
                    self.groups[part.owner_of(n)].push(n);
                }
            }
        }
        for owner in 0..servers.len() {
            if self.groups[owner].is_empty() {
                continue;
            }
            let batch = std::mem::take(&mut self.groups[owner]);
            let batch_nodes = batch.len() as u64;
            let from = id_u32(self.trainer_id);
            let rid = self.req_id;
            let frame = if self.chunks.is_some() {
                Frame::ChunkReq { req_id: rid, from, nodes: batch, have: Vec::new() }
            } else {
                Frame::FetchReq { req_id: rid, from, nodes: batch }
            };
            let req_len = frame.encoded_len() as u64;
            self.stats.nodes_requested += batch_nodes;
            self.ev.emit(0.0, EventKind::FetchIssue {
                req_id: rid,
                owner: id_u32(owner),
                nodes: batch_nodes,
                bytes: req_len,
            });
            self.req_id += 1;
            self.stats.req_frames += 1;
            self.stats.req_bytes += req_len;
            // Inline round trip: no decision downstream depends on when
            // the response lands, only that it does.
            let (got_nodes, resp_len) = match &frame {
                Frame::ChunkReq { nodes, .. } => {
                    servers[owner].serve_chunks(rid, from, nodes, req_len)
                }
                Frame::FetchReq { nodes, .. } => {
                    servers[owner].serve_rows(rid, from, nodes, req_len)
                }
                _ => unreachable!("request frames only"),
            };
            self.stats.resp_frames += 1;
            self.stats.resp_bytes += resp_len;
            self.stats.nodes_received += got_nodes;
            self.ev.emit(0.0, EventKind::FetchResponse {
                req_id: rid,
                nodes: got_nodes,
                bytes: resp_len,
            });
        }
    }

    /// Process one `PrefetchMsg::Evict` command.
    fn evict(&mut self, nodes: &[u32]) {
        self.ev.emit(0.0, EventKind::Evict { nodes: nodes.len() as u64 });
        for &n in nodes {
            self.want.remove(&n);
        }
    }
}

/// Everything one re-drive produces: per-trainer sim metrics, the wire
/// and server counters of the modelled protocol, the re-emitted virtual
/// event streams, and the fetch-blocked accounting for the what-if
/// report.
pub(crate) struct DriveResult {
    pub per_trainer: Vec<RunMetrics>,
    pub wire: Vec<WireStats>,
    pub servers: Vec<ServerStats>,
    pub events: Vec<TraceEvent>,
    /// Σ fetch-blocked virtual seconds over all trainers' active steps.
    pub exposed_vsecs: f64,
    /// Σ step virtual seconds over all recorded minibatches.
    pub step_vsecs: f64,
    pub rounds: u64,
}

/// Re-drive `cfg` over the recorded per-trainer demand, mirroring
/// `run_trainer`'s choreography round for round.
pub(crate) fn drive(
    cfg: &RunConfig,
    ds: &Dataset,
    part: &Partition,
    demands: &[DemandSource],
    offline: Option<&TrainingSet>,
) -> Result<DriveResult> {
    let n = cfg.num_trainers;
    crate::ensure!(n >= 1, "replay: need at least one trainer");
    crate::ensure!(
        n == part.num_parts,
        "replay: {n} trainers but {} partitions",
        part.num_parts
    );
    crate::ensure!(
        demands.len() == n,
        "replay: demand for {} trainers but config has {n}",
        demands.len()
    );

    // Identical model constants to `run_trainer` (bit-identity requires it).
    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), n);
    let compute = AnalyticModel::new(cfg.compute.clone(), shape);
    let allreduce = net.allreduce_time(shape.param_bytes());
    let max_mb = sim::max_minibatches_per_epoch(cfg, ds, part);
    let ctx = RunCtx {
        ds,
        part,
        net,
        compute,
        mode: cfg.mode,
        epochs_total: cfg.epochs,
        total_minibatches: (max_mb * cfg.epochs) as u64,
    };

    let mut trainers = Vec::with_capacity(n);
    let mut tev = Vec::with_capacity(n);
    let mut pf = Vec::with_capacity(n);
    let mut servers = Vec::with_capacity(n);
    for p in 0..n {
        let mut t = sim::build_trainer(cfg, ds, part, p, offline);
        t.fetch_plan = Some(FetchPlan::default());
        t.demand = Some(demands[p].clone());
        trainers.push(t);
        tev.push(Emitter::new(Role::Trainer, id_u32(p)));
        pf.push(PrefetchModel::new(
            p,
            part,
            ds.spec.feat_dim,
            cfg.chunk_rows,
            cfg.chunk_cache_bytes,
        ));
        servers.push(ServerModel::new(part, p, ds.spec.feat_dim, cfg.chunk_rows));
    }
    let mut hub = Emitter::new(Role::Hub, 0);

    // Warm start (MassiveGNN prepopulation), exactly as `run_trainer`.
    for p in 0..n {
        let warm = trainers[p].buffer.resident_nodes();
        if !warm.is_empty() {
            pf[p].fetch(&warm, part, &mut servers);
        }
    }

    let mut exposed = 0.0f64;
    let mut round: u64 = 0;
    let mut mb_vstarts = vec![0.0f64; n];
    for epoch in 0..cfg.epochs {
        let epoch_vstart: Vec<f64> = trainers.iter().map(|t| t.clock).collect();
        for mb in 0..max_mb {
            for p in 0..n {
                let t = &mut trainers[p];
                mb_vstarts[p] = t.clock;
                tev[p].emit(t.clock, EventKind::MinibatchBegin {
                    epoch: id_u32(epoch),
                    mb: id_u32(mb),
                });
                // Replayed demand: the sampler is never consulted, so the
                // epoch order is irrelevant here.
                let active = t.step_minibatch(&ctx, epoch, mb, &[]);
                if !active {
                    continue;
                }
                let mut plan = t
                    .fetch_plan
                    .replace(FetchPlan::default())
                    .expect("fetch plan armed");
                tev[p].emit(t.clock, EventKind::SampleDemand {
                    epoch: id_u32(epoch),
                    mb: id_u32(mb),
                    targets: plan.targets,
                    sampled: plan.sampled,
                    remote: plan.unique_remote.clone(),
                });
                let admitted_n = plan.admitted.len() as u64;
                let evicted_n = plan.evicted.len() as u64;
                if admitted_n + evicted_n > 0 {
                    tev[p].emit(t.clock, EventKind::Replacement {
                        admitted: admitted_n,
                        evicted: evicted_n,
                    });
                }
                if !plan.admitted.is_empty() {
                    let admitted = std::mem::take(&mut plan.admitted);
                    pf[p].fetch(&admitted, part, &mut servers);
                }
                if !plan.missed.is_empty() {
                    pf[p].fetch(&plan.missed, part, &mut servers);
                }
                tev[p].emit(t.clock, EventKind::FetchWait {
                    nodes: plan.unique_remote.len() as u64,
                    wall_secs: 0.0,
                });
                tev[p].emit(t.clock, EventKind::Compute {
                    virtual_secs: plan.t_ddp,
                    wall_secs: 0.0,
                });
                let mut drop_nodes = plan.evicted;
                for &miss in &plan.missed {
                    if !t.buffer.contains(miss) {
                        drop_nodes.push(miss);
                    }
                }
                if !drop_nodes.is_empty() {
                    pf[p].evict(&drop_nodes);
                }
                exposed += plan.t_exposed;
            }
            // DDP barrier: the hub takes the max clock over every
            // trainer's Allreduce frame and broadcasts it back.
            let max_vclock = trainers.iter().fold(f64::NEG_INFINITY, |m, t| m.max(t.clock));
            hub.emit(max_vclock, EventKind::AllreduceRound {
                round,
                vclock_max: max_vclock,
                trainers: id_u32(n),
            });
            for p in 0..n {
                let t = &mut trainers[p];
                tev[p].emit(t.clock, EventKind::AllreduceWait { round, wall_secs: 0.0 });
                t.clock = max_vclock + allreduce;
                tev[p].emit(t.clock, EventKind::MinibatchEnd {
                    epoch: id_u32(epoch),
                    mb: id_u32(mb),
                    step_vsecs: t.clock - mb_vstarts[p],
                });
            }
            round += 1;
        }
        for (p, t) in trainers.iter_mut().enumerate() {
            t.metrics.epoch_times.push(t.clock - epoch_vstart[p]);
        }
    }

    let step_vsecs: f64 = trainers
        .iter()
        .flat_map(|t| &t.metrics.minibatches)
        .map(|m| m.step_time)
        .sum();
    let mut events: Vec<TraceEvent> = Vec::new();
    for e in tev {
        events.extend(e.finish());
    }
    let mut wire = Vec::with_capacity(n);
    for m in pf {
        events.extend(m.ev.finish());
        wire.push(m.stats);
    }
    let mut server_stats = Vec::with_capacity(n);
    for s in servers {
        events.extend(s.ev.finish());
        server_stats.push(s.stats);
    }
    events.extend(hub.finish());

    Ok(DriveResult {
        per_trainer: trainers.into_iter().map(|t| t.metrics).collect(),
        wire,
        servers: server_stats,
        events,
        exposed_vsecs: exposed,
        step_vsecs,
        rounds: round,
    })
}
