//! Trace-driven replay and what-if controller evaluation.
//!
//! A `rudder-trace/v1` trace records everything needed to re-drive a
//! cluster run without a cluster: [`crate::trace::TraceMeta::config`]
//! embeds the full run config, and each trainer stream carries one
//! [`EventKind::SampleDemand`] per active minibatch — the sampled demand
//! (target count, sampled-node count, deduplicated remote want-set) that
//! the sim state machine consumed.  Replay feeds those records back
//! through [`crate::sim::trainer::Trainer::step_minibatch`] (the sampler
//! is never invoked) and re-enacts the cluster protocol offline
//! ([`engine`]), in two modes:
//!
//! * **Check** ([`check`]): replay under the *same* config and require
//!   the re-emitted virtual streams to be bit-identical to the original
//!   via [`crate::trace::diff`] — the CI gate that the replay engine and
//!   the live runtime never drift apart.  Only emulated-compute traces
//!   can pass: a measured run's `compute`/`minibatch_end` events carry
//!   real `t_ddp`, which replay deliberately re-models.
//! * **What-if** ([`replay`] with [`Overrides`], [`sweep`] for a grid):
//!   swap the controller, buffer fraction, or chunk-cache geometry and
//!   re-drive the *recorded* demand under the new policy.  The sampled
//!   demand is a pure function of dataset/seed/partition — none of the
//!   overridable knobs feed it — so the counterfactual is exact, not
//!   approximated.  Results land in a schema-stable JSON report
//!   ([`whatif_json`], `rudder-replay-whatif/v1`).
//!
//! This module is virtual-time-only: no wall clocks anywhere (the
//! `wall-clock-in-virtual-path` audit rule covers `src/replay/`), and no
//! printing — rendering belongs to the CLI.

mod engine;

use crate::classifier::trainer::TrainingSet;
use crate::error::Result;
use crate::graph::Dataset;
use crate::metrics::WireStats;
use crate::partition::Partition;
use crate::sim::trainer::{DemandRecord, DemandSource};
use crate::sim::{self, ControllerSpec, ExperimentResult, RunConfig};
use crate::trace::diff::{diff, DiffReport};
use crate::trace::{EventKind, Role, Trace, TraceMeta};
use crate::util::json::Json;

pub use crate::cluster::ServerStats;

/// Config knobs a what-if replay may swap.  Everything else (dataset,
/// scale, seed, trainer count, batch geometry, epochs) is pinned to the
/// recorded run — those knobs *shape the demand*, and the demand is what
/// the trace recorded.
#[derive(Debug, Clone, Default)]
pub struct Overrides {
    pub controller: Option<ControllerSpec>,
    pub buffer_pct: Option<f64>,
    pub chunk_rows: Option<usize>,
    pub chunk_cache_bytes: Option<u64>,
}

impl Overrides {
    pub fn is_empty(&self) -> bool {
        self.controller.is_none()
            && self.buffer_pct.is_none()
            && self.chunk_rows.is_none()
            && self.chunk_cache_bytes.is_none()
    }

    /// The recorded config with these overrides applied.
    pub fn apply(&self, base: &RunConfig) -> RunConfig {
        let mut cfg = base.clone();
        if let Some(c) = &self.controller {
            cfg.controller = c.clone();
        }
        if let Some(b) = self.buffer_pct {
            cfg.buffer_pct = b;
        }
        if let Some(r) = self.chunk_rows {
            cfg.chunk_rows = r;
        }
        if let Some(b) = self.chunk_cache_bytes {
            cfg.chunk_cache_bytes = b;
        }
        cfg
    }
}

/// A parsed trace ready to re-drive: the embedded config, the rebuilt
/// dataset + partition, and the per-trainer demand records.  Build once
/// ([`load`]), replay many times ([`replay`], [`sweep`]).
pub struct ReplaySetup {
    pub cfg: RunConfig,
    pub meta: TraceMeta,
    pub ds: Dataset,
    pub part: Partition,
    pub max_mb: usize,
    pub demands: Vec<DemandSource>,
    /// Recorded active minibatches (`sample_demand` events) across all
    /// trainers.
    pub recorded_minibatches: usize,
    /// Lazily built offline training set for classifier controllers
    /// (config-independent, exactly as the live cluster builds it).
    offline: std::cell::OnceCell<TrainingSet>,
}

impl ReplaySetup {
    /// Measured-compute traces carry real `t_ddp`; replay re-models it,
    /// so `--check` cannot hold against them.
    pub fn is_measured(&self) -> bool {
        self.meta.compute == "measured"
    }

    fn offline_for(&self, cfg: &RunConfig) -> Option<&TrainingSet> {
        matches!(cfg.controller, ControllerSpec::Classifier { .. }).then(|| {
            self.offline
                .get_or_init(|| crate::eval::harness::offline_training_set(crate::eval::Quality::Quick))
        })
    }
}

/// Parse + validate a trace into a [`ReplaySetup`].
pub fn load(trace: &Trace) -> Result<ReplaySetup> {
    trace.verify_complete()?;
    crate::ensure!(
        !trace.meta.config.is_empty(),
        "trace embeds no run config — recorded by a pre-replay build?"
    );
    let cfg = crate::config::from_toml_str(&trace.meta.config)?;
    crate::ensure!(
        cfg.seed == trace.meta.seed,
        "trace meta seed {} disagrees with embedded config seed {}",
        trace.meta.seed,
        cfg.seed
    );
    let (ds, part) = sim::build_cluster(&cfg)?;
    let max_mb = sim::max_minibatches_per_epoch(&cfg, &ds, &part);
    let (demands, recorded) = extract_demands(trace, &cfg, max_mb)?;
    Ok(ReplaySetup {
        cfg,
        meta: trace.meta.clone(),
        ds,
        part,
        max_mb,
        demands,
        recorded_minibatches: recorded,
        offline: std::cell::OnceCell::new(),
    })
}

/// Collect each trainer stream's `sample_demand` events into a
/// [`DemandSource`] indexed `epoch * max_mb + mb`.
fn extract_demands(
    trace: &Trace,
    cfg: &RunConfig,
    max_mb: usize,
) -> Result<(Vec<DemandSource>, usize)> {
    let n = cfg.num_trainers;
    let mut demands: Vec<DemandSource> = (0..n)
        .map(|_| DemandSource {
            max_mb_per_epoch: max_mb,
            records: vec![None; cfg.epochs * max_mb],
        })
        .collect();
    let mut found = 0usize;
    for e in &trace.events {
        if e.role != Role::Trainer {
            continue;
        }
        let EventKind::SampleDemand { epoch, mb, targets, sampled, ref remote } = e.kind else {
            continue;
        };
        let p = e.id as usize;
        crate::ensure!(p < n, "trace demand from trainer {p} but config has {n} trainers");
        let (epoch, mb) = (epoch as usize, mb as usize);
        crate::ensure!(
            epoch < cfg.epochs && mb < max_mb,
            "trace demand at epoch {epoch} mb {mb} outside the config's \
             {} epochs x {max_mb} minibatches",
            cfg.epochs
        );
        let slot = &mut demands[p].records[epoch * max_mb + mb];
        crate::ensure!(
            slot.is_none(),
            "duplicate sample_demand for trainer {p} epoch {epoch} mb {mb}"
        );
        *slot = Some(DemandRecord { targets, sampled, unique_remote: remote.clone() });
        found += 1;
    }
    crate::ensure!(
        found > 0,
        "trace carries no sample_demand events — record one with \
         `rudder cluster --trace <file>` on a replay-capable build"
    );
    Ok((demands, found))
}

/// Outcome of one re-drive: sim-shaped experiment summary, modelled wire
/// and server counters, and the re-emitted trace.
pub struct ReplayRun {
    pub cfg: RunConfig,
    pub experiment: ExperimentResult,
    /// Merged wire counters (sum over the modelled prefetchers).
    pub wire: WireStats,
    pub servers: Vec<ServerStats>,
    pub rounds: u64,
    /// Σ fetch-blocked virtual seconds over all active steps.
    pub fetch_blocked_vsecs: f64,
    /// Σ step virtual seconds over all recorded minibatches.
    pub step_vsecs: f64,
    /// The re-emitted trace (meta `transport = "replay"`), canonically
    /// sorted and `verify_complete`-clean.
    pub trace: Trace,
}

impl ReplayRun {
    /// Fraction of total step time spent blocked on remote features.
    pub fn fetch_blocked_ratio(&self) -> f64 {
        if self.step_vsecs > 0.0 {
            self.fetch_blocked_vsecs / self.step_vsecs
        } else {
            0.0
        }
    }
}

/// Re-drive the recorded demand under the recorded config with
/// `overrides` applied.
pub fn replay(setup: &ReplaySetup, overrides: &Overrides) -> Result<ReplayRun> {
    let cfg = overrides.apply(&setup.cfg);
    let offline = setup.offline_for(&cfg);
    let d = engine::drive(&cfg, &setup.ds, &setup.part, &setup.demands, offline)?;
    let mut wire = WireStats::default();
    for w in &d.wire {
        wire.merge(w);
    }
    // Barrier-synchronized epochs: trainer 0's series is the run-level
    // series, exactly as the cluster orchestrator aggregates it.
    let epoch_times = d
        .per_trainer
        .first()
        .map(|m| m.epoch_times.clone())
        .unwrap_or_default();
    let experiment = ExperimentResult::aggregate(cfg.controller.label(), d.per_trainer, epoch_times);
    let mut trace = Trace::new(TraceMeta {
        label: cfg.controller.label(),
        seed: cfg.seed,
        transport: "replay".to_string(),
        compute: "emulated".to_string(),
        config: crate::config::to_toml(&cfg)?,
    });
    trace.events = d.events;
    trace.sort_canonical();
    Ok(ReplayRun {
        cfg,
        experiment,
        wire,
        servers: d.servers,
        rounds: d.rounds,
        fetch_blocked_vsecs: d.exposed_vsecs,
        step_vsecs: d.step_vsecs,
        trace,
    })
}

/// Bit-identity check: replay the same config and diff the re-emitted
/// virtual streams against the original trace.
pub fn check(setup: &ReplaySetup, original: &Trace) -> Result<(ReplayRun, DiffReport)> {
    let run = replay(setup, &Overrides::default())?;
    let report = diff(original, &run.trace);
    Ok((run, report))
}

/// A controller × buffer grid for `rudder replay sweep`, with optional
/// chunk-geometry overrides applied to every cell.  Empty axes mean
/// "keep the recorded value".
#[derive(Debug, Clone, Default)]
pub struct SweepSpec {
    pub controllers: Vec<ControllerSpec>,
    pub buffers: Vec<f64>,
    pub chunk_rows: Option<usize>,
    pub chunk_cache_bytes: Option<u64>,
}

/// Replay every grid cell in one process (the dataset, partition, and
/// demand extraction are shared across all cells).
pub fn sweep(setup: &ReplaySetup, spec: &SweepSpec) -> Result<Vec<ReplayRun>> {
    let controllers: Vec<Option<ControllerSpec>> = if spec.controllers.is_empty() {
        vec![None]
    } else {
        spec.controllers.iter().cloned().map(Some).collect()
    };
    let buffers: Vec<Option<f64>> = if spec.buffers.is_empty() {
        vec![None]
    } else {
        spec.buffers.iter().copied().map(Some).collect()
    };
    let mut out = Vec::with_capacity(controllers.len() * buffers.len());
    for c in &controllers {
        for b in &buffers {
            let ov = Overrides {
                controller: c.clone(),
                buffer_pct: *b,
                chunk_rows: spec.chunk_rows,
                chunk_cache_bytes: spec.chunk_cache_bytes,
            };
            out.push(replay(setup, &ov)?);
        }
    }
    Ok(out)
}

fn json_u64(v: u64) -> Json {
    Json::Num(v as f64)
}

/// One variant's row of the what-if report: the config knobs that
/// identify it plus every replayed outcome metric.
pub fn variant_json(run: &ReplayRun) -> Json {
    let w = &run.wire;
    let cache_lookups = w.chunks_hit + w.chunks_fetched;
    let cache_hit_pct = if cache_lookups > 0 {
        w.chunks_hit as f64 / cache_lookups as f64 * 100.0
    } else {
        0.0
    };
    Json::obj(vec![
        ("controller", Json::str(run.cfg.controller.spec())),
        ("label", Json::str(&run.experiment.label)),
        ("buffer_pct", Json::num(run.cfg.buffer_pct)),
        ("chunk_rows", json_u64(run.cfg.chunk_rows as u64)),
        ("chunk_cache_bytes", json_u64(run.cfg.chunk_cache_bytes)),
        ("virtual_epoch_secs", Json::num(run.experiment.mean_epoch_time)),
        ("mean_hits_pct", Json::num(run.experiment.mean_hits_pct)),
        ("steady_hits_pct", Json::num(run.experiment.steady_hits_pct)),
        ("fetched_nodes", json_u64(run.experiment.total_comm_nodes)),
        ("payload_bytes", json_u64(run.experiment.total_comm_bytes)),
        ("fetch_blocked_ratio", Json::num(run.fetch_blocked_ratio())),
        ("allreduce_rounds", json_u64(run.rounds)),
        (
            "wire",
            Json::obj(vec![
                ("req_frames", json_u64(w.req_frames)),
                ("req_bytes", json_u64(w.req_bytes)),
                ("resp_frames", json_u64(w.resp_frames)),
                ("resp_bytes", json_u64(w.resp_bytes)),
                ("nodes_requested", json_u64(w.nodes_requested)),
                ("nodes_deduped", json_u64(w.nodes_deduped)),
                ("nodes_received", json_u64(w.nodes_received)),
                ("chunks_hit", json_u64(w.chunks_hit)),
                ("chunks_fetched", json_u64(w.chunks_fetched)),
                ("cache_hit_pct", Json::num(cache_hit_pct)),
                ("bytes_saved_cache", json_u64(w.bytes_saved_cache)),
            ]),
        ),
    ])
}

/// The full `rudder-replay-whatif/v1` document: trace provenance, the
/// same-config baseline, and one entry per what-if variant.  Key order is
/// deterministic (sorted maps) and every number is shortest-round-trip,
/// so the same trace + grid yields byte-identical output.
pub fn whatif_json(meta: &TraceMeta, baseline: &ReplayRun, variants: &[ReplayRun]) -> Json {
    Json::obj(vec![
        ("schema", Json::str("rudder-replay-whatif/v1")),
        (
            "source",
            Json::obj(vec![
                ("label", Json::str(&meta.label)),
                ("seed", json_u64(meta.seed)),
                ("transport", Json::str(&meta.transport)),
                ("compute", Json::str(&meta.compute)),
            ]),
        ),
        ("baseline", variant_json(baseline)),
        ("variants", Json::Arr(variants.iter().map(variant_json).collect())),
    ])
}
