//! Pluggable cluster transport: how encoded [`Frame`]s move between
//! trainers, feature servers, and the allreduce hub.
//!
//! The protocol layer ([`super::prefetch`], [`super::server`],
//! [`super::run`]) speaks only [`FrameSender`] / [`FrameReceiver`] — one
//! whole encoded frame per call — so the same trainer/server/hub loops run
//! unchanged over either backend:
//!
//! * **Channel** — in-process `mpsc` channels carrying `Vec<u8>` frames
//!   (PR 2's runtime).  Framing is trivially preserved by the channel.
//! * **TCP** — `std::net` sockets (loopback or real network).  The byte
//!   stream has no message boundaries, so the receive path runs every read
//!   through a [`FrameAssembler`] that reassembles partial frames split at
//!   arbitrary byte positions (short reads, chopped writes, coalesced
//!   segments).  Fresh connections handshake with [`Frame::Hello`] so
//!   listeners can index the reply route by trainer id.
//!
//! Each trainer-owned link carries a shared [`LinkStats`] cell counting
//! frames/bytes in both directions plus connect retries; snapshots land in
//! [`crate::metrics::WireStats::links`].
//!
//! [`FaultSender`] is the deterministic fault-injection shim: seeded
//! duplicate/reorder of whole frames (any backend) and write chopping
//! (TCP), so reassembly and response-dedup paths are testable without
//! flaky sockets or sleeps.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::metrics::LinkStats;
use crate::util::rng::{derive_seed, Pcg32};

use super::prefetch::PrefetchMsg;
use super::wire::{Frame, MAX_FRAME_BYTES, ROLE_TRAINER};

/// Which backend moves the frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Transport {
    /// In-process `mpsc` channels (single-process runtime).
    #[default]
    Channel,
    /// TCP sockets behind the same wire codec (multi-process capable),
    /// one blocking pump thread per link.
    Tcp,
    /// Nonblocking sockets behind one readiness-polled event loop
    /// ([`super::eventloop`]): every logical link a trainer owns is
    /// multiplexed over a single physical connection via a channel-id
    /// field, and queued frames coalesce into syscall-sized writes.
    Event,
}

impl std::str::FromStr for Transport {
    type Err = crate::error::RudderError;

    fn from_str(s: &str) -> Result<Transport> {
        match s {
            "channel" | "chan" => Ok(Transport::Channel),
            "tcp" | "socket" => Ok(Transport::Tcp),
            "event" | "eventloop" => Ok(Transport::Event),
            _ => crate::bail!("unknown transport '{s}' (valid: channel | tcp | event)"),
        }
    }
}

impl Transport {
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Channel => "channel",
            Transport::Tcp => "tcp",
            Transport::Event => "event",
        }
    }
}

/// Shared per-link counter cell (trainer-side view of one link): a cheap
/// clonable handle whose snapshots land in
/// [`crate::metrics::WireStats::links`].
#[derive(Clone, Default)]
pub struct LinkStatsHandle(Arc<Mutex<LinkStats>>);

impl LinkStatsHandle {
    /// Fresh counter cell for a link to `peer` (channel id 0 — the
    /// per-connection backends overwrite it with the link index).
    pub fn new(peer: impl Into<String>) -> LinkStatsHandle {
        LinkStatsHandle::on_channel(peer, 0)
    }

    /// Fresh counter cell for a link to `peer` riding logical channel
    /// `channel` (the mux tag under the event-loop transport, the link
    /// index elsewhere).
    pub fn on_channel(peer: impl Into<String>, channel: u32) -> LinkStatsHandle {
        LinkStatsHandle(Arc::new(Mutex::new(LinkStats {
            peer: peer.into(),
            channel,
            ..LinkStats::default()
        })))
    }

    /// Copy of the current counters.
    pub fn snapshot(&self) -> LinkStats {
        self.lock().clone()
    }

    /// Count `bytes` sent as one frame.
    pub fn count_sent(&self, bytes: usize) {
        let mut s = self.lock();
        s.frames_sent += 1;
        s.bytes_sent += bytes as u64;
    }

    /// Count `bytes` received as one frame.
    pub fn count_recv(&self, bytes: usize) {
        let mut s = self.lock();
        s.frames_recv += 1;
        s.bytes_recv += bytes as u64;
    }

    /// Lock the counters, recovering from poisoning: every update is a
    /// few integer increments (no tear-able invariant), so a panic in a
    /// pump thread must not turn every later stats read into a cascade
    /// of poisoned-lock panics that hides the original failure.
    pub(crate) fn lock(&self) -> std::sync::MutexGuard<'_, LinkStats> {
        self.0.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// Sending half of a frame link.  One call = one whole encoded frame.
///
/// Contract: `send_frame`/`send_frames` may block on transport
/// backpressure (a full kernel buffer, a full event-loop write queue) but
/// must never drop or reorder frames; per-link FIFO order is what the
/// protocol layer's req-id bookkeeping assumes.  Implementations built on
/// *nonblocking* I/O (the event-loop backend) enqueue the frame and
/// return once it is queued — delivery continues asynchronously, and
/// [`FrameSender::close`] guarantees everything already queued is flushed
/// before the end-of-stream marker.
pub trait FrameSender: Send {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()>;

    /// Send a batch of frames, preserving order — the coalescing entry
    /// point of the contract.  Stream backends pack the batch into one
    /// syscall-sized write; the default just loops [`Self::send_frame`]
    /// (which is also what the fault shim needs: one fault draw per
    /// frame, batched or not).
    fn send_frames(&mut self, frames: &[Vec<u8>]) -> Result<()> {
        for f in frames {
            self.send_frame(f)?;
        }
        Ok(())
    }

    /// Release any frame the link is allowed to be holding back (only the
    /// fault shim holds frames).  Endpoints call this when going idle so
    /// an injected delay reorders frames but can never stall a peer that
    /// is blocked waiting on the held frame.
    fn flush_pending(&mut self) {}
    /// Half-close: signal end-of-stream to the peer (the peer's receiver
    /// returns `Ok(None)` once drained).  Further sends error.
    fn close(&mut self);
}

/// Receiving half of a frame link.  Yields whole frames in send order.
///
/// Contract: receivers are *pull*-style and blocking; readiness-driven
/// backends (the event loop) bridge to this interface by demultiplexing
/// inbound frames onto a per-link inbox that the receiver blocks on, so
/// the protocol layer never sees partial frames or `WouldBlock`.
pub trait FrameReceiver: Send {
    /// Blocking next frame; `Ok(None)` once the peer closed cleanly at a
    /// frame boundary; `Err` on mid-frame EOF or transport failure.
    fn recv_frame(&mut self) -> Result<Option<Vec<u8>>>;
    /// As [`FrameReceiver::recv_frame`], but errors once `timeout` passes
    /// with no complete frame.
    fn recv_frame_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>>;
}

/// Inbox protocol for listener-style endpoints (feature servers, the
/// allreduce hub): connection registration plus decoded frames, already
/// demultiplexed onto one `mpsc` receiver per endpoint.
pub enum NetMsg {
    /// A dialing peer announced itself: the reply route for trainer `id`.
    Register(u32, Box<dyn FrameSender>),
    /// One encoded frame from any registered peer (frames self-identify
    /// their sender: `FetchReq.from`, `Allreduce.part`).
    Frame(Vec<u8>),
}

// ---------------------------------------------------------------------------
// channel backend

/// Channel-backed [`FrameSender`]: wraps frames into the receiving
/// endpoint's inbox message type via a plain `fn` constructor (e.g.
/// `NetMsg::Frame`, `PrefetchMsg::Wire`).
pub struct ChannelSender<T: Send + 'static> {
    tx: Option<Sender<T>>,
    wrap: fn(Vec<u8>) -> T,
    stats: LinkStatsHandle,
    /// Reply links count as *received* on the owning trainer's link cell
    /// (delivery into the trainer-side inbox), mirroring what the TCP
    /// receive path counts on read.
    count_as_recv: bool,
}

impl<T: Send + 'static> ChannelSender<T> {
    /// Request-direction sender: counts `frames_sent`/`bytes_sent`.
    pub fn new(tx: Sender<T>, wrap: fn(Vec<u8>) -> T, stats: LinkStatsHandle) -> Self {
        ChannelSender { tx: Some(tx), wrap, stats, count_as_recv: false }
    }

    /// Reply-direction sender: counts `frames_recv`/`bytes_recv` on the
    /// destination trainer's link cell.
    pub fn delivering(tx: Sender<T>, wrap: fn(Vec<u8>) -> T, stats: LinkStatsHandle) -> Self {
        ChannelSender { tx: Some(tx), wrap, stats, count_as_recv: true }
    }
}

impl<T: Send + 'static> FrameSender for ChannelSender<T> {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        let Some(tx) = &self.tx else {
            crate::bail!("transport: send on closed channel link");
        };
        tx.send((self.wrap)(frame.to_vec()))
            .map_err(|_| crate::err!("transport: peer inbox hung up"))?;
        if self.count_as_recv {
            self.stats.count_recv(frame.len());
        } else {
            self.stats.count_sent(frame.len());
        }
        Ok(())
    }

    fn close(&mut self) {
        self.tx = None;
    }
}

/// Channel-backed [`FrameReceiver`] over a raw `Vec<u8>` inbox (the
/// trainer's hub-reply channel).  Counting happens at the paired
/// [`ChannelSender::delivering`] end, so this side stays count-free.
pub struct ChannelReceiver {
    rx: Receiver<Vec<u8>>,
}

impl ChannelReceiver {
    pub fn new(rx: Receiver<Vec<u8>>) -> Self {
        ChannelReceiver { rx }
    }
}

impl FrameReceiver for ChannelReceiver {
    fn recv_frame(&mut self) -> Result<Option<Vec<u8>>> {
        Ok(self.rx.recv().ok())
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        match self.rx.recv_timeout(timeout) {
            Ok(b) => Ok(Some(b)),
            Err(RecvTimeoutError::Disconnected) => Ok(None),
            Err(RecvTimeoutError::Timeout) => {
                crate::bail!("transport: link receive timed out after {timeout:?}")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// frame reassembly (shared by every stream transport)

/// Incremental length-prefixed frame reassembly over an arbitrary byte
/// stream: bytes go in at whatever granularity the transport delivers
/// (short reads, chopped writes, coalesced segments), whole frames come
/// out.  Pure — no I/O — so the splitting/truncation behavior is
/// property-testable (`tests/wire.rs`).
#[derive(Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler::default()
    }

    /// Feed raw stream bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as a frame.  Non-zero at EOF
    /// means the stream died mid-frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Extract the next complete frame (prefix + body, ready for
    /// [`Frame::decode`]).  `Ok(None)` = need more bytes.  Errors on a
    /// malformed length prefix (empty or oversized body) — the stream is
    /// unrecoverable past that point, never silently resynced.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let body_len =
            u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        crate::ensure!(body_len >= 1, "transport: empty frame body in stream");
        crate::ensure!(
            body_len <= MAX_FRAME_BYTES,
            "transport: frame body {body_len} exceeds cap"
        );
        let total = 4 + body_len;
        if self.buf.len() < total {
            return Ok(None);
        }
        let rest = self.buf.split_off(total);
        let frame = std::mem::replace(&mut self.buf, rest);
        Ok(Some(frame))
    }
}

// ---------------------------------------------------------------------------
// TCP backend

/// TCP-backed [`FrameSender`]: one `write_all` per frame (or chopped into
/// `chop`-byte writes under fault injection, forcing the peer through the
/// partial-frame reassembly path).
pub struct TcpFrameSender {
    stream: Option<TcpStream>,
    chop: usize,
    stats: LinkStatsHandle,
}

impl TcpFrameSender {
    pub fn new(stream: TcpStream, stats: LinkStatsHandle) -> TcpFrameSender {
        let _ = stream.set_nodelay(true);
        TcpFrameSender { stream: Some(stream), chop: 0, stats }
    }

    pub fn with_chop(mut self, chop: usize) -> TcpFrameSender {
        self.chop = chop;
        self
    }
}

impl FrameSender for TcpFrameSender {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        let Some(stream) = &mut self.stream else {
            crate::bail!("transport: send on closed tcp link");
        };
        if self.chop == 0 {
            stream.write_all(frame)?;
        } else {
            for piece in frame.chunks(self.chop) {
                stream.write_all(piece)?;
                stream.flush()?;
            }
        }
        self.stats.count_sent(frame.len());
        Ok(())
    }

    /// Coalesce the batch into one buffer and one `write_all` (one
    /// syscall for typical batch sizes).  Chopped mode keeps the per-frame
    /// path so fault chopping stays byte-identical batched or not.
    fn send_frames(&mut self, frames: &[Vec<u8>]) -> Result<()> {
        if self.chop != 0 || frames.len() < 2 {
            for f in frames {
                self.send_frame(f)?;
            }
            return Ok(());
        }
        let Some(stream) = &mut self.stream else {
            crate::bail!("transport: send on closed tcp link");
        };
        let total: usize = frames.iter().map(Vec::len).sum();
        let mut buf = Vec::with_capacity(total);
        for f in frames {
            buf.extend_from_slice(f);
        }
        stream.write_all(&buf)?;
        for f in frames {
            self.stats.count_sent(f.len());
        }
        Ok(())
    }

    fn close(&mut self) {
        if let Some(stream) = self.stream.take() {
            // Half-close: EOF to the peer's read side; our paired read
            // half (a separate clone of the fd) keeps working.
            let _ = stream.shutdown(Shutdown::Write);
        }
    }
}

/// TCP-backed [`FrameReceiver`]: blocking reads through a
/// [`FrameAssembler`].
pub struct TcpFrameReceiver {
    stream: TcpStream,
    asm: FrameAssembler,
    stats: LinkStatsHandle,
}

impl TcpFrameReceiver {
    pub fn new(stream: TcpStream, stats: LinkStatsHandle) -> TcpFrameReceiver {
        TcpFrameReceiver { stream, asm: FrameAssembler::new(), stats }
    }

    fn count(&self, frame: &[u8]) {
        self.stats.count_recv(frame.len());
    }

    fn at_eof(&self) -> Result<Option<Vec<u8>>> {
        crate::ensure!(
            self.asm.pending() == 0,
            "transport: EOF mid-frame ({} bytes pending)",
            self.asm.pending()
        );
        Ok(None)
    }
}

impl FrameReceiver for TcpFrameReceiver {
    fn recv_frame(&mut self) -> Result<Option<Vec<u8>>> {
        let _ = self.stream.set_read_timeout(None);
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(f) = self.asm.next_frame()? {
                self.count(&f);
                return Ok(Some(f));
            }
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return self.at_eof();
            }
            self.asm.push(&chunk[..n]);
        }
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        let deadline = Instant::now() + timeout;
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(f) = self.asm.next_frame()? {
                self.count(&f);
                return Ok(Some(f));
            }
            let now = Instant::now();
            crate::ensure!(
                now < deadline,
                "transport: link receive timed out after {timeout:?}"
            );
            let _ = self.stream.set_read_timeout(Some(deadline - now));
            match self.stream.read(&mut chunk) {
                Ok(0) => return self.at_eof(),
                Ok(n) => self.asm.push(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    crate::bail!("transport: link receive timed out after {timeout:?}")
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// Dial `addr` with bounded retries (a worker process may beat its peer's
/// listener into existence), send the [`Frame::Hello`] handshake, and
/// return the connected stream.  Retries are counted as `reconnects` on
/// the link cell.
pub fn connect_hello(addr: &str, trainer_id: u32, stats: &LinkStatsHandle) -> Result<TcpStream> {
    let mut last_err = String::new();
    for attempt in 0..100u64 {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                let hello = Frame::Hello { role: ROLE_TRAINER, id: trainer_id }.encode()?;
                (&stream).write_all(&hello)?;
                let mut s = stats.lock();
                s.frames_sent += 1;
                s.bytes_sent += hello.len() as u64;
                s.reconnects += attempt;
                return Ok(stream);
            }
            Err(e) => {
                last_err = e.to_string();
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    Err(crate::err!("transport: connect {addr} failed after retries: {last_err}"))
}

/// Accept exactly `expect` connections on `listener`.  Each must open with
/// a [`Frame::Hello`]; its write half is handed to the owning loop as
/// [`NetMsg::Register`] (wrapped with `chop`-byte fault chopping when
/// non-zero), then every subsequent frame is pumped into `inbox` as
/// [`NetMsg::Frame`].  The thread exits — dropping its `inbox` clones —
/// once all peers disconnected.
pub(crate) fn serve_listener(
    listener: TcpListener,
    expect: usize,
    inbox: Sender<NetMsg>,
    endpoint: &str,
    chop: usize,
) -> JoinHandle<()> {
    let endpoint = endpoint.to_string();
    std::thread::Builder::new()
        .name(format!("rudder-accept-{endpoint}"))
        .spawn(move || {
            let mut pumps: Vec<JoinHandle<()>> = Vec::new();
            let mut registered = 0usize;
            while registered < expect {
                let stream = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(e) => {
                        crate::log_info!("{endpoint}: accept failed: {e}");
                        break;
                    }
                };
                let _ = stream.set_nodelay(true);
                let stats = LinkStatsHandle::new(format!("{endpoint}:peer"));
                let read_half = match stream.try_clone() {
                    Ok(s) => s,
                    Err(e) => {
                        crate::log_info!("{endpoint}: clone failed: {e}");
                        continue;
                    }
                };
                let mut rx = TcpFrameReceiver::new(read_half, stats.clone());
                // Bounded handshake: a connection that never sends Hello
                // (port scanner, miswired worker) must not stall the
                // accept loop — and with it the whole cluster — forever.
                let id = match rx.recv_frame_timeout(Duration::from_secs(30)) {
                    Ok(Some(bytes)) => match Frame::decode(&bytes) {
                        Ok((Frame::Hello { id, .. }, _)) => id,
                        _ => {
                            crate::log_info!("{endpoint}: bad handshake frame");
                            continue;
                        }
                    },
                    _ => {
                        crate::log_info!("{endpoint}: peer closed or stalled before handshake");
                        continue;
                    }
                };
                stats.lock().peer = format!("trainer:{id}");
                let sender = TcpFrameSender::new(stream, stats).with_chop(chop);
                if inbox.send(NetMsg::Register(id, Box::new(sender))).is_err() {
                    break;
                }
                registered += 1;
                pumps.push(pump_frames(
                    rx,
                    inbox.clone(),
                    NetMsg::Frame,
                    format!("{endpoint}-t{id}"),
                ));
            }
            drop(inbox);
            for p in pumps {
                let _ = p.join();
            }
        })
        .expect("spawn accept thread")
}

/// Pump every frame arriving on a TCP link into an `mpsc` inbox, wrapped
/// into the destination's message type (`NetMsg::Frame` for listener
/// endpoints, `PrefetchMsg::Wire` for prefetcher inboxes).  Exits on
/// clean EOF, link error, or a dropped inbox.
pub(crate) fn pump_frames<T: Send + 'static>(
    mut rx: TcpFrameReceiver,
    tx: Sender<T>,
    wrap: fn(Vec<u8>) -> T,
    label: String,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("rudder-pump-{label}"))
        .spawn(move || loop {
            match rx.recv_frame() {
                Ok(Some(bytes)) => {
                    if tx.send(wrap(bytes)).is_err() {
                        break;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    crate::log_info!("{label}: link error: {e}");
                    break;
                }
            }
        })
        .expect("spawn pump thread")
}

/// A trainer's fully-dialed TCP endpoint set: request links to every
/// feature server (responses pumped into the prefetcher inbox) plus both
/// halves of the hub link.
pub(crate) struct TrainerDial {
    /// Request senders, one per feature server, in partition order.
    pub request_links: Vec<Box<dyn FrameSender>>,
    pub hub_tx: Box<dyn FrameSender>,
    pub hub_rx: Box<dyn FrameReceiver>,
    /// Link cells: server links in partition order, then the hub link.
    pub links: Vec<LinkStatsHandle>,
    /// Response pump threads (exit when the servers close their ends).
    pub pumps: Vec<JoinHandle<()>>,
}

/// Dial every feature server and the hub for trainer `trainer_id` —
/// shared by the in-process TCP wiring and the `--role trainer` worker
/// process, so the two runtimes can never drift apart.
pub(crate) fn dial_trainer_links(
    servers: &[String],
    hub: &str,
    trainer_id: u32,
    pf_tx: &Sender<PrefetchMsg>,
) -> Result<TrainerDial> {
    let mut links: Vec<LinkStatsHandle> = Vec::with_capacity(servers.len() + 1);
    let mut request_links: Vec<Box<dyn FrameSender>> = Vec::with_capacity(servers.len());
    let mut pumps = Vec::with_capacity(servers.len());
    for (p, addr) in servers.iter().enumerate() {
        let link = LinkStatsHandle::on_channel(format!("server:{p}"), super::id_u32(p));
        let stream = connect_hello(addr, trainer_id, &link)?;
        let read_half = TcpFrameReceiver::new(stream.try_clone()?, link.clone());
        pumps.push(pump_frames(
            read_half,
            pf_tx.clone(),
            PrefetchMsg::Wire,
            format!("trainer{trainer_id}-server{p}"),
        ));
        request_links.push(Box::new(TcpFrameSender::new(stream, link.clone())));
        links.push(link);
    }
    let hub_link = LinkStatsHandle::on_channel("hub", super::id_u32(servers.len()));
    let hub_stream = connect_hello(hub, trainer_id, &hub_link)?;
    let hub_rx: Box<dyn FrameReceiver> =
        Box::new(TcpFrameReceiver::new(hub_stream.try_clone()?, hub_link.clone()));
    let hub_tx: Box<dyn FrameSender> = Box::new(TcpFrameSender::new(hub_stream, hub_link.clone()));
    links.push(hub_link);
    Ok(TrainerDial { request_links, hub_tx, hub_rx, links, pumps })
}

// ---------------------------------------------------------------------------
// fault injection

/// Deterministic fault schedule for the server→trainer response links.
/// All randomness is a pure function of `seed` and the per-link frame
/// index, so a faulted run is exactly reproducible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    /// Probability a frame is sent twice (exercises response dedup).
    pub dup: f64,
    /// Probability a frame is held back and re-ordered after the next
    /// frame on the same link (exercises delayed-response handling; held
    /// frames flush on the owner's idle turn and on close, never
    /// dropped).
    pub delay: f64,
    /// TCP write chop in bytes (exercises partial-frame reassembly);
    /// 0 disables; ignored by channel links, which are message-preserving.
    pub chop: usize,
}

/// Parse `"seed[:dup[:delay[:chop]]]"`, e.g. `"7:0.25:0.25:9"`.
/// Seed and chop are exact integers (a lossy f64 detour would let a
/// worker's fault schedule silently diverge from the orchestrator's).
impl std::str::FromStr for FaultSpec {
    type Err = crate::error::RudderError;

    fn from_str(s: &str) -> Result<FaultSpec> {
        const SHAPE: &str = "valid shape: seed[:dup[:delay[:chop]]], e.g. 7:0.25:0.25:9";
        let p: Vec<&str> = s.split(':').collect();
        crate::ensure!(p.len() <= 4, "fault spec '{s}' has too many fields ({SHAPE})");
        let rate = |i: usize, default: f64| -> Result<f64> {
            match p.get(i) {
                None => Ok(default),
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|_| crate::err!("bad fault rate '{v}' in '{s}' ({SHAPE})")),
            }
        };
        let seed = p[0]
            .parse::<u64>()
            .map_err(|_| crate::err!("bad fault seed '{}' in '{s}' ({SHAPE})", p[0]))?;
        let chop = match p.get(3) {
            None => 0,
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| crate::err!("bad fault chop '{v}' in '{s}' ({SHAPE})"))?,
        };
        Ok(FaultSpec { seed, dup: rate(1, 0.25)?, delay: rate(2, 0.25)?, chop })
    }
}

/// Fault-injection wrapper around any [`FrameSender`]: seeded duplicate
/// and hold-one-reorder of whole frames.  A held frame is flushed by the
/// next send, by the owner's idle turn ([`FrameSender::flush_pending`]),
/// or by [`FrameSender::close`]/drop — faults reorder and duplicate, they
/// never lose frames.  Every frame's fate (held or not, duplicated or
/// not) is decided by its own draw alone — never by whether an earlier
/// held frame happens to still occupy the slot — so the fault schedule
/// and all downstream counters stay pure functions of the seed even
/// though *when* a held frame is released is timing-dependent.
pub struct FaultSender {
    inner: Box<dyn FrameSender>,
    rng: Pcg32,
    dup: f64,
    delay: f64,
    /// A delayed frame plus its (preserved) duplicate decision.
    held: Option<(Vec<u8>, bool)>,
}

impl FaultSender {
    /// `labels` identify the link (e.g. `[server_part, trainer_id]`) so
    /// every link draws an independent, reproducible schedule.
    pub fn new(inner: Box<dyn FrameSender>, spec: &FaultSpec, labels: &[u64]) -> FaultSender {
        FaultSender {
            inner,
            rng: Pcg32::new(derive_seed(spec.seed, labels)),
            dup: spec.dup,
            delay: spec.delay,
            held: None,
        }
    }

    fn deliver(&mut self, frame: &[u8], dup: bool) -> Result<()> {
        self.inner.send_frame(frame)?;
        if dup {
            self.inner.send_frame(frame)?;
        }
        Ok(())
    }

    fn flush_held(&mut self) {
        if let Some((h, dup)) = self.held.take() {
            let _ = self.deliver(&h, dup);
        }
    }
}

impl FrameSender for FaultSender {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        // Fixed draw order: the fault schedule depends only on the frame
        // index, not on which faults previously fired.
        let dup = self.rng.chance(self.dup);
        let hold = self.rng.chance(self.delay);
        if hold {
            // Make room first: an earlier held frame goes out now, its
            // own dup decision intact.
            self.flush_held();
            self.held = Some((frame.to_vec(), dup));
            return Ok(());
        }
        self.deliver(frame, dup)?;
        self.flush_held();
        Ok(())
    }

    fn flush_pending(&mut self) {
        self.flush_held();
        self.inner.flush_pending();
    }

    fn close(&mut self) {
        self.flush_held();
        self.inner.close();
    }
}

impl Drop for FaultSender {
    fn drop(&mut self) {
        self.flush_held();
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;
    use std::sync::mpsc;

    /// Recording sink for fault-shim tests.
    struct Rec(Arc<Mutex<Vec<Vec<u8>>>>);

    impl FrameSender for Rec {
        fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
            self.0.lock().unwrap().push(frame.to_vec());
            Ok(())
        }
        fn close(&mut self) {}
    }

    #[test]
    fn assembler_reassembles_byte_by_byte() {
        let a = Frame::FetchReq { req_id: 1, from: 0, nodes: vec![7, 8, 9] }.encode().unwrap();
        let b = Frame::Hello { role: ROLE_TRAINER, id: 2 }.encode().unwrap();
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let mut asm = FrameAssembler::new();
        let mut out = Vec::new();
        for &byte in &stream {
            asm.push(&[byte]);
            while let Some(f) = asm.next_frame().unwrap() {
                out.push(f);
            }
        }
        assert_eq!(out, vec![a, b]);
        assert_eq!(asm.pending(), 0);
    }

    #[test]
    fn assembler_rejects_malformed_length() {
        let mut asm = FrameAssembler::new();
        asm.push(&0u32.to_le_bytes()); // empty body
        assert!(asm.next_frame().is_err());
        let mut asm = FrameAssembler::new();
        asm.push(&u32::MAX.to_le_bytes()); // oversized body
        assert!(asm.next_frame().is_err());
    }

    #[test]
    fn channel_link_roundtrip_with_counters() {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        let link = LinkStatsHandle::new("peer");
        let mut s = ChannelSender::new(tx, |v| v, link.clone());
        let frame = Frame::Hello { role: ROLE_TRAINER, id: 1 }.encode().unwrap();
        s.send_frame(&frame).unwrap();
        let mut r = ChannelReceiver::new(rx);
        assert_eq!(r.recv_frame().unwrap().unwrap(), frame);
        s.close();
        assert!(s.send_frame(&frame).is_err());
        assert_eq!(r.recv_frame().unwrap(), None, "closed link yields None");
        let snap = link.snapshot();
        assert_eq!((snap.frames_sent, snap.bytes_sent), (1, frame.len() as u64));
    }

    #[test]
    fn transport_and_fault_specs_parse_via_fromstr() {
        assert_eq!("channel".parse::<Transport>().unwrap(), Transport::Channel);
        assert_eq!("tcp".parse::<Transport>().unwrap(), Transport::Tcp);
        assert_eq!("eventloop".parse::<Transport>().unwrap(), Transport::Event);
        let err = "carrier-pigeon".parse::<Transport>().unwrap_err().to_string();
        assert!(err.contains("channel | tcp | event"), "error enumerates values: {err}");
        let f: FaultSpec = "7:0.5:0.25:9".parse().unwrap();
        assert_eq!(f, FaultSpec { seed: 7, dup: 0.5, delay: 0.25, chop: 9 });
        let f: FaultSpec = "3".parse().unwrap();
        assert_eq!(f, FaultSpec { seed: 3, dup: 0.25, delay: 0.25, chop: 0 });
        let err = "x".parse::<FaultSpec>().unwrap_err().to_string();
        assert!(err.contains("seed[:dup[:delay[:chop]]]"), "error shows shape: {err}");
        assert!("1:2:3:4:5".parse::<FaultSpec>().is_err(), "too many fields");
    }

    #[test]
    fn tcp_send_frames_coalesces_into_one_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let frames: Vec<Vec<u8>> = (0..5u32)
            .map(|i| {
                Frame::FetchReq { req_id: i as u64, from: i, nodes: vec![i, i + 1] }
                    .encode()
                    .unwrap()
            })
            .collect();
        let want = frames.clone();
        let link = LinkStatsHandle::new("peer");
        let batch_link = link.clone();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut tx = TcpFrameSender::new(stream, batch_link);
            tx.send_frames(&frames).unwrap();
            tx.close();
        });
        let stream = TcpStream::connect(&addr).unwrap();
        let mut rx = TcpFrameReceiver::new(stream, LinkStatsHandle::new("server"));
        let mut got = Vec::new();
        while let Some(f) = rx.recv_frame().unwrap() {
            got.push(f);
        }
        server.join().unwrap();
        assert_eq!(got, want, "coalesced batch must reassemble frame-exact");
        let snap = link.snapshot();
        assert_eq!(snap.frames_sent, 5, "counters stay per-frame under coalescing");
        assert_eq!(snap.bytes_sent, want.iter().map(|f| f.len() as u64).sum::<u64>());
    }

    #[test]
    fn tcp_link_roundtrip_with_chopped_writes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let frames: Vec<Vec<u8>> = vec![
            Frame::FetchReq { req_id: 9, from: 1, nodes: (0..300).collect() }.encode().unwrap(),
            Frame::FetchResp {
                req_id: 9,
                feat_dim: 2,
                nodes: vec![4, 5],
                feats: vec![0.5, 1.5, 2.5, 3.5],
            }
            .encode()
            .unwrap(),
        ];
        let want = frames.clone();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let link = LinkStatsHandle::new("client");
            let mut tx = TcpFrameSender::new(stream, link).with_chop(3);
            for f in &frames {
                tx.send_frame(f).unwrap();
            }
            tx.close();
        });
        let link = LinkStatsHandle::new("server");
        let stream = TcpStream::connect(&addr).unwrap();
        let mut rx = TcpFrameReceiver::new(stream, link.clone());
        let mut got = Vec::new();
        while let Some(f) = rx.recv_frame().unwrap() {
            got.push(f);
        }
        server.join().unwrap();
        assert_eq!(got, want, "3-byte chopped writes must reassemble exactly");
        let snap = link.snapshot();
        assert_eq!(snap.frames_recv, 2);
        assert_eq!(snap.bytes_recv, want.iter().map(|f| f.len() as u64).sum::<u64>());
    }

    #[test]
    fn tcp_receive_timeout_errors_then_recovers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (hold_tx, hold_rx) = mpsc::channel::<()>();
        let frame = Frame::Hello { role: ROLE_TRAINER, id: 7 }.encode().unwrap();
        let sent = frame.clone();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            hold_rx.recv().unwrap(); // send nothing until released
            let mut tx = TcpFrameSender::new(stream, LinkStatsHandle::new("client"));
            tx.send_frame(&sent).unwrap();
            tx.close();
        });
        let stream = TcpStream::connect(&addr).unwrap();
        let mut rx = TcpFrameReceiver::new(stream, LinkStatsHandle::new("server"));
        let err = rx.recv_frame_timeout(Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        hold_tx.send(()).unwrap();
        assert_eq!(rx.recv_frame().unwrap().unwrap(), frame);
        assert_eq!(rx.recv_frame().unwrap(), None);
        server.join().unwrap();
    }

    #[test]
    fn fault_sender_duplicates_deterministically() {
        let spec = FaultSpec { seed: 11, dup: 1.0, delay: 0.0, chop: 0 };
        let out = Arc::new(Mutex::new(Vec::new()));
        let mut s = FaultSender::new(Box::new(Rec(out.clone())), &spec, &[0, 1]);
        let f1 = Frame::Hello { role: ROLE_TRAINER, id: 1 }.encode().unwrap();
        s.send_frame(&f1).unwrap();
        assert_eq!(out.lock().unwrap().as_slice(), &[f1.clone(), f1.clone()]);
    }

    #[test]
    fn fault_sender_holds_flushes_and_never_loses() {
        // delay=1.0: every frame is held; each is released by the next
        // send, an idle flush, or close — one-frame delays, zero loss.
        let spec = FaultSpec { seed: 3, dup: 0.0, delay: 1.0, chop: 0 };
        let out = Arc::new(Mutex::new(Vec::new()));
        let mut s = FaultSender::new(Box::new(Rec(out.clone())), &spec, &[0, 0]);
        let f1 = Frame::Hello { role: ROLE_TRAINER, id: 1 }.encode().unwrap();
        let f2 = Frame::Hello { role: ROLE_TRAINER, id: 2 }.encode().unwrap();
        let f3 = Frame::Hello { role: ROLE_TRAINER, id: 3 }.encode().unwrap();
        s.send_frame(&f1).unwrap(); // held
        assert!(out.lock().unwrap().is_empty());
        s.send_frame(&f2).unwrap(); // f1 released to make room, f2 held
        assert_eq!(out.lock().unwrap().as_slice(), &[f1.clone()]);
        s.flush_pending(); // the owner's idle turn releases f2
        assert_eq!(out.lock().unwrap().as_slice(), &[f1.clone(), f2.clone()]);
        s.send_frame(&f3).unwrap(); // held again
        s.close(); // flush on close: nothing is ever lost
        assert_eq!(out.lock().unwrap().as_slice(), &[f1, f2, f3]);
    }

    #[test]
    fn fault_sender_preserves_dup_decision_across_hold() {
        // dup=1.0 + delay=1.0: the frame is held, and its duplicate
        // decision must survive until the flush — dup_frames stays a pure
        // function of the seed no matter when the release happens.
        let spec = FaultSpec { seed: 5, dup: 1.0, delay: 1.0, chop: 0 };
        let out = Arc::new(Mutex::new(Vec::new()));
        let mut s = FaultSender::new(Box::new(Rec(out.clone())), &spec, &[2, 2]);
        let f1 = Frame::Hello { role: ROLE_TRAINER, id: 1 }.encode().unwrap();
        s.send_frame(&f1).unwrap(); // held, dup pending
        assert!(out.lock().unwrap().is_empty());
        s.close();
        assert_eq!(out.lock().unwrap().as_slice(), &[f1.clone(), f1.clone()]);
    }

    #[test]
    fn fault_schedule_is_seed_deterministic() {
        let spec = FaultSpec { seed: 42, dup: 0.5, delay: 0.5, chop: 0 };
        let run = || {
            let out = Arc::new(Mutex::new(Vec::new()));
            let mut s = FaultSender::new(Box::new(Rec(out.clone())), &spec, &[1, 2]);
            for i in 0..50u32 {
                s.send_frame(&Frame::Hello { role: ROLE_TRAINER, id: i }.encode().unwrap())
                    .unwrap();
            }
            s.close();
            let sent = out.lock().unwrap();
            sent.clone()
        };
        assert_eq!(run(), run(), "same seed, same fault schedule");
    }
}
