//! Binary result blobs for the multi-process cluster runtime.
//!
//! `--role` worker processes hand their results back to the orchestrator
//! as [`super::wire::Frame::Result`] payloads over the results TCP link
//! (or, as a manual-debugging fallback, `--out` files): per-trainer
//! `RunMetrics` + `WallStats` + `WireStats` + `MeasuredStats`, per-server
//! `ServerStats`, and the hub's round count.  The encoding is the wire
//! codec's style — little-endian, length-prefixed vectors — with every
//! `f64` carried as raw bits, so parity-checked quantities (virtual
//! clocks, epoch times) survive the process boundary *bit-exactly*, which
//! text formats cannot guarantee.

use crate::error::Result;
use crate::metrics::{
    DecisionRecord, HitsPrediction, LinkStats, MeasuredStats, MinibatchRecord, RunMetrics,
    WireStats,
};
use crate::trace::{codec as trace_codec, TraceEvent};
use crate::util::stats::LogHistogram;

use super::id_u32;
use super::server::ServerStats;
use super::trainer::WallStats;
use super::wire::{len_u32, put_u32, put_u64, Reader};

// Blob magics (format + version in four bytes) live in [`crate::magic`]
// with every other protocol magic — `rudder audit` rejects stray magic
// literals.  v4 added the chunk-cache counters; v3/v2 added the trace
// sections, the per-owner fetch-latency histograms, and the link channel
// ids; stale magics are rejected, not best-effort parsed.
use crate::magic::{IPC_HUB as MAGIC_HUB, IPC_SERVER as MAGIC_SERVER, IPC_TRAINER as MAGIC_TRAINER};

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_bool(out: &mut Vec<u8>, b: bool) {
    out.push(u8::from(b));
}

fn put_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    put_u32(out, len_u32(s.len(), "ipc string")?);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn get_bool(r: &mut Reader) -> Result<bool> {
    match r.u8()? {
        0 => Ok(false),
        1 => Ok(true),
        other => crate::bail!("ipc: bad bool byte {other}"),
    }
}

fn get_str(r: &mut Reader) -> Result<String> {
    let len = r.u32()? as usize;
    let bytes = r.take(len)?;
    String::from_utf8(bytes.to_vec()).map_err(|_| crate::err!("ipc: non-utf8 string"))
}

fn check_magic(r: &mut Reader, magic: &[u8; 4], what: &str) -> Result<()> {
    let got = r.take(4)?;
    crate::ensure!(got == magic, "ipc: bad {what} blob magic {got:?}");
    Ok(())
}

// ---------------------------------------------------------------------------
// field-level codecs

fn put_minibatch(out: &mut Vec<u8>, m: &MinibatchRecord) {
    put_u32(out, id_u32(m.epoch));
    put_u32(out, id_u32(m.minibatch));
    put_u32(out, id_u32(m.trainer));
    put_f64(out, m.hits_pct);
    put_u64(out, m.hits);
    put_u64(out, m.comm_nodes);
    put_u64(out, m.comm_bytes);
    put_u64(out, m.unique_remote);
    put_f64(out, m.buffer_occupancy);
    put_f64(out, m.step_time);
    put_bool(out, m.replaced);
    put_f64(out, m.replaced_frac);
}

fn get_minibatch(r: &mut Reader) -> Result<MinibatchRecord> {
    Ok(MinibatchRecord {
        epoch: r.u32()? as usize,
        minibatch: r.u32()? as usize,
        trainer: r.u32()? as usize,
        hits_pct: r.f64()?,
        hits: r.u64()?,
        comm_nodes: r.u64()?,
        comm_bytes: r.u64()?,
        unique_remote: r.u64()?,
        buffer_occupancy: r.f64()?,
        step_time: r.f64()?,
        replaced: get_bool(r)?,
        replaced_frac: r.f64()?,
    })
}

fn put_decision(out: &mut Vec<u8>, d: &DecisionRecord) {
    put_u32(out, id_u32(d.minibatch));
    put_bool(out, d.replace);
    out.push(match d.prediction {
        None => 0,
        Some(HitsPrediction::Increase) => 1,
        Some(HitsPrediction::Decrease) => 2,
        Some(HitsPrediction::Unchanged) => 3,
    });
    put_bool(out, d.valid_response);
    put_f64(out, d.hits_before);
    match d.hits_after {
        None => put_bool(out, false),
        Some(v) => {
            put_bool(out, true);
            put_f64(out, v);
        }
    }
    put_f64(out, d.latency);
}

fn get_decision(r: &mut Reader) -> Result<DecisionRecord> {
    let minibatch = r.u32()? as usize;
    let replace = get_bool(r)?;
    let prediction = match r.u8()? {
        0 => None,
        1 => Some(HitsPrediction::Increase),
        2 => Some(HitsPrediction::Decrease),
        3 => Some(HitsPrediction::Unchanged),
        other => crate::bail!("ipc: bad prediction tag {other}"),
    };
    let valid_response = get_bool(r)?;
    let hits_before = r.f64()?;
    let hits_after = if get_bool(r)? { Some(r.f64()?) } else { None };
    let latency = r.f64()?;
    Ok(DecisionRecord {
        minibatch,
        replace,
        prediction,
        valid_response,
        hits_before,
        hits_after,
        latency,
    })
}

fn put_metrics(out: &mut Vec<u8>, m: &RunMetrics) -> Result<()> {
    put_u32(out, len_u32(m.minibatches.len(), "minibatch records")?);
    for mb in &m.minibatches {
        put_minibatch(out, mb);
    }
    put_u32(out, len_u32(m.decisions.len(), "decision records")?);
    for d in &m.decisions {
        put_decision(out, d);
    }
    put_u32(out, len_u32(m.epoch_times.len(), "epoch times")?);
    for &t in &m.epoch_times {
        put_f64(out, t);
    }
    Ok(())
}

fn get_metrics(r: &mut Reader) -> Result<RunMetrics> {
    let mut m = RunMetrics::default();
    for _ in 0..r.u32()? {
        m.minibatches.push(get_minibatch(r)?);
    }
    for _ in 0..r.u32()? {
        m.decisions.push(get_decision(r)?);
    }
    for _ in 0..r.u32()? {
        m.epoch_times.push(r.f64()?);
    }
    Ok(m)
}

fn put_wall(out: &mut Vec<u8>, w: &WallStats) -> Result<()> {
    put_f64(out, w.total);
    put_u32(out, len_u32(w.epochs.len(), "epoch walls")?);
    for &e in &w.epochs {
        put_f64(out, e);
    }
    put_f64(out, w.fetch_wait);
    put_f64(out, w.compute);
    put_f64(out, w.barrier);
    put_u64(out, w.minibatches);
    Ok(())
}

fn get_wall(r: &mut Reader) -> Result<WallStats> {
    let mut w = WallStats { total: r.f64()?, ..WallStats::default() };
    for _ in 0..r.u32()? {
        w.epochs.push(r.f64()?);
    }
    w.fetch_wait = r.f64()?;
    w.compute = r.f64()?;
    w.barrier = r.f64()?;
    w.minibatches = r.u64()?;
    Ok(w)
}

fn put_f64_vec(out: &mut Vec<u8>, v: &[f64]) -> Result<()> {
    put_u32(out, len_u32(v.len(), "f64 vec")?);
    for &x in v {
        put_f64(out, x);
    }
    Ok(())
}

fn get_f64_vec(r: &mut Reader) -> Result<Vec<f64>> {
    let mut v = Vec::new();
    for _ in 0..r.u32()? {
        v.push(r.f64()?);
    }
    Ok(v)
}

fn put_measured(out: &mut Vec<u8>, m: &MeasuredStats) -> Result<()> {
    put_f64_vec(out, &m.compute_secs)?;
    put_f64_vec(out, &m.fetch_wait_secs)?;
    put_f64_vec(out, &m.barrier_secs)?;
    put_u32(out, len_u32(m.losses.len(), "losses")?);
    for &l in &m.losses {
        put_u32(out, l.to_bits());
    }
    put_u64(out, m.rows_from_store);
    put_u64(out, m.rows_local);
    put_u64(out, m.rows_fallback);
    put_u64(out, m.grad_bytes);
    put_u64(out, m.param_hash);
    Ok(())
}

fn get_measured(r: &mut Reader) -> Result<MeasuredStats> {
    let mut m = MeasuredStats {
        compute_secs: get_f64_vec(r)?,
        fetch_wait_secs: get_f64_vec(r)?,
        barrier_secs: get_f64_vec(r)?,
        ..MeasuredStats::default()
    };
    for _ in 0..r.u32()? {
        m.losses.push(f32::from_bits(r.u32()?));
    }
    m.rows_from_store = r.u64()?;
    m.rows_local = r.u64()?;
    m.rows_fallback = r.u64()?;
    m.grad_bytes = r.u64()?;
    m.param_hash = r.u64()?;
    Ok(m)
}

fn put_link(out: &mut Vec<u8>, l: &LinkStats) -> Result<()> {
    put_str(out, &l.peer)?;
    put_u32(out, l.channel);
    put_u64(out, l.frames_sent);
    put_u64(out, l.bytes_sent);
    put_u64(out, l.frames_recv);
    put_u64(out, l.bytes_recv);
    put_u64(out, l.reconnects);
    Ok(())
}

fn get_link(r: &mut Reader) -> Result<LinkStats> {
    Ok(LinkStats {
        peer: get_str(r)?,
        channel: r.u32()?,
        frames_sent: r.u64()?,
        bytes_sent: r.u64()?,
        frames_recv: r.u64()?,
        bytes_recv: r.u64()?,
        reconnects: r.u64()?,
    })
}

/// Sparse bucket encoding: most of a log histogram's 128 buckets are
/// empty, so ship `(index, count)` pairs for the occupied ones only.
fn put_hist(out: &mut Vec<u8>, h: &LogHistogram) -> Result<()> {
    let counts = h.bucket_counts();
    let nonzero = counts.iter().filter(|&&c| c != 0).count();
    put_u32(out, len_u32(nonzero, "histogram buckets")?);
    for (i, &c) in counts.iter().enumerate() {
        if c != 0 {
            put_u32(out, id_u32(i));
            put_u64(out, c);
        }
    }
    Ok(())
}

fn get_hist(r: &mut Reader) -> Result<LogHistogram> {
    let mut counts = vec![0u64; LogHistogram::BUCKETS];
    for _ in 0..r.u32()? {
        let i = r.u32()? as usize;
        crate::ensure!(i < counts.len(), "ipc: histogram bucket {i} out of range");
        counts[i] = r.u64()?;
    }
    LogHistogram::from_bucket_counts(counts)
}

fn put_trace(out: &mut Vec<u8>, evs: &[TraceEvent]) -> Result<()> {
    put_u32(out, len_u32(evs.len(), "trace events")?);
    for e in evs {
        trace_codec::put_event(out, e)?;
    }
    Ok(())
}

fn get_trace(r: &mut Reader) -> Result<Vec<TraceEvent>> {
    let n = r.u32()? as usize;
    let mut evs = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        evs.push(trace_codec::get_event(r)?);
    }
    Ok(evs)
}

fn put_wire(out: &mut Vec<u8>, w: &WireStats) -> Result<()> {
    put_u64(out, w.req_frames);
    put_u64(out, w.req_bytes);
    put_u64(out, w.resp_frames);
    put_u64(out, w.resp_bytes);
    put_u64(out, w.nodes_requested);
    put_u64(out, w.nodes_deduped);
    put_u64(out, w.nodes_received);
    put_u64(out, w.dup_frames);
    put_u64(out, w.bad_frames);
    put_u64(out, w.chunks_hit);
    put_u64(out, w.chunks_fetched);
    put_u64(out, w.bytes_saved_cache);
    put_u32(out, len_u32(w.links.len(), "links")?);
    for l in &w.links {
        put_link(out, l)?;
    }
    put_u32(out, len_u32(w.fetch_latency.len(), "latency histograms")?);
    for h in &w.fetch_latency {
        put_hist(out, h)?;
    }
    Ok(())
}

fn get_wire(r: &mut Reader) -> Result<WireStats> {
    let mut w = WireStats {
        req_frames: r.u64()?,
        req_bytes: r.u64()?,
        resp_frames: r.u64()?,
        resp_bytes: r.u64()?,
        nodes_requested: r.u64()?,
        nodes_deduped: r.u64()?,
        nodes_received: r.u64()?,
        dup_frames: r.u64()?,
        bad_frames: r.u64()?,
        chunks_hit: r.u64()?,
        chunks_fetched: r.u64()?,
        bytes_saved_cache: r.u64()?,
        links: Vec::new(),
        fetch_latency: Vec::new(),
    };
    for _ in 0..r.u32()? {
        w.links.push(get_link(r)?);
    }
    for _ in 0..r.u32()? {
        w.fetch_latency.push(get_hist(r)?);
    }
    Ok(w)
}

// ---------------------------------------------------------------------------
// blob-level API

/// One trainer worker's full result: metrics + stats + the trainer's and
/// its prefetcher's trace events (empty when tracing is off).
pub fn encode_trainer_result(
    metrics: &RunMetrics,
    wall: &WallStats,
    wire: &WireStats,
    measured: &MeasuredStats,
    trace: &[TraceEvent],
) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(4096);
    out.extend_from_slice(MAGIC_TRAINER);
    put_metrics(&mut out, metrics)?;
    put_wall(&mut out, wall)?;
    put_wire(&mut out, wire)?;
    put_measured(&mut out, measured)?;
    put_trace(&mut out, trace)?;
    Ok(out)
}

type TrainerResult = (RunMetrics, WallStats, WireStats, MeasuredStats, Vec<TraceEvent>);

pub fn decode_trainer_result(buf: &[u8]) -> Result<TrainerResult> {
    let mut r = Reader { b: buf, pos: 0 };
    check_magic(&mut r, MAGIC_TRAINER, "trainer")?;
    let metrics = get_metrics(&mut r)?;
    let wall = get_wall(&mut r)?;
    let wire = get_wire(&mut r)?;
    let measured = get_measured(&mut r)?;
    let trace = get_trace(&mut r)?;
    crate::ensure!(r.pos == buf.len(), "ipc: {} trailing bytes", buf.len() - r.pos);
    Ok((metrics, wall, wire, measured, trace))
}

pub fn encode_server_stats(s: &ServerStats, trace: &[TraceEvent]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(MAGIC_SERVER);
    put_u32(&mut out, id_u32(s.part));
    put_u64(&mut out, s.requests);
    put_u64(&mut out, s.nodes_served);
    put_u64(&mut out, s.bytes_in);
    put_u64(&mut out, s.bytes_out);
    put_u64(&mut out, s.bad_frames);
    put_trace(&mut out, trace)?;
    Ok(out)
}

pub fn decode_server_stats(buf: &[u8]) -> Result<(ServerStats, Vec<TraceEvent>)> {
    let mut r = Reader { b: buf, pos: 0 };
    check_magic(&mut r, MAGIC_SERVER, "server")?;
    let s = ServerStats {
        part: r.u32()? as usize,
        requests: r.u64()?,
        nodes_served: r.u64()?,
        bytes_in: r.u64()?,
        bytes_out: r.u64()?,
        bad_frames: r.u64()?,
    };
    let trace = get_trace(&mut r)?;
    crate::ensure!(r.pos == buf.len(), "ipc: {} trailing bytes", buf.len() - r.pos);
    Ok((s, trace))
}

pub fn encode_hub_result(rounds: u64, trace: &[TraceEvent]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(MAGIC_HUB);
    put_u64(&mut out, rounds);
    put_trace(&mut out, trace)?;
    Ok(out)
}

pub fn decode_hub_result(buf: &[u8]) -> Result<(u64, Vec<TraceEvent>)> {
    let mut r = Reader { b: buf, pos: 0 };
    check_magic(&mut r, MAGIC_HUB, "hub")?;
    let rounds = r.u64()?;
    let trace = get_trace(&mut r)?;
    crate::ensure!(r.pos == buf.len(), "ipc: {} trailing bytes", buf.len() - r.pos);
    Ok((rounds, trace))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;
    use crate::trace::{EventKind, Role};

    fn sample_trace() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                role: Role::Trainer,
                id: 2,
                seq: 0,
                vclock: 0.1 + 0.2,
                wall: 0.000321,
                kind: EventKind::MinibatchBegin { epoch: 0, mb: 4 },
            },
            TraceEvent {
                role: Role::Trainer,
                id: 2,
                seq: 1,
                vclock: 0.0,
                wall: 0.0005,
                kind: EventKind::RoleEnd { emitted: 1 },
            },
        ]
    }

    fn sample_metrics() -> RunMetrics {
        let mut m = RunMetrics::default();
        m.minibatches.push(MinibatchRecord {
            epoch: 1,
            minibatch: 5,
            trainer: 2,
            hits_pct: 37.25,
            hits: 91,
            comm_nodes: 120,
            comm_bytes: 48_000,
            unique_remote: 130,
            buffer_occupancy: 0.75,
            step_time: 0.1 + 0.2, // a value with a non-trivial bit pattern
            replaced: true,
            replaced_frac: 0.125,
        });
        m.decisions.push(DecisionRecord {
            minibatch: 5,
            replace: true,
            prediction: Some(HitsPrediction::Increase),
            valid_response: true,
            hits_before: 31.5,
            hits_after: Some(40.0),
            latency: 1.75,
        });
        m.decisions.push(DecisionRecord {
            minibatch: 9,
            replace: false,
            prediction: None,
            valid_response: false,
            hits_before: 0.0,
            hits_after: None,
            latency: f64::MIN_POSITIVE,
        });
        m.epoch_times.push(1.0 / 3.0);
        m
    }

    #[test]
    fn trainer_blob_round_trips_bit_exactly() {
        let metrics = sample_metrics();
        let wall = WallStats {
            total: 2.5,
            epochs: vec![1.25, 1.25],
            fetch_wait: 0.1,
            compute: 0.9,
            barrier: 0.01,
            minibatches: 40,
        };
        let mut lat = LogHistogram::new();
        lat.push(0.0011);
        lat.push(0.0042);
        lat.push(0.9);
        let wire = WireStats {
            req_frames: 10,
            req_bytes: 2000,
            resp_frames: 10,
            resp_bytes: 90_000,
            nodes_requested: 500,
            nodes_deduped: 70,
            nodes_received: 500,
            dup_frames: 3,
            bad_frames: 0,
            chunks_hit: 12,
            chunks_fetched: 34,
            bytes_saved_cache: 5600,
            links: vec![LinkStats {
                peer: "server:1".into(),
                channel: 1,
                frames_sent: 11,
                bytes_sent: 2100,
                frames_recv: 10,
                bytes_recv: 90_000,
                reconnects: 2,
            }],
            fetch_latency: vec![LogHistogram::new(), lat],
        };
        let measured = MeasuredStats {
            compute_secs: vec![0.1 + 0.2, 0.25],
            fetch_wait_secs: vec![0.01],
            barrier_secs: vec![0.002, 0.003, 0.004],
            losses: vec![2.5, f32::MIN_POSITIVE],
            rows_from_store: 321,
            rows_local: 999,
            rows_fallback: 0,
            grad_bytes: 160_000,
            param_hash: 0xDEAD_BEEF_1234_5678,
        };
        let trace = sample_trace();
        let blob = encode_trainer_result(&metrics, &wall, &wire, &measured, &trace).unwrap();
        let (m2, w2, wire2, meas2, trace2) = decode_trainer_result(&blob).unwrap();
        assert_eq!(m2.minibatches.len(), 1);
        assert_eq!(
            m2.minibatches[0].step_time.to_bits(),
            metrics.minibatches[0].step_time.to_bits(),
            "f64 must survive bit-exactly"
        );
        assert_eq!(m2.epoch_times[0].to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(m2.decisions.len(), 2);
        assert_eq!(m2.decisions[0].prediction, Some(HitsPrediction::Increase));
        assert_eq!(m2.decisions[1].prediction, None);
        assert_eq!(m2.decisions[1].hits_after, None);
        assert_eq!(w2.minibatches, 40);
        assert_eq!(w2.epochs, vec![1.25, 1.25]);
        assert_eq!(wire2.nodes_requested, 500);
        assert_eq!(wire2.dup_frames, 3);
        assert_eq!(
            (wire2.chunks_hit, wire2.chunks_fetched, wire2.bytes_saved_cache),
            (12, 34, 5600),
            "chunk-cache counters must survive"
        );
        assert_eq!(wire2.links, wire.links);
        assert_eq!(wire2.links[0].channel, 1, "link channel id must survive");
        assert_eq!(wire2.fetch_latency, wire.fetch_latency);
        assert_eq!(wire2.fetch_latency[1].count(), 3);
        assert_eq!(trace2, trace, "trace section must round-trip bit-exactly");
        assert_eq!(trace2[0].vclock.to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(meas2.compute_secs[0].to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(meas2.losses[1].to_bits(), f32::MIN_POSITIVE.to_bits());
        assert_eq!(meas2.barrier_secs.len(), 3);
        assert_eq!(meas2.rows_from_store, 321);
        assert_eq!(meas2.param_hash, 0xDEAD_BEEF_1234_5678);
    }

    #[test]
    fn empty_measured_stats_round_trip() {
        let blob = encode_trainer_result(
            &RunMetrics::default(),
            &WallStats::default(),
            &WireStats::default(),
            &MeasuredStats::default(),
            &[],
        )
        .unwrap();
        let (_, _, _, meas, trace) = decode_trainer_result(&blob).unwrap();
        assert!(!meas.is_populated(), "emulated-mode blobs carry empty measured stats");
        assert!(trace.is_empty(), "tracing-off blobs carry an empty trace section");
    }

    #[test]
    fn server_and_hub_blobs_round_trip() {
        let s = ServerStats {
            part: 3,
            requests: 44,
            nodes_served: 1000,
            bytes_in: 9000,
            bytes_out: 400_000,
            bad_frames: 1,
        };
        let trace = sample_trace();
        let (back, t2) = decode_server_stats(&encode_server_stats(&s, &trace).unwrap()).unwrap();
        assert_eq!(back.part, 3);
        assert_eq!(back.nodes_served, 1000);
        assert_eq!(back.bad_frames, 1);
        assert_eq!(t2, trace);
        let (rounds, t3) = decode_hub_result(&encode_hub_result(77, &trace).unwrap()).unwrap();
        assert_eq!(rounds, 77);
        assert_eq!(t3, trace);
    }

    #[test]
    fn corrupt_blobs_error_cleanly() {
        let blob = encode_hub_result(5, &[]).unwrap();
        assert!(decode_hub_result(&blob[..blob.len() - 1]).is_err(), "truncated");
        let mut wrong = blob.clone();
        wrong[0] = b'X';
        assert!(decode_hub_result(&wrong).is_err(), "bad magic");
        let mut trailing = blob;
        trailing.push(0);
        assert!(decode_hub_result(&trailing).is_err(), "trailing bytes");
        assert!(decode_trainer_result(b"RTR4").is_err(), "short trainer blob");
        assert!(decode_trainer_result(b"RTR1").is_err(), "stale blob version rejected");
        assert!(decode_trainer_result(b"RTR3").is_err(), "pre-chunk blob version rejected");
    }
}
