//! Cluster orchestration: spawn servers, prefetchers, the allreduce hub,
//! and one thread per trainer; join everything and aggregate results.
//!
//! Thread/channel topology for `n` trainers (always `n` partitions):
//!
//! ```text
//!  trainer t ──Fetch/Evict──▶ prefetcher t ──FetchReq──▶ server p (per owner)
//!      ▲                          ▲                          │
//!      │ wait_all()               └───────FetchResp──────────┘
//!      ▼
//!  FeatureStore t (shared trainer t ↔ prefetcher t)
//!
//!  trainer 0..n ──Allreduce──▶ hub ──reduced Allreduce──▶ trainer 0..n
//! ```
//!
//! Shutdown is drop-driven: trainers send `Shutdown` to their prefetcher
//! and drop their channel ends; prefetchers drop the server senders;
//! servers and the hub exit when their receivers disconnect.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::classifier::trainer::TrainingSet;
use crate::error::Result;
use crate::gnn::SageShape;
use crate::graph::Dataset;
use crate::metrics::{RunMetrics, WireStats};
use crate::net::Network;
use crate::partition::Partition;
use crate::sim::{self, ExperimentResult, RunConfig};

use super::prefetch::{spawn_prefetcher, FeatureStore, PrefetchMsg};
use super::server::{spawn_server, ServerStats, WireDelay};
use super::trainer::{run_trainer, TrainerArgs, WallStats};
use super::wire::Frame;

/// Cluster-runtime configuration: the shared [`RunConfig`] plus how much
/// wall time to spend emulating the modelled network/compute costs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub run: RunConfig,
    /// Wall seconds slept per virtual second of modelled cost (server
    /// transfer delay, T_DDP compute, allreduce).  `0.0` disables all
    /// emulation — the cluster runs as fast as the hardware allows.
    pub time_scale: f64,
}

impl ClusterConfig {
    pub fn new(run: RunConfig) -> ClusterConfig {
        ClusterConfig { run, time_scale: 0.0 }
    }
}

/// Outcome of one cluster run: the sim-shaped experiment summary (virtual
/// time + traffic counters, parity-comparable) plus the real-runtime
/// measurements the sim cannot produce.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub experiment: ExperimentResult,
    /// Wall seconds from first spawn to last trainer exit.
    pub wall_total: f64,
    pub walls: Vec<WallStats>,
    pub wire: Vec<WireStats>,
    pub servers: Vec<ServerStats>,
    pub allreduce_rounds: u64,
}

impl ClusterResult {
    /// Cluster-wide wire totals (sum over trainers' prefetchers).
    pub fn wire_total(&self) -> WireStats {
        let mut total = WireStats::default();
        for w in &self.wire {
            total.merge(w);
        }
        total
    }

    /// Mean wall seconds per epoch (max over trainers within an epoch).
    pub fn mean_epoch_wall(&self) -> f64 {
        let epochs = self.walls.iter().map(|w| w.epochs.len()).max().unwrap_or(0);
        if epochs == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        for e in 0..epochs {
            total += self
                .walls
                .iter()
                .filter_map(|w| w.epochs.get(e))
                .fold(0.0f64, |m, &v| m.max(v));
        }
        total / epochs as f64
    }
}

/// Build the dataset + partition and run the cluster runtime.
pub fn run_cluster(ccfg: &ClusterConfig) -> Result<ClusterResult> {
    let (ds, part) = sim::build_cluster(&ccfg.run)?;
    run_cluster_on(Arc::new(ds), Arc::new(part), ccfg, None)
}

/// Run on a pre-built cluster (shared with parity tests so the sim and the
/// cluster runtime see the same graph object).
pub fn run_cluster_on(
    ds: Arc<Dataset>,
    part: Arc<Partition>,
    ccfg: &ClusterConfig,
    offline: Option<TrainingSet>,
) -> Result<ClusterResult> {
    let cfg = ccfg.run.clone();
    let n = cfg.num_trainers;
    crate::ensure!(n >= 1, "cluster: need at least one trainer");
    crate::ensure!(
        n == part.num_parts,
        "cluster: {n} trainers but {} partitions",
        part.num_parts
    );

    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), n);
    let delay = WireDelay::from_net(&net, ccfg.time_scale);
    let allreduce_sleep = ccfg.time_scale * net.allreduce_time(shape.param_bytes());
    let max_mb = sim::max_minibatches_per_epoch(&cfg, &ds, &part);
    let offline = Arc::new(offline);

    // Channels: requests into each server, each prefetcher's inbox
    // (commands from its trainer + responses from every server), the hub's
    // inbox, and one reply channel per trainer.
    let mut server_txs: Vec<Sender<Vec<u8>>> = Vec::with_capacity(n);
    let mut server_rxs: Vec<Receiver<Vec<u8>>> = Vec::with_capacity(n);
    let mut pf_txs: Vec<Sender<PrefetchMsg>> = Vec::with_capacity(n);
    let mut pf_rxs: Vec<Receiver<PrefetchMsg>> = Vec::with_capacity(n);
    let mut reply_txs: Vec<Sender<Vec<u8>>> = Vec::with_capacity(n);
    let mut reply_rxs: Vec<Receiver<Vec<u8>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel();
        server_txs.push(tx);
        server_rxs.push(rx);
        let (tx, rx) = mpsc::channel();
        pf_txs.push(tx);
        pf_rxs.push(rx);
        let (tx, rx) = mpsc::channel();
        reply_txs.push(tx);
        reply_rxs.push(rx);
    }
    let (hub_tx, hub_rx) = mpsc::channel::<Vec<u8>>();
    let stores: Vec<Arc<FeatureStore>> = (0..n).map(|_| Arc::new(FeatureStore::new())).collect();

    let server_handles: Vec<JoinHandle<ServerStats>> = server_rxs
        .into_iter()
        .enumerate()
        .map(|(p, rx)| {
            let replies = pf_txs.clone();
            spawn_server(p, ds.feature_seed, ds.spec.feat_dim, part.clone(), rx, replies, delay)
        })
        .collect();
    let pf_handles: Vec<JoinHandle<WireStats>> = pf_rxs
        .into_iter()
        .enumerate()
        .map(|(p, rx)| spawn_prefetcher(p, stores[p].clone(), rx, server_txs.clone(), part.clone()))
        .collect();
    let hub_handle = spawn_hub(n, hub_rx, reply_txs, allreduce_sleep);

    let wall_start = Instant::now();
    let trainer_handles: Vec<JoinHandle<super::trainer::TrainerOutput>> = reply_rxs
        .into_iter()
        .enumerate()
        .map(|(p, hub_rx_p)| {
            let args = TrainerArgs {
                part_id: p,
                cfg: cfg.clone(),
                ds: ds.clone(),
                part: part.clone(),
                offline: offline.clone(),
                store: stores[p].clone(),
                prefetch_tx: pf_txs[p].clone(),
                hub_tx: hub_tx.clone(),
                hub_rx: hub_rx_p,
                max_mb_per_epoch: max_mb,
                time_scale: ccfg.time_scale,
            };
            std::thread::Builder::new()
                .name(format!("rudder-trainer-{p}"))
                .spawn(move || run_trainer(args))
                .expect("spawn trainer thread")
        })
        .collect();

    // Drop the orchestrator's channel ends so disconnect-driven shutdown
    // can propagate once the workers drop theirs.
    drop(hub_tx);
    drop(pf_txs);
    drop(server_txs);

    let mut per_trainer: Vec<RunMetrics> = Vec::with_capacity(n);
    let mut walls: Vec<WallStats> = Vec::with_capacity(n);
    for h in trainer_handles {
        let out = h
            .join()
            .map_err(|_| crate::err!("cluster trainer thread panicked"))?;
        per_trainer.push(out.metrics);
        walls.push(out.wall);
    }
    let wall_total = wall_start.elapsed().as_secs_f64();

    let mut wire: Vec<WireStats> = Vec::with_capacity(n);
    for h in pf_handles {
        wire.push(h.join().map_err(|_| crate::err!("prefetcher thread panicked"))?);
    }
    let mut servers: Vec<ServerStats> = Vec::with_capacity(n);
    for h in server_handles {
        servers.push(h.join().map_err(|_| crate::err!("feature-server thread panicked"))?);
    }
    let allreduce_rounds = hub_handle
        .join()
        .map_err(|_| crate::err!("allreduce hub thread panicked"))?;

    // Barrier-synchronized epochs: every trainer records identical virtual
    // epoch times, so trainer 0's series is the run-level series (exactly
    // as `sim::run_on` computes it).
    let epoch_times = per_trainer
        .first()
        .map(|m| m.epoch_times.clone())
        .unwrap_or_default();
    let experiment = ExperimentResult::aggregate(cfg.controller.label(), per_trainer, epoch_times);
    Ok(ClusterResult { experiment, wall_total, walls, wire, servers, allreduce_rounds })
}

/// The DDP allreduce hub: collects one `Allreduce` frame per trainer per
/// round, element-wise-reduces the gradient payloads, takes the max
/// virtual clock (the barrier), and broadcasts the reduced frame back.
fn spawn_hub(
    n: usize,
    rx: Receiver<Vec<u8>>,
    replies: Vec<Sender<Vec<u8>>>,
    round_sleep: f64,
) -> JoinHandle<u64> {
    std::thread::Builder::new()
        .name("rudder-allreduce-hub".into())
        .spawn(move || {
            let mut rounds = 0u64;
            let mut acc: Vec<f32> = Vec::new();
            let mut max_vclock = f64::NEG_INFINITY;
            let mut got = 0usize;
            for bytes in rx.iter() {
                let Ok((Frame::Allreduce { vclock, grads, .. }, _)) = Frame::decode(&bytes)
                else {
                    continue; // tolerate garbage; trainers would time out loudly
                };
                if got == 0 {
                    acc = grads;
                } else {
                    for (a, g) in acc.iter_mut().zip(&grads) {
                        *a += g;
                    }
                }
                max_vclock = max_vclock.max(vclock);
                got += 1;
                if got == n {
                    if round_sleep > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(round_sleep));
                    }
                    let reduced = Frame::Allreduce {
                        part: u32::MAX,
                        round: rounds,
                        vclock: max_vclock,
                        grads: std::mem::take(&mut acc),
                    }
                    .encode();
                    for tx in &replies {
                        let _ = tx.send(reduced.clone());
                    }
                    rounds += 1;
                    got = 0;
                    max_vclock = f64::NEG_INFINITY;
                }
            }
            rounds
        })
        .expect("spawn allreduce hub thread")
}

/// Traffic parity between the virtual-time sim and the cluster runtime:
/// for the same config + seed the per-trainer fetched-node, buffer-hit,
/// and payload-byte counters (and the virtual schedule built from them)
/// must be *identical*.  Returns a human-readable diagnosis on mismatch.
pub fn parity_check(
    sim_r: &ExperimentResult,
    cluster_r: &ExperimentResult,
) -> std::result::Result<(), String> {
    if sim_r.per_trainer.len() != cluster_r.per_trainer.len() {
        return Err(format!(
            "trainer count: sim {} vs cluster {}",
            sim_r.per_trainer.len(),
            cluster_r.per_trainer.len()
        ));
    }
    for (i, (a, b)) in sim_r.per_trainer.iter().zip(&cluster_r.per_trainer).enumerate() {
        let checks: [(&str, u64, u64); 5] = [
            ("minibatches", a.minibatches.len() as u64, b.minibatches.len() as u64),
            ("decisions", a.decisions.len() as u64, b.decisions.len() as u64),
            ("fetched nodes", a.total_comm_nodes(), b.total_comm_nodes()),
            ("buffer hits", a.total_hits(), b.total_hits()),
            ("payload bytes", a.total_comm_bytes(), b.total_comm_bytes()),
        ];
        for (what, va, vb) in checks {
            if va != vb {
                return Err(format!("trainer {i} {what}: sim {va} vs cluster {vb}"));
            }
        }
    }
    if sim_r.mean_epoch_time != cluster_r.mean_epoch_time {
        return Err(format!(
            "mean virtual epoch time: sim {} vs cluster {}",
            sim_r.mean_epoch_time, cluster_r.mean_epoch_time
        ));
    }
    Ok(())
}
