//! Cluster orchestration: spawn servers, prefetchers, the allreduce hub,
//! and one thread per trainer; join everything and aggregate results.
//!
//! Thread/link topology for `n` trainers (always `n` partitions):
//!
//! ```text
//!  trainer t ──Fetch/Evict──▶ prefetcher t ──FetchReq──▶ server p (per owner)
//!      ▲                          ▲                          │
//!      │ wait_all()               └───────FetchResp──────────┘
//!      ▼
//!  FeatureStore t (shared trainer t ↔ prefetcher t)
//!
//!  trainer 0..n ──Allreduce──▶ hub ──reduced Allreduce──▶ trainer 0..n
//! ```
//!
//! The prefetcher↔server and trainer↔hub edges are *transport links*
//! ([`super::transport`]): in-process `mpsc` channels by default, or
//! loopback TCP sockets when [`ClusterConfig::transport`] is
//! [`Transport::Tcp`] — same loops, same counters, different bytes path.
//! (The `rudder cluster --transport tcp` CLI goes further and runs each
//! role as a separate OS process; see [`super::multiproc`].)
//!
//! Shutdown is close-driven: trainers send `Shutdown` to their prefetcher
//! and half-close the hub link; prefetchers half-close the server request
//! links after draining the responses they are owed; servers and the hub
//! exit when every inbound link has hung up.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::classifier::trainer::TrainingSet;
use crate::error::Result;
use crate::gnn::SageShape;
use crate::graph::Dataset;
use crate::metrics::{MeasuredStats, RunMetrics, WireStats};
use crate::net::Network;
use crate::partition::Partition;
use crate::sim::{self, ExperimentResult, RunConfig};
use crate::trace::{EventKind, Role, Trace, TraceEvent, TraceMeta, Tracer};

use super::prefetch::{spawn_prefetcher, FeatureStore, PrefetchConfig, PrefetchMsg};
use super::server::{spawn_server, ServerStats, WireDelay};
use super::trainer::{io_timeout, run_trainer, TrainerArgs, WallStats};
use super::transport::{
    self, ChannelReceiver, ChannelSender, FaultSpec, FrameReceiver, FrameSender,
    LinkStatsHandle, NetMsg, Transport,
};
use super::wire::Frame;

/// Where a cluster trainer's compute time comes from.
///
/// In *both* modes the embedded sim state machine keeps charging the
/// modelled α–β costs to the virtual clock, so decisions and every traffic
/// counter stay a pure function of config + seed — `--parity` holds either
/// way.  The mode only selects the *wall-clock* source: sleeps scaled from
/// the model, or the real interpreter-backend `SageRunner`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ComputeMode {
    /// Sleep `time_scale ×` the modelled virtual seconds (server transfer
    /// delay, T_DDP compute, allreduce).  `Emulated(0.0)` disables all
    /// sleeps — the protocol runs as fast as the hardware allows.
    Emulated(f64),
    /// Spend real CPU cycles: every trainer owns a [`crate::gnn::SageRunner`]
    /// (interpreter backend) and runs actual sage fwd/bwd on the features
    /// materialized in its [`FeatureStore`], with real gradient blobs
    /// reduced by the allreduce hub.  No emulation sleeps anywhere.
    Measured,
}

impl std::str::FromStr for ComputeMode {
    type Err = crate::error::RudderError;

    fn from_str(s: &str) -> Result<ComputeMode> {
        match s {
            "emulated" => Ok(ComputeMode::Emulated(0.0)),
            "measured" => Ok(ComputeMode::Measured),
            _ => crate::bail!("unknown compute mode '{s}' (valid: emulated | measured)"),
        }
    }
}

impl ComputeMode {
    pub fn name(&self) -> &'static str {
        match self {
            ComputeMode::Emulated(_) => "emulated",
            ComputeMode::Measured => "measured",
        }
    }

    /// Wall seconds slept per modelled virtual second (0 in measured mode:
    /// real compute replaces every sleep).
    pub fn time_scale(&self) -> f64 {
        match self {
            ComputeMode::Emulated(ts) => *ts,
            ComputeMode::Measured => 0.0,
        }
    }

    pub fn is_measured(&self) -> bool {
        matches!(self, ComputeMode::Measured)
    }
}

/// Cluster-runtime configuration: the shared [`RunConfig`] plus how the
/// bytes move and where compute wall time comes from.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub run: RunConfig,
    /// Emulated (sleep-scaled modelled costs) or measured (real SageRunner
    /// fwd/bwd) compute.
    pub compute: ComputeMode,
    /// Which transport carries the RPC frames (in-process runs).
    pub transport: Transport,
    /// Deterministic fault injection on the server→trainer response links
    /// (duplicate / reorder / TCP write chop).
    pub fault: Option<FaultSpec>,
    /// Record a structured [`Trace`] of every role's phases
    /// ([`ClusterResult::trace`]).
    pub trace: bool,
}

impl ClusterConfig {
    pub fn new(run: RunConfig) -> ClusterConfig {
        ClusterConfig {
            run,
            compute: ComputeMode::Emulated(0.0),
            transport: Transport::Channel,
            fault: None,
            trace: false,
        }
    }
}

/// Outcome of one cluster run: the sim-shaped experiment summary (virtual
/// time + traffic counters, parity-comparable) plus the real-runtime
/// measurements the sim cannot produce.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub experiment: ExperimentResult,
    /// Wall seconds from first spawn to last trainer exit.
    pub wall_total: f64,
    pub walls: Vec<WallStats>,
    /// Real-compute accounting, one per trainer (empty structs in
    /// emulated mode).
    pub measured: Vec<MeasuredStats>,
    pub wire: Vec<WireStats>,
    pub servers: Vec<ServerStats>,
    pub allreduce_rounds: u64,
    /// Merged flight-recorder trace, canonically sorted
    /// (`Some` iff [`ClusterConfig::trace`]).
    pub trace: Option<Trace>,
}

impl ClusterResult {
    /// Cluster-wide wire totals (sum over trainers' prefetchers).
    pub fn wire_total(&self) -> WireStats {
        let mut total = WireStats::default();
        for w in &self.wire {
            total.merge(w);
        }
        total
    }

    /// Mean wall seconds per epoch (max over trainers within an epoch).
    pub fn mean_epoch_wall(&self) -> f64 {
        let epochs = self.walls.iter().map(|w| w.epochs.len()).max().unwrap_or(0);
        if epochs == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        for e in 0..epochs {
            total += self
                .walls
                .iter()
                .filter_map(|w| w.epochs.get(e))
                .fold(0.0f64, |m, &v| m.max(v));
        }
        total / epochs as f64
    }
}

/// Build the dataset + partition and run the cluster runtime.
pub fn run_cluster(ccfg: &ClusterConfig) -> Result<ClusterResult> {
    let (ds, part) = sim::build_cluster(&ccfg.run)?;
    run_cluster_on(Arc::new(ds), Arc::new(part), ccfg, None)
}

/// Per-trainer wiring produced by a transport backend: the trainer's ends
/// of its links plus the already-spawned prefetcher.
struct TrainerWiring {
    prefetch_tx: Sender<PrefetchMsg>,
    hub_tx: Box<dyn FrameSender>,
    hub_rx: Box<dyn FrameReceiver>,
    store: Arc<FeatureStore>,
    pf_handle: JoinHandle<(WireStats, Vec<TraceEvent>)>,
    /// Server links in partition order, then the hub link.
    links: Vec<LinkStatsHandle>,
}

/// Background machinery shared by both transports.
struct Backstage {
    server_handles: Vec<JoinHandle<(ServerStats, Vec<TraceEvent>)>>,
    hub_handle: JoinHandle<(u64, Vec<TraceEvent>)>,
    /// TCP-only: accept threads and trainer-side response pumps.
    aux_handles: Vec<JoinHandle<()>>,
    /// Event transport only: the I/O loop thread, joining to its trace
    /// buffer (empty unless tracing).
    loop_handle: Option<JoinHandle<Vec<TraceEvent>>>,
}

/// Run on a pre-built cluster (shared with parity tests so the sim and the
/// cluster runtime see the same graph object).
pub fn run_cluster_on(
    ds: Arc<Dataset>,
    part: Arc<Partition>,
    ccfg: &ClusterConfig,
    offline: Option<TrainingSet>,
) -> Result<ClusterResult> {
    let cfg = ccfg.run.clone();
    let n = cfg.num_trainers;
    crate::ensure!(n >= 1, "cluster: need at least one trainer");
    crate::ensure!(
        n == part.num_parts,
        "cluster: {n} trainers but {} partitions",
        part.num_parts
    );

    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), n);
    let time_scale = ccfg.compute.time_scale();
    let delay = WireDelay::from_net(&net, time_scale);
    let allreduce_sleep = time_scale * net.allreduce_time(shape.param_bytes());
    let max_mb = sim::max_minibatches_per_epoch(&cfg, &ds, &part);
    let offline = Arc::new(offline);

    let (wirings, backstage) = match ccfg.transport {
        Transport::Channel => wire_channel(n, &ds, &part, ccfg, delay, allreduce_sleep),
        Transport::Tcp => wire_tcp(n, &ds, &part, ccfg, delay, allreduce_sleep)?,
        Transport::Event => wire_event(n, &ds, &part, ccfg, delay, allreduce_sleep)?,
    };

    let wall_start = Instant::now();
    let mut trainer_handles: Vec<JoinHandle<super::trainer::TrainerOutput>> = Vec::new();
    let mut link_sets: Vec<Vec<LinkStatsHandle>> = Vec::new();
    let mut pf_handles: Vec<JoinHandle<(WireStats, Vec<TraceEvent>)>> = Vec::new();
    for (p, w) in wirings.into_iter().enumerate() {
        link_sets.push(w.links);
        pf_handles.push(w.pf_handle);
        let args = TrainerArgs {
            part_id: p,
            cfg: cfg.clone(),
            ds: ds.clone(),
            part: part.clone(),
            offline: offline.clone(),
            store: w.store,
            prefetch_tx: w.prefetch_tx,
            hub_tx: w.hub_tx,
            hub_rx: w.hub_rx,
            max_mb_per_epoch: max_mb,
            compute: ccfg.compute,
            trace: ccfg.trace,
        };
        trainer_handles.push(
            std::thread::Builder::new()
                .name(format!("rudder-trainer-{p}"))
                .spawn(move || run_trainer(args))
                .expect("spawn trainer thread"),
        );
    }

    let mut per_trainer: Vec<RunMetrics> = Vec::with_capacity(n);
    let mut walls: Vec<WallStats> = Vec::with_capacity(n);
    let mut measured: Vec<MeasuredStats> = Vec::with_capacity(n);
    let mut trace_events: Vec<TraceEvent> = Vec::new();
    for h in trainer_handles {
        let out = h
            .join()
            .map_err(|_| crate::err!("cluster trainer thread panicked"))?;
        per_trainer.push(out.metrics);
        walls.push(out.wall);
        measured.push(out.measured);
        trace_events.extend(out.trace);
    }
    let wall_total = wall_start.elapsed().as_secs_f64();

    let mut wire: Vec<WireStats> = Vec::with_capacity(n);
    for (h, links) in pf_handles.into_iter().zip(&link_sets) {
        let (mut w, pf_trace) =
            h.join().map_err(|_| crate::err!("prefetcher thread panicked"))?;
        w.links = links.iter().map(LinkStatsHandle::snapshot).collect();
        wire.push(w);
        trace_events.extend(pf_trace);
    }
    let mut servers: Vec<ServerStats> = Vec::with_capacity(n);
    for h in backstage.server_handles {
        let (s, sv_trace) =
            h.join().map_err(|_| crate::err!("feature-server thread panicked"))?;
        servers.push(s);
        trace_events.extend(sv_trace);
    }
    let (allreduce_rounds, hub_trace) = backstage
        .hub_handle
        .join()
        .map_err(|_| crate::err!("allreduce hub thread panicked"))?;
    trace_events.extend(hub_trace);
    for h in backstage.aux_handles {
        let _ = h.join();
    }
    if let Some(h) = backstage.loop_handle {
        trace_events.extend(h.join().map_err(|_| crate::err!("event loop thread panicked"))?);
    }

    let trace = if ccfg.trace {
        let mut t = Trace::new(TraceMeta {
            label: cfg.controller.label(),
            seed: cfg.seed,
            transport: ccfg.transport.name().to_string(),
            compute: ccfg.compute.name().to_string(),
            config: crate::config::to_toml(&cfg)?,
        });
        t.events = trace_events;
        t.sort_canonical();
        Some(t)
    } else {
        None
    };

    // Barrier-synchronized epochs: every trainer records identical virtual
    // epoch times, so trainer 0's series is the run-level series (exactly
    // as `sim::run_on` computes it).
    let epoch_times = per_trainer
        .first()
        .map(|m| m.epoch_times.clone())
        .unwrap_or_default();
    let experiment = ExperimentResult::aggregate(cfg.controller.label(), per_trainer, epoch_times);
    Ok(ClusterResult {
        experiment,
        wall_total,
        walls,
        measured,
        wire,
        servers,
        allreduce_rounds,
        trace,
    })
}

/// Wire everything over in-process `mpsc` channels.
fn wire_channel(
    n: usize,
    ds: &Arc<Dataset>,
    part: &Arc<Partition>,
    ccfg: &ClusterConfig,
    delay: WireDelay,
    allreduce_sleep: f64,
) -> (Vec<TrainerWiring>, Backstage) {
    let drain = io_timeout(ccfg.compute.time_scale());
    // Endpoint inboxes.
    let mut server_txs: Vec<Sender<NetMsg>> = Vec::with_capacity(n);
    let mut server_rxs: Vec<Receiver<NetMsg>> = Vec::with_capacity(n);
    let mut pf_txs: Vec<Sender<PrefetchMsg>> = Vec::with_capacity(n);
    let mut pf_rxs: Vec<Receiver<PrefetchMsg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel();
        server_txs.push(tx);
        server_rxs.push(rx);
        let (tx, rx) = mpsc::channel();
        pf_txs.push(tx);
        pf_rxs.push(rx);
    }
    let (hub_tx, hub_rx) = mpsc::channel::<NetMsg>();

    // Per-trainer link cells: server links in partition order, then hub.
    let link_sets: Vec<Vec<LinkStatsHandle>> = (0..n)
        .map(|_| {
            let mut v: Vec<LinkStatsHandle> = (0..n)
                .map(|p| LinkStatsHandle::on_channel(format!("server:{p}"), super::id_u32(p)))
                .collect();
            v.push(LinkStatsHandle::on_channel("hub", super::id_u32(n)));
            v
        })
        .collect();

    // Feature servers: reply routes pre-registered (trainer t's responses
    // are delivered straight into prefetcher t's inbox).
    let server_handles: Vec<JoinHandle<(ServerStats, Vec<TraceEvent>)>> = server_rxs
        .into_iter()
        .enumerate()
        .map(|(p, rx)| {
            let prereg: Vec<(u32, Box<dyn FrameSender>)> = (0..n)
                .map(|t| {
                    let s: Box<dyn FrameSender> = Box::new(ChannelSender::delivering(
                        pf_txs[t].clone(),
                        PrefetchMsg::Wire,
                        link_sets[t][p].clone(),
                    ));
                    (super::id_u32(t), s)
                })
                .collect();
            spawn_server(
                p,
                ds.feature_seed,
                ds.spec.feat_dim,
                ccfg.run.chunk_rows,
                part.clone(),
                rx,
                prereg,
                delay,
                ccfg.fault,
                ccfg.trace,
            )
        })
        .collect();

    // Allreduce hub: reduced frames delivered into per-trainer reply
    // channels.
    let mut reply_rxs: Vec<Receiver<Vec<u8>>> = Vec::with_capacity(n);
    let mut hub_prereg: Vec<(u32, Box<dyn FrameSender>)> = Vec::with_capacity(n);
    for (t, links) in link_sets.iter().enumerate() {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        reply_rxs.push(rx);
        hub_prereg.push((
            super::id_u32(t),
            Box::new(ChannelSender::delivering(tx, |v| v, links[n].clone())),
        ));
    }
    let hub_handle = spawn_hub(n, hub_rx, hub_prereg, allreduce_sleep, ccfg.trace);

    // Trainer wirings + prefetchers.
    let mut wirings = Vec::with_capacity(n);
    let stores: Vec<Arc<FeatureStore>> = (0..n).map(|_| Arc::new(FeatureStore::new())).collect();
    for (t, ((pf_rx, reply_rx), links)) in pf_rxs
        .into_iter()
        .zip(reply_rxs)
        .zip(link_sets)
        .enumerate()
    {
        let request_links: Vec<Box<dyn FrameSender>> = (0..n)
            .map(|p| {
                let s: Box<dyn FrameSender> = Box::new(ChannelSender::new(
                    server_txs[p].clone(),
                    NetMsg::Frame,
                    links[p].clone(),
                ));
                s
            })
            .collect();
        let pf_handle = spawn_prefetcher(
            t,
            stores[t].clone(),
            pf_rx,
            request_links,
            part.clone(),
            PrefetchConfig {
                feat_dim: ds.spec.feat_dim,
                chunk_rows: ccfg.run.chunk_rows,
                cache_bytes: ccfg.run.chunk_cache_bytes,
            },
            drain,
            ccfg.trace,
        );
        wirings.push(TrainerWiring {
            prefetch_tx: pf_txs[t].clone(),
            hub_tx: Box::new(ChannelSender::new(hub_tx.clone(), NetMsg::Frame, links[n].clone())),
            hub_rx: Box::new(ChannelReceiver::new(reply_rx)),
            store: stores[t].clone(),
            pf_handle,
            links,
        });
    }
    // The orchestrator's own channel ends drop here (server_txs, pf_txs,
    // hub_tx), so close-driven shutdown propagates once the workers drop
    // theirs.
    (
        wirings,
        Backstage { server_handles, hub_handle, aux_handles: Vec::new(), loop_handle: None },
    )
}

/// Wire everything over loopback TCP sockets (still in-process threads —
/// the multi-process flavor lives in [`super::multiproc`], built from the same
/// parts).
fn wire_tcp(
    n: usize,
    ds: &Arc<Dataset>,
    part: &Arc<Partition>,
    ccfg: &ClusterConfig,
    delay: WireDelay,
    allreduce_sleep: f64,
) -> Result<(Vec<TrainerWiring>, Backstage)> {
    let drain = io_timeout(ccfg.compute.time_scale());
    let chop = ccfg.fault.map(|f| f.chop).unwrap_or(0);
    let mut aux_handles: Vec<JoinHandle<()>> = Vec::new();

    // Listeners first (ephemeral loopback ports), so dialing never races.
    let mut server_addrs: Vec<String> = Vec::with_capacity(n);
    let mut server_handles: Vec<JoinHandle<(ServerStats, Vec<TraceEvent>)>> =
        Vec::with_capacity(n);
    for p in 0..n {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        server_addrs.push(listener.local_addr()?.to_string());
        let (tx, rx) = mpsc::channel::<NetMsg>();
        aux_handles.push(transport::serve_listener(listener, n, tx, &format!("server{p}"), chop));
        server_handles.push(spawn_server(
            p,
            ds.feature_seed,
            ds.spec.feat_dim,
            ccfg.run.chunk_rows,
            part.clone(),
            rx,
            Vec::new(),
            delay,
            ccfg.fault,
            ccfg.trace,
        ));
    }
    let hub_listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let hub_addr = hub_listener.local_addr()?.to_string();
    let (hub_tx, hub_rx) = mpsc::channel::<NetMsg>();
    aux_handles.push(transport::serve_listener(hub_listener, n, hub_tx, "hub", 0));
    let hub_handle = spawn_hub(n, hub_rx, Vec::new(), allreduce_sleep, ccfg.trace);

    let mut wirings = Vec::with_capacity(n);
    for t in 0..n {
        let (pf_tx, pf_rx) = mpsc::channel::<PrefetchMsg>();
        let store = Arc::new(FeatureStore::new());
        let mut dial =
            transport::dial_trainer_links(&server_addrs, &hub_addr, super::id_u32(t), &pf_tx)?;
        aux_handles.append(&mut dial.pumps);
        let pf_handle = spawn_prefetcher(
            t,
            store.clone(),
            pf_rx,
            dial.request_links,
            part.clone(),
            PrefetchConfig {
                feat_dim: ds.spec.feat_dim,
                chunk_rows: ccfg.run.chunk_rows,
                cache_bytes: ccfg.run.chunk_cache_bytes,
            },
            drain,
            ccfg.trace,
        );
        wirings.push(TrainerWiring {
            prefetch_tx: pf_tx,
            hub_tx: dial.hub_tx,
            hub_rx: dial.hub_rx,
            store,
            pf_handle,
            links: dial.links,
        });
    }
    Ok((wirings, Backstage { server_handles, hub_handle, aux_handles, loop_handle: None }))
}

/// Wire everything over the readiness-polled event-loop transport
/// ([`super::eventloop`]): real nonblocking loopback sockets, but all of a
/// trainer's logical links multiplexed over one physical connection and
/// one I/O thread total — no per-link pump threads.
fn wire_event(
    n: usize,
    ds: &Arc<Dataset>,
    part: &Arc<Partition>,
    ccfg: &ClusterConfig,
    delay: WireDelay,
    allreduce_sleep: f64,
) -> Result<(Vec<TrainerWiring>, Backstage)> {
    let drain = io_timeout(ccfg.compute.time_scale());
    // Endpoint inboxes, exactly as in the channel backend.
    let mut server_txs: Vec<Sender<NetMsg>> = Vec::with_capacity(n);
    let mut server_rxs: Vec<Receiver<NetMsg>> = Vec::with_capacity(n);
    let mut pf_txs: Vec<Sender<PrefetchMsg>> = Vec::with_capacity(n);
    let mut pf_rxs: Vec<Receiver<PrefetchMsg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel();
        server_txs.push(tx);
        server_rxs.push(rx);
        let (tx, rx) = mpsc::channel();
        pf_txs.push(tx);
        pf_rxs.push(rx);
    }
    let (hub_inbox_tx, hub_inbox_rx) = mpsc::channel::<NetMsg>();

    let ec =
        super::eventloop::wire_event_cluster(n, &server_txs, &hub_inbox_tx, &pf_txs, ccfg.trace)?;
    // Master inbox clones drop here; close-driven shutdown then hinges on
    // the per-connection route clones the loop releases on close markers.
    drop(server_txs);
    drop(hub_inbox_tx);

    let mut server_prereg = ec.server_prereg;
    let server_handles: Vec<JoinHandle<(ServerStats, Vec<TraceEvent>)>> = server_rxs
        .into_iter()
        .enumerate()
        .map(|(p, rx)| {
            spawn_server(
                p,
                ds.feature_seed,
                ds.spec.feat_dim,
                ccfg.run.chunk_rows,
                part.clone(),
                rx,
                std::mem::take(&mut server_prereg[p]),
                delay,
                ccfg.fault,
                ccfg.trace,
            )
        })
        .collect();
    let hub_handle = spawn_hub(n, hub_inbox_rx, ec.hub_prereg, allreduce_sleep, ccfg.trace);

    let mut wirings = Vec::with_capacity(n);
    for (t, (end, pf_rx)) in ec.trainers.into_iter().zip(pf_rxs).enumerate() {
        let store = Arc::new(FeatureStore::new());
        let pf_handle = spawn_prefetcher(
            t,
            store.clone(),
            pf_rx,
            end.request_links,
            part.clone(),
            PrefetchConfig {
                feat_dim: ds.spec.feat_dim,
                chunk_rows: ccfg.run.chunk_rows,
                cache_bytes: ccfg.run.chunk_cache_bytes,
            },
            drain,
            ccfg.trace,
        );
        wirings.push(TrainerWiring {
            prefetch_tx: pf_txs[t].clone(),
            hub_tx: end.hub_tx,
            hub_rx: end.hub_rx,
            store,
            pf_handle,
            links: end.links,
        });
    }
    drop(pf_txs);
    Ok((
        wirings,
        Backstage {
            server_handles,
            hub_handle,
            aux_handles: Vec::new(),
            loop_handle: Some(ec.loop_handle),
        },
    ))
}

/// The DDP allreduce hub loop: collects one `Allreduce` frame per trainer
/// per round, element-wise-reduces the gradient payloads, takes the max
/// virtual clock (the barrier), and broadcasts the reduced frame back.
///
/// Reduction runs in *trainer-id order*, not arrival order: f32 addition
/// is not associative, so an arrival-order sum would make measured-mode
/// gradients (and every model replica downstream of them) depend on
/// thread scheduling.  Buffering one contribution per trainer and summing
/// `0..n` keeps the reduced blob bit-deterministic for a fixed config +
/// seed.
///
/// Transport-agnostic: reply routes arrive pre-registered or via
/// [`NetMsg::Register`]; runs until every inbound link hangs up.  Used
/// inline by the hub worker process and on a thread by [`spawn_hub`].
pub(crate) fn hub_loop(
    n: usize,
    rx: Receiver<NetMsg>,
    prereg: Vec<(u32, Box<dyn FrameSender>)>,
    round_sleep: f64,
    trace: bool,
) -> (u64, Vec<TraceEvent>) {
    let mut tracer = Tracer::new(trace, Role::Hub, 0);
    let mut replies: Vec<Option<Box<dyn FrameSender>>> = (0..n).map(|_| None).collect();
    for (id, s) in prereg {
        if (id as usize) < n {
            replies[id as usize] = Some(s);
        }
    }
    let mut rounds = 0u64;
    let mut contrib: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
    let mut max_vclock = f64::NEG_INFINITY;
    let mut got = 0usize;
    for msg in rx.iter() {
        let bytes = match msg {
            NetMsg::Register(id, s) => {
                if (id as usize) < n {
                    replies[id as usize] = Some(s);
                }
                continue;
            }
            NetMsg::Frame(bytes) => bytes,
        };
        let Ok((Frame::Allreduce { part, vclock, grads, .. }, _)) = Frame::decode(&bytes) else {
            continue; // tolerate garbage; trainers would time out loudly
        };
        let Some(slot) = contrib.get_mut(part as usize) else {
            continue; // out-of-range trainer id: ignore like garbage
        };
        if slot.is_none() {
            got += 1;
        }
        *slot = Some(grads);
        max_vclock = max_vclock.max(vclock);
        if got == n {
            if round_sleep > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(round_sleep));
            }
            let mut acc: Vec<f32> = Vec::new();
            for slot in contrib.iter_mut() {
                let g = slot.take().expect("all contributions present");
                if acc.is_empty() {
                    acc = g;
                } else {
                    for (a, v) in acc.iter_mut().zip(&g) {
                        *a += v;
                    }
                }
            }
            let reduced = match (Frame::Allreduce {
                part: u32::MAX,
                round: rounds,
                vclock: max_vclock,
                grads: acc,
            })
            .encode()
            {
                Ok(bytes) => bytes,
                Err(e) => {
                    // Unreachable with sane gradient sizes; dropping the
                    // round (trainers time out loudly) beats panicking the
                    // hub and hanging every link.
                    crate::log_info!("hub: reduced frame encode failed: {e}");
                    rounds += 1;
                    got = 0;
                    max_vclock = f64::NEG_INFINITY;
                    continue;
                }
            };
            tracer.emit(
                max_vclock,
                EventKind::AllreduceRound {
                    round: rounds,
                    vclock_max: max_vclock,
                    trainers: super::id_u32(n),
                },
            );
            for r in replies.iter_mut().flatten() {
                let _ = r.send_frame(&reduced);
            }
            rounds += 1;
            got = 0;
            max_vclock = f64::NEG_INFINITY;
        }
    }
    (rounds, tracer.finish())
}

/// Spawn [`hub_loop`] on its own OS thread.
fn spawn_hub(
    n: usize,
    rx: Receiver<NetMsg>,
    prereg: Vec<(u32, Box<dyn FrameSender>)>,
    round_sleep: f64,
    trace: bool,
) -> JoinHandle<(u64, Vec<TraceEvent>)> {
    std::thread::Builder::new()
        .name("rudder-allreduce-hub".into())
        .spawn(move || hub_loop(n, rx, prereg, round_sleep, trace))
        .expect("spawn allreduce hub thread")
}

/// Traffic parity between the virtual-time sim and the cluster runtime:
/// for the same config + seed the per-trainer fetched-node, buffer-hit,
/// and payload-byte counters (and the virtual schedule built from them)
/// must be *identical*.  Returns a human-readable diagnosis on mismatch.
pub fn parity_check(
    sim_r: &ExperimentResult,
    cluster_r: &ExperimentResult,
) -> std::result::Result<(), String> {
    if sim_r.per_trainer.len() != cluster_r.per_trainer.len() {
        return Err(format!(
            "trainer count: sim {} vs cluster {}",
            sim_r.per_trainer.len(),
            cluster_r.per_trainer.len()
        ));
    }
    for (i, (a, b)) in sim_r.per_trainer.iter().zip(&cluster_r.per_trainer).enumerate() {
        let checks: [(&str, u64, u64); 5] = [
            ("minibatches", a.minibatches.len() as u64, b.minibatches.len() as u64),
            ("decisions", a.decisions.len() as u64, b.decisions.len() as u64),
            ("fetched nodes", a.total_comm_nodes(), b.total_comm_nodes()),
            ("buffer hits", a.total_hits(), b.total_hits()),
            ("payload bytes", a.total_comm_bytes(), b.total_comm_bytes()),
        ];
        for (what, va, vb) in checks {
            if va != vb {
                return Err(format!("trainer {i} {what}: sim {va} vs cluster {vb}"));
            }
        }
    }
    if sim_r.mean_epoch_time != cluster_r.mean_epoch_time {
        return Err(format!(
            "mean virtual epoch time: sim {} vs cluster {}",
            sim_r.mean_epoch_time, cluster_r.mean_epoch_time
        ));
    }
    Ok(())
}

/// Wire-level parity across transports: the want-set dedup and req-id
/// response dedup make every protocol counter a pure function of
/// config + seed, so two runs of the same config — channel vs TCP,
/// faulted vs clean — must agree *exactly* on everything except
/// `dup_frames` (which counts the injected duplicates themselves) and the
/// transport-layer `links` detail.  Returns a diagnosis on mismatch.
pub fn wire_parity(a: &[WireStats], b: &[WireStats]) -> std::result::Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("trainer count: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let checks: [(&str, u64, u64); 11] = [
            ("req_frames", x.req_frames, y.req_frames),
            ("req_bytes", x.req_bytes, y.req_bytes),
            ("resp_frames", x.resp_frames, y.resp_frames),
            ("resp_bytes", x.resp_bytes, y.resp_bytes),
            ("nodes_requested", x.nodes_requested, y.nodes_requested),
            ("nodes_deduped", x.nodes_deduped, y.nodes_deduped),
            ("nodes_received", x.nodes_received, y.nodes_received),
            ("bad_frames", x.bad_frames, y.bad_frames),
            ("chunks_hit", x.chunks_hit, y.chunks_hit),
            ("chunks_fetched", x.chunks_fetched, y.chunks_fetched),
            ("bytes_saved_cache", x.bytes_saved_cache, y.bytes_saved_cache),
        ];
        for (what, va, vb) in checks {
            if va != vb {
                return Err(format!("trainer {i} {what}: {va} vs {vb}"));
            }
        }
    }
    Ok(())
}
