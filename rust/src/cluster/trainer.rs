//! The cluster trainer thread: real concurrency around the sim trainer's
//! deterministic core.
//!
//! Each trainer embeds a [`crate::sim::trainer::Trainer`] and drives it
//! minibatch by minibatch: the virtual-time state machine remains the
//! single source of truth for *what* happens (sampling, buffer lookups,
//! controller decisions, replacement rounds, all traffic counters — which
//! is what makes `same config + seed ⇒ identical counters` hold against
//! the sim), while this thread executes the resulting I/O for real:
//!
//! 1. replacement admissions are handed to the prefetcher (async, overlaps
//!    the compute phase),
//! 2. the minibatch's buffer misses are fetched urgently,
//! 3. the trainer blocks until every sampled remote feature is resident,
//! 4. compute runs — emulated (`time_scale × T_DDP` wall seconds of sleep)
//!    or measured (real [`SageRunner`] fwd/bwd on the features gathered
//!    from the [`FeatureStore`], [`ComputeMode::Measured`]),
//! 5. evictions + non-admitted transients leave the feature store,
//! 6. the minibatch closes with a *real* DDP barrier: an `Allreduce` frame
//!    to the hub — carrying the real local gradient delta in measured mode
//!    — blocking on the reduced reply, which measured trainers apply to
//!    their replica (`params ← pre + Σdeltas / n`).
//!
//! In both modes the virtual clock advances by the *modelled* costs, so
//! decisions and traffic counters stay a pure function of config + seed
//! (the parity guarantee); the compute mode only changes where wall time
//! goes.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::classifier::trainer::TrainingSet;
use crate::gnn::{AnalyticModel, SageRunner, SageShape};
use crate::graph::features::fill_features;
use crate::graph::Dataset;
use crate::metrics::{MeasuredStats, RunMetrics};
use crate::net::Network;
use crate::partition::Partition;
use crate::runtime::{ArtifactConfig, Engine};
use crate::sim::trainer::{FetchPlan, RunCtx};
use crate::sim::{self, RunConfig};
use crate::trace::{EventKind, Role, TraceEvent, Tracer};
use crate::util::rng::derive_seed;

use super::prefetch::{FeatureStore, PrefetchMsg};
use super::run::ComputeMode;
use super::transport::{FrameReceiver, FrameSender};
use super::wire::Frame;

/// Learning rate of the measured-mode runner (matches `rudder calibrate`
/// and the e2e example).
const MEASURED_LR: f32 = 0.05;

/// Timeouts for feature waits and the allreduce barrier, bounded so that
/// a dead thread fails the whole run with a diagnostic instead of
/// deadlocking the remaining trainers (and the orchestrator's join)
/// forever.  Emulation sleeps scale with the user-supplied `time_scale`,
/// so the budgets do too: the base covers scheduling noise, the scaled
/// term covers ~30 virtual seconds of emulated cost per round — far above
/// any legitimate minibatch (T_DDP ≈ 0.1–0.3 virtual s, fetches less).
pub(crate) fn io_timeout(time_scale: f64) -> Duration {
    Duration::from_secs_f64(30.0 + 30.0 * time_scale.max(0.0))
}

/// Wall-clock accounting for one cluster trainer.
#[derive(Debug, Clone, Default)]
pub struct WallStats {
    /// Total wall seconds inside the epoch loop.
    pub total: f64,
    /// Wall seconds per epoch.
    pub epochs: Vec<f64>,
    /// Wall seconds blocked waiting for remote features (the exposed,
    /// un-overlapped part of communication).
    pub fetch_wait: f64,
    /// Wall seconds in compute (emulation sleeps, or real fwd/bwd in
    /// measured mode).
    pub compute: f64,
    /// Wall seconds blocked in the DDP barrier.
    pub barrier: f64,
    pub minibatches: u64,
}

/// Everything a trainer thread needs (moved into the thread at spawn).
/// The hub link is a transport-abstract frame link, so the same loop runs
/// over in-process channels or a TCP connection to a hub process.
pub(crate) struct TrainerArgs {
    pub part_id: usize,
    pub cfg: RunConfig,
    pub ds: Arc<Dataset>,
    pub part: Arc<Partition>,
    pub offline: Arc<Option<TrainingSet>>,
    pub store: Arc<FeatureStore>,
    pub prefetch_tx: Sender<PrefetchMsg>,
    pub hub_tx: Box<dyn FrameSender>,
    pub hub_rx: Box<dyn FrameReceiver>,
    pub max_mb_per_epoch: usize,
    pub compute: ComputeMode,
    /// Record a structured trace of this trainer's phases.
    pub trace: bool,
}

pub(crate) struct TrainerOutput {
    pub metrics: RunMetrics,
    pub wall: WallStats,
    /// Real-compute accounting (default-empty in emulated mode).
    pub measured: MeasuredStats,
    /// This trainer's trace buffer (empty unless `TrainerArgs::trace`).
    pub trace: Vec<TraceEvent>,
}

pub(crate) fn run_trainer(mut a: TrainerArgs) -> TrainerOutput {
    let cfg = &a.cfg;
    let ds: &Dataset = &a.ds;
    let part: &Partition = &a.part;
    let offline = (*a.offline).as_ref();

    // Identical model constants to `sim::run_on` (parity requirement).
    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), cfg.num_trainers);
    let compute = AnalyticModel::new(cfg.compute.clone(), shape);
    let allreduce = net.allreduce_time(shape.param_bytes());
    let grads_len = usize::try_from(shape.param_bytes() / 4).expect("param count fits usize");

    let mut t = sim::build_trainer(cfg, ds, part, a.part_id, offline);
    t.fetch_plan = Some(FetchPlan::default());

    // Measured mode: a real interpreter-backend runner per trainer.  Every
    // replica derives the same init seed from the run seed, so parameters
    // start bit-identical — the invariant the gradient allreduce preserves.
    let mut measured = MeasuredStats::default();
    let mut runner = if a.compute.is_measured() {
        let engine = Arc::new(Engine::builtin(ArtifactConfig {
            batch: cfg.batch_size,
            fanout1: cfg.fanout1,
            fanout2: cfg.fanout2,
            feat_dim: ds.spec.feat_dim,
            hidden: cfg.hidden,
            classes: ds.spec.num_classes,
            ..ArtifactConfig::default()
        }));
        Some(SageRunner::new(engine, derive_seed(cfg.seed, &[0xDD]), MEASURED_LR))
    } else {
        None
    };
    t.capture_minibatch = runner.is_some();

    // Warm start (MassiveGNN): stream the prepopulated residents' features
    // in the background; per-minibatch waits cover stragglers.
    let warm = t.buffer.resident_nodes();
    if !warm.is_empty() {
        let _ = a.prefetch_tx.send(PrefetchMsg::Fetch(warm));
    }

    let total_minibatches = (a.max_mb_per_epoch * cfg.epochs) as u64;
    let ctx = RunCtx {
        ds,
        part,
        net,
        compute,
        mode: cfg.mode,
        epochs_total: cfg.epochs,
        total_minibatches,
    };

    let mut wall = WallStats::default();
    let mut tracer = Tracer::new(a.trace, Role::Trainer, super::id_u32(a.part_id));
    let mut round: u64 = 0;
    let time_scale = a.compute.time_scale();
    let wait_budget = io_timeout(time_scale);
    // The barrier additionally waits on the *slowest* peer's whole round.
    let barrier_budget = wait_budget * 2;
    let run_start = Instant::now();
    for epoch in 0..cfg.epochs {
        let order = t.sampler.epoch_order(&t.train_nodes, epoch);
        let epoch_vstart = t.clock;
        let epoch_wstart = Instant::now();
        for mb in 0..a.max_mb_per_epoch {
            // Measured mode: snapshot the replica at round start.  Local
            // deltas are taken against it, and the reduced update is
            // applied on top of it — on inactive rounds too, so replicas
            // that skipped a minibatch still track their peers.
            let params_pre: Option<Vec<f32>> = runner.as_ref().map(|r| r.state.flat());
            let mut grads = vec![0.0f32; grads_len];
            let mb_vstart = t.clock;
            tracer.emit(
                mb_vstart,
                EventKind::MinibatchBegin { epoch: super::id_u32(epoch), mb: super::id_u32(mb) },
            );
            // Deterministic core: sampling, lookup, decision, counters.
            let active = t.step_minibatch(&ctx, epoch, mb, &order);
            if active {
                let mut plan = t
                    .fetch_plan
                    .replace(FetchPlan::default())
                    .expect("fetch plan armed");
                // The replay hook: record this minibatch's sampled demand
                // so `rudder replay` can re-drive the state machine from
                // the trace alone (sampling is seed-deterministic, so the
                // event is virtual and diff-gated like every counter).
                tracer.emit(
                    t.clock,
                    EventKind::SampleDemand {
                        epoch: super::id_u32(epoch),
                        mb: super::id_u32(mb),
                        targets: plan.targets,
                        sampled: plan.sampled,
                        remote: plan.unique_remote.clone(),
                    },
                );
                let admitted_n = plan.admitted.len() as u64;
                let evicted_n = plan.evicted.len() as u64;
                if admitted_n + evicted_n > 0 {
                    tracer.emit(
                        t.clock,
                        EventKind::Replacement { admitted: admitted_n, evicted: evicted_n },
                    );
                }
                // 1. Async prefetch of the replacement admissions — these
                //    overlap compute; the sim charges them as hidden.
                if !plan.admitted.is_empty() {
                    let admitted = std::mem::take(&mut plan.admitted);
                    let _ = a.prefetch_tx.send(PrefetchMsg::Fetch(admitted));
                }
                // 2. Urgent fetch of this minibatch's misses (in-flight
                //    dedup merges them with any pending prefetch).  Cloned:
                //    `missed` is re-read for the transient cleanup below.
                if !plan.missed.is_empty() {
                    let _ = a.prefetch_tx.send(PrefetchMsg::Fetch(plan.missed.clone()));
                }
                // 3. Assembly barrier: every sampled remote feature —
                //    buffer hits and fetched misses — must be resident.
                let w = Instant::now();
                if let Err(e) = a.store.wait_all(&plan.unique_remote, wait_budget) {
                    panic!("trainer {}: {e}", a.part_id);
                }
                let waited = w.elapsed().as_secs_f64();
                wall.fetch_wait += waited;
                tracer.emit(
                    t.clock,
                    EventKind::FetchWait {
                        nodes: plan.unique_remote.len() as u64,
                        wall_secs: waited,
                    },
                );
                let mut compute_wall = 0.0f64;
                // 4. Compute: real fwd/bwd on the gathered features
                //    (measured), or a scaled sleep of the modelled T_DDP
                //    (emulated).
                if let Some(r) = runner.as_mut() {
                    measured.fetch_wait_secs.push(waited);
                    let mbatch = plan
                        .minibatch
                        .take()
                        .expect("measured mode captures the minibatch");
                    let pre = params_pre.as_ref().expect("params snapshot");
                    let store = &a.store;
                    let (mut from_store, mut local, mut fallback) = (0u64, 0u64, 0u64);
                    let w = Instant::now();
                    let step = r.train_step_with(&mbatch, &ds.labels, |node, dst| {
                        if part.owner_of(node) == a.part_id {
                            // Partition-resident row: synthesized locally,
                            // never on the wire.
                            fill_features(ds.feature_seed, node, dst);
                            local += 1;
                        } else if store.copy_into(node, dst) {
                            from_store += 1;
                        } else {
                            // Covered by the assembly barrier; keep the
                            // numerics identical if it ever is not.
                            fill_features(ds.feature_seed, node, dst);
                            fallback += 1;
                        }
                    });
                    let dt = w.elapsed().as_secs_f64();
                    let loss = match step {
                        Ok((loss, _)) => loss,
                        Err(e) => panic!("trainer {}: measured train step: {e}", a.part_id),
                    };
                    wall.compute += dt;
                    compute_wall = dt;
                    measured.compute_secs.push(dt);
                    measured.losses.push(loss);
                    measured.rows_from_store += from_store;
                    measured.rows_local += local;
                    measured.rows_fallback += fallback;
                    // This round's real gradient blob: post − pre, i.e.
                    // −lr · local gradient.
                    for ((g, po), pr) in grads.iter_mut().zip(r.state.flat()).zip(pre) {
                        *g = po - *pr;
                    }
                } else if time_scale > 0.0 && plan.t_ddp > 0.0 {
                    let w = Instant::now();
                    std::thread::sleep(Duration::from_secs_f64(plan.t_ddp * time_scale));
                    compute_wall = w.elapsed().as_secs_f64();
                    wall.compute += compute_wall;
                }
                tracer.emit(
                    t.clock,
                    EventKind::Compute { virtual_secs: plan.t_ddp, wall_secs: compute_wall },
                );
                // 5. Bound the store: evictions plus transient misses that
                //    were not admitted this round.
                let mut drop_nodes = plan.evicted;
                for &n in &plan.missed {
                    if !t.buffer.contains(n) {
                        drop_nodes.push(n);
                    }
                }
                if !drop_nodes.is_empty() {
                    let _ = a.prefetch_tx.send(PrefetchMsg::Evict(drop_nodes));
                }
                wall.minibatches += 1;
            }
            // 6. DDP barrier: every trainer joins every round (inactive
            //    ones too), mirroring the sim's barrier arithmetic.  In
            //    measured mode `grads` carries the real local delta
            //    (zeros on inactive rounds — the replica contributed no
            //    step this round).
            let frame = Frame::Allreduce {
                part: super::id_u32(a.part_id),
                round,
                vclock: t.clock,
                grads,
            };
            if runner.is_some() {
                measured.grad_bytes += (grads_len * 4) as u64;
            }
            let w = Instant::now();
            let encoded = frame.encode().expect("allreduce frame within wire limits");
            a.hub_tx.send_frame(&encoded).expect("allreduce hub hung up");
            let reply = match a.hub_rx.recv_frame_timeout(barrier_budget) {
                Ok(Some(r)) => r,
                Ok(None) => panic!(
                    "trainer {}: allreduce hub closed mid-run at round {round}",
                    a.part_id
                ),
                Err(e) => panic!(
                    "trainer {}: allreduce barrier round {round} unresponsive ({e}); \
                     a peer trainer likely died",
                    a.part_id
                ),
            };
            let barrier_secs = w.elapsed().as_secs_f64();
            wall.barrier += barrier_secs;
            tracer.emit(t.clock, EventKind::AllreduceWait { round, wall_secs: barrier_secs });
            let (reduced, _) = Frame::decode(&reply).expect("bad hub frame");
            let Frame::Allreduce { vclock: max_vclock, grads: sum, .. } = reduced else {
                panic!("unexpected hub frame kind");
            };
            if let Some(r) = runner.as_mut() {
                measured.barrier_secs.push(barrier_secs);
                // Apply the mean of every replica's delta on top of the
                // round-start snapshot: all replicas end bit-identical
                // (the hub reduces in trainer-id order, so the sum is
                // deterministic too).
                let mut next = params_pre.expect("params snapshot");
                let inv_n = 1.0 / cfg.num_trainers as f32;
                for (p, g) in next.iter_mut().zip(&sum) {
                    *p += g * inv_n;
                }
                r.state.set_flat(&next).expect("param layout");
            }
            t.clock = max_vclock + allreduce;
            tracer.emit(
                t.clock,
                EventKind::MinibatchEnd {
                    epoch: super::id_u32(epoch),
                    mb: super::id_u32(mb),
                    step_vsecs: t.clock - mb_vstart,
                },
            );
            round += 1;
        }
        t.metrics.epoch_times.push(t.clock - epoch_vstart);
        wall.epochs.push(epoch_wstart.elapsed().as_secs_f64());
    }
    wall.total = run_start.elapsed().as_secs_f64();
    if let Some(r) = &runner {
        measured.param_hash = r.state.fingerprint();
    }
    let _ = a.prefetch_tx.send(PrefetchMsg::Shutdown);
    // Half-close the hub link so the hub (thread or process) sees EOF.
    a.hub_tx.close();
    TrainerOutput { metrics: t.metrics, wall, measured, trace: tracer.finish() }
}
