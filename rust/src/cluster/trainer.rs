//! The cluster trainer thread: real concurrency around the sim trainer's
//! deterministic core.
//!
//! Each trainer embeds a [`crate::sim::trainer::Trainer`] and drives it
//! minibatch by minibatch: the virtual-time state machine remains the
//! single source of truth for *what* happens (sampling, buffer lookups,
//! controller decisions, replacement rounds, all traffic counters — which
//! is what makes `same config + seed ⇒ identical counters` hold against
//! the sim), while this thread executes the resulting I/O for real:
//!
//! 1. replacement admissions are handed to the prefetcher (async, overlaps
//!    the compute phase),
//! 2. the minibatch's buffer misses are fetched urgently,
//! 3. the trainer blocks until every sampled remote feature is resident,
//! 4. compute runs (emulated at `time_scale × T_DDP` wall seconds),
//! 5. evictions + non-admitted transients leave the feature store,
//! 6. the minibatch closes with a *real* DDP barrier: an `Allreduce` frame
//!    to the hub, blocking on the reduced reply.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::classifier::trainer::TrainingSet;
use crate::gnn::{AnalyticModel, SageShape};
use crate::graph::Dataset;
use crate::metrics::RunMetrics;
use crate::net::Network;
use crate::partition::Partition;
use crate::sim::trainer::{FetchPlan, RunCtx};
use crate::sim::{self, RunConfig};

use super::prefetch::{FeatureStore, PrefetchMsg};
use super::transport::{FrameReceiver, FrameSender};
use super::wire::Frame;

/// Timeouts for feature waits and the allreduce barrier, bounded so that
/// a dead thread fails the whole run with a diagnostic instead of
/// deadlocking the remaining trainers (and the orchestrator's join)
/// forever.  Emulation sleeps scale with the user-supplied `time_scale`,
/// so the budgets do too: the base covers scheduling noise, the scaled
/// term covers ~30 virtual seconds of emulated cost per round — far above
/// any legitimate minibatch (T_DDP ≈ 0.1–0.3 virtual s, fetches less).
pub(crate) fn io_timeout(time_scale: f64) -> Duration {
    Duration::from_secs_f64(30.0 + 30.0 * time_scale.max(0.0))
}

/// Wall-clock accounting for one cluster trainer.
#[derive(Debug, Clone, Default)]
pub struct WallStats {
    /// Total wall seconds inside the epoch loop.
    pub total: f64,
    /// Wall seconds per epoch.
    pub epochs: Vec<f64>,
    /// Wall seconds blocked waiting for remote features (the exposed,
    /// un-overlapped part of communication).
    pub fetch_wait: f64,
    /// Wall seconds in (emulated) compute.
    pub compute: f64,
    /// Wall seconds blocked in the DDP barrier.
    pub barrier: f64,
    pub minibatches: u64,
}

/// Everything a trainer thread needs (moved into the thread at spawn).
/// The hub link is a transport-abstract frame link, so the same loop runs
/// over in-process channels or a TCP connection to a hub process.
pub(crate) struct TrainerArgs {
    pub part_id: usize,
    pub cfg: RunConfig,
    pub ds: Arc<Dataset>,
    pub part: Arc<Partition>,
    pub offline: Arc<Option<TrainingSet>>,
    pub store: Arc<FeatureStore>,
    pub prefetch_tx: Sender<PrefetchMsg>,
    pub hub_tx: Box<dyn FrameSender>,
    pub hub_rx: Box<dyn FrameReceiver>,
    pub max_mb_per_epoch: usize,
    pub time_scale: f64,
}

pub(crate) struct TrainerOutput {
    pub metrics: RunMetrics,
    pub wall: WallStats,
}

pub(crate) fn run_trainer(mut a: TrainerArgs) -> TrainerOutput {
    let cfg = &a.cfg;
    let ds: &Dataset = &a.ds;
    let part: &Partition = &a.part;
    let offline = (*a.offline).as_ref();

    // Identical model constants to `sim::run_on` (parity requirement).
    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), cfg.num_trainers);
    let compute = AnalyticModel::new(cfg.compute.clone(), shape);
    let allreduce = net.allreduce_time(shape.param_bytes());
    let grads_len = (shape.param_bytes() / 4) as usize;

    let mut t = sim::build_trainer(cfg, ds, part, a.part_id, offline);
    t.fetch_plan = Some(FetchPlan::default());

    // Warm start (MassiveGNN): stream the prepopulated residents' features
    // in the background; per-minibatch waits cover stragglers.
    let warm = t.buffer.resident_nodes();
    if !warm.is_empty() {
        let _ = a.prefetch_tx.send(PrefetchMsg::Fetch(warm));
    }

    let total_minibatches = (a.max_mb_per_epoch * cfg.epochs) as u64;
    let ctx = RunCtx {
        ds,
        part,
        net,
        compute,
        mode: cfg.mode,
        epochs_total: cfg.epochs,
        total_minibatches,
    };

    let mut wall = WallStats::default();
    let mut round: u64 = 0;
    let wait_budget = io_timeout(a.time_scale);
    // The barrier additionally waits on the *slowest* peer's whole round.
    let barrier_budget = wait_budget * 2;
    let run_start = Instant::now();
    for epoch in 0..cfg.epochs {
        let order = t.sampler.epoch_order(&t.train_nodes, epoch);
        let epoch_vstart = t.clock;
        let epoch_wstart = Instant::now();
        for mb in 0..a.max_mb_per_epoch {
            // Deterministic core: sampling, lookup, decision, counters.
            let active = t.step_minibatch(&ctx, epoch, mb, &order);
            if active {
                let mut plan = t
                    .fetch_plan
                    .replace(FetchPlan::default())
                    .expect("fetch plan armed");
                // 1. Async prefetch of the replacement admissions — these
                //    overlap compute; the sim charges them as hidden.
                if !plan.admitted.is_empty() {
                    let admitted = std::mem::take(&mut plan.admitted);
                    let _ = a.prefetch_tx.send(PrefetchMsg::Fetch(admitted));
                }
                // 2. Urgent fetch of this minibatch's misses (in-flight
                //    dedup merges them with any pending prefetch).  Cloned:
                //    `missed` is re-read for the transient cleanup below.
                if !plan.missed.is_empty() {
                    let _ = a.prefetch_tx.send(PrefetchMsg::Fetch(plan.missed.clone()));
                }
                // 3. Assembly barrier: every sampled remote feature —
                //    buffer hits and fetched misses — must be resident.
                let w = Instant::now();
                if let Err(e) = a.store.wait_all(&plan.unique_remote, wait_budget) {
                    panic!("trainer {}: {e}", a.part_id);
                }
                wall.fetch_wait += w.elapsed().as_secs_f64();
                // 4. Compute (scaled wall-time emulation of T_DDP).
                if a.time_scale > 0.0 && plan.t_ddp > 0.0 {
                    let w = Instant::now();
                    std::thread::sleep(Duration::from_secs_f64(plan.t_ddp * a.time_scale));
                    wall.compute += w.elapsed().as_secs_f64();
                }
                // 5. Bound the store: evictions plus transient misses that
                //    were not admitted this round.
                let mut drop_nodes = plan.evicted;
                for &n in &plan.missed {
                    if !t.buffer.contains(n) {
                        drop_nodes.push(n);
                    }
                }
                if !drop_nodes.is_empty() {
                    let _ = a.prefetch_tx.send(PrefetchMsg::Evict(drop_nodes));
                }
                wall.minibatches += 1;
            }
            // 6. DDP barrier: every trainer joins every round (inactive
            //    ones too), mirroring the sim's barrier arithmetic.
            let frame = Frame::Allreduce {
                part: a.part_id as u32,
                round,
                vclock: t.clock,
                grads: vec![0.0; grads_len],
            };
            let w = Instant::now();
            a.hub_tx.send_frame(&frame.encode()).expect("allreduce hub hung up");
            let reply = match a.hub_rx.recv_frame_timeout(barrier_budget) {
                Ok(Some(r)) => r,
                Ok(None) => panic!(
                    "trainer {}: allreduce hub closed mid-run at round {round}",
                    a.part_id
                ),
                Err(e) => panic!(
                    "trainer {}: allreduce barrier round {round} unresponsive ({e}); \
                     a peer trainer likely died",
                    a.part_id
                ),
            };
            wall.barrier += w.elapsed().as_secs_f64();
            let (reduced, _) = Frame::decode(&reply).expect("bad hub frame");
            let Frame::Allreduce { vclock: max_vclock, .. } = reduced else {
                panic!("unexpected hub frame kind");
            };
            t.clock = max_vclock + allreduce;
            round += 1;
        }
        t.metrics.epoch_times.push(t.clock - epoch_vstart);
        wall.epochs.push(epoch_wstart.elapsed().as_secs_f64());
    }
    wall.total = run_start.elapsed().as_secs_f64();
    let _ = a.prefetch_tx.send(PrefetchMsg::Shutdown);
    // Half-close the hub link so the hub (thread or process) sees EOF.
    a.hub_tx.close();
    TrainerOutput { metrics: t.metrics, wall }
}
