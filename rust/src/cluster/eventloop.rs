//! Readiness-polled event-loop transport ([`Transport::Event`]).
//!
//! The threaded TCP backend spends one blocking pump thread per link —
//! `n` trainers × (`n` servers + hub) links means O(n²) parked threads,
//! which caps the cluster at dozens of roles.  This backend replaces all
//! of them with **one** I/O thread:
//!
//! * every logical link a trainer owns (one per feature server, one for
//!   the allreduce hub) is *multiplexed* over a single physical
//!   connection, tagged per frame with a `u32` channel id
//!   ([`MuxAssembler`] is the framing codec);
//! * sockets are nonblocking; the loop sweeps them for readiness
//!   (`WouldBlock` = not ready — the zero-dependency stand-in for
//!   `epoll`), reassembling partial frames per connection and routing
//!   complete frames to the owning endpoint's inbox;
//! * each connection has a write-side [`WriteQueue`] with a byte cap:
//!   senders enqueue whole tagged frames and *block* once the cap is
//!   exceeded (backpressure), while the loop drains queues into
//!   syscall-sized coalesced writes — many small `FetchReq`/`FetchResp`
//!   frames leave in one `write` call.
//!
//! The protocol layer is unchanged: endpoints still speak
//! [`FrameSender`]/[`FrameReceiver`], servers and the hub still consume
//! [`NetMsg`] inboxes, and every [`crate::metrics::WireStats`] counter
//! stays a pure function of config + seed (`wire_parity` holds bit-exact
//! against the channel and threaded-TCP backends).
//!
//! Lifecycle is close-driven, like the other backends: a logical link's
//! [`FrameSender::close`] enqueues an 8-byte *close marker*
//! (`[channel][len=0]`) behind everything already queued; the receiving
//! side drops that channel's route (disconnecting the endpoint's inbox
//! clone).  Once every channel on a connection is closed and flushed the
//! loop half-closes the socket, and it exits when all connections are
//! drained in both directions.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::Result;
use crate::trace::{EventKind, Role, TraceEvent, Tracer};

use super::prefetch::PrefetchMsg;
use super::transport::{
    ChannelReceiver, FrameReceiver, FrameSender, LinkStatsHandle, NetMsg,
};
use super::wire::MAX_FRAME_BYTES;

/// Default per-connection write-queue capacity before senders block.
pub const WRITE_QUEUE_CAP: usize = 1 << 20;

/// Coalescing bound: the loop packs at most this many queued bytes into
/// one `write` syscall.
const WRITE_BATCH_BYTES: usize = 256 * 1024;

/// Consecutive idle sweeps before the loop parks on its waker instead of
/// yielding (keeps hot-path latency competitive with blocking threads
/// while not burning a core when the cluster is computing).
const IDLE_SWEEPS_BEFORE_PARK: u32 = 64;

// ---------------------------------------------------------------------------
// mux framing

/// One decoded event from a multiplexed byte stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MuxEvent {
    /// A whole standard frame (length prefix + body, ready for
    /// [`super::wire::Frame::decode`]) on logical channel `.0`.
    Frame(u32, Vec<u8>),
    /// Channel `.0` was half-closed by the peer (no more frames follow on
    /// that channel).
    Close(u32),
}

/// Incremental reassembly of the multiplexed stream format:
///
/// ```text
/// [u32 channel][u32 body_len][u8 kind][payload]   — a tagged frame
/// [u32 channel][u32 0]                            — a channel-close marker
/// ```
///
/// i.e. a 4-byte channel id in front of every standard wire frame, with a
/// zero body length (invalid for real frames) reserved as the close
/// marker.  Bytes go in at whatever granularity readiness delivers them —
/// a frame may need many wakeups to complete — whole events come out.
/// Pure (no I/O), so splitting behavior is property-testable.
#[derive(Default)]
pub struct MuxAssembler {
    buf: Vec<u8>,
}

/// Tag `frame` (a standard encoded frame) with `channel` for the wire.
pub fn encode_tagged(channel: u32, frame: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + frame.len());
    out.extend_from_slice(&channel.to_le_bytes());
    out.extend_from_slice(frame);
    out
}

/// The 8-byte close marker for `channel`.
pub fn close_marker(channel: u32) -> [u8; 8] {
    let mut out = [0u8; 8];
    out[..4].copy_from_slice(&channel.to_le_bytes());
    out
}

impl MuxAssembler {
    pub fn new() -> MuxAssembler {
        MuxAssembler::default()
    }

    /// Feed raw stream bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as an event.  Non-zero at EOF
    /// means the stream died mid-frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Extract the next complete event.  `Ok(None)` = need more bytes.
    /// Errors on an oversized body length — the stream is unrecoverable
    /// past that point, never silently resynced.
    pub fn next_event(&mut self) -> Result<Option<MuxEvent>> {
        if self.buf.len() < 8 {
            return Ok(None);
        }
        let channel = u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]);
        let body_len =
            u32::from_le_bytes([self.buf[4], self.buf[5], self.buf[6], self.buf[7]]) as usize;
        if body_len == 0 {
            self.consume(8);
            return Ok(Some(MuxEvent::Close(channel)));
        }
        crate::ensure!(
            body_len <= MAX_FRAME_BYTES,
            "eventloop: frame body {body_len} on channel {channel} exceeds cap"
        );
        let total = 4 + 4 + body_len;
        if self.buf.len() < total {
            return Ok(None);
        }
        let frame = self.buf[4..total].to_vec();
        self.consume(total);
        Ok(Some(MuxEvent::Frame(channel, frame)))
    }

    fn consume(&mut self, n: usize) {
        let rest = self.buf.split_off(n);
        self.buf = rest;
    }
}

// ---------------------------------------------------------------------------
// waker + write queue

/// Wakes the parked loop thread after an enqueue.  The atomic flag
/// deduplicates wakes so a burst of sends posts at most one token.
#[derive(Clone)]
pub(crate) struct Waker {
    tx: Sender<()>,
    flagged: Arc<AtomicBool>,
}

impl Waker {
    fn wake(&self) {
        if !self.flagged.swap(true, Ordering::AcqRel) {
            let _ = self.tx.send(());
        }
    }
}

struct QueueInner {
    /// Whole tagged frames (or close markers) awaiting the loop.
    chunks: Vec<Vec<u8>>,
    queued_bytes: usize,
    /// Close markers enqueued so far (one per logical out-channel).
    closes: usize,
    /// Loop died or the connection errored: senders fail fast instead of
    /// blocking on a queue nobody will ever drain.
    wedged: bool,
}

/// Write-side queue of one physical connection, shared between the
/// endpoint threads that enqueue tagged frames and the loop that drains
/// them.  Enqueues block while more than `cap` bytes are queued — the
/// backpressure half of the nonblocking-sender contract.
pub(crate) struct WriteQueue {
    inner: Mutex<QueueInner>,
    can_send: Condvar,
    cap: usize,
    /// Logical out-channels this connection carries; the loop half-closes
    /// the socket once this many close markers have been flushed.
    expected_closes: usize,
    waker: Waker,
}

/// How long a backpressured sender sleeps between re-checks of the queue.
/// Bounded so a lost wakeup (the draining loop panicking without wedging
/// the queue first) degrades to polling instead of hanging shutdown —
/// the `untimed-condvar-wait` audit rule pins this property.
const ENQUEUE_WAIT_SLICE: Duration = Duration::from_millis(50);

impl WriteQueue {
    fn new(cap: usize, expected_closes: usize, waker: Waker) -> Arc<WriteQueue> {
        Arc::new(WriteQueue {
            inner: Mutex::new(QueueInner {
                chunks: Vec::new(),
                queued_bytes: 0,
                closes: 0,
                wedged: false,
            }),
            can_send: Condvar::new(),
            cap,
            expected_closes,
            waker,
        })
    }

    /// Lock the queue state, recovering from poisoning.  Every mutation
    /// under this lock keeps `queued_bytes` / `chunks` / `closes`
    /// consistent before any point that can panic, so the state a
    /// panicking holder leaves behind is still safe to drain — recovering
    /// (instead of cascading the panic into every sender and the loop)
    /// is what lets the survivors flush and shut down cleanly.
    fn locked(&self) -> MutexGuard<'_, QueueInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Queue one chunk of tagged bytes, blocking while the queue is over
    /// capacity.
    fn enqueue(&self, bytes: Vec<u8>) -> Result<()> {
        let mut q = self.locked();
        while q.queued_bytes >= self.cap && !q.wedged {
            q = self
                .can_send
                .wait_timeout(q, ENQUEUE_WAIT_SLICE)
                .unwrap_or_else(PoisonError::into_inner)
                .0;
        }
        crate::ensure!(!q.wedged, "eventloop: send on a dead connection");
        q.queued_bytes += bytes.len();
        q.chunks.push(bytes);
        drop(q);
        self.waker.wake();
        Ok(())
    }

    /// Queue a channel-close marker.  Never blocks on capacity — close
    /// paths must always make progress — and is a no-op once wedged.
    fn enqueue_close(&self, channel: u32) {
        let mut q = self.locked();
        if !q.wedged {
            let m = close_marker(channel);
            q.queued_bytes += m.len();
            q.chunks.push(m.to_vec());
            q.closes += 1;
        }
        drop(q);
        self.waker.wake();
    }

    /// Loop side: take up to `max` queued bytes as one coalesced buffer
    /// (always at least one whole chunk), releasing blocked senders.
    fn take_batch(&self, max: usize) -> Vec<u8> {
        let mut q = self.locked();
        let mut out = Vec::new();
        let mut taken = 0usize;
        while taken < q.chunks.len() {
            let len = q.chunks[taken].len();
            if !out.is_empty() && out.len() + len > max {
                break;
            }
            out.extend_from_slice(&q.chunks[taken]);
            taken += 1;
        }
        q.chunks.drain(..taken);
        q.queued_bytes -= out.len();
        drop(q);
        if !out.is_empty() {
            self.can_send.notify_all();
        }
        out
    }

    /// Every out-channel closed and nothing left to drain?
    fn fully_closed(&self) -> bool {
        let q = self.locked();
        q.closes >= self.expected_closes && q.chunks.is_empty()
    }

    /// Kill the queue: senders unblock and error from now on.
    fn wedge(&self) {
        self.locked().wedged = true;
        self.can_send.notify_all();
    }

    #[cfg(test)]
    fn queued_bytes(&self) -> usize {
        self.locked().queued_bytes
    }
}

// ---------------------------------------------------------------------------
// sender endpoint

/// [`FrameSender`] for one logical channel of an event-loop connection:
/// tags each frame with the channel id and enqueues it (blocking only on
/// queue backpressure — delivery continues asynchronously in the loop).
pub struct EventFrameSender {
    queue: Arc<WriteQueue>,
    channel: u32,
    /// Trainer-owned directions count `frames_sent` here; reply
    /// directions count nothing (the demux on the receiving side counts
    /// `frames_recv`, mirroring the TCP receive path).
    stats: Option<LinkStatsHandle>,
    closed: bool,
}

impl EventFrameSender {
    fn new(queue: Arc<WriteQueue>, channel: u32, stats: Option<LinkStatsHandle>) -> Self {
        EventFrameSender { queue, channel, stats, closed: false }
    }
}

impl FrameSender for EventFrameSender {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        crate::ensure!(!self.closed, "eventloop: send on closed channel {}", self.channel);
        self.queue.enqueue(encode_tagged(self.channel, frame))?;
        if let Some(s) = &self.stats {
            s.count_sent(frame.len());
        }
        Ok(())
    }

    /// Pack the whole batch into a single queue chunk: the loop writes it
    /// with one syscall (up to the coalescing bound).
    fn send_frames(&mut self, frames: &[Vec<u8>]) -> Result<()> {
        crate::ensure!(!self.closed, "eventloop: send on closed channel {}", self.channel);
        if frames.is_empty() {
            return Ok(());
        }
        let total: usize = frames.iter().map(|f| 4 + f.len()).sum();
        let mut buf = Vec::with_capacity(total);
        for f in frames {
            buf.extend_from_slice(&self.channel.to_le_bytes());
            buf.extend_from_slice(f);
        }
        self.queue.enqueue(buf)?;
        if let Some(s) = &self.stats {
            for f in frames {
                s.count_sent(f.len());
            }
        }
        Ok(())
    }

    fn close(&mut self) {
        if !self.closed {
            self.closed = true;
            self.queue.enqueue_close(self.channel);
        }
    }
}

impl Drop for EventFrameSender {
    fn drop(&mut self) {
        // A dropped sender (e.g. the hub loop returning) still owes the
        // peer its end-of-stream marker.
        self.close();
    }
}

// ---------------------------------------------------------------------------
// the loop

/// Inbound route for one logical channel of a connection: delivery into
/// the owning endpoint's inbox, plus the trainer link cell to count
/// received frames on (trainer-side routes only).
struct Route {
    deliver: Box<dyn FnMut(Vec<u8>) -> bool + Send>,
    stats: Option<LinkStatsHandle>,
}

/// One registered nonblocking connection (the loop owns both directions).
struct Conn {
    stream: TcpStream,
    mux: MuxAssembler,
    wq: Arc<WriteQueue>,
    /// Partially-written coalesced batch ([`WriteQueue::take_batch`]
    /// output that hit `WouldBlock` mid-write).
    pending: Vec<u8>,
    pending_off: usize,
    routes: Vec<Option<Route>>,
    write_shut: bool,
    read_eof: bool,
    label: String,
}

impl Conn {
    fn done(&self) -> bool {
        self.write_shut && self.read_eof
    }

    /// Flush queued writes (nonblocking).  Returns whether bytes moved.
    fn sweep_write(&mut self, conn_id: u32, tracer: &mut Tracer) -> Result<bool> {
        if self.write_shut {
            return Ok(false);
        }
        let mut progress = false;
        loop {
            if self.pending_off == self.pending.len() {
                self.pending = self.wq.take_batch(WRITE_BATCH_BYTES);
                self.pending_off = 0;
                if self.pending.is_empty() {
                    break;
                }
                if tracer.enabled() {
                    tracer.emit(
                        0.0,
                        EventKind::LinkFlush {
                            conn: conn_id,
                            frames: count_tagged_entries(&self.pending),
                            bytes: self.pending.len() as u64,
                        },
                    );
                }
            }
            match self.stream.write(&self.pending[self.pending_off..]) {
                Ok(0) => crate::bail!("eventloop: {}: write returned 0", self.label),
                Ok(k) => {
                    self.pending_off += k;
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(progress),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => crate::bail!("eventloop: {}: write failed: {e}", self.label),
            }
        }
        // Everything queued so far is on the wire; if every logical
        // channel has closed, the connection itself can half-close.
        if self.wq.fully_closed() {
            let _ = self.stream.shutdown(Shutdown::Write);
            self.write_shut = true;
        }
        Ok(progress)
    }

    /// Read available bytes and route complete events.  Returns whether
    /// bytes moved.
    fn sweep_read(&mut self, conn_id: u32, tracer: &mut Tracer) -> Result<bool> {
        if self.read_eof {
            return Ok(false);
        }
        let mut chunk = [0u8; 64 * 1024];
        let mut progress = false;
        // Bounded reads per sweep so one firehose connection cannot starve
        // the others' writes.
        for _ in 0..4 {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    crate::ensure!(
                        self.mux.pending() == 0,
                        "eventloop: {}: EOF mid-frame ({} bytes pending)",
                        self.label,
                        self.mux.pending()
                    );
                    self.read_eof = true;
                    // EOF is the backstop teardown: any route the peer did
                    // not explicitly close drops here.
                    for r in self.routes.iter_mut() {
                        *r = None;
                    }
                    return Ok(true);
                }
                Ok(k) => {
                    progress = true;
                    self.mux.push(&chunk[..k]);
                    while let Some(ev) = self.mux.next_event()? {
                        self.route(ev, conn_id, tracer);
                    }
                    if k < chunk.len() {
                        return Ok(progress);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(progress),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => crate::bail!("eventloop: {}: read failed: {e}", self.label),
            }
        }
        Ok(progress)
    }

    fn route(&mut self, ev: MuxEvent, conn_id: u32, tracer: &mut Tracer) {
        match ev {
            MuxEvent::Frame(c, frame) => {
                let Some(slot) = self.routes.get_mut(c as usize) else {
                    crate::log_debug!("{}: frame on unknown channel {c}", self.label);
                    return;
                };
                let Some(r) = slot else {
                    crate::log_debug!("{}: frame on closed channel {c}", self.label);
                    return;
                };
                if let Some(s) = &r.stats {
                    s.count_recv(frame.len());
                }
                if !(r.deliver)(frame) {
                    // Inbox hung up: stop delivering on this channel.
                    *slot = None;
                }
            }
            MuxEvent::Close(c) => {
                tracer.emit(0.0, EventKind::ChannelClose { conn: conn_id, channel: c });
                if let Some(slot) = self.routes.get_mut(c as usize) {
                    // Dropping the route drops the inbox clone — the
                    // endpoint sees the disconnect once every clone is
                    // gone, exactly like the channel backend.
                    *slot = None;
                }
            }
        }
    }

    fn fail(&mut self, err: &crate::error::RudderError) {
        crate::log_info!("{}: connection failed: {err}", self.label);
        self.wq.wedge();
        for r in self.routes.iter_mut() {
            *r = None;
        }
        let _ = self.stream.shutdown(Shutdown::Both);
        self.write_shut = true;
        self.read_eof = true;
    }
}

/// Count whole tagged entries (frames and close markers) in a coalesced
/// write batch — `[u32 channel][u32 body_len][body]` repeated, a zero
/// body length being a close marker.  Batches always hold whole chunks,
/// so the walk lands exactly on the end.
fn count_tagged_entries(batch: &[u8]) -> u64 {
    let mut n = 0u64;
    let mut pos = 0usize;
    while pos + 8 <= batch.len() {
        let body_len =
            u32::from_le_bytes([batch[pos + 4], batch[pos + 5], batch[pos + 6], batch[pos + 7]])
                as usize;
        pos += 8 + body_len;
        n += 1;
    }
    n
}

/// The loop body: sweep every connection for read/write readiness until
/// all are drained and closed in both directions.  Adaptive idling: spin
/// with `yield_now` while traffic flows, park on the waker once idle.
/// Returns the loop's trace buffer (empty unless `trace`).
fn event_loop(
    mut conns: Vec<Conn>,
    cmd_rx: Receiver<()>,
    flagged: Arc<AtomicBool>,
    trace: bool,
) -> Vec<TraceEvent> {
    let mut tracer = Tracer::new(trace, Role::EventLoop, 0);
    let mut idle_sweeps = 0u32;
    loop {
        flagged.store(false, Ordering::Release);
        while cmd_rx.try_recv().is_ok() {}
        let mut progress = false;
        let mut all_done = true;
        for (i, conn) in conns.iter_mut().enumerate() {
            if conn.done() {
                continue;
            }
            match conn.sweep_write(super::id_u32(i), &mut tracer) {
                Ok(p) => progress |= p,
                Err(e) => conn.fail(&e),
            }
            match conn.sweep_read(super::id_u32(i), &mut tracer) {
                Ok(p) => progress |= p,
                Err(e) => conn.fail(&e),
            }
            all_done &= conn.done();
        }
        if all_done {
            break;
        }
        if progress {
            idle_sweeps = 0;
            continue;
        }
        idle_sweeps += 1;
        if idle_sweeps < IDLE_SWEEPS_BEFORE_PARK {
            std::thread::yield_now();
        } else {
            // Park until a sender wakes us; the timeout is a safety net
            // (all traffic originates from our own enqueues, which wake).
            let _ = cmd_rx.recv_timeout(Duration::from_millis(2));
        }
    }
    for conn in &conns {
        conn.wq.wedge();
    }
    tracer.finish()
}

// ---------------------------------------------------------------------------
// cluster wiring

/// A trainer's endpoint set over the event transport — the multiplexed
/// equivalent of [`super::transport::TrainerDial`].
pub(crate) struct EventTrainerEnd {
    /// Request senders, one per feature server, in channel (= partition)
    /// order.
    pub request_links: Vec<Box<dyn FrameSender>>,
    pub hub_tx: Box<dyn FrameSender>,
    pub hub_rx: Box<dyn FrameReceiver>,
    /// Link cells: server channels in partition order, then the hub
    /// channel.
    pub links: Vec<LinkStatsHandle>,
}

/// Everything [`super::run`] needs to run a cluster over the event
/// transport: per-trainer endpoints, pre-registered reply routes for the
/// servers and the hub, and the single I/O thread's handle.
pub(crate) struct EventCluster {
    pub trainers: Vec<EventTrainerEnd>,
    /// `server_prereg[p]` = reply senders for feature server `p`, one per
    /// trainer.
    pub server_prereg: Vec<Vec<(u32, Box<dyn FrameSender>)>>,
    pub hub_prereg: Vec<(u32, Box<dyn FrameSender>)>,
    /// Joins to the loop's trace buffer (empty unless tracing).
    pub loop_handle: JoinHandle<Vec<TraceEvent>>,
}

/// Build the full event-loop topology for `n` trainers: one loopback
/// "switch" listener, one physical connection per trainer carrying `n+1`
/// logical channels (channel `p` → server `p`, channel `n` → hub), and
/// one loop thread owning both ends of every connection.
pub(crate) fn wire_event_cluster(
    n: usize,
    server_txs: &[Sender<NetMsg>],
    hub_tx: &Sender<NetMsg>,
    pf_txs: &[Sender<PrefetchMsg>],
    trace: bool,
) -> Result<EventCluster> {
    crate::ensure!(server_txs.len() == n && pf_txs.len() == n, "eventloop: wiring arity");
    let (cmd_tx, cmd_rx) = mpsc::channel::<()>();
    let flagged = Arc::new(AtomicBool::new(false));
    let waker = Waker { tx: cmd_tx, flagged: flagged.clone() };

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;

    let hub_channel = super::id_u32(n);
    let mut conns: Vec<Conn> = Vec::with_capacity(2 * n);
    let mut trainers: Vec<EventTrainerEnd> = Vec::with_capacity(n);
    let mut server_prereg: Vec<Vec<(u32, Box<dyn FrameSender>)>> =
        (0..n).map(|_| Vec::with_capacity(n)).collect();
    let mut hub_prereg: Vec<(u32, Box<dyn FrameSender>)> = Vec::with_capacity(n);

    for t in 0..n {
        // Loopback accept order is FIFO, so connect-then-accept pairs the
        // two ends of the same connection deterministically.
        let dial = TcpStream::connect(addr)?;
        let (accept, _) = listener.accept()?;
        for s in [&dial, &accept] {
            s.set_nodelay(true)?;
            s.set_nonblocking(true)?;
        }

        let links: Vec<LinkStatsHandle> = (0..n)
            .map(|p| LinkStatsHandle::on_channel(format!("server:{p}"), super::id_u32(p)))
            .chain([LinkStatsHandle::on_channel("hub", hub_channel)])
            .collect();
        let (hub_reply_tx, hub_reply_rx) = mpsc::channel::<Vec<u8>>();

        // Trainer-side (dial) demux: responses into the prefetcher inbox,
        // reduced gradients into the hub reply channel.
        let dial_wq = WriteQueue::new(WRITE_QUEUE_CAP, n + 1, waker.clone());
        let dial_routes: Vec<Option<Route>> = (0..n)
            .map(|p| {
                let tx = pf_txs[t].clone();
                Some(Route {
                    deliver: Box::new(move |b| tx.send(PrefetchMsg::Wire(b)).is_ok()),
                    stats: Some(links[p].clone()),
                })
            })
            .chain([Some(Route {
                deliver: Box::new(move |b| hub_reply_tx.send(b).is_ok()),
                stats: Some(links[n].clone()),
            })])
            .collect();
        conns.push(Conn {
            stream: dial,
            mux: MuxAssembler::new(),
            wq: dial_wq.clone(),
            pending: Vec::new(),
            pending_off: 0,
            routes: dial_routes,
            write_shut: false,
            read_eof: false,
            label: format!("event-dial-t{t}"),
        });

        // Switch-side (accept) demux: requests into the owning server's
        // inbox, gradient contributions into the hub's.
        let accept_wq = WriteQueue::new(WRITE_QUEUE_CAP, n + 1, waker.clone());
        let accept_routes: Vec<Option<Route>> = (0..n)
            .map(|p| {
                let tx = server_txs[p].clone();
                Some(Route {
                    deliver: Box::new(move |b| tx.send(NetMsg::Frame(b)).is_ok()),
                    stats: None,
                })
            })
            .chain([{
                let tx = hub_tx.clone();
                Some(Route {
                    deliver: Box::new(move |b| tx.send(NetMsg::Frame(b)).is_ok()),
                    stats: None,
                })
            }])
            .collect();
        conns.push(Conn {
            stream: accept,
            mux: MuxAssembler::new(),
            wq: accept_wq.clone(),
            pending: Vec::new(),
            pending_off: 0,
            routes: accept_routes,
            write_shut: false,
            read_eof: false,
            label: format!("event-switch-t{t}"),
        });

        // Reply senders ride the switch-side queue, tagged per channel.
        for (p, prereg) in server_prereg.iter_mut().enumerate() {
            prereg.push((
                super::id_u32(t),
                Box::new(EventFrameSender::new(accept_wq.clone(), super::id_u32(p), None))
                    as Box<dyn FrameSender>,
            ));
        }
        hub_prereg.push((
            super::id_u32(t),
            Box::new(EventFrameSender::new(accept_wq.clone(), hub_channel, None)),
        ));

        let request_links: Vec<Box<dyn FrameSender>> = (0..n)
            .map(|p| {
                Box::new(EventFrameSender::new(
                    dial_wq.clone(),
                    super::id_u32(p),
                    Some(links[p].clone()),
                )) as Box<dyn FrameSender>
            })
            .collect();
        trainers.push(EventTrainerEnd {
            request_links,
            hub_tx: Box::new(EventFrameSender::new(
                dial_wq.clone(),
                hub_channel,
                Some(links[n].clone()),
            )),
            hub_rx: Box::new(ChannelReceiver::new(hub_reply_rx)),
            links,
        });
    }

    let loop_handle = std::thread::Builder::new()
        .name("rudder-eventloop".into())
        .spawn(move || event_loop(conns, cmd_rx, flagged, trace))
        .expect("spawn event loop thread");

    Ok(EventCluster { trainers, server_prereg, hub_prereg, loop_handle })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;
    use crate::cluster::wire::{Frame, ROLE_TRAINER};

    #[test]
    fn mux_reassembles_interleaved_channels_byte_by_byte() {
        // A frame split across many "readiness wakeups" (here: one byte
        // per push) must come out whole, channels and markers intact.
        let a = Frame::FetchReq { req_id: 1, from: 0, nodes: vec![7, 8, 9] }.encode().unwrap();
        let b = Frame::Hello { role: ROLE_TRAINER, id: 2 }.encode().unwrap();
        let mut stream = encode_tagged(3, &a);
        stream.extend_from_slice(&close_marker(3));
        stream.extend_from_slice(&encode_tagged(0, &b));
        stream.extend_from_slice(&close_marker(0));
        let mut mux = MuxAssembler::new();
        let mut out = Vec::new();
        for &byte in &stream {
            mux.push(&[byte]);
            while let Some(ev) = mux.next_event().unwrap() {
                out.push(ev);
            }
        }
        assert_eq!(
            out,
            vec![
                MuxEvent::Frame(3, a),
                MuxEvent::Close(3),
                MuxEvent::Frame(0, b),
                MuxEvent::Close(0),
            ]
        );
        assert_eq!(mux.pending(), 0);
    }

    #[test]
    fn mux_rejects_oversized_body() {
        let mut mux = MuxAssembler::new();
        mux.push(&encode_tagged(1, &u32::MAX.to_le_bytes()));
        assert!(mux.next_event().is_err());
    }

    #[test]
    fn write_queue_backpressure_blocks_until_drained() {
        let (tx, _rx) = mpsc::channel();
        let waker = Waker { tx, flagged: Arc::new(AtomicBool::new(false)) };
        // Cap of 64 bytes: the second large frame must block the sender
        // until the loop side drains the queue.
        let wq = WriteQueue::new(64, 1, waker);
        let mut sender = EventFrameSender::new(wq.clone(), 0, None);
        let frame =
            Frame::FetchReq { req_id: 1, from: 0, nodes: (0..32).collect() }.encode().unwrap();
        sender.send_frame(&frame).unwrap(); // fills past the cap
        assert!(wq.queued_bytes() > 64);
        let (done_tx, done_rx) = mpsc::channel();
        let f2 = frame.clone();
        let blocked = std::thread::spawn(move || {
            sender.send_frame(&f2).unwrap(); // blocks on backpressure
            done_tx.send(()).unwrap();
            sender.close();
        });
        assert!(
            done_rx.recv_timeout(Duration::from_millis(100)).is_err(),
            "second send must block while the queue is over capacity"
        );
        let batch = wq.take_batch(usize::MAX);
        assert_eq!(batch.len(), 4 + frame.len(), "first tagged frame drained");
        done_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("drain must unblock the sender");
        blocked.join().unwrap();
        // Second frame + close marker are now queued; after draining them
        // the queue reports fully closed.
        assert!(!wq.fully_closed());
        let rest = wq.take_batch(usize::MAX);
        assert_eq!(rest.len(), 4 + frame.len() + 8);
        assert!(wq.fully_closed());
    }

    #[test]
    fn wedged_queue_fails_senders_fast() {
        let (tx, _rx) = mpsc::channel();
        let waker = Waker { tx, flagged: Arc::new(AtomicBool::new(false)) };
        let wq = WriteQueue::new(16, 1, waker);
        let mut sender = EventFrameSender::new(wq.clone(), 0, None);
        let frame = Frame::Hello { role: ROLE_TRAINER, id: 1 }.encode().unwrap();
        sender.send_frame(&frame).unwrap();
        wq.wedge();
        let err = sender.send_frame(&frame).unwrap_err();
        assert!(err.to_string().contains("dead connection"), "{err}");
    }

    #[test]
    fn event_cluster_roundtrip_with_counters() {
        // n=1 micro-cluster with hand-held inboxes: request up through the
        // switch, reply back down through the demux, then close-driven
        // teardown all the way to loop exit.
        let (server_tx, server_rx) = mpsc::channel::<NetMsg>();
        let (hub_tx, hub_rx) = mpsc::channel::<NetMsg>();
        let (pf_tx, pf_rx) = mpsc::channel::<PrefetchMsg>();
        let mut ec = wire_event_cluster(1, &[server_tx], &hub_tx, &[pf_tx], true).unwrap();
        drop(hub_tx);

        let req = Frame::FetchReq { req_id: 7, from: 0, nodes: vec![1, 2, 3] }.encode().unwrap();
        let mut end = ec.trainers.pop().unwrap();
        end.request_links[0].send_frame(&req).unwrap();
        let got = match server_rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            NetMsg::Frame(b) => b,
            NetMsg::Register(..) => panic!("unexpected register"),
        };
        assert_eq!(got, req);

        let resp = Frame::FetchResp { req_id: 7, feat_dim: 1, nodes: vec![1], feats: vec![0.5] }
            .encode()
            .unwrap();
        let (_, mut reply) = ec.server_prereg.remove(0).remove(0);
        reply.send_frame(&resp).unwrap();
        match pf_rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            PrefetchMsg::Wire(b) => assert_eq!(b, resp),
            _ => panic!("expected wire frame"),
        }

        let grad = Frame::Allreduce { part: 0, round: 0, vclock: 1.0, grads: vec![1.0] }
            .encode()
            .unwrap();
        end.hub_tx.send_frame(&grad).unwrap();
        match hub_rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            NetMsg::Frame(b) => assert_eq!(b, grad),
            NetMsg::Register(..) => panic!("unexpected register"),
        }
        let reduced = grad.clone();
        let (_, mut hub_reply) = ec.hub_prereg.remove(0);
        hub_reply.send_frame(&reduced).unwrap();
        assert_eq!(end.hub_rx.recv_frame().unwrap().unwrap(), reduced);

        // Counters: trainer link cells saw one frame each way per link.
        let server_link = end.links[0].snapshot();
        assert_eq!((server_link.frames_sent, server_link.bytes_sent), (1, req.len() as u64));
        assert_eq!((server_link.frames_recv, server_link.bytes_recv), (1, resp.len() as u64));
        assert_eq!(server_link.channel, 0);
        let hub_link = end.links[1].snapshot();
        assert_eq!(hub_link.frames_recv, 1);
        assert_eq!(hub_link.channel, 1);

        // Close everything; the loop must drain and exit on its own.
        for l in end.request_links.iter_mut() {
            l.close();
        }
        end.hub_tx.close();
        reply.close();
        hub_reply.close();
        drop(end);
        drop(reply);
        drop(hub_reply);
        let trace = ec.loop_handle.join().unwrap();
        // Tracing was on: flush + close events with a terminal RoleEnd.
        use crate::trace::EventKind;
        assert!(trace.iter().any(|e| matches!(e.kind, EventKind::LinkFlush { .. })));
        assert!(trace.iter().any(|e| matches!(e.kind, EventKind::ChannelClose { .. })));
        assert!(matches!(trace.last().unwrap().kind, EventKind::RoleEnd { .. }));
        // Close markers propagated: the server/pf inboxes are disconnected.
        assert!(server_rx.recv_timeout(Duration::from_millis(200)).is_err());
        assert!(pf_rx.recv_timeout(Duration::from_millis(200)).is_err());
    }
}
