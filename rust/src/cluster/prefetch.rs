//! The async prefetch engine: one prefetcher thread per trainer plus the
//! [`FeatureStore`] it shares with its trainer thread.
//!
//! The prefetcher consumes fetch orders (replacement admissions decided by
//! the controller, and the current minibatch's buffer misses), suppresses
//! nodes whose features are already resident *or already in flight*
//! (dedup), coalesces the remainder into one [`Frame::FetchReq`] per owner
//! partition, and installs [`Frame::FetchResp`] payloads into the store —
//! all concurrently with the trainer's sampler/compute loop, which only
//! blocks in [`FeatureStore::wait_all`] when a feature it needs *now* has
//! not landed yet.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::WireStats;
use crate::partition::Partition;
use crate::util::fasthash::{FastMap, FastSet};

use super::wire::Frame;

/// Commands and network input multiplexed onto the prefetcher's inbox
/// (single-receiver design: no select needed on std channels).
pub enum PrefetchMsg {
    /// Fetch these nodes' features (deduped against resident + in-flight).
    Fetch(Vec<u32>),
    /// Drop these nodes' features from the store (buffer evictions and
    /// end-of-minibatch transients).
    Evict(Vec<u32>),
    /// An encoded frame from a feature server (a `FetchResp`).
    Wire(Vec<u8>),
    /// Trainer finished: drain nothing further, exit.
    Shutdown,
}

#[derive(Default)]
struct StoreInner {
    feats: FastMap<u32, Box<[f32]>>,
    /// Requested on the wire, response not yet installed.
    inflight: FastSet<u32>,
    /// Evicted while in flight: drop the payload on arrival.
    discard: FastSet<u32>,
}

/// Feature cache shared between one trainer and its prefetcher.
pub struct FeatureStore {
    inner: Mutex<StoreInner>,
    cv: Condvar,
}

impl Default for FeatureStore {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureStore {
    pub fn new() -> FeatureStore {
        FeatureStore { inner: Mutex::new(StoreInner::default()), cv: Condvar::new() }
    }

    /// Number of resident feature rows.
    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().feats.len()
    }

    pub fn contains(&self, node: u32) -> bool {
        self.inner.lock().unwrap().feats.contains_key(&node)
    }

    /// Copy of one node's feature row, if resident.
    pub fn get(&self, node: u32) -> Option<Box<[f32]>> {
        self.inner.lock().unwrap().feats.get(&node).cloned()
    }

    /// Block until every node in `nodes` is resident.  Errors (instead of
    /// hanging) once `timeout` passes with features still outstanding —
    /// callers size the timeout to their emulation scale, so expiry
    /// indicates a wiring bug, not a slow fetch.
    pub fn wait_all(&self, nodes: &[u32], timeout: Duration) -> crate::error::Result<()> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if nodes.iter().all(|n| g.feats.contains_key(n)) {
                return Ok(());
            }
            crate::ensure!(
                Instant::now() < deadline,
                "feature wait timed out ({} of {} nodes outstanding)",
                nodes.iter().filter(|n| !g.feats.contains_key(n)).count(),
                nodes.len()
            );
            let (back, _) = self.cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
            g = back;
        }
    }

    /// Filter a fetch order against resident + in-flight nodes, marking
    /// the remainder in flight.  Returns the nodes that must go on the
    /// wire.
    fn begin_fetch(&self, nodes: &[u32], stats: &mut WireStats) -> Vec<u32> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for &n in nodes {
            if g.discard.remove(&n) {
                // Evicted while in flight, wanted again: the pending
                // response satisfies this request — no new wire traffic.
                debug_assert!(g.inflight.contains(&n));
                stats.nodes_deduped += 1;
            } else if g.feats.contains_key(&n) || g.inflight.contains(&n) {
                stats.nodes_deduped += 1;
            } else {
                g.inflight.insert(n);
                out.push(n);
            }
        }
        out
    }

    /// Install a response's feature rows; returns how many were stored
    /// (discarded-in-flight rows are dropped).
    fn complete_fetch(&self, nodes: &[u32], feats: &[f32], dim: usize) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let mut stored = 0u64;
        for (i, &n) in nodes.iter().enumerate() {
            g.inflight.remove(&n);
            if g.discard.remove(&n) {
                continue;
            }
            let row = &feats[i * dim..(i + 1) * dim];
            g.feats.insert(n, row.to_vec().into_boxed_slice());
            stored += 1;
        }
        drop(g);
        self.cv.notify_all();
        stored
    }

    /// Drop features (deferred for nodes still in flight).
    fn evict(&self, nodes: &[u32]) {
        let mut g = self.inner.lock().unwrap();
        for &n in nodes {
            if g.inflight.contains(&n) {
                g.discard.insert(n);
            } else {
                g.feats.remove(&n);
            }
        }
    }
}

/// Spawn the prefetcher thread for `trainer_id`.  Exits on
/// [`PrefetchMsg::Shutdown`], returning its wire counters.
pub(crate) fn spawn_prefetcher(
    trainer_id: usize,
    store: Arc<FeatureStore>,
    rx: Receiver<PrefetchMsg>,
    servers: Vec<Sender<Vec<u8>>>,
    part: Arc<Partition>,
) -> JoinHandle<WireStats> {
    std::thread::Builder::new()
        .name(format!("rudder-prefetch-{trainer_id}"))
        .spawn(move || {
            let mut stats = WireStats::default();
            let mut req_id: u64 = 0;
            // Reused per-owner coalescing buckets.
            let mut groups: Vec<Vec<u32>> = vec![Vec::new(); servers.len()];
            for msg in rx.iter() {
                match msg {
                    PrefetchMsg::Fetch(nodes) => {
                        let to_req = store.begin_fetch(&nodes, &mut stats);
                        if to_req.is_empty() {
                            continue;
                        }
                        for &n in &to_req {
                            groups[part.owner_of(n)].push(n);
                        }
                        for (owner, group) in groups.iter_mut().enumerate() {
                            if group.is_empty() {
                                continue;
                            }
                            let batch = std::mem::take(group);
                            stats.nodes_requested += batch.len() as u64;
                            let bytes = Frame::FetchReq {
                                req_id,
                                from: trainer_id as u32,
                                nodes: batch,
                            }
                            .encode();
                            req_id += 1;
                            stats.req_frames += 1;
                            stats.req_bytes += bytes.len() as u64;
                            // A dead server surfaces as a wait timeout in
                            // the trainer; nothing useful to do here.
                            let _ = servers[owner].send(bytes);
                        }
                    }
                    PrefetchMsg::Wire(bytes) => {
                        stats.resp_frames += 1;
                        stats.resp_bytes += bytes.len() as u64;
                        match Frame::decode(&bytes) {
                            Ok((Frame::FetchResp { feat_dim, nodes, feats, .. }, _)) => {
                                stats.nodes_received +=
                                    store.complete_fetch(&nodes, &feats, feat_dim as usize);
                            }
                            // A lost response leaves its nodes marked
                            // in-flight and will surface as a feature-wait
                            // timeout — leave a trace of the real cause.
                            Ok((other, _)) => {
                                stats.bad_frames += 1;
                                let kind = match other {
                                    Frame::FetchReq { .. } => "FetchReq",
                                    Frame::FetchResp { .. } => "FetchResp",
                                    Frame::Allreduce { .. } => "Allreduce",
                                };
                                eprintln!("prefetcher {trainer_id}: unexpected {kind} frame");
                            }
                            Err(e) => {
                                stats.bad_frames += 1;
                                eprintln!("prefetcher {trainer_id}: bad frame: {e}");
                            }
                        }
                    }
                    PrefetchMsg::Evict(nodes) => store.evict(&nodes),
                    PrefetchMsg::Shutdown => break,
                }
            }
            stats
        })
        .expect("spawn prefetcher thread")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_fetch_dedups_resident_and_inflight() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        let first = store.begin_fetch(&[1, 2, 3], &mut stats);
        assert_eq!(first, vec![1, 2, 3]);
        // All three now in flight: nothing new to request.
        assert!(store.begin_fetch(&[1, 2, 3], &mut stats).is_empty());
        assert_eq!(stats.nodes_deduped, 3);
        store.complete_fetch(&[1, 2, 3], &[0.0; 6], 2);
        // Resident: still deduped.
        assert!(store.begin_fetch(&[2], &mut stats).is_empty());
        assert_eq!(store.resident(), 3);
        assert_eq!(store.get(2).unwrap().len(), 2);
    }

    #[test]
    fn evict_while_inflight_discards_on_arrival() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        assert_eq!(store.begin_fetch(&[9], &mut stats), vec![9]);
        store.evict(&[9]);
        assert_eq!(store.complete_fetch(&[9], &[1.0], 1), 0, "discarded");
        assert!(!store.contains(9));
        // A fresh request goes back on the wire.
        assert_eq!(store.begin_fetch(&[9], &mut stats), vec![9]);
    }

    #[test]
    fn refetch_request_rescues_inflight_eviction() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        assert_eq!(store.begin_fetch(&[4], &mut stats), vec![4]);
        store.evict(&[4]); // marked discard-on-arrival
        // Re-requested before the response lands: the pending response
        // must now be kept, with no duplicate wire request.
        assert!(store.begin_fetch(&[4], &mut stats).is_empty());
        assert_eq!(store.complete_fetch(&[4], &[2.5], 1), 1);
        assert_eq!(store.get(4).unwrap()[0], 2.5);
    }

    #[test]
    fn wait_all_returns_once_resident() {
        let store = Arc::new(FeatureStore::new());
        let mut stats = WireStats::default();
        store.begin_fetch(&[1, 2], &mut stats);
        let s2 = store.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.complete_fetch(&[1, 2], &[0.0, 0.0], 1);
        });
        store.wait_all(&[1, 2], Duration::from_secs(10)).unwrap();
        h.join().unwrap();
        assert_eq!(store.resident(), 2);
    }
}
