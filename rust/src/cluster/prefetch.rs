//! The async prefetch engine: one prefetcher thread per trainer plus the
//! [`FeatureStore`] it shares with its trainer thread.
//!
//! The prefetcher consumes fetch orders (replacement admissions decided by
//! the controller, and the current minibatch's buffer misses), suppresses
//! nodes that are already *wanted* (resident or expected from an earlier
//! request — the dedup), coalesces the remainder into one
//! [`Frame::FetchReq`] per owner partition, and installs
//! [`Frame::FetchResp`] payloads into the store — all concurrently with
//! the trainer's sampler/compute loop, which only blocks in
//! [`FeatureStore::wait_all`] when a feature it needs *now* has not landed
//! yet.
//!
//! **Determinism:** the dedup bookkeeping (the want-set) is driven purely
//! by the trainer's `Fetch`/`Evict` command sequence — never by response
//! arrival timing — and responses are deduplicated by request id, so every
//! [`WireStats`] counter is a pure function of config + seed.  That is
//! what makes cross-transport parity (`channel` vs `tcp`, and both vs the
//! virtual-time sim) assertable down to exact frame and byte counts, and
//! keeps counters bit-identical even under the fault-injection shim's
//! duplicated/reordered responses.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::WireStats;
use crate::partition::Partition;
use crate::trace::{EventKind, Role, TraceEvent, Tracer};
use crate::util::fasthash::{FastMap, FastSet};

use super::transport::FrameSender;
use super::wire::Frame;

/// Commands and network input multiplexed onto the prefetcher's inbox
/// (single-receiver design: no select needed on std channels).
pub enum PrefetchMsg {
    /// Fetch these nodes' features (deduped against the want-set).
    Fetch(Vec<u32>),
    /// Drop these nodes' features from the store (buffer evictions and
    /// end-of-minibatch transients).
    Evict(Vec<u32>),
    /// An encoded frame from a feature server (a `FetchResp`).
    Wire(Vec<u8>),
    /// Trainer finished: drain outstanding responses, then exit.
    Shutdown,
}

#[derive(Default)]
struct StoreInner {
    feats: FastMap<u32, Box<[f32]>>,
    /// Nodes currently wanted: fetch-ordered and not evicted since.  Every
    /// member either has its row in `feats` already or has a response
    /// inbound.  Dedup tests membership here — never arrival state — so
    /// the wire request sequence is a pure function of the trainer's
    /// command sequence (see the module docs on determinism).
    want: FastSet<u32>,
}

/// Feature cache shared between one trainer and its prefetcher.
pub struct FeatureStore {
    inner: Mutex<StoreInner>,
    cv: Condvar,
}

impl Default for FeatureStore {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureStore {
    pub fn new() -> FeatureStore {
        FeatureStore { inner: Mutex::new(StoreInner::default()), cv: Condvar::new() }
    }

    /// Number of resident feature rows.
    pub fn resident(&self) -> usize {
        self.inner.lock().unwrap().feats.len()
    }

    pub fn contains(&self, node: u32) -> bool {
        self.inner.lock().unwrap().feats.contains_key(&node)
    }

    /// Copy of one node's feature row, if resident.
    pub fn get(&self, node: u32) -> Option<Box<[f32]>> {
        self.inner.lock().unwrap().feats.get(&node).cloned()
    }

    /// Copy one node's feature row straight into `dst` under the lock;
    /// returns whether the row was resident.  The measured-compute gather
    /// uses this instead of [`FeatureStore::get`] so the timed compute
    /// region pays no per-row allocation.
    pub fn copy_into(&self, node: u32, dst: &mut [f32]) -> bool {
        match self.inner.lock().unwrap().feats.get(&node) {
            Some(row) => {
                dst.copy_from_slice(row);
                true
            }
            None => false,
        }
    }

    /// Block until every node in `nodes` is resident.  Errors (instead of
    /// hanging) once `timeout` passes with features still outstanding —
    /// callers size the timeout to their emulation scale, so expiry
    /// indicates a wiring bug, not a slow fetch.
    pub fn wait_all(&self, nodes: &[u32], timeout: Duration) -> crate::error::Result<()> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if nodes.iter().all(|n| g.feats.contains_key(n)) {
                return Ok(());
            }
            crate::ensure!(
                Instant::now() < deadline,
                "feature wait timed out ({} of {} nodes outstanding)",
                nodes.iter().filter(|n| !g.feats.contains_key(n)).count(),
                nodes.len()
            );
            let (back, _) = self.cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
            g = back;
        }
    }

    /// Filter a fetch order against the want-set, admitting the remainder.
    /// Returns the nodes that must go on the wire.
    fn begin_fetch(&self, nodes: &[u32], stats: &mut WireStats) -> Vec<u32> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for &n in nodes {
            if g.want.contains(&n) {
                // Resident, or a response is already inbound: either way
                // no new wire traffic.
                stats.nodes_deduped += 1;
            } else {
                g.want.insert(n);
                out.push(n);
            }
        }
        out
    }

    /// Install a response's feature rows; returns how many were stored.
    /// Rows for nodes evicted since their request (no longer wanted) are
    /// dropped — a later re-request re-fetches them on the wire, never
    /// rescues the stale payload, keeping traffic timing-independent.
    fn complete_fetch(&self, nodes: &[u32], feats: &[f32], dim: usize) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let mut stored = 0u64;
        for (i, &n) in nodes.iter().enumerate() {
            if g.want.contains(&n) {
                let row = &feats[i * dim..(i + 1) * dim];
                g.feats.insert(n, row.to_vec().into_boxed_slice());
                stored += 1;
            }
        }
        drop(g);
        self.cv.notify_all();
        stored
    }

    /// Drop nodes from the want-set (and their rows, if resident).  Rows
    /// still inbound for them will be dropped on arrival.
    fn evict(&self, nodes: &[u32]) {
        let mut g = self.inner.lock().unwrap();
        for &n in nodes {
            g.want.remove(&n);
            g.feats.remove(&n);
        }
    }
}

/// Decode one server frame and apply it to the store + counters.
/// `outstanding` maps req-ids sent but not yet answered to the owner
/// partition and issue instant (for round-trip latency); responses with
/// an unknown req-id are duplicates (fault shim) and are dropped without
/// touching any other counter.
fn handle_wire(
    trainer_id: usize,
    store: &FeatureStore,
    bytes: &[u8],
    stats: &mut WireStats,
    outstanding: &mut FastMap<u64, (u32, Instant)>,
    tracer: &mut Tracer,
) {
    match Frame::decode(bytes) {
        Ok((Frame::FetchResp { req_id, feat_dim, nodes, feats }, _)) => {
            let Some((owner, issued)) = outstanding.remove(&req_id) else {
                stats.dup_frames += 1;
                return;
            };
            stats.resp_frames += 1;
            stats.resp_bytes += bytes.len() as u64;
            stats.nodes_received += nodes.len() as u64;
            if let Some(h) = stats.fetch_latency.get_mut(owner as usize) {
                h.push(issued.elapsed().as_secs_f64());
            }
            tracer.emit(
                0.0,
                EventKind::FetchResponse {
                    req_id,
                    nodes: nodes.len() as u64,
                    bytes: bytes.len() as u64,
                },
            );
            store.complete_fetch(&nodes, &feats, feat_dim as usize);
        }
        Ok((other, _)) => {
            stats.bad_frames += 1;
            let kind = match other {
                Frame::FetchReq { .. } => "FetchReq",
                Frame::FetchResp { .. } => "FetchResp",
                Frame::Allreduce { .. } => "Allreduce",
                Frame::Hello { .. } => "Hello",
                Frame::Result { .. } => "Result",
                Frame::Config { .. } => "Config",
            };
            crate::log_info!("prefetcher {trainer_id}: unexpected {kind} frame");
        }
        Err(e) => {
            stats.bad_frames += 1;
            crate::log_info!("prefetcher {trainer_id}: bad frame: {e}");
        }
    }
}

/// Spawn the prefetcher thread for `trainer_id`.  `servers[p]` is the
/// request link to partition `p`'s feature server (any transport).  On
/// [`PrefetchMsg::Shutdown`] it half-closes the request links, drains
/// every outstanding response (bounded by `drain_timeout`), and returns
/// its wire counters plus its trace buffer (empty unless `trace`).
pub(crate) fn spawn_prefetcher(
    trainer_id: usize,
    store: Arc<FeatureStore>,
    rx: Receiver<PrefetchMsg>,
    servers: Vec<Box<dyn FrameSender>>,
    part: Arc<Partition>,
    drain_timeout: Duration,
    trace: bool,
) -> JoinHandle<(WireStats, Vec<TraceEvent>)> {
    std::thread::Builder::new()
        .name(format!("rudder-prefetch-{trainer_id}"))
        .spawn(move || {
            let mut servers = servers;
            let mut stats = WireStats::default();
            stats.fetch_latency.resize_with(servers.len(), Default::default);
            let mut tracer = Tracer::new(trace, Role::Prefetcher, trainer_id as u32);
            let mut req_id: u64 = 0;
            let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
            // Reused per-owner coalescing buckets (nodes within one fetch
            // order) and per-owner encoded-frame batches (across a burst).
            let mut groups: Vec<Vec<u32>> = vec![Vec::new(); servers.len()];
            let mut batches: Vec<Vec<Vec<u8>>> = vec![Vec::new(); servers.len()];
            let mut burst: Vec<PrefetchMsg> = Vec::new();
            let mut shutdown = false;
            while !shutdown {
                // Burst-drain the inbox: take everything immediately
                // available (bounded) and flush each owner's accumulated
                // requests as ONE coalesced `send_frames` batch — the hot
                // fetch path's many small `FetchReq` frames leave in
                // syscall-sized writes.  Frame contents, req-id order, and
                // every counter are driven by message order alone, so the
                // wire stays bit-identical to the unbatched path.
                match rx.recv() {
                    Ok(m) => burst.push(m),
                    Err(_) => break,
                }
                while burst.len() < 64 {
                    match rx.try_recv() {
                        Ok(m) => burst.push(m),
                        Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
                    }
                }
                for msg in burst.drain(..) {
                    match msg {
                        PrefetchMsg::Fetch(nodes) => {
                            let to_req = store.begin_fetch(&nodes, &mut stats);
                            for &n in &to_req {
                                groups[part.owner_of(n)].push(n);
                            }
                            for (owner, group) in groups.iter_mut().enumerate() {
                                if group.is_empty() {
                                    continue;
                                }
                                let batch = std::mem::take(group);
                                let batch_nodes = batch.len() as u64;
                                stats.nodes_requested += batch_nodes;
                                let bytes = Frame::FetchReq {
                                    req_id,
                                    from: trainer_id as u32,
                                    nodes: batch,
                                }
                                .encode();
                                tracer.emit(
                                    0.0,
                                    EventKind::FetchIssue {
                                        req_id,
                                        owner: owner as u32,
                                        nodes: batch_nodes,
                                        bytes: bytes.len() as u64,
                                    },
                                );
                                outstanding.insert(req_id, (owner as u32, Instant::now()));
                                req_id += 1;
                                stats.req_frames += 1;
                                stats.req_bytes += bytes.len() as u64;
                                batches[owner].push(bytes);
                            }
                        }
                        PrefetchMsg::Wire(bytes) => {
                            handle_wire(
                                trainer_id,
                                &store,
                                &bytes,
                                &mut stats,
                                &mut outstanding,
                                &mut tracer,
                            );
                        }
                        PrefetchMsg::Evict(nodes) => {
                            tracer.emit(0.0, EventKind::Evict { nodes: nodes.len() as u64 });
                            store.evict(&nodes);
                        }
                        // The trainer sends Shutdown last, so only `Wire`
                        // can trail it within a burst — keep processing so
                        // no response is dropped before the drain phase.
                        PrefetchMsg::Shutdown => shutdown = true,
                    }
                }
                for (owner, batch) in batches.iter_mut().enumerate() {
                    if batch.is_empty() {
                        continue;
                    }
                    let frames = std::mem::take(batch);
                    tracer.emit(
                        0.0,
                        EventKind::BatchFlush {
                            owner: owner as u32,
                            frames: frames.len() as u64,
                            bytes: frames.iter().map(|f| f.len() as u64).sum(),
                        },
                    );
                    // A dead server surfaces as a wait timeout in the
                    // trainer; nothing useful to do here.
                    let _ = servers[owner].send_frames(&frames);
                }
            }
            // Half-close the request links (servers finish our pending
            // requests, then see EOF), then drain the inbox until every
            // reply link has closed — not merely until the responses we
            // are owed arrived, because a fault-shim duplicate of the
            // *last* response may still be in flight behind it.  Draining
            // to link-close makes every counter, `dup_frames` included, a
            // pure function of config + seed.  Afterwards
            // `nodes_received == nodes_requested` and
            // `resp_frames == req_frames` hold deterministically.
            for s in &mut servers {
                s.close();
            }
            let deadline = Instant::now() + drain_timeout;
            loop {
                let remaining = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(PrefetchMsg::Wire(bytes)) => {
                        handle_wire(
                            trainer_id,
                            &store,
                            &bytes,
                            &mut stats,
                            &mut outstanding,
                            &mut tracer,
                        );
                    }
                    Ok(_) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                    Err(RecvTimeoutError::Timeout) => {
                        crate::log_info!("prefetcher {trainer_id}: drain timed out");
                        break;
                    }
                }
            }
            if !outstanding.is_empty() {
                stats.bad_frames += outstanding.len() as u64;
                crate::log_info!(
                    "prefetcher {trainer_id}: {} responses never arrived",
                    outstanding.len()
                );
            }
            (stats, tracer.finish())
        })
        .expect("spawn prefetcher thread")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_fetch_dedups_resident_and_expected() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        let first = store.begin_fetch(&[1, 2, 3], &mut stats);
        assert_eq!(first, vec![1, 2, 3]);
        // All three now expected: nothing new to request.
        assert!(store.begin_fetch(&[1, 2, 3], &mut stats).is_empty());
        assert_eq!(stats.nodes_deduped, 3);
        store.complete_fetch(&[1, 2, 3], &[0.0; 6], 2);
        // Resident: still deduped.
        assert!(store.begin_fetch(&[2], &mut stats).is_empty());
        assert_eq!(store.resident(), 3);
        assert_eq!(store.get(2).unwrap().len(), 2);
    }

    #[test]
    fn copy_into_matches_get_without_allocating() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        store.begin_fetch(&[5], &mut stats);
        store.complete_fetch(&[5], &[1.5, -2.5], 2);
        let mut row = [0.0f32; 2];
        assert!(store.copy_into(5, &mut row));
        assert_eq!(&row[..], &store.get(5).unwrap()[..]);
        assert!(!store.copy_into(6, &mut row), "absent row reports non-resident");
    }

    #[test]
    fn evict_while_expected_discards_on_arrival() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        assert_eq!(store.begin_fetch(&[9], &mut stats), vec![9]);
        store.evict(&[9]);
        assert_eq!(store.complete_fetch(&[9], &[1.0], 1), 0, "discarded");
        assert!(!store.contains(9));
        // A fresh request goes back on the wire.
        assert_eq!(store.begin_fetch(&[9], &mut stats), vec![9]);
    }

    #[test]
    fn refetch_after_evict_goes_back_on_wire() {
        // Re-requesting a node that was evicted while its response was
        // still inbound must cost a *new* wire request (deterministic
        // traffic), never rescue the stale payload.
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        assert_eq!(store.begin_fetch(&[4], &mut stats), vec![4]);
        store.evict(&[4]);
        assert_eq!(store.begin_fetch(&[4], &mut stats), vec![4], "refetch is a wire request");
        // Both responses eventually land; payloads are identical by
        // construction (features are a function of the node id), so
        // whichever arrives first installs the row.
        assert_eq!(store.complete_fetch(&[4], &[2.5], 1), 1);
        assert_eq!(store.complete_fetch(&[4], &[2.5], 1), 1);
        assert_eq!(store.get(4).unwrap()[0], 2.5);
        assert_eq!(stats.nodes_deduped, 0);
    }

    #[test]
    fn duplicate_responses_are_dropped_by_req_id() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        stats.fetch_latency.resize_with(1, Default::default);
        let mut tracer = Tracer::new(true, Role::Prefetcher, 0);
        let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
        outstanding.insert(7, (0, Instant::now()));
        let resp =
            Frame::FetchResp { req_id: 7, feat_dim: 1, nodes: vec![3], feats: vec![0.5] };
        store.begin_fetch(&[3], &mut stats);
        let bytes = resp.encode();
        handle_wire(0, &store, &bytes, &mut stats, &mut outstanding, &mut tracer);
        handle_wire(0, &store, &bytes, &mut stats, &mut outstanding, &mut tracer);
        assert_eq!(stats.resp_frames, 1);
        assert_eq!(stats.nodes_received, 1);
        assert_eq!(stats.dup_frames, 1, "second copy is dropped by req-id dedup");
        assert_eq!(stats.bad_frames, 0);
        assert!(store.contains(3));
        assert_eq!(stats.fetch_latency[0].count(), 1, "latency recorded once");
        let evs = tracer.finish();
        assert_eq!(evs.len(), 2, "one FetchResponse + RoleEnd (dup is silent)");
        assert!(matches!(evs[0].kind, EventKind::FetchResponse { req_id: 7, .. }));
    }

    #[test]
    fn wait_all_returns_once_resident() {
        let store = Arc::new(FeatureStore::new());
        let mut stats = WireStats::default();
        store.begin_fetch(&[1, 2], &mut stats);
        let s2 = store.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.complete_fetch(&[1, 2], &[0.0, 0.0], 1);
        });
        store.wait_all(&[1, 2], Duration::from_secs(10)).unwrap();
        h.join().unwrap();
        assert_eq!(store.resident(), 2);
    }
}
