//! The async prefetch engine: one prefetcher thread per trainer plus the
//! [`FeatureStore`] it shares with its trainer thread.
//!
//! The prefetcher consumes fetch orders (replacement admissions decided by
//! the controller, and the current minibatch's buffer misses), suppresses
//! nodes that are already *wanted* (resident or expected from an earlier
//! request — the dedup), coalesces the remainder into one
//! [`Frame::FetchReq`] per owner partition, and installs
//! [`Frame::FetchResp`] payloads into the store — all concurrently with
//! the trainer's sampler/compute loop, which only blocks in
//! [`FeatureStore::wait_all`] when a feature it needs *now* has not landed
//! yet.
//!
//! **Content-addressed chunk mode** (`chunk_cache_bytes > 0`): feature
//! rows are grouped into fixed chunks of `chunk_rows` consecutive rows in
//! the owner partition's `local_nodes` order — the same canonical order
//! the server's `FeatureShard` materializes, so both ends agree on chunk
//! membership without negotiation.  Each server link gets a byte-budgeted
//! LRU [`ChunkCache`]; a fetch order first consults the cache, and only
//! nodes of absent chunks go on the wire (as [`Frame::ChunkReq`]).  The
//! server answers with whole digest-keyed chunks ([`Frame::ChunkResp`]);
//! the trainer verifies each FNV-1a digest, installs the wanted rows, and
//! settles the cache entry — so chunks survive buffer replacement and
//! epoch boundaries, and a re-touched transient costs zero wire bytes.
//!
//! **Determinism:** the dedup bookkeeping (the want-set) is driven purely
//! by the trainer's `Fetch`/`Evict` command sequence — never by response
//! arrival timing — and responses are deduplicated by request id, so every
//! [`WireStats`] counter is a pure function of config + seed.  The chunk
//! cache preserves this: admission and LRU eviction happen at *command*
//! time only (an entry for an in-flight chunk is admitted unsettled when
//! its request is issued), so hit/miss decisions — and therefore every
//! frame and byte on the wire — never depend on arrival order.  That is
//! what makes cross-transport parity (`channel` vs `tcp` vs `event`, and
//! all vs the virtual-time sim) assertable down to exact frame and byte
//! counts, cache enabled or not, even under the fault-injection shim's
//! duplicated/reordered responses.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::WireStats;
use crate::partition::Partition;
use crate::trace::{EventKind, Role, TraceEvent, Tracer};
use crate::util::fasthash::{digest_f32, FastMap, FastSet};

use super::id_u32;
use super::transport::FrameSender;
use super::wire::Frame;

/// Commands and network input multiplexed onto the prefetcher's inbox
/// (single-receiver design: no select needed on std channels).
pub enum PrefetchMsg {
    /// Fetch these nodes' features (deduped against the want-set).
    Fetch(Vec<u32>),
    /// Drop these nodes' features from the store (buffer evictions and
    /// end-of-minibatch transients).
    Evict(Vec<u32>),
    /// An encoded frame from a feature server (a `FetchResp`).
    Wire(Vec<u8>),
    /// Trainer finished: drain outstanding responses, then exit.
    Shutdown,
}

/// Feature-plane knobs the prefetcher needs beyond its links: the run's
/// feature width (used to validate response shapes before any row is
/// installed) and the chunk-store geometry.  `cache_bytes == 0` disables
/// the chunk protocol entirely — the v1 row protocol runs unchanged.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PrefetchConfig {
    pub feat_dim: usize,
    pub chunk_rows: usize,
    pub cache_bytes: u64,
}

#[derive(Default)]
struct StoreInner {
    feats: FastMap<u32, Box<[f32]>>,
    /// Nodes currently wanted: fetch-ordered and not evicted since.  Every
    /// member either has its row in `feats` already or has a response
    /// inbound.  Dedup tests membership here — never arrival state — so
    /// the wire request sequence is a pure function of the trainer's
    /// command sequence (see the module docs on determinism).
    want: FastSet<u32>,
}

/// Feature cache shared between one trainer and its prefetcher.
pub struct FeatureStore {
    inner: Mutex<StoreInner>,
    cv: Condvar,
}

impl Default for FeatureStore {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureStore {
    pub fn new() -> FeatureStore {
        FeatureStore { inner: Mutex::new(StoreInner::default()), cv: Condvar::new() }
    }

    /// Lock the store, recovering from poisoning.  Both maps are only
    /// ever mutated through infallible insert/remove calls, so a panic
    /// elsewhere in the holding thread cannot leave them mid-update —
    /// recovering lets the trainer's shutdown-path `wait_all` drain (and
    /// report the real timeout) instead of cascading a prefetcher panic
    /// into a poisoned-lock abort.
    fn locked(&self) -> std::sync::MutexGuard<'_, StoreInner> {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Number of resident feature rows.
    pub fn resident(&self) -> usize {
        self.locked().feats.len()
    }

    pub fn contains(&self, node: u32) -> bool {
        self.locked().feats.contains_key(&node)
    }

    /// Copy of one node's feature row, if resident.
    pub fn get(&self, node: u32) -> Option<Box<[f32]>> {
        self.locked().feats.get(&node).cloned()
    }

    /// Copy one node's feature row straight into `dst` under the lock;
    /// returns whether the row was resident.  The measured-compute gather
    /// uses this instead of [`FeatureStore::get`] so the timed compute
    /// region pays no per-row allocation.
    pub fn copy_into(&self, node: u32, dst: &mut [f32]) -> bool {
        match self.locked().feats.get(&node) {
            Some(row) => {
                dst.copy_from_slice(row);
                true
            }
            None => false,
        }
    }

    /// Block until every node in `nodes` is resident.  Errors (instead of
    /// hanging) once `timeout` passes with features still outstanding —
    /// callers size the timeout to their emulation scale, so expiry
    /// indicates a wiring bug, not a slow fetch.
    pub fn wait_all(&self, nodes: &[u32], timeout: Duration) -> crate::error::Result<()> {
        // audit:allow(wall-clock-in-virtual-path) liveness deadline for a real wait, not a decision input
        let deadline = Instant::now() + timeout;
        let mut g = self.locked();
        loop {
            if nodes.iter().all(|n| g.feats.contains_key(n)) {
                return Ok(());
            }
            // audit:allow(wall-clock-in-virtual-path) measures the real wait against the deadline
            let remaining = deadline.saturating_duration_since(Instant::now());
            crate::ensure!(
                !remaining.is_zero(),
                "feature wait timed out ({} of {} nodes outstanding)",
                nodes.iter().filter(|n| !g.feats.contains_key(n)).count(),
                nodes.len()
            );
            // Wake periodically even without a notify, but never sleep
            // past the deadline: expiry must land within the caller's
            // tolerance, not up to a full slice late.
            let slice = remaining.min(Duration::from_millis(50));
            let (back, _) =
                self.cv.wait_timeout(g, slice).unwrap_or_else(std::sync::PoisonError::into_inner);
            g = back;
        }
    }

    /// Filter a fetch order against the want-set, admitting the remainder.
    /// Returns the nodes that must go on the wire.
    fn begin_fetch(&self, nodes: &[u32], stats: &mut WireStats) -> Vec<u32> {
        let mut g = self.locked();
        let mut out = Vec::new();
        for &n in nodes {
            if g.want.contains(&n) {
                // Resident, or a response is already inbound: either way
                // no new wire traffic.
                stats.nodes_deduped += 1;
            } else {
                g.want.insert(n);
                out.push(n);
            }
        }
        out
    }

    /// Install a response's feature rows; returns how many were stored.
    /// Rows for nodes evicted since their request (no longer wanted) are
    /// dropped — a later re-request re-fetches them on the wire, never
    /// rescues the stale payload, keeping traffic timing-independent.
    ///
    /// A payload whose shape disagrees with `nodes.len() × dim` is
    /// rejected whole (returns 0): installing it would slice out of
    /// bounds or store wrong-width rows that panic the consumer later.
    fn complete_fetch(&self, nodes: &[u32], feats: &[f32], dim: usize) -> u64 {
        if feats.len() != nodes.len() * dim || (dim == 0 && !nodes.is_empty()) {
            return 0;
        }
        let mut g = self.locked();
        let mut stored = 0u64;
        for (i, &n) in nodes.iter().enumerate() {
            if g.want.contains(&n) {
                let row = &feats[i * dim..(i + 1) * dim];
                g.feats.insert(n, row.to_vec().into_boxed_slice());
                stored += 1;
            }
        }
        drop(g);
        self.cv.notify_all();
        stored
    }

    /// Drop nodes from the want-set (and their rows, if resident).  Rows
    /// still inbound for them will be dropped on arrival.
    fn evict(&self, nodes: &[u32]) {
        let mut g = self.locked();
        for &n in nodes {
            g.want.remove(&n);
            g.feats.remove(&n);
        }
    }
}

/// Chunk layout of one owner partition, derived from
/// [`Partition::local_nodes`]: node → local row index, with chunk `c`
/// covering local rows `[c·chunk_rows, (c+1)·chunk_rows)`.
/// `pub(crate)` so [`crate::replay`] can model the same command-time
/// hit/miss decisions offline.
pub(crate) struct ChunkLayout {
    chunk_rows: usize,
    total: usize,
    local_idx: FastMap<u32, u32>,
}

impl ChunkLayout {
    pub(crate) fn build(owned: &[u32], chunk_rows: usize) -> ChunkLayout {
        let mut local_idx = FastMap::default();
        for (i, &n) in owned.iter().enumerate() {
            local_idx.insert(n, id_u32(i));
        }
        ChunkLayout { chunk_rows, total: owned.len(), local_idx }
    }

    /// `(chunk id, row offset within the chunk)` of `node`, if owned.
    pub(crate) fn slot_of(&self, node: u32) -> Option<(u32, usize)> {
        let i = *self.local_idx.get(&node)? as usize;
        Some((id_u32(i / self.chunk_rows), i % self.chunk_rows))
    }

    /// Rows in chunk `c` (the last chunk of a partition may be short).
    pub(crate) fn rows_in(&self, chunk: u32) -> usize {
        let start = chunk as usize * self.chunk_rows;
        self.chunk_rows.min(self.total.saturating_sub(start))
    }
}

/// Wire payload-byte estimate of one cached chunk: digest + per-row node
/// id + row floats (what [`Frame::ChunkResp`] pays per chunk, and what a
/// hit therefore saves).
pub(crate) fn chunk_wire_bytes(rows: usize, dim: usize) -> u64 {
    8 + rows as u64 * (4 + 4 * dim as u64)
}

struct ChunkEntry {
    last_use: u64,
    bytes: u64,
    /// Settled payload: the chunk's node ids plus its row-major rows.
    /// `None` while the chunk's response is still in flight.
    payload: Option<(Vec<u32>, Box<[f32]>)>,
}

/// Byte-budgeted LRU over content-addressed chunks, one per server link
/// (shared-nothing).  Admission and eviction happen at command time only;
/// arrival merely settles a previously admitted entry (an entry evicted
/// while in flight stays evicted) — so the resident set, and with it
/// every hit/miss decision, is a pure function of the command sequence.
pub(crate) struct ChunkCache {
    budget: u64,
    used: u64,
    tick: u64,
    entries: FastMap<u32, ChunkEntry>,
}

impl ChunkCache {
    pub(crate) fn new(budget: u64) -> ChunkCache {
        ChunkCache { budget, used: 0, tick: 0, entries: FastMap::default() }
    }

    /// Bump `chunk`'s LRU stamp if present; returns whether it was.
    pub(crate) fn touch(&mut self, chunk: u32) -> bool {
        self.tick += 1;
        match self.entries.get_mut(&chunk) {
            Some(e) => {
                e.last_use = self.tick;
                true
            }
            None => false,
        }
    }

    /// The cached row at `offset` within a settled chunk, or `None` while
    /// the chunk is still in flight (its inbound response installs the
    /// row instead).
    fn row(&self, chunk: u32, offset: usize, dim: usize) -> Option<&[f32]> {
        let (_, feats) = self.entries.get(&chunk)?.payload.as_ref()?;
        feats.get(offset * dim..(offset + 1) * dim)
    }

    /// Admit `chunk` unsettled (its request goes on the wire now), then
    /// evict least-recently-used entries until the budget holds again.
    /// The newest entry is never evicted, so a chunk larger than the
    /// whole budget still caches alone.
    pub(crate) fn admit(&mut self, chunk: u32, bytes: u64) {
        self.tick += 1;
        if let Some(old) = self
            .entries
            .insert(chunk, ChunkEntry { last_use: self.tick, bytes, payload: None })
        {
            self.used -= old.bytes;
        }
        self.used += bytes;
        while self.used > self.budget && self.entries.len() > 1 {
            let lru = self
                .entries
                .iter()
                .filter(|(&id, _)| id != chunk)
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&id, _)| id)
                .expect("cache has an evictable entry");
            let e = self.entries.remove(&lru).expect("lru entry present");
            self.used -= e.bytes;
        }
    }

    /// Settle a still-admitted, still-unsettled entry with its verified
    /// payload.  No-op if the entry was evicted while in flight:
    /// re-admission is a command-time decision only.
    fn settle(&mut self, chunk: u32, nodes: Vec<u32>, feats: Box<[f32]>) {
        if let Some(e) = self.entries.get_mut(&chunk) {
            if e.payload.is_none() {
                e.payload = Some((nodes, feats));
            }
        }
    }
}

/// All chunk-mode state of one prefetcher: per-owner layouts (shared
/// geometry with the servers) and per-link caches.
pub(crate) struct ChunkState {
    pub(crate) dim: usize,
    pub(crate) layouts: Vec<ChunkLayout>,
    pub(crate) caches: Vec<ChunkCache>,
}

impl ChunkState {
    pub(crate) fn build(
        part: &Partition,
        dim: usize,
        chunk_rows: usize,
        cache_bytes: u64,
    ) -> ChunkState {
        let layouts =
            part.local_nodes.iter().map(|o| ChunkLayout::build(o, chunk_rows)).collect();
        let caches = (0..part.num_parts).map(|_| ChunkCache::new(cache_bytes)).collect();
        ChunkState { dim, layouts, caches }
    }
}

/// Decode one server frame and apply it to the store + counters.
/// `outstanding` maps req-ids sent but not yet answered to the owner
/// partition and issue instant (for round-trip latency); responses with
/// an unknown req-id are duplicates (fault shim) and are dropped without
/// touching any other counter.  `feat_dim` is the run's feature width:
/// a response whose dim disagrees is counted in `bad_frames` and dropped
/// whole, never installed.
fn handle_wire(
    trainer_id: usize,
    store: &FeatureStore,
    bytes: &[u8],
    stats: &mut WireStats,
    outstanding: &mut FastMap<u64, (u32, Instant)>,
    tracer: &mut Tracer,
    feat_dim: usize,
    chunks: Option<&mut ChunkState>,
) {
    match Frame::decode(bytes) {
        Ok((Frame::FetchResp { req_id, feat_dim: dim, nodes, feats }, _)) => {
            if dim as usize != feat_dim || feats.len() != nodes.len() * feat_dim {
                stats.bad_frames += 1;
                crate::log_info!(
                    "prefetcher {trainer_id}: FetchResp dim {dim} != run dim {feat_dim}, dropped"
                );
                return;
            }
            let Some((owner, issued)) = outstanding.remove(&req_id) else {
                stats.dup_frames += 1;
                return;
            };
            stats.resp_frames += 1;
            stats.resp_bytes += bytes.len() as u64;
            stats.nodes_received += nodes.len() as u64;
            if let Some(h) = stats.fetch_latency.get_mut(owner as usize) {
                h.push(issued.elapsed().as_secs_f64());
            }
            tracer.emit(
                0.0,
                EventKind::FetchResponse {
                    req_id,
                    nodes: nodes.len() as u64,
                    bytes: bytes.len() as u64,
                },
            );
            store.complete_fetch(&nodes, &feats, feat_dim);
        }
        Ok((Frame::ChunkResp { req_id, feat_dim: dim, refs, chunks: got }, _)) => {
            let Some(cs) = chunks else {
                stats.bad_frames += 1;
                crate::log_info!(
                    "prefetcher {trainer_id}: ChunkResp with the chunk cache disabled"
                );
                return;
            };
            if dim as usize != feat_dim {
                stats.bad_frames += 1;
                crate::log_info!(
                    "prefetcher {trainer_id}: ChunkResp dim {dim} != run dim {feat_dim}, dropped"
                );
                return;
            }
            let Some((owner, issued)) = outstanding.remove(&req_id) else {
                stats.dup_frames += 1;
                return;
            };
            stats.resp_frames += 1;
            stats.resp_bytes += bytes.len() as u64;
            if let Some(h) = stats.fetch_latency.get_mut(owner as usize) {
                h.push(issued.elapsed().as_secs_f64());
            }
            let mut total_nodes = 0u64;
            for c in got {
                // Decode guarantees shape vs the frame's dim; the digest
                // check catches payload corruption end to end.
                if digest_f32(&c.feats) != c.digest {
                    stats.bad_frames += 1;
                    crate::log_info!(
                        "prefetcher {trainer_id}: chunk digest mismatch, dropped"
                    );
                    continue;
                }
                total_nodes += c.nodes.len() as u64;
                stats.nodes_received += c.nodes.len() as u64;
                store.complete_fetch(&c.nodes, &c.feats, feat_dim);
                if let Some((chunk, _)) =
                    c.nodes.first().and_then(|&n| cs.layouts[owner as usize].slot_of(n))
                {
                    cs.caches[owner as usize].settle(
                        chunk,
                        c.nodes,
                        c.feats.into_boxed_slice(),
                    );
                }
            }
            // `refs` lists chunks the server elided because we declared
            // them held: their rows were already cache-resident at
            // request time, nothing to install.
            let _ = refs;
            tracer.emit(
                0.0,
                EventKind::FetchResponse {
                    req_id,
                    nodes: total_nodes,
                    bytes: bytes.len() as u64,
                },
            );
        }
        Ok((other, _)) => {
            stats.bad_frames += 1;
            let kind = match other {
                Frame::FetchReq { .. } => "FetchReq",
                Frame::FetchResp { .. } => "FetchResp",
                Frame::Allreduce { .. } => "Allreduce",
                Frame::Hello { .. } => "Hello",
                Frame::Result { .. } => "Result",
                Frame::Config { .. } => "Config",
                Frame::ChunkReq { .. } => "ChunkReq",
                Frame::ChunkResp { .. } => "ChunkResp",
            };
            crate::log_info!("prefetcher {trainer_id}: unexpected {kind} frame");
        }
        Err(e) => {
            stats.bad_frames += 1;
            crate::log_info!("prefetcher {trainer_id}: bad frame: {e}");
        }
    }
}

/// Spawn the prefetcher thread for `trainer_id`.  `servers[p]` is the
/// request link to partition `p`'s feature server (any transport).  On
/// [`PrefetchMsg::Shutdown`] it half-closes the request links, drains
/// every outstanding response (bounded by `drain_timeout`), and returns
/// its wire counters plus its trace buffer (empty unless `trace`).
pub(crate) fn spawn_prefetcher(
    trainer_id: usize,
    store: Arc<FeatureStore>,
    rx: Receiver<PrefetchMsg>,
    servers: Vec<Box<dyn FrameSender>>,
    part: Arc<Partition>,
    pcfg: PrefetchConfig,
    drain_timeout: Duration,
    trace: bool,
) -> JoinHandle<(WireStats, Vec<TraceEvent>)> {
    std::thread::Builder::new()
        .name(format!("rudder-prefetch-{trainer_id}"))
        .spawn(move || {
            let mut servers = servers;
            let mut stats = WireStats::default();
            stats.fetch_latency.resize_with(servers.len(), Default::default);
            let mut tracer = Tracer::new(trace, Role::Prefetcher, id_u32(trainer_id));
            let mut chunk_state: Option<ChunkState> = (pcfg.cache_bytes > 0).then(|| {
                ChunkState::build(&part, pcfg.feat_dim, pcfg.chunk_rows.max(1), pcfg.cache_bytes)
            });
            let mut req_id: u64 = 0;
            let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
            // Reused per-owner coalescing buckets (nodes within one fetch
            // order) and per-owner encoded-frame batches (across a burst).
            let mut groups: Vec<Vec<u32>> = vec![Vec::new(); servers.len()];
            let mut batches: Vec<Vec<Vec<u8>>> = vec![Vec::new(); servers.len()];
            let mut burst: Vec<PrefetchMsg> = Vec::new();
            let mut shutdown = false;
            while !shutdown {
                // Burst-drain the inbox: take everything immediately
                // available (bounded) and flush each owner's accumulated
                // requests as ONE coalesced `send_frames` batch — the hot
                // fetch path's many small `FetchReq` frames leave in
                // syscall-sized writes.  Frame contents, req-id order, and
                // every counter are driven by message order alone, so the
                // wire stays bit-identical to the unbatched path.
                match rx.recv() {
                    Ok(m) => burst.push(m),
                    Err(_) => break,
                }
                while burst.len() < 64 {
                    match rx.try_recv() {
                        Ok(m) => burst.push(m),
                        Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
                    }
                }
                for msg in burst.drain(..) {
                    match msg {
                        PrefetchMsg::Fetch(nodes) => {
                            let to_req = store.begin_fetch(&nodes, &mut stats);
                            match chunk_state.as_mut() {
                                Some(cs) => {
                                    let mut hit_nodes = vec![0u64; servers.len()];
                                    let mut miss_chunks = vec![0u64; servers.len()];
                                    for &n in &to_req {
                                        let owner = part.owner_of(n);
                                        let Some((chunk, offset)) =
                                            cs.layouts[owner].slot_of(n)
                                        else {
                                            // Not in the owner's layout
                                            // (impossible under owner
                                            // routing): plain wire fetch.
                                            groups[owner].push(n);
                                            continue;
                                        };
                                        if cs.caches[owner].touch(chunk) {
                                            hit_nodes[owner] += 1;
                                            stats.chunks_hit += 1;
                                            stats.bytes_saved_cache +=
                                                4 + 4 * cs.dim as u64;
                                            if let Some(row) =
                                                cs.caches[owner].row(chunk, offset, cs.dim)
                                            {
                                                // Settled: install now.
                                                // In flight: the inbound
                                                // response installs it.
                                                store.complete_fetch(&[n], row, cs.dim);
                                            }
                                        } else {
                                            let bytes = chunk_wire_bytes(
                                                cs.layouts[owner].rows_in(chunk),
                                                cs.dim,
                                            );
                                            cs.caches[owner].admit(chunk, bytes);
                                            miss_chunks[owner] += 1;
                                            stats.chunks_fetched += 1;
                                            groups[owner].push(n);
                                        }
                                    }
                                    for owner in 0..servers.len() {
                                        if hit_nodes[owner] > 0 {
                                            tracer.emit(
                                                0.0,
                                                EventKind::CacheHit {
                                                    owner: id_u32(owner),
                                                    nodes: hit_nodes[owner],
                                                },
                                            );
                                        }
                                        if miss_chunks[owner] > 0 {
                                            tracer.emit(
                                                0.0,
                                                EventKind::CacheMiss {
                                                    owner: id_u32(owner),
                                                    chunks: miss_chunks[owner],
                                                    nodes: groups[owner].len() as u64,
                                                },
                                            );
                                        }
                                    }
                                }
                                None => {
                                    for &n in &to_req {
                                        groups[part.owner_of(n)].push(n);
                                    }
                                }
                            }
                            for (owner, group) in groups.iter_mut().enumerate() {
                                if group.is_empty() {
                                    continue;
                                }
                                let batch = std::mem::take(group);
                                let batch_nodes = batch.len() as u64;
                                let frame = if chunk_state.is_some() {
                                    Frame::ChunkReq {
                                        req_id,
                                        from: id_u32(trainer_id),
                                        nodes: batch,
                                        have: Vec::new(),
                                    }
                                } else {
                                    Frame::FetchReq {
                                        req_id,
                                        from: id_u32(trainer_id),
                                        nodes: batch,
                                    }
                                };
                                let bytes = match frame.encode() {
                                    Ok(b) => b,
                                    Err(e) => {
                                        stats.bad_frames += 1;
                                        crate::log_info!(
                                            "prefetcher {trainer_id}: encode failed: {e}"
                                        );
                                        continue;
                                    }
                                };
                                stats.nodes_requested += batch_nodes;
                                tracer.emit(
                                    0.0,
                                    EventKind::FetchIssue {
                                        req_id,
                                        owner: id_u32(owner),
                                        nodes: batch_nodes,
                                        bytes: bytes.len() as u64,
                                    },
                                );
                                // audit:allow(wall-clock-in-virtual-path) issue timestamp feeds the latency histogram, never a decision
                                outstanding.insert(req_id, (id_u32(owner), Instant::now()));
                                req_id += 1;
                                stats.req_frames += 1;
                                stats.req_bytes += bytes.len() as u64;
                                batches[owner].push(bytes);
                            }
                        }
                        PrefetchMsg::Wire(bytes) => {
                            handle_wire(
                                trainer_id,
                                &store,
                                &bytes,
                                &mut stats,
                                &mut outstanding,
                                &mut tracer,
                                pcfg.feat_dim,
                                chunk_state.as_mut(),
                            );
                        }
                        PrefetchMsg::Evict(nodes) => {
                            tracer.emit(0.0, EventKind::Evict { nodes: nodes.len() as u64 });
                            store.evict(&nodes);
                        }
                        // The trainer sends Shutdown last, so only `Wire`
                        // can trail it within a burst — keep processing so
                        // no response is dropped before the drain phase.
                        PrefetchMsg::Shutdown => shutdown = true,
                    }
                }
                for (owner, batch) in batches.iter_mut().enumerate() {
                    if batch.is_empty() {
                        continue;
                    }
                    let frames = std::mem::take(batch);
                    tracer.emit(
                        0.0,
                        EventKind::BatchFlush {
                            owner: id_u32(owner),
                            frames: frames.len() as u64,
                            bytes: frames.iter().map(|f| f.len() as u64).sum(),
                        },
                    );
                    // A dead server surfaces as a wait timeout in the
                    // trainer; nothing useful to do here.
                    let _ = servers[owner].send_frames(&frames);
                }
            }
            // Half-close the request links (servers finish our pending
            // requests, then see EOF), then drain the inbox until every
            // reply link has closed — not merely until the responses we
            // are owed arrived, because a fault-shim duplicate of the
            // *last* response may still be in flight behind it.  Draining
            // to link-close makes every counter, `dup_frames` included, a
            // pure function of config + seed.  Afterwards
            // `nodes_received == nodes_requested` and
            // `resp_frames == req_frames` hold deterministically in v1
            // mode (chunk mode receives whole chunks, so
            // `nodes_received >= nodes_requested`).
            for s in &mut servers {
                s.close();
            }
            // audit:allow(wall-clock-in-virtual-path) drain deadline bounds a real shutdown wait
            let deadline = Instant::now() + drain_timeout;
            loop {
                // audit:allow(wall-clock-in-virtual-path) measures the real drain wait against the deadline
                let remaining = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(PrefetchMsg::Wire(bytes)) => {
                        handle_wire(
                            trainer_id,
                            &store,
                            &bytes,
                            &mut stats,
                            &mut outstanding,
                            &mut tracer,
                            pcfg.feat_dim,
                            chunk_state.as_mut(),
                        );
                    }
                    Ok(_) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                    Err(RecvTimeoutError::Timeout) => {
                        crate::log_info!("prefetcher {trainer_id}: drain timed out");
                        break;
                    }
                }
            }
            if !outstanding.is_empty() {
                stats.bad_frames += outstanding.len() as u64;
                crate::log_info!(
                    "prefetcher {trainer_id}: {} responses never arrived",
                    outstanding.len()
                );
            }
            (stats, tracer.finish())
        })
        .expect("spawn prefetcher thread")
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;

    #[test]
    fn store_survives_lock_poisoning() {
        // Regression: the shutdown path used `.lock().unwrap()`, so a
        // panic in any thread holding the store lock poisoned it and
        // cascaded the trainer's `wait_all` into a second panic, hiding
        // the original failure.  `locked()` now recovers: the maps are
        // only mutated through infallible insert/remove calls, so the
        // state is consistent and the drain can finish (or report its
        // own honest timeout).
        let store = Arc::new(FeatureStore::new());
        let mut stats = WireStats::default();
        store.begin_fetch(&[1, 2], &mut stats);
        store.complete_fetch(&[1], &[7.5], 1);
        let s2 = store.clone();
        std::thread::spawn(move || {
            let _g = s2.inner.lock().unwrap();
            panic!("poison the store lock");
        })
        .join()
        .unwrap_err();
        assert!(store.inner.is_poisoned(), "precondition: lock is poisoned");
        // Reads, installs, and the blocking wait all still work.
        assert!(store.contains(1));
        assert_eq!(store.get(1).unwrap()[0], 7.5);
        assert_eq!(store.complete_fetch(&[2], &[8.5], 1), 1);
        store.wait_all(&[1, 2], Duration::from_secs(1)).unwrap();
        let err = store.wait_all(&[99], Duration::from_millis(10));
        assert!(err.is_err(), "absent node still reports a timeout, not a poisoned panic");
    }

    #[test]
    fn begin_fetch_dedups_resident_and_expected() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        let first = store.begin_fetch(&[1, 2, 3], &mut stats);
        assert_eq!(first, vec![1, 2, 3]);
        // All three now expected: nothing new to request.
        assert!(store.begin_fetch(&[1, 2, 3], &mut stats).is_empty());
        assert_eq!(stats.nodes_deduped, 3);
        store.complete_fetch(&[1, 2, 3], &[0.0; 6], 2);
        // Resident: still deduped.
        assert!(store.begin_fetch(&[2], &mut stats).is_empty());
        assert_eq!(store.resident(), 3);
        assert_eq!(store.get(2).unwrap().len(), 2);
    }

    #[test]
    fn copy_into_matches_get_without_allocating() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        store.begin_fetch(&[5], &mut stats);
        store.complete_fetch(&[5], &[1.5, -2.5], 2);
        let mut row = [0.0f32; 2];
        assert!(store.copy_into(5, &mut row));
        assert_eq!(&row[..], &store.get(5).unwrap()[..]);
        assert!(!store.copy_into(6, &mut row), "absent row reports non-resident");
    }

    #[test]
    fn complete_fetch_rejects_shape_mismatch() {
        // Regression: an undersized payload used to slice
        // `feats[i*dim..(i+1)*dim]` out of bounds and panic the
        // prefetcher thread; now the malformed payload is dropped whole.
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        store.begin_fetch(&[1, 2], &mut stats);
        assert_eq!(store.complete_fetch(&[1, 2], &[1.0], 1), 0, "chopped payload dropped");
        assert!(!store.contains(1) && !store.contains(2));
        // Zero-dim rows for real nodes would panic `copy_into` later.
        assert_eq!(store.complete_fetch(&[1, 2], &[], 0), 0);
        assert!(!store.contains(1));
        // A well-formed payload still installs.
        assert_eq!(store.complete_fetch(&[1, 2], &[1.0, 2.0], 1), 2);
    }

    #[test]
    fn handle_wire_drops_dim_skewed_and_chopped_responses() {
        // Regression: a `FetchResp` whose dim disagrees with the run's
        // feature width passed straight into the store pre-fix (a
        // zero-dim frame satisfies the decoder's shape identity
        // `0 == n × 0`) and panicked the trainer's row copy later.
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        stats.fetch_latency.resize_with(1, Default::default);
        let mut tracer = Tracer::new(false, Role::Prefetcher, 0);
        let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
        outstanding.insert(1, (0, Instant::now()));
        store.begin_fetch(&[3], &mut stats);
        let skewed = Frame::FetchResp { req_id: 1, feat_dim: 0, nodes: vec![3], feats: vec![] }
            .encode()
            .unwrap();
        handle_wire(0, &store, &skewed, &mut stats, &mut outstanding, &mut tracer, 2, None);
        assert_eq!(stats.bad_frames, 1, "dim-skewed response dropped");
        assert!(!store.contains(3), "no row installed from the skewed frame");
        assert!(outstanding.contains_key(&1), "request still owed a real response");
        // A chopped frame (fault shim cut mid-payload) fails decode.
        let good =
            Frame::FetchResp { req_id: 1, feat_dim: 2, nodes: vec![3], feats: vec![1.0, 2.0] }
                .encode()
                .unwrap();
        handle_wire(
            0,
            &store,
            &good[..good.len() - 3],
            &mut stats,
            &mut outstanding,
            &mut tracer,
            2,
            None,
        );
        assert_eq!(stats.bad_frames, 2, "chopped payload counted and dropped");
        assert!(!store.contains(3));
        // The intact response still lands.
        handle_wire(0, &store, &good, &mut stats, &mut outstanding, &mut tracer, 2, None);
        assert_eq!(stats.resp_frames, 1);
        assert!(store.contains(3));
    }

    #[test]
    fn evict_while_expected_discards_on_arrival() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        assert_eq!(store.begin_fetch(&[9], &mut stats), vec![9]);
        store.evict(&[9]);
        assert_eq!(store.complete_fetch(&[9], &[1.0], 1), 0, "discarded");
        assert!(!store.contains(9));
        // A fresh request goes back on the wire.
        assert_eq!(store.begin_fetch(&[9], &mut stats), vec![9]);
    }

    #[test]
    fn refetch_after_evict_goes_back_on_wire() {
        // Re-requesting a node that was evicted while its response was
        // still inbound must cost a *new* wire request (deterministic
        // traffic), never rescue the stale payload.
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        assert_eq!(store.begin_fetch(&[4], &mut stats), vec![4]);
        store.evict(&[4]);
        assert_eq!(store.begin_fetch(&[4], &mut stats), vec![4], "refetch is a wire request");
        // Both responses eventually land; payloads are identical by
        // construction (features are a function of the node id), so
        // whichever arrives first installs the row.
        assert_eq!(store.complete_fetch(&[4], &[2.5], 1), 1);
        assert_eq!(store.complete_fetch(&[4], &[2.5], 1), 1);
        assert_eq!(store.get(4).unwrap()[0], 2.5);
        assert_eq!(stats.nodes_deduped, 0);
    }

    #[test]
    fn duplicate_responses_are_dropped_by_req_id() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        stats.fetch_latency.resize_with(1, Default::default);
        let mut tracer = Tracer::new(true, Role::Prefetcher, 0);
        let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
        outstanding.insert(7, (0, Instant::now()));
        let resp =
            Frame::FetchResp { req_id: 7, feat_dim: 1, nodes: vec![3], feats: vec![0.5] };
        store.begin_fetch(&[3], &mut stats);
        let bytes = resp.encode().unwrap();
        handle_wire(0, &store, &bytes, &mut stats, &mut outstanding, &mut tracer, 1, None);
        handle_wire(0, &store, &bytes, &mut stats, &mut outstanding, &mut tracer, 1, None);
        assert_eq!(stats.resp_frames, 1);
        assert_eq!(stats.nodes_received, 1);
        assert_eq!(stats.dup_frames, 1, "second copy is dropped by req-id dedup");
        assert_eq!(stats.bad_frames, 0);
        assert!(store.contains(3));
        assert_eq!(stats.fetch_latency[0].count(), 1, "latency recorded once");
        let evs = tracer.finish();
        assert_eq!(evs.len(), 2, "one FetchResponse + RoleEnd (dup is silent)");
        assert!(matches!(evs[0].kind, EventKind::FetchResponse { req_id: 7, .. }));
    }

    #[test]
    fn wait_all_returns_once_resident() {
        let store = Arc::new(FeatureStore::new());
        let mut stats = WireStats::default();
        store.begin_fetch(&[1, 2], &mut stats);
        let s2 = store.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.complete_fetch(&[1, 2], &[0.0, 0.0], 1);
        });
        store.wait_all(&[1, 2], Duration::from_secs(10)).unwrap();
        h.join().unwrap();
        assert_eq!(store.resident(), 2);
    }

    #[test]
    fn wait_all_expiry_lands_near_deadline() {
        // Regression: the fixed 50 ms wake slice let a 60 ms deadline
        // expire only at ~100 ms (the next slice boundary).  The slice is
        // now capped at the remaining deadline.
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        store.begin_fetch(&[1], &mut stats);
        let start = Instant::now();
        let err = store.wait_all(&[1], Duration::from_millis(60));
        let elapsed = start.elapsed();
        assert!(err.is_err(), "absent node must time out");
        assert!(elapsed >= Duration::from_millis(60), "no early expiry ({elapsed:?})");
        assert!(elapsed < Duration::from_millis(90), "expiry overshot deadline ({elapsed:?})");
    }

    #[test]
    fn chunk_layout_slots_follow_local_order() {
        let owned = [40u32, 10, 77, 3, 8];
        let l = ChunkLayout::build(&owned, 2);
        assert_eq!(l.slot_of(40), Some((0, 0)));
        assert_eq!(l.slot_of(10), Some((0, 1)));
        assert_eq!(l.slot_of(77), Some((1, 0)));
        assert_eq!(l.slot_of(8), Some((2, 0)), "short tail chunk");
        assert_eq!(l.slot_of(999), None);
        assert_eq!(l.rows_in(0), 2);
        assert_eq!(l.rows_in(2), 1, "last chunk is short");
    }

    #[test]
    fn chunk_cache_evicts_lru_within_budget() {
        // Budget fits two 100-byte chunks; admitting a third evicts the
        // least recently touched.
        let mut c = ChunkCache::new(200);
        c.admit(0, 100);
        c.admit(1, 100);
        assert!(c.touch(0), "refresh chunk 0");
        c.admit(2, 100);
        assert!(c.touch(0), "recently used survives");
        assert!(c.touch(2), "newest survives");
        assert!(!c.touch(1), "LRU chunk evicted");
        assert_eq!(c.used, 200);
        // An over-budget chunk still caches alone.
        let mut big = ChunkCache::new(10);
        big.admit(5, 1000);
        assert!(big.touch(5));
    }

    #[test]
    fn chunk_cache_settle_after_evict_is_noop() {
        let mut c = ChunkCache::new(100);
        c.admit(0, 80);
        c.admit(1, 80); // evicts chunk 0 (LRU) while "in flight"
        assert!(!c.touch(0));
        c.settle(0, vec![1, 2], vec![0.0; 4].into_boxed_slice());
        assert!(!c.touch(0), "arrival never re-admits an evicted chunk");
        c.settle(1, vec![3, 4], vec![1.0, 2.0, 3.0, 4.0].into_boxed_slice());
        assert_eq!(c.row(1, 1, 2), Some(&[3.0f32, 4.0][..]), "settled row served");
        // A second settle (duplicate response) keeps the first payload.
        c.settle(1, vec![3, 4], vec![9.0; 4].into_boxed_slice());
        assert_eq!(c.row(1, 1, 2), Some(&[3.0f32, 4.0][..]));
    }

    #[test]
    fn chunked_response_installs_rows_and_settles_cache() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        stats.fetch_latency.resize_with(1, Default::default);
        let mut tracer = Tracer::new(false, Role::Prefetcher, 0);
        let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
        let owned = [7u32, 9, 11];
        let mut cs = ChunkState {
            dim: 2,
            layouts: vec![ChunkLayout::build(&owned, 2)],
            caches: vec![ChunkCache::new(1 << 20)],
        };
        cs.caches[0].admit(0, chunk_wire_bytes(2, 2));
        outstanding.insert(0, (0, Instant::now()));
        store.begin_fetch(&[7], &mut stats);
        let feats = vec![1.0f32, 2.0, 3.0, 4.0];
        let resp = Frame::ChunkResp {
            req_id: 0,
            feat_dim: 2,
            refs: vec![],
            chunks: vec![super::super::wire::Chunk {
                digest: digest_f32(&feats),
                nodes: vec![7, 9],
                feats,
            }],
        }
        .encode()
        .unwrap();
        handle_wire(
            0,
            &store,
            &resp,
            &mut stats,
            &mut outstanding,
            &mut tracer,
            2,
            Some(&mut cs),
        );
        assert_eq!(stats.resp_frames, 1);
        assert_eq!(stats.nodes_received, 2, "whole chunk counted");
        assert!(store.contains(7), "wanted row installed");
        assert!(!store.contains(9), "unwanted chunk row not installed");
        assert_eq!(cs.caches[0].row(0, 1, 2), Some(&[3.0f32, 4.0][..]), "entry settled");
        // A later fetch of node 9 is a settled hit: served from cache.
        store.begin_fetch(&[9], &mut stats);
        assert!(cs.caches[0].touch(0));
        let row = cs.caches[0].row(0, 1, 2).unwrap().to_vec();
        store.complete_fetch(&[9], &row, 2);
        assert!(store.contains(9));
    }

    #[test]
    fn digest_mismatch_drops_chunk() {
        let store = FeatureStore::new();
        let mut stats = WireStats::default();
        stats.fetch_latency.resize_with(1, Default::default);
        let mut tracer = Tracer::new(false, Role::Prefetcher, 0);
        let mut outstanding: FastMap<u64, (u32, Instant)> = FastMap::default();
        let owned = [5u32, 6];
        let mut cs = ChunkState {
            dim: 1,
            layouts: vec![ChunkLayout::build(&owned, 2)],
            caches: vec![ChunkCache::new(1 << 20)],
        };
        cs.caches[0].admit(0, chunk_wire_bytes(2, 1));
        outstanding.insert(3, (0, Instant::now()));
        store.begin_fetch(&[5], &mut stats);
        let resp = Frame::ChunkResp {
            req_id: 3,
            feat_dim: 1,
            refs: vec![],
            chunks: vec![super::super::wire::Chunk {
                digest: 0xBAD, // corrupt: does not match the payload
                nodes: vec![5, 6],
                feats: vec![1.0, 2.0],
            }],
        }
        .encode()
        .unwrap();
        handle_wire(
            0,
            &store,
            &resp,
            &mut stats,
            &mut outstanding,
            &mut tracer,
            1,
            Some(&mut cs),
        );
        assert_eq!(stats.bad_frames, 1, "digest mismatch counted");
        assert!(!store.contains(5), "corrupt payload never installed");
        assert_eq!(cs.caches[0].row(0, 0, 1), None, "entry stays unsettled");
    }
}
