//! Multi-process cluster: trainers, feature servers, and the allreduce
//! hub as genuinely separate OS processes connected by the TCP transport.
//!
//! `rudder cluster --transport tcp` runs the *orchestrator*
//! ([`run_cluster_multiproc`]): it serializes the resolved [`RunConfig`]
//! (`config::to_toml` — lossless, so every process derives identical
//! graphs, partitions, and schedules from the same seeds), then re-invokes
//! its own binary once per role:
//!
//! ```text
//! rudder cluster --role hub     --listen 127.0.0.1:0 --trainers n ...
//! rudder cluster --role server  --listen 127.0.0.1:0 --part p --run-config f ...
//! rudder cluster --role trainer --part t --connect a1,a2 --hub ah --run-config f ...
//! ```
//!
//! Listeners bind ephemeral loopback ports and announce them on stdout
//! (`RUDDER_LISTEN <addr>`); the orchestrator collects the addresses and
//! passes them to the trainer workers, so there is no port-picking race.
//! The orchestrator's results listener doubles as a *control* link: a
//! worker that needs the run config dials it, sends [`Frame::Hello`], and
//! receives the resolved TOML inline as [`Frame::Config`]
//! (`config::to_toml` — lossless, so every process derives identical
//! graphs, partitions, and schedules from the same seeds).  Results come
//! back over the same wire: every worker dials the listener and sends one
//! [`Frame::Result`] carrying its binary blob ([`super::ipc`]) — `f64`s
//! as raw bits, so the parity check against the in-process sim stays
//! bit-exact across the process boundary.  No shared filesystem is needed
//! in either direction; `--run-config <file>` / `--out <file>` remain as
//! manual-debugging fallbacks.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::eval::{harness, Quality};
use crate::gnn::SageShape;
use crate::graph::Dataset;
use crate::metrics::{MeasuredStats, RunMetrics, WireStats};
use crate::net::Network;
use crate::partition::Partition;
use crate::sim::{self, ControllerSpec, ExperimentResult};
use crate::trace::{Trace, TraceEvent, TraceMeta};

use super::ipc;
use super::prefetch::{spawn_prefetcher, FeatureStore, PrefetchConfig};
use super::run::{hub_loop, ClusterConfig, ClusterResult, ComputeMode};
use super::server::{server_loop, ServerStats, WireDelay};
use super::trainer::{io_timeout, run_trainer, TrainerArgs, WallStats};
use super::transport::{
    self, FaultSpec, FrameReceiver, FrameSender, LinkStatsHandle, TcpFrameReceiver, TcpFrameSender,
};
use super::wire::{Frame, ROLE_HUB, ROLE_SERVER, ROLE_TRAINER};

/// Announce a bound listener to the orchestrator (must be the first stdout
/// line a listening worker emits).
fn announce_listen(listener: &TcpListener) -> Result<()> {
    // audit:allow(printing-outside-log) protocol line the orchestrator parses from worker stdout
    println!("RUDDER_LISTEN {}", listener.local_addr()?);
    std::io::stdout().flush()?;
    Ok(())
}

/// Hand a worker's result blob back to the orchestrator: over the results
/// TCP link when `--results` was given (one [`Frame::Result`] on a fresh
/// connection — no shared filesystem needed), to an `--out` file
/// otherwise.
fn deliver_result(
    role: u8,
    id: u32,
    blob: Vec<u8>,
    results: &Option<String>,
    out: &Option<PathBuf>,
) -> Result<()> {
    if let Some(addr) = results {
        let stream = TcpStream::connect(addr.as_str())
            .map_err(|e| crate::err!("worker: connect results listener {addr}: {e}"))?;
        let mut tx = TcpFrameSender::new(stream, LinkStatsHandle::new("results"));
        tx.send_frame(&Frame::Result { role, id, blob }.encode()?)?;
        tx.close();
        return Ok(());
    }
    if let Some(path) = out {
        std::fs::write(path, blob)?;
        return Ok(());
    }
    crate::bail!("worker: need --results <addr> or --out <file> to return results")
}

/// `Frame::Result` role the orchestrator sends to its own collector to
/// abort collection on a failure path.  Workers use the `ROLE_*` tags
/// (all non-zero), so the marker can never collide with a real result.
const RESULT_POISON_ROLE: u8 = 0;

/// Accept worker connections on the control/results listener until
/// `expect` [`Frame::Result`]s arrived; returns the collected
/// `(role, id, blob)` triples.  A connection that opens with
/// [`Frame::Hello`] is a *control* handshake: the collector replies with
/// the resolved run config as one inline [`Frame::Config`] and moves on —
/// config fetches never count toward `expect`.  Stray connections (port
/// scanners, misdirected clients: close without data, stall into the read
/// timeout, or send garbage) are dropped and collection continues — only
/// the orchestrator's own poison frame ([`RESULT_POISON_ROLE`], sent when
/// a failure path is unwinding) ends collection early.
fn spawn_result_collector(
    listener: TcpListener,
    expect: usize,
    config_toml: Arc<Vec<u8>>,
) -> JoinHandle<Vec<(u8, u32, Vec<u8>)>> {
    std::thread::Builder::new()
        .name("rudder-results".into())
        .spawn(move || {
            let mut results: Vec<(u8, u32, Vec<u8>)> = Vec::with_capacity(expect);
            while results.len() < expect {
                let stream = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(e) => {
                        crate::log_info!("results listener: accept failed: {e}");
                        break;
                    }
                };
                let reply = stream.try_clone();
                let mut rx = TcpFrameReceiver::new(stream, LinkStatsHandle::new("worker"));
                match rx.recv_frame_timeout(Duration::from_secs(60)) {
                    Ok(Some(bytes)) => match Frame::decode(&bytes) {
                        Ok((Frame::Result { role: RESULT_POISON_ROLE, .. }, _)) => break,
                        Ok((Frame::Result { role, id, blob }, _)) => {
                            results.push((role, id, blob));
                        }
                        Ok((Frame::Hello { .. }, _)) => match reply {
                            Ok(stream) => {
                                let mut tx =
                                    TcpFrameSender::new(stream, LinkStatsHandle::new("config"));
                                let frame = Frame::Config { toml: (*config_toml).clone() };
                                match frame.encode() {
                                    Ok(bytes) => {
                                        let _ = tx.send_frame(&bytes);
                                    }
                                    Err(e) => crate::log_info!(
                                        "results listener: config frame encode: {e}"
                                    ),
                                }
                                tx.close();
                            }
                            Err(e) => {
                                crate::log_info!("results listener: clone for config reply: {e}")
                            }
                        },
                        Ok(_) | Err(_) => {
                            crate::log_info!("results listener: dropping garbage frame")
                        }
                    },
                    Ok(None) => crate::log_info!("results listener: dropping dataless connection"),
                    Err(e) => {
                        crate::log_info!("results listener: dropping stalled connection: {e}")
                    }
                }
            }
            results
        })
        .expect("spawn results collector")
}

/// Resolve a worker's run config: from `--run-config <file>` when given
/// (manual-debugging fallback), otherwise by dialing the orchestrator's
/// control/results listener and exchanging [`Frame::Hello`] for the
/// inline TOML ([`Frame::Config`]) — no shared filesystem needed.
fn fetch_config(
    role: u8,
    id: u32,
    config: &Option<PathBuf>,
    control: &Option<String>,
) -> Result<crate::config::RunConfig> {
    if let Some(path) = config {
        return crate::config::load(path);
    }
    let Some(addr) = control else {
        crate::bail!("worker: need --run-config <file> or --results <addr> for the run config")
    };
    let stream = TcpStream::connect(addr.as_str())
        .map_err(|e| crate::err!("worker: connect control listener {addr}: {e}"))?;
    let read_half = stream.try_clone()?;
    let mut tx = TcpFrameSender::new(stream, LinkStatsHandle::new("control"));
    tx.send_frame(&Frame::Hello { role, id }.encode()?)?;
    tx.close();
    let mut rx = TcpFrameReceiver::new(read_half, LinkStatsHandle::new("control"));
    let bytes = rx
        .recv_frame_timeout(Duration::from_secs(60))?
        .ok_or_else(|| crate::err!("worker: control listener closed before sending the config"))?;
    match Frame::decode(&bytes)? {
        (Frame::Config { toml }, _) => {
            let s = String::from_utf8(toml)
                .map_err(|_| crate::err!("worker: config frame is not valid UTF-8"))?;
            crate::config::from_toml_str(&s)
        }
        _ => crate::bail!("worker: control listener sent an unexpected frame"),
    }
}

// ---------------------------------------------------------------------------
// worker entry points (one per --role)

pub struct ServerWorkerOpts {
    pub part: usize,
    pub listen: String,
    /// File fallback (`--run-config`) for manual debugging; workers
    /// normally fetch the config inline over the control link.
    pub config: Option<PathBuf>,
    pub time_scale: f64,
    pub fault: Option<FaultSpec>,
    /// Control/results-listener address (`--results`): the normal config
    /// fetch + result return path.
    pub results: Option<String>,
    /// File fallback (`--out`) for manual debugging.
    pub out: Option<PathBuf>,
    /// Record a flight-recorder trace and ship it in the result blob.
    pub trace: bool,
}

/// `--role server`: rebuild the dataset/partition from the shared config,
/// serve fetches on a TCP listener until every trainer hangs up, then
/// write the stats blob.
pub fn run_server_worker(o: &ServerWorkerOpts) -> Result<()> {
    crate::util::log::set_role(&format!("server-{}", o.part));
    // Bind + announce *before* the (expensive) dataset rebuild, so the
    // orchestrator can move on to spawning the next worker and the graph
    // builds run in parallel across server processes; early dialers just
    // sit in the accept backlog until serving starts.
    let listener = TcpListener::bind(o.listen.as_str())?;
    announce_listen(&listener)?;
    let cfg = fetch_config(ROLE_SERVER, super::id_u32(o.part), &o.config, &o.results)?;
    let (ds, part) = sim::build_cluster(&cfg)?;
    let part = Arc::new(part);
    crate::ensure!(o.part < part.num_parts, "server worker: part {} out of range", o.part);
    let n = cfg.num_trainers;
    let net = Network::new(cfg.net.clone(), n);
    let delay = WireDelay::from_net(&net, o.time_scale);
    let chop = o.fault.map(|f| f.chop).unwrap_or(0);
    let (tx, rx) = mpsc::channel();
    let accept = transport::serve_listener(listener, n, tx, &format!("server{}", o.part), chop);
    let (stats, trace) = server_loop(
        o.part,
        ds.feature_seed,
        ds.spec.feat_dim,
        cfg.chunk_rows,
        part,
        rx,
        Vec::new(),
        delay,
        o.fault,
        o.trace,
    );
    let _ = accept.join();
    deliver_result(
        ROLE_SERVER,
        super::id_u32(o.part),
        ipc::encode_server_stats(&stats, &trace)?,
        &o.results,
        &o.out,
    )
}

pub struct HubWorkerOpts {
    pub listen: String,
    pub trainers: usize,
    pub round_sleep: f64,
    pub results: Option<String>,
    pub out: Option<PathBuf>,
    /// Record a flight-recorder trace and ship it in the result blob.
    pub trace: bool,
}

/// `--role hub`: run the allreduce barrier for `trainers` peers, then
/// write the round count blob.
pub fn run_hub_worker(o: &HubWorkerOpts) -> Result<()> {
    crate::util::log::set_role("hub");
    let listener = TcpListener::bind(o.listen.as_str())?;
    announce_listen(&listener)?;
    let (tx, rx) = mpsc::channel();
    let accept = transport::serve_listener(listener, o.trainers, tx, "hub", 0);
    let (rounds, trace) = hub_loop(o.trainers, rx, Vec::new(), o.round_sleep, o.trace);
    let _ = accept.join();
    deliver_result(ROLE_HUB, 0, ipc::encode_hub_result(rounds, &trace)?, &o.results, &o.out)
}

pub struct TrainerWorkerOpts {
    pub part: usize,
    /// File fallback (`--run-config`); normally fetched over the control
    /// link at Hello time.
    pub config: Option<PathBuf>,
    pub servers: Vec<String>,
    pub hub: String,
    pub compute: ComputeMode,
    pub results: Option<String>,
    pub out: Option<PathBuf>,
    /// Record a flight-recorder trace and ship it in the result blob.
    pub trace: bool,
}

/// `--role trainer`: rebuild the dataset/partition, dial every feature
/// server and the hub, run the trainer + prefetcher threads, and write
/// the result blob.
pub fn run_trainer_worker(o: &TrainerWorkerOpts) -> Result<()> {
    crate::util::log::set_role(&format!("trainer-{}", o.part));
    let cfg = fetch_config(ROLE_TRAINER, super::id_u32(o.part), &o.config, &o.results)?;
    let (ds, part) = sim::build_cluster(&cfg)?;
    crate::ensure!(
        o.servers.len() == cfg.num_trainers,
        "trainer worker: {} server addrs for {} partitions",
        o.servers.len(),
        cfg.num_trainers
    );
    crate::ensure!(o.part < cfg.num_trainers, "trainer worker: part {} out of range", o.part);
    // Classifier controllers pretrain on the deterministic offline trace
    // set; every process derives the identical set from the same seeds.
    let offline = if matches!(cfg.controller, ControllerSpec::Classifier { .. }) {
        Some(harness::offline_training_set(Quality::Quick))
    } else {
        None
    };
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let max_mb = sim::max_minibatches_per_epoch(&cfg, &ds, &part);
    let store = Arc::new(FeatureStore::new());
    let (pf_tx, pf_rx) = mpsc::channel();
    let dial = transport::dial_trainer_links(&o.servers, &o.hub, super::id_u32(o.part), &pf_tx)?;
    let pf_handle = spawn_prefetcher(
        o.part,
        store.clone(),
        pf_rx,
        dial.request_links,
        part.clone(),
        PrefetchConfig {
            feat_dim: ds.spec.feat_dim,
            chunk_rows: cfg.chunk_rows,
            cache_bytes: cfg.chunk_cache_bytes,
        },
        io_timeout(o.compute.time_scale()),
        o.trace,
    );
    let args = TrainerArgs {
        part_id: o.part,
        cfg: cfg.clone(),
        ds,
        part,
        offline: Arc::new(offline),
        store,
        prefetch_tx: pf_tx,
        hub_tx: dial.hub_tx,
        hub_rx: dial.hub_rx,
        max_mb_per_epoch: max_mb,
        compute: o.compute,
        trace: o.trace,
    };
    let out = run_trainer(args);
    let (mut wire, pf_trace) = pf_handle
        .join()
        .map_err(|_| crate::err!("trainer worker {}: prefetcher panicked", o.part))?;
    for p in dial.pumps {
        let _ = p.join();
    }
    wire.links = dial.links.iter().map(LinkStatsHandle::snapshot).collect();
    let mut trace = out.trace;
    trace.extend(pf_trace);
    let blob = ipc::encode_trainer_result(&out.metrics, &out.wall, &wire, &out.measured, &trace)?;
    deliver_result(ROLE_TRAINER, super::id_u32(o.part), blob, &o.results, &o.out)
}

// ---------------------------------------------------------------------------
// orchestrator

/// Spawn a worker with piped stdout (listener roles announce their port
/// there).
fn spawn_piped(exe: &Path, args: &[String]) -> Result<Child> {
    Command::new(exe)
        .arg("cluster")
        .args(args)
        .stdout(Stdio::piped())
        .spawn()
        .map_err(|e| crate::err!("spawn worker {args:?}: {e}"))
}

/// Read the `RUDDER_LISTEN <addr>` line from a worker's stdout, passing
/// any other output through; keep draining the pipe in the background so
/// the worker can never block on a full pipe.
fn read_listen_addr(child: &mut Child, what: &str) -> Result<String> {
    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| crate::err!("{what}: stdout not piped"))?;
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        crate::ensure!(n > 0, "{what}: exited before announcing its listen address");
        if let Some(addr) = line.trim().strip_prefix("RUDDER_LISTEN ") {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let _ = std::io::copy(&mut reader, &mut std::io::sink());
            });
            return Ok(addr);
        }
        // audit:allow(printing-outside-log) passthrough of a worker's pre-announce stdout lines
        print!("{line}");
    }
}

fn wait_worker(mut child: Child, what: &str) -> Result<()> {
    let status = child.wait()?;
    crate::ensure!(status.success(), "{what} exited with {status}");
    Ok(())
}

fn kill_all(children: &mut [(String, Child)]) {
    for (_, c) in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Run the cluster as separate OS processes (TCP transport on loopback)
/// and aggregate the workers' results into the same [`ClusterResult`]
/// shape the in-process runtime produces, so `--parity` and the reporting
/// path are transport-agnostic.  The run config ships inline over the
/// control link ([`Frame::Config`] in reply to a worker's Hello) and
/// results return over the same listener ([`Frame::Result`]) — no shared
/// filesystem in either direction.
pub fn run_cluster_multiproc(
    ds: Arc<Dataset>,
    part: Arc<Partition>,
    ccfg: &ClusterConfig,
) -> Result<ClusterResult> {
    let cfg = &ccfg.run;
    let n = cfg.num_trainers;
    crate::ensure!(n >= 1, "cluster: need at least one trainer");
    crate::ensure!(
        n == part.num_parts,
        "cluster: {n} trainers but {} partitions",
        part.num_parts
    );
    let exe = std::env::current_exe()?;
    let config_toml = Arc::new(crate::config::to_toml(cfg)?.into_bytes());
    let ts_arg = format!("{}", ccfg.compute.time_scale());

    // Control + results path: every worker that needs the run config
    // dials this listener and trades a Hello for the inline TOML; every
    // worker dials it again to send one Result frame (2n + 1 results
    // expected).
    let results_listener = TcpListener::bind("127.0.0.1:0")?;
    let results_addr = results_listener.local_addr()?.to_string();
    let collector = spawn_result_collector(results_listener, 2 * n + 1, config_toml);
    // Poison the collector (explicit abort frame) so its accept loop ends
    // on failure paths instead of leaking a blocked thread.
    let poison = |collector: JoinHandle<Vec<(u8, u32, Vec<u8>)>>| {
        if let Ok(stream) = TcpStream::connect(results_addr.as_str()) {
            let mut tx = TcpFrameSender::new(stream, LinkStatsHandle::new("poison"));
            let frame = Frame::Result { role: RESULT_POISON_ROLE, id: 0, blob: Vec::new() };
            if let Ok(bytes) = frame.encode() {
                let _ = tx.send_frame(&bytes);
            }
            tx.close();
        }
        let _ = collector.join();
    };

    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), n);
    let round_sleep = ccfg.compute.time_scale() * net.allreduce_time(shape.param_bytes());

    // Listener workers first; collect their announced addresses.
    let mut listeners: Vec<(String, Child)> = Vec::new();
    let mut hub_args: Vec<String> = vec![
        "--role".into(),
        "hub".into(),
        "--listen".into(),
        "127.0.0.1:0".into(),
        "--trainers".into(),
        n.to_string(),
        "--round-sleep".into(),
        format!("{round_sleep}"),
        "--results".into(),
        results_addr.clone(),
    ];
    if ccfg.trace {
        hub_args.push("--record-trace".into());
    }
    let mut hub_child = match spawn_piped(&exe, &hub_args) {
        Ok(c) => c,
        Err(e) => {
            poison(collector);
            return Err(e);
        }
    };
    let hub_addr = match read_listen_addr(&mut hub_child, "hub worker") {
        Ok(a) => a,
        Err(e) => {
            let _ = hub_child.kill();
            let _ = hub_child.wait();
            poison(collector);
            return Err(e);
        }
    };
    listeners.push(("hub worker".into(), hub_child));

    let mut server_addrs: Vec<String> = Vec::new();
    for p in 0..n {
        let mut args = vec![
            "--role".into(),
            "server".into(),
            "--part".into(),
            p.to_string(),
            "--listen".into(),
            "127.0.0.1:0".into(),
            "--time-scale".into(),
            ts_arg.clone(),
            "--results".into(),
            results_addr.clone(),
        ];
        if let Some(f) = ccfg.fault {
            args.push("--fault".into());
            args.push(format!("{}:{}:{}:{}", f.seed, f.dup, f.delay, f.chop));
        }
        if ccfg.trace {
            args.push("--record-trace".into());
        }
        let mut child = match spawn_piped(&exe, &args) {
            Ok(c) => c,
            Err(e) => {
                kill_all(&mut listeners);
                poison(collector);
                return Err(e);
            }
        };
        match read_listen_addr(&mut child, &format!("server worker {p}")) {
            Ok(a) => server_addrs.push(a),
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                kill_all(&mut listeners);
                poison(collector);
                return Err(e);
            }
        }
        listeners.push((format!("server worker {p}"), child));
    }

    // Trainer workers (stdio inherited — their panics land on stderr).
    let wall_start = Instant::now();
    let mut trainers: Vec<(String, Child)> = Vec::new();
    for t in 0..n {
        let mut args: Vec<String> = vec![
            "--role".into(),
            "trainer".into(),
            "--part".into(),
            t.to_string(),
            "--servers".into(),
            server_addrs.join(","),
            "--hub".into(),
            hub_addr.clone(),
            "--compute".into(),
            ccfg.compute.name().into(),
            "--time-scale".into(),
            ts_arg.clone(),
            "--results".into(),
            results_addr.clone(),
        ];
        if ccfg.trace {
            args.push("--record-trace".into());
        }
        let child = Command::new(&exe)
            .arg("cluster")
            .args(&args)
            .spawn()
            .map_err(|e| crate::err!("spawn trainer worker {t}: {e}"));
        match child {
            Ok(c) => trainers.push((format!("trainer worker {t}"), c)),
            Err(e) => {
                kill_all(&mut trainers);
                kill_all(&mut listeners);
                poison(collector);
                return Err(e);
            }
        }
    }

    // Join everything: trainers first (they drive shutdown), then the
    // listener roles, which exit once every trainer connection closes.
    let mut failure: Option<crate::error::RudderError> = None;
    for (what, child) in trainers.drain(..) {
        if let Err(e) = wait_worker(child, &what) {
            failure.get_or_insert(e);
        }
    }
    if let Some(e) = failure {
        kill_all(&mut listeners);
        poison(collector);
        return Err(e);
    }
    // All trainers succeeded, so every listener has seen its EOFs; a
    // non-zero exit here still must not leak the remaining children.
    for (what, child) in listeners.drain(..) {
        if let Err(e) = wait_worker(child, &what) {
            failure.get_or_insert(e);
        }
    }
    if let Some(e) = failure {
        poison(collector);
        return Err(e);
    }
    let wall_total = wall_start.elapsed().as_secs_f64();

    // Every worker exited cleanly, so every result frame is already sent
    // (workers deliver before exiting); the collector drains them.
    let received = collector
        .join()
        .map_err(|_| crate::err!("results collector panicked"))?;
    let mut trainer_blobs: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut server_blobs: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut hub_blob: Option<Vec<u8>> = None;
    for (role, id, blob) in received {
        match role {
            ROLE_TRAINER if (id as usize) < n => trainer_blobs[id as usize] = Some(blob),
            ROLE_SERVER if (id as usize) < n => server_blobs[id as usize] = Some(blob),
            ROLE_HUB => hub_blob = Some(blob),
            _ => crate::log_info!("results listener: unknown worker role {role} id {id}"),
        }
    }

    let mut per_trainer: Vec<RunMetrics> = Vec::with_capacity(n);
    let mut walls: Vec<WallStats> = Vec::with_capacity(n);
    let mut wire: Vec<WireStats> = Vec::with_capacity(n);
    let mut measured: Vec<MeasuredStats> = Vec::with_capacity(n);
    let mut trace_events: Vec<TraceEvent> = Vec::new();
    for (t, blob) in trainer_blobs.into_iter().enumerate() {
        let blob = blob.ok_or_else(|| crate::err!("trainer worker {t} returned no result"))?;
        let (m, w, ws, me, tr) = ipc::decode_trainer_result(&blob)?;
        per_trainer.push(m);
        walls.push(w);
        wire.push(ws);
        measured.push(me);
        trace_events.extend(tr);
    }
    let mut servers: Vec<ServerStats> = Vec::with_capacity(n);
    for (p, blob) in server_blobs.into_iter().enumerate() {
        let blob = blob.ok_or_else(|| crate::err!("server worker {p} returned no result"))?;
        let (s, tr) = ipc::decode_server_stats(&blob)?;
        servers.push(s);
        trace_events.extend(tr);
    }
    let hub_blob = hub_blob.ok_or_else(|| crate::err!("hub worker returned no result"))?;
    let (allreduce_rounds, hub_trace) = ipc::decode_hub_result(&hub_blob)?;
    trace_events.extend(hub_trace);

    let trace = if ccfg.trace {
        let mut t = Trace::new(TraceMeta {
            label: cfg.controller.label(),
            seed: cfg.seed,
            transport: ccfg.transport.name().to_string(),
            compute: ccfg.compute.name().to_string(),
            config: crate::config::to_toml(cfg)?,
        });
        t.events = trace_events;
        t.sort_canonical();
        Some(t)
    } else {
        None
    };

    let epoch_times = per_trainer
        .first()
        .map(|m| m.epoch_times.clone())
        .unwrap_or_default();
    let experiment = ExperimentResult::aggregate(cfg.controller.label(), per_trainer, epoch_times);
    Ok(ClusterResult {
        experiment,
        wall_total,
        walls,
        measured,
        wire,
        servers,
        allreduce_rounds,
        trace,
    })
}
