//! Multi-process cluster: trainers, feature servers, and the allreduce
//! hub as genuinely separate OS processes connected by the TCP transport.
//!
//! `rudder cluster --transport tcp` runs the *orchestrator*
//! ([`run_cluster_multiproc`]): it serializes the resolved [`RunConfig`]
//! (`config::to_toml` — lossless, so every process derives identical
//! graphs, partitions, and schedules from the same seeds), then re-invokes
//! its own binary once per role:
//!
//! ```text
//! rudder cluster --role hub     --listen 127.0.0.1:0 --trainers n ...
//! rudder cluster --role server  --listen 127.0.0.1:0 --part p --run-config f ...
//! rudder cluster --role trainer --part t --connect a1,a2 --hub ah --run-config f ...
//! ```
//!
//! Listeners bind ephemeral loopback ports and announce them on stdout
//! (`RUDDER_LISTEN <addr>`); the orchestrator collects the addresses and
//! passes them to the trainer workers, so there is no port-picking race.
//! Results come back as binary blobs ([`super::ipc`]) written to
//! `--out` files — `f64`s as raw bits, so the parity check against the
//! in-process sim stays bit-exact across the process boundary.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;
use crate::eval::{harness, Quality};
use crate::gnn::SageShape;
use crate::graph::Dataset;
use crate::metrics::{RunMetrics, WireStats};
use crate::net::Network;
use crate::partition::Partition;
use crate::sim::{self, ControllerSpec, ExperimentResult};

use super::ipc;
use super::prefetch::{spawn_prefetcher, FeatureStore};
use super::run::{hub_loop, ClusterConfig, ClusterResult};
use super::server::{server_loop, ServerStats, WireDelay};
use super::trainer::{io_timeout, run_trainer, TrainerArgs, WallStats};
use super::transport::{self, FaultSpec};

/// Announce a bound listener to the orchestrator (must be the first stdout
/// line a listening worker emits).
fn announce_listen(listener: &TcpListener) -> Result<()> {
    println!("RUDDER_LISTEN {}", listener.local_addr()?);
    std::io::stdout().flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// worker entry points (one per --role)

pub struct ServerWorkerOpts {
    pub part: usize,
    pub listen: String,
    pub config: PathBuf,
    pub time_scale: f64,
    pub fault: Option<FaultSpec>,
    pub out: PathBuf,
}

/// `--role server`: rebuild the dataset/partition from the shared config,
/// serve fetches on a TCP listener until every trainer hangs up, then
/// write the stats blob.
pub fn run_server_worker(o: &ServerWorkerOpts) -> Result<()> {
    // Bind + announce *before* the (expensive) dataset rebuild, so the
    // orchestrator can move on to spawning the next worker and the graph
    // builds run in parallel across server processes; early dialers just
    // sit in the accept backlog until serving starts.
    let listener = TcpListener::bind(o.listen.as_str())?;
    announce_listen(&listener)?;
    let cfg = crate::config::load(&o.config)?;
    let (ds, part) = sim::build_cluster(&cfg)?;
    let part = Arc::new(part);
    crate::ensure!(o.part < part.num_parts, "server worker: part {} out of range", o.part);
    let n = cfg.num_trainers;
    let net = Network::new(cfg.net.clone(), n);
    let delay = WireDelay::from_net(&net, o.time_scale);
    let chop = o.fault.map(|f| f.chop).unwrap_or(0);
    let (tx, rx) = mpsc::channel();
    let accept = transport::serve_listener(listener, n, tx, &format!("server{}", o.part), chop);
    let stats = server_loop(
        o.part,
        ds.feature_seed,
        ds.spec.feat_dim,
        part,
        rx,
        Vec::new(),
        delay,
        o.fault,
    );
    let _ = accept.join();
    std::fs::write(&o.out, ipc::encode_server_stats(&stats))?;
    Ok(())
}

pub struct HubWorkerOpts {
    pub listen: String,
    pub trainers: usize,
    pub round_sleep: f64,
    pub out: PathBuf,
}

/// `--role hub`: run the allreduce barrier for `trainers` peers, then
/// write the round count blob.
pub fn run_hub_worker(o: &HubWorkerOpts) -> Result<()> {
    let listener = TcpListener::bind(o.listen.as_str())?;
    announce_listen(&listener)?;
    let (tx, rx) = mpsc::channel();
    let accept = transport::serve_listener(listener, o.trainers, tx, "hub", 0);
    let rounds = hub_loop(o.trainers, rx, Vec::new(), o.round_sleep);
    let _ = accept.join();
    std::fs::write(&o.out, ipc::encode_hub_rounds(rounds))?;
    Ok(())
}

pub struct TrainerWorkerOpts {
    pub part: usize,
    pub config: PathBuf,
    pub servers: Vec<String>,
    pub hub: String,
    pub time_scale: f64,
    pub out: PathBuf,
}

/// `--role trainer`: rebuild the dataset/partition, dial every feature
/// server and the hub, run the trainer + prefetcher threads, and write
/// the result blob.
pub fn run_trainer_worker(o: &TrainerWorkerOpts) -> Result<()> {
    let cfg = crate::config::load(&o.config)?;
    let (ds, part) = sim::build_cluster(&cfg)?;
    crate::ensure!(
        o.servers.len() == cfg.num_trainers,
        "trainer worker: {} server addrs for {} partitions",
        o.servers.len(),
        cfg.num_trainers
    );
    crate::ensure!(o.part < cfg.num_trainers, "trainer worker: part {} out of range", o.part);
    // Classifier controllers pretrain on the deterministic offline trace
    // set; every process derives the identical set from the same seeds.
    let offline = if matches!(cfg.controller, ControllerSpec::Classifier { .. }) {
        Some(harness::offline_training_set(Quality::Quick))
    } else {
        None
    };
    let ds = Arc::new(ds);
    let part = Arc::new(part);
    let max_mb = sim::max_minibatches_per_epoch(&cfg, &ds, &part);
    let store = Arc::new(FeatureStore::new());
    let (pf_tx, pf_rx) = mpsc::channel();
    let dial = transport::dial_trainer_links(&o.servers, &o.hub, o.part as u32, &pf_tx)?;
    let pf_handle = spawn_prefetcher(
        o.part,
        store.clone(),
        pf_rx,
        dial.request_links,
        part.clone(),
        io_timeout(o.time_scale),
    );
    let args = TrainerArgs {
        part_id: o.part,
        cfg: cfg.clone(),
        ds,
        part,
        offline: Arc::new(offline),
        store,
        prefetch_tx: pf_tx,
        hub_tx: dial.hub_tx,
        hub_rx: dial.hub_rx,
        max_mb_per_epoch: max_mb,
        time_scale: o.time_scale,
    };
    let out = run_trainer(args);
    let mut wire = pf_handle
        .join()
        .map_err(|_| crate::err!("trainer worker {}: prefetcher panicked", o.part))?;
    for p in dial.pumps {
        let _ = p.join();
    }
    wire.links = dial.links.iter().map(transport::snapshot).collect();
    std::fs::write(&o.out, ipc::encode_trainer_result(&out.metrics, &out.wall, &wire))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// orchestrator

/// Spawn a worker with piped stdout (listener roles announce their port
/// there).
fn spawn_piped(exe: &Path, args: &[String]) -> Result<Child> {
    Command::new(exe)
        .arg("cluster")
        .args(args)
        .stdout(Stdio::piped())
        .spawn()
        .map_err(|e| crate::err!("spawn worker {args:?}: {e}"))
}

/// Read the `RUDDER_LISTEN <addr>` line from a worker's stdout, passing
/// any other output through; keep draining the pipe in the background so
/// the worker can never block on a full pipe.
fn read_listen_addr(child: &mut Child, what: &str) -> Result<String> {
    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| crate::err!("{what}: stdout not piped"))?;
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        crate::ensure!(n > 0, "{what}: exited before announcing its listen address");
        if let Some(addr) = line.trim().strip_prefix("RUDDER_LISTEN ") {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let _ = std::io::copy(&mut reader, &mut std::io::sink());
            });
            return Ok(addr);
        }
        print!("{line}");
    }
}

fn wait_worker(mut child: Child, what: &str) -> Result<()> {
    let status = child.wait()?;
    crate::ensure!(status.success(), "{what} exited with {status}");
    Ok(())
}

fn kill_all(children: &mut [(String, Child)]) {
    for (_, c) in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Run the cluster as separate OS processes (TCP transport on loopback)
/// and aggregate the workers' result blobs into the same [`ClusterResult`]
/// shape the in-process runtime produces, so `--parity` and the reporting
/// path are transport-agnostic.
pub fn run_cluster_multiproc(
    ds: Arc<Dataset>,
    part: Arc<Partition>,
    ccfg: &ClusterConfig,
) -> Result<ClusterResult> {
    let cfg = &ccfg.run;
    let n = cfg.num_trainers;
    crate::ensure!(n >= 1, "cluster: need at least one trainer");
    crate::ensure!(
        n == part.num_parts,
        "cluster: {n} trainers but {} partitions",
        part.num_parts
    );
    let exe = std::env::current_exe()?;
    let dir = std::env::temp_dir().join(format!("rudder-cluster-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let cfg_path = dir.join("run-config.toml");
    std::fs::write(&cfg_path, crate::config::to_toml(cfg)?)?;
    let cfg_arg = cfg_path.to_string_lossy().to_string();
    let ts_arg = format!("{}", ccfg.time_scale);

    let shape = SageShape {
        batch: cfg.batch_size,
        fanout1: cfg.fanout1,
        fanout2: cfg.fanout2,
        feat_dim: ds.spec.feat_dim,
        hidden: cfg.hidden,
        classes: ds.spec.num_classes,
    };
    let net = Network::new(cfg.net.clone(), n);
    let round_sleep = ccfg.time_scale * net.allreduce_time(shape.param_bytes());

    // Listener workers first; collect their announced addresses.
    let mut listeners: Vec<(String, Child)> = Vec::new();
    let hub_out = dir.join("hub.bin");
    let mut hub_child = spawn_piped(
        &exe,
        &[
            "--role".into(),
            "hub".into(),
            "--listen".into(),
            "127.0.0.1:0".into(),
            "--trainers".into(),
            n.to_string(),
            "--round-sleep".into(),
            format!("{round_sleep}"),
            "--out".into(),
            hub_out.to_string_lossy().to_string(),
        ],
    )?;
    let hub_addr = match read_listen_addr(&mut hub_child, "hub worker") {
        Ok(a) => a,
        Err(e) => {
            let _ = hub_child.kill();
            let _ = hub_child.wait();
            let _ = std::fs::remove_dir_all(&dir);
            return Err(e);
        }
    };
    listeners.push(("hub worker".into(), hub_child));

    let mut server_addrs: Vec<String> = Vec::new();
    let mut server_outs: Vec<PathBuf> = Vec::new();
    for p in 0..n {
        let out = dir.join(format!("server-{p}.bin"));
        let mut args = vec![
            "--role".into(),
            "server".into(),
            "--part".into(),
            p.to_string(),
            "--listen".into(),
            "127.0.0.1:0".into(),
            "--run-config".into(),
            cfg_arg.clone(),
            "--time-scale".into(),
            ts_arg.clone(),
            "--out".into(),
            out.to_string_lossy().to_string(),
        ];
        if let Some(f) = ccfg.fault {
            args.push("--fault".into());
            args.push(format!("{}:{}:{}:{}", f.seed, f.dup, f.delay, f.chop));
        }
        let mut child = spawn_piped(&exe, &args)?;
        match read_listen_addr(&mut child, &format!("server worker {p}")) {
            Ok(a) => server_addrs.push(a),
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                kill_all(&mut listeners);
                let _ = std::fs::remove_dir_all(&dir);
                return Err(e);
            }
        }
        listeners.push((format!("server worker {p}"), child));
        server_outs.push(out);
    }

    // Trainer workers (stdio inherited — their panics land on stderr).
    let wall_start = Instant::now();
    let mut trainers: Vec<(String, Child, PathBuf)> = Vec::new();
    for t in 0..n {
        let out = dir.join(format!("trainer-{t}.bin"));
        let args: Vec<String> = vec![
            "--role".into(),
            "trainer".into(),
            "--part".into(),
            t.to_string(),
            "--run-config".into(),
            cfg_arg.clone(),
            "--servers".into(),
            server_addrs.join(","),
            "--hub".into(),
            hub_addr.clone(),
            "--time-scale".into(),
            ts_arg.clone(),
            "--out".into(),
            out.to_string_lossy().to_string(),
        ];
        let child = Command::new(&exe)
            .arg("cluster")
            .args(&args)
            .spawn()
            .map_err(|e| crate::err!("spawn trainer worker {t}: {e}"));
        match child {
            Ok(c) => trainers.push((format!("trainer worker {t}"), c, out)),
            Err(e) => {
                let mut started: Vec<(String, Child)> =
                    trainers.drain(..).map(|(w, c, _)| (w, c)).collect();
                kill_all(&mut started);
                kill_all(&mut listeners);
                let _ = std::fs::remove_dir_all(&dir);
                return Err(e);
            }
        }
    }

    // Join everything: trainers first (they drive shutdown), then the
    // listener roles, which exit once every trainer connection closes.
    let mut failure: Option<crate::error::RudderError> = None;
    let mut trainer_outs: Vec<PathBuf> = Vec::new();
    let mut remaining: Vec<(String, Child)> = Vec::new();
    for (what, child, out) in trainers {
        remaining.push((what, child));
        trainer_outs.push(out);
    }
    for (what, child) in remaining.drain(..) {
        if let Err(e) = wait_worker(child, &what) {
            failure.get_or_insert(e);
        }
    }
    if let Some(e) = failure {
        kill_all(&mut listeners);
        let _ = std::fs::remove_dir_all(&dir);
        return Err(e);
    }
    // All trainers succeeded, so every listener has seen its EOFs; a
    // non-zero exit here still must not leak the remaining children or
    // the blob directory.
    for (what, child) in listeners.drain(..) {
        if let Err(e) = wait_worker(child, &what) {
            failure.get_or_insert(e);
        }
    }
    if let Some(e) = failure {
        let _ = std::fs::remove_dir_all(&dir);
        return Err(e);
    }
    let wall_total = wall_start.elapsed().as_secs_f64();

    // Collect the result blobs; the temp dir goes away whether or not a
    // blob turns out unreadable.
    type Collected = (Vec<RunMetrics>, Vec<WallStats>, Vec<WireStats>, Vec<ServerStats>, u64);
    let collected: Result<Collected> = (|| {
        let mut per_trainer: Vec<RunMetrics> = Vec::with_capacity(n);
        let mut walls: Vec<WallStats> = Vec::with_capacity(n);
        let mut wire: Vec<WireStats> = Vec::with_capacity(n);
        for out in &trainer_outs {
            let blob = std::fs::read(out)?;
            let (m, w, ws) = ipc::decode_trainer_result(&blob)?;
            per_trainer.push(m);
            walls.push(w);
            wire.push(ws);
        }
        let mut servers: Vec<ServerStats> = Vec::with_capacity(n);
        for out in &server_outs {
            servers.push(ipc::decode_server_stats(&std::fs::read(out)?)?);
        }
        let allreduce_rounds = ipc::decode_hub_rounds(&std::fs::read(&hub_out)?)?;
        Ok((per_trainer, walls, wire, servers, allreduce_rounds))
    })();
    let _ = std::fs::remove_dir_all(&dir);
    let (per_trainer, walls, wire, servers, allreduce_rounds) = collected?;

    let epoch_times = per_trainer
        .first()
        .map(|m| m.epoch_times.clone())
        .unwrap_or_default();
    let experiment = ExperimentResult::aggregate(cfg.controller.label(), per_trainer, epoch_times);
    Ok(ClusterResult { experiment, wall_total, walls, wire, servers, allreduce_rounds })
}
