//! Distributed cluster runtime: the paper's training cluster as *real*
//! concurrency — and, over TCP, as real processes — instead of virtual
//! time.
//!
//! Where [`crate::sim`] steps trainers sequentially against the α–β clock,
//! this subsystem runs one OS thread per trainer, one per partition
//! feature-server, one async prefetcher per trainer, and a DDP allreduce
//! hub — all communicating through the serialized, length-prefixed wire
//! format ([`wire::Frame`]), so the RPC path pays honest encode/decode
//! cost and request coalescing, response dedup, server-side queuing, and
//! prefetch/compute overlap are *exercised*, not assumed.
//!
//! The split of responsibilities is the design's core:
//!
//! * **What** to fetch — sampling, buffer lookups, controller decisions,
//!   replacement rounds, and every traffic counter — is computed by the
//!   embedded [`crate::sim::trainer::Trainer`] state machine, driven by
//!   the same seeds as the sim.  This yields the traffic-parity guarantee
//!   ([`run::parity_check`]): same config + seed ⇒ fetched-node, hit, and
//!   byte counters identical to the virtual-time sim, for *every*
//!   controller including LLM agents.
//! * **How** the bytes move is a pluggable [`transport::Transport`] behind
//!   [`transport::FrameSender`]/[`transport::FrameReceiver`]:
//!
//!   | transport | endpoints              | bytes path                         |
//!   |-----------|------------------------|------------------------------------|
//!   | `channel` | threads, one process   | in-process `mpsc`, whole frames    |
//!   | `tcp`     | threads *or processes* | loopback/remote sockets, reassembled from arbitrary stream segments |
//!
//!   Wire-level counters ([`crate::metrics::WireStats`], including
//!   per-link [`crate::metrics::LinkStats`]) come from this layer.  The
//!   prefetcher's want-set dedup plus response req-id dedup make every
//!   protocol counter a pure function of config + seed, so the *same*
//!   counters are also identical across transports and under injected
//!   faults ([`run::wire_parity`], [`transport::FaultSpec`]) — only
//!   `dup_frames` records the faults themselves.
//!
//! Topology (one trainer process shown; `channel` collapses everything
//! into one process):
//!
//! ```text
//!            ┌── trainer process t ──────────────┐
//!            │ trainer thread ⇄ FeatureStore     │     FetchReq ▶
//!            │        │ Fetch/Evict              ├────────────────▶ server p
//!            │        ▼                          │ ◀ FetchResp      (per owner
//!            │ prefetcher thread ◀─ pump threads │                   partition)
//!            └──────┬────────────────────────────┘
//!                   │ Allreduce ⇄ reduced Allreduce
//!                   ▼
//!               allreduce hub (barrier: max vclock + summed grads)
//! ```
//!
//! `rudder cluster --transport tcp` runs each role as a separate OS
//! process via `--role trainer|server|hub --listen/--connect`
//! sub-invocations of the same binary ([`multiproc`]); results return
//! over the orchestrator's results listener as bit-exact binary blobs
//! ([`wire::Frame::Result`] carrying [`ipc`] payloads) so parity survives
//! the process boundary without a shared filesystem.
//!
//! Compute wall time comes from [`run::ComputeMode`]:
//!
//! * `Emulated(time_scale)` bridges the virtual and wall clocks: servers,
//!   compute, and the hub sleep `time_scale × modelled seconds`, so
//!   prefetch overlap shows up in real wall time at any convenient speed
//!   (0 = no emulation).
//! * `Measured` spends real CPU cycles instead: every trainer owns an
//!   interpreter-backend [`crate::gnn::SageRunner`] and runs actual sage
//!   fwd/bwd on the features its prefetcher materialized, closing each
//!   round with a *real* gradient allreduce (the hub element-wise-reduces
//!   the replicas' deltas in trainer-id order — bit-deterministic — and
//!   every replica applies the same mean update).  The virtual clock still
//!   advances by the modelled costs, so decisions and traffic counters
//!   stay a pure function of config + seed and every parity guarantee
//!   above keeps holding; `rudder bench` gates CI on this mode's
//!   prefetch-vs-baseline ratios (`BENCH_cluster.json`).

pub mod ipc;
pub mod multiproc;
pub mod prefetch;
pub mod run;
pub mod server;
pub mod trainer;
pub mod transport;
pub mod wire;

pub use multiproc::run_cluster_multiproc;
pub use prefetch::{FeatureStore, PrefetchMsg};
pub use run::{
    parity_check, run_cluster, run_cluster_on, wire_parity, ClusterConfig, ClusterResult,
    ComputeMode,
};
pub use server::{ServerStats, WireDelay};
pub use trainer::WallStats;
pub use transport::{FaultSpec, FrameAssembler, FrameReceiver, FrameSender, Transport};
pub use wire::Frame;
