//! Distributed cluster runtime: the paper's training cluster as *real*
//! concurrency — and, over TCP, as real processes — instead of virtual
//! time.
//!
//! Where [`crate::sim`] steps trainers sequentially against the α–β clock,
//! this subsystem runs one OS thread per trainer, one per partition
//! feature-server, one async prefetcher per trainer, and a DDP allreduce
//! hub — all communicating through the serialized, length-prefixed wire
//! format ([`wire::Frame`]), so the RPC path pays honest encode/decode
//! cost and request coalescing, response dedup, server-side queuing, and
//! prefetch/compute overlap are *exercised*, not assumed.
//!
//! The split of responsibilities is the design's core:
//!
//! * **What** to fetch — sampling, buffer lookups, controller decisions,
//!   replacement rounds, and every traffic counter — is computed by the
//!   embedded [`crate::sim::trainer::Trainer`] state machine, driven by
//!   the same seeds as the sim.  This yields the traffic-parity guarantee
//!   ([`run::parity_check`]): same config + seed ⇒ fetched-node, hit, and
//!   byte counters identical to the virtual-time sim, for *every*
//!   controller including LLM agents.
//! * **How** the bytes move is a pluggable [`transport::Transport`] behind
//!   [`transport::FrameSender`]/[`transport::FrameReceiver`]:
//!
//!   | transport | endpoints              | bytes path                         |
//!   |-----------|------------------------|------------------------------------|
//!   | `channel` | threads, one process   | in-process `mpsc`, whole frames    |
//!   | `tcp`     | threads *or processes* | loopback/remote sockets, one blocking pump thread per link, reassembled from arbitrary stream segments |
//!   | `event`   | threads, one process   | nonblocking loopback sockets behind **one** readiness-polled I/O thread ([`eventloop`]); all of a trainer's logical links multiplexed over a single connection |
//!
//!   Wire-level counters ([`crate::metrics::WireStats`], including
//!   per-link [`crate::metrics::LinkStats`]) come from this layer.  The
//!   prefetcher's want-set dedup plus response req-id dedup make every
//!   protocol counter a pure function of config + seed, so the *same*
//!   counters are also identical across transports and under injected
//!   faults ([`run::wire_parity`], [`transport::FaultSpec`]) — only
//!   `dup_frames` records the faults themselves.
//!
//! Topology (one trainer process shown; `channel` collapses everything
//! into one process):
//!
//! ```text
//!            ┌── trainer process t ──────────────┐
//!            │ trainer thread ⇄ FeatureStore     │   FetchReq |
//!            │        │ Fetch/Evict              │   ChunkReq ▶
//!            │        ▼                          ├────────────────▶ server p
//!            │ prefetcher thread ◀─ pump threads │ ◀ FetchResp |    (per owner
//!            │        │                          │   ChunkResp      partition,
//!            │  [ChunkCache p]  (LRU, per link)  │                   FeatureShard
//!            └──────┬────────────────────────────┘                   + digests)
//!                   │ Allreduce ⇄ reduced Allreduce
//!                   ▼
//!               allreduce hub (barrier: max vclock + summed grads)
//! ```
//!
//! With `chunk_cache_bytes > 0` ([`crate::sim::RunConfig`], `rudder
//! cluster --chunk-cache`) the feature plane is **content-addressed**:
//! each owner partition's rows are grouped into fixed `chunk_rows`-row
//! chunks (in `local_nodes` order, so trainer and server agree on the
//! layout without negotiation), keyed by an FNV-1a digest over the row
//! bytes.  The prefetcher keeps one byte-budgeted LRU `ChunkCache`
//! (shared-nothing) per server link; fetch orders consult it first, only
//! missed chunks' nodes go on the wire as `ChunkReq`, and the server
//! answers with whole digest-verified chunks (`ChunkResp`).  Admission
//! and eviction happen at command time only, so hits and misses — and
//! every wire counter — stay a pure function of config + seed, and all
//! parity guarantees below hold with the cache on.
//!
//! Under `--transport event` the per-link pipes and pump threads collapse
//! into a channel-id-multiplexed stream: trainer `t` holds **one**
//! physical connection whose logical channel `p` carries the
//! trainer↔server-`p` link (`p < n`) and channel `n` carries the hub
//! link.  Each frame travels as `[u32 channel][frame]`; a zero-length
//! marker half-closes one channel.  A single event-loop thread sweeps
//! every connection for readiness (nonblocking reads through a
//! per-connection assembler, queued writes coalesced into syscall-sized
//! batches with a byte-capped backpressure queue) and routes inbound
//! frames to the owning endpoint's inbox.  Senders see the explicit
//! nonblocking contract of [`transport::FrameSender`]: `send_frame`
//! enqueues (blocking only on backpressure), `send_frames` batches, and
//! `close` flushes everything queued before the end-of-stream marker.
//!
//! `rudder cluster --transport tcp` runs each role as a separate OS
//! process via `--role trainer|server|hub --listen/--connect`
//! sub-invocations of the same binary ([`multiproc`]); results return
//! over the orchestrator's results listener as bit-exact binary blobs
//! ([`wire::Frame::Result`] carrying [`ipc`] payloads) so parity survives
//! the process boundary without a shared filesystem.
//!
//! Compute wall time comes from [`run::ComputeMode`]:
//!
//! * `Emulated(time_scale)` bridges the virtual and wall clocks: servers,
//!   compute, and the hub sleep `time_scale × modelled seconds`, so
//!   prefetch overlap shows up in real wall time at any convenient speed
//!   (0 = no emulation).
//! * `Measured` spends real CPU cycles instead: every trainer owns an
//!   interpreter-backend [`crate::gnn::SageRunner`] and runs actual sage
//!   fwd/bwd on the features its prefetcher materialized, closing each
//!   round with a *real* gradient allreduce (the hub element-wise-reduces
//!   the replicas' deltas in trainer-id order — bit-deterministic — and
//!   every replica applies the same mean update).  The virtual clock still
//!   advances by the modelled costs, so decisions and traffic counters
//!   stay a pure function of config + seed and every parity guarantee
//!   above keeps holding; `rudder bench` gates CI on this mode's
//!   prefetch-vs-baseline ratios (`BENCH_cluster.json`).
//!
//! # Flight recorder
//!
//! With tracing on ([`ClusterConfig::trace`], `rudder cluster --trace`),
//! every role owns a [`crate::trace::Tracer`] and emits typed
//! [`crate::trace::TraceEvent`]s — minibatch begin/end, fetch
//! issue/response/serve, chunk-cache hits/misses, batch and link
//! flushes, allreduce rounds,
//! replacement, stalls — each carrying the virtual clock *and* a wall
//! clock, tagged `(role, id, seq)`.  Buffers flow back to the
//! orchestrator on the same paths as the stats they annotate:
//!
//! ```text
//!  trainer thread ──┐
//!  prefetcher ──────┤ per-role Vec<TraceEvent>
//!  server p ────────┤   channel/event: returned by each thread's join
//!  hub ─────────────┘   tcp: shipped in the ipc result blob
//!                              (Frame::Result, magics RTR4/RSV2/RHB2)
//!          ▼
//!  merged + canonically sorted ⇒ ClusterResult::trace ⇒ Trace::write_file
//!          ▼
//!  rudder trace dump | stats | diff   (JSONL ⇄ RTRC binary, lossless)
//! ```
//!
//! Virtual-time fields of the trace are a pure function of config + seed,
//! so `rudder trace diff` extends the wire-parity guarantee to the whole
//! timeline: same-seed runs on `channel`, `tcp`, and `event` transports
//! must be bit-identical after wall clocks and arrival order are
//! projected out ([`crate::trace::diff`]).  Every role stream ends with a
//! `role_end { emitted }` record and gapless per-stream sequence numbers,
//! so [`crate::trace::Trace::verify_complete`] detects any event silently
//! dropped at shutdown.
//!
//! # Machine-enforced invariants
//!
//! The properties this module depends on are checked by `rudder audit`
//! (see [`crate::audit`]) and by hardened clippy lints below, not by
//! convention: codec narrowing is checked ([`wire::len_u32`]), cluster
//! locks recover from poisoning instead of cascading panics, condvar
//! waits are always timed, and the `RTR*`/`RSV*`/`RHB*` protocol magics
//! resolve through [`crate::magic`].  A blocking `audit` CI job keeps
//! these true for future changes.

#![warn(
    clippy::cast_possible_truncation,
    clippy::cast_sign_loss,
    clippy::unwrap_used
)]

pub mod eventloop;
pub mod ipc;
pub mod multiproc;
pub mod prefetch;
pub mod run;
pub mod server;
pub mod trainer;
pub mod transport;
pub mod wire;

pub use eventloop::{MuxAssembler, MuxEvent};
pub use multiproc::run_cluster_multiproc;
pub use prefetch::{FeatureStore, PrefetchMsg};
pub use run::{
    parity_check, run_cluster, run_cluster_on, wire_parity, ClusterConfig, ClusterResult,
    ComputeMode,
};
pub use server::{ServerStats, WireDelay};
pub use trainer::WallStats;
pub use transport::{
    FaultSpec, FrameAssembler, FrameReceiver, FrameSender, LinkStatsHandle, Transport,
};
pub use wire::Frame;

/// Narrow a small topology id/count (trainer, partition, channel —
/// bounded by cluster configuration, far below 2^32) to its `u32` wire
/// width.  Centralizing the one intentional narrowing keeps
/// `clippy::cast_possible_truncation` deniable everywhere else; lengths
/// that an adversarial peer could inflate go through the fallible
/// [`wire::len_u32`] instead.
#[allow(clippy::cast_possible_truncation)] // bounded by construction; debug-asserted
pub(crate) fn id_u32(n: usize) -> u32 {
    debug_assert!(n <= u32::MAX as usize, "topology id {n} exceeds u32");
    n as u32
}
