//! In-process distributed cluster runtime: the paper's training cluster as
//! *real* concurrency instead of virtual time.
//!
//! Where [`crate::sim`] steps trainers sequentially against the α–β clock,
//! this subsystem runs one OS thread per trainer, one per partition
//! feature-server, one async prefetcher per trainer, and a DDP allreduce
//! hub — all communicating through a serialized, length-prefixed wire
//! format ([`wire::Frame`]), so the RPC path pays honest encode/decode
//! cost and request coalescing, in-flight dedup, server-side queuing, and
//! prefetch/compute overlap are *exercised*, not assumed.
//!
//! The split of responsibilities is the design's core:
//!
//! * **What** to fetch — sampling, buffer lookups, controller decisions,
//!   replacement rounds, and every traffic counter — is computed by the
//!   embedded [`crate::sim::trainer::Trainer`] state machine, driven by
//!   the same seeds as the sim.  This yields the traffic-parity guarantee
//!   ([`run::parity_check`]): same config + seed ⇒ fetched-node, hit, and
//!   byte counters identical to the virtual-time sim, for *every*
//!   controller including LLM agents.
//! * **How** the bytes move is real: feature payloads are synthesized by
//!   the owner partition's server thread, serialized, routed, installed in
//!   a [`prefetch::FeatureStore`], and waited on; gradients cross the
//!   allreduce hub as frames.  Wall-clock and wire-level counters
//!   ([`crate::metrics::WireStats`]) come from this layer — dedup and
//!   coalescing make the wire counters *smaller* than the logical ones,
//!   and they are timing-dependent, so parity never compares them.
//!
//! `time_scale` bridges the two clocks: servers, compute, and the hub
//! sleep `time_scale × modelled seconds`, so prefetch overlap shows up in
//! real wall time at any convenient speed (0 = no emulation).

pub mod prefetch;
pub mod run;
pub mod server;
pub mod trainer;
pub mod wire;

pub use prefetch::{FeatureStore, PrefetchMsg};
pub use run::{parity_check, run_cluster, run_cluster_on, ClusterConfig, ClusterResult};
pub use server::{ServerStats, WireDelay};
pub use trainer::WallStats;
pub use wire::Frame;
