//! Per-partition feature servers: the remote end of the fetch RPC.
//!
//! Each partition gets one serving loop owning its feature shard — the
//! partition's rows materialized once at spawn as a seeded, resident
//! tensor ([`FeatureShard`]), so serving is a row copy, not a per-request
//! re-synthesis.  It decodes [`Frame::FetchReq`] frames, gathers the
//! requested rows, optionally emulates the fabric's α–β transfer time at a
//! configurable wall-clock scale, and replies with a serialized
//! [`Frame::FetchResp`] on the requesting trainer's reply link.  The loop
//! is transport-agnostic: its inbox is a [`NetMsg`] channel fed either
//! directly by in-process prefetchers (channel transport) or by the
//! accept/pump threads of a TCP listener, and reply routes arrive either
//! pre-registered (channel) or via [`NetMsg::Register`] handshakes (TCP).
//! The loop exits when every request source has hung up.

use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::graph::features::fill_features;
use crate::net::Network;
use crate::partition::Partition;
use crate::trace::{EventKind, Role, TraceEvent, Tracer};
use crate::util::fasthash::{digest_f32, FastMap, FastSet};

use super::transport::{FaultSender, FaultSpec, FrameSender, NetMsg};
use super::wire::{Chunk, Frame};

/// Traffic served by one feature server.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub part: usize,
    pub requests: u64,
    pub nodes_served: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Frames that failed to decode, had an unexpected kind, or named an
    /// unknown reply route.
    pub bad_frames: u64,
}

/// Wall-clock emulation of the RPC fabric, derived from the same α–β
/// [`crate::net::NetParams`] the virtual-time sim charges: each reply is
/// delayed by `scale × (α + β·bytes·contention)`.  `scale = 0` disables
/// emulation (as fast as the hardware allows).
#[derive(Debug, Clone, Copy)]
pub struct WireDelay {
    pub alpha: f64,
    pub beta_contended: f64,
    pub scale: f64,
}

impl WireDelay {
    pub fn from_net(net: &Network, scale: f64) -> WireDelay {
        WireDelay {
            alpha: net.params.alpha,
            beta_contended: net.params.beta * net.contention_factor(),
            scale,
        }
    }

    /// Sleep for the emulated transfer time of a `bytes`-sized payload.
    pub fn emulate(&self, bytes: usize) {
        if self.scale <= 0.0 {
            return;
        }
        let secs = self.scale * (self.alpha + self.beta_contended * bytes as f64);
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

/// Partition-resident feature shard: every owned node's feature row
/// materialized once (row-major block plus an id → row index), exactly as
/// a real feature server would hold its partition's slice of the feature
/// matrix in memory.  Values are identical to on-demand synthesis —
/// features are a pure function of `(seed, node)` — so the wire payloads
/// are unchanged; only the serving cost moves from hashing to a copy.
pub(crate) struct FeatureShard {
    feat_dim: usize,
    feature_seed: u64,
    index: FastMap<u32, u32>,
    /// Owned node ids in local (row) order — the canonical chunk order
    /// shared with the trainers' chunk layouts.
    nodes: Vec<u32>,
    rows: Vec<f32>,
    /// Content-addressed chunk table: chunk `c` covers local rows
    /// `[c·chunk_rows, (c+1)·chunk_rows)`; `chunk_digests[c]` is the
    /// FNV-1a digest of its row payload, computed once at build.
    chunk_rows: usize,
    chunk_digests: Vec<u64>,
}

impl FeatureShard {
    pub(crate) fn build(
        part: &Partition,
        part_id: usize,
        feature_seed: u64,
        feat_dim: usize,
        chunk_rows: usize,
    ) -> FeatureShard {
        let owned = part.local_nodes[part_id].clone();
        let chunk_rows = chunk_rows.max(1);
        let mut index = FastMap::default();
        let mut rows = vec![0.0f32; owned.len() * feat_dim];
        for (i, &n) in owned.iter().enumerate() {
            index.insert(n, super::id_u32(i));
            fill_features(feature_seed, n, &mut rows[i * feat_dim..(i + 1) * feat_dim]);
        }
        let n_chunks = owned.len().div_ceil(chunk_rows);
        let mut chunk_digests = Vec::with_capacity(n_chunks);
        for c in 0..n_chunks {
            let start = c * chunk_rows;
            let end = (start + chunk_rows).min(owned.len());
            chunk_digests.push(digest_f32(&rows[start * feat_dim..end * feat_dim]));
        }
        FeatureShard {
            feat_dim,
            feature_seed,
            index,
            nodes: owned,
            rows,
            chunk_rows,
            chunk_digests,
        }
    }

    /// Materialize chunk `c` for the wire: its node ids + row payload.
    fn chunk(&self, c: usize) -> Chunk {
        let start = c * self.chunk_rows;
        let end = (start + self.chunk_rows).min(self.nodes.len());
        Chunk {
            digest: self.chunk_digests[c],
            nodes: self.nodes[start..end].to_vec(),
            feats: self.rows[start * self.feat_dim..end * self.feat_dim].to_vec(),
        }
    }

    /// Expand requested nodes to whole chunks (first-appearance order),
    /// eliding any chunk whose digest the requester declared in `have`.
    /// Returns `(elided digests, chunks to send, rows going on the wire)`.
    pub(crate) fn gather_chunks(
        &self,
        nodes: &[u32],
        have: &[u64],
    ) -> (Vec<u64>, Vec<Chunk>, u64) {
        let mut seen: FastSet<u32> = FastSet::default();
        let mut refs = Vec::new();
        let mut chunks = Vec::new();
        let mut served = 0u64;
        for &n in nodes {
            let Some(&i) = self.index.get(&n) else { continue };
            let c = i as usize / self.chunk_rows;
            if !seen.insert(super::id_u32(c)) {
                continue;
            }
            let digest = self.chunk_digests[c];
            if have.contains(&digest) {
                refs.push(digest);
                continue;
            }
            let chunk = self.chunk(c);
            served += chunk.nodes.len() as u64;
            chunks.push(chunk);
        }
        (refs, chunks, served)
    }

    /// Copy node `n`'s row into `dst`.  A non-resident node (impossible
    /// under owner routing) falls back to synthesis so the payload stays
    /// correct either way.
    pub(crate) fn fill(&self, n: u32, dst: &mut [f32]) {
        match self.index.get(&n) {
            Some(&i) => {
                let i = i as usize;
                dst.copy_from_slice(&self.rows[i * self.feat_dim..(i + 1) * self.feat_dim]);
            }
            None => fill_features(self.feature_seed, n, dst),
        }
    }
}

/// Wrap a reply link with the fault-injection shim when configured.  The
/// schedule seed is derived per (server, trainer) link so every link draws
/// an independent, reproducible fault sequence.
fn wrap_fault(
    sender: Box<dyn FrameSender>,
    fault: &Option<FaultSpec>,
    part_id: usize,
    trainer_id: u32,
) -> Box<dyn FrameSender> {
    match fault {
        Some(spec) => Box::new(FaultSender::new(
            sender,
            spec,
            &[part_id as u64, trainer_id as u64],
        )),
        None => sender,
    }
}

/// The serving loop for partition `part_id`.  `prereg` carries reply links
/// known at spawn time (channel transport); socket transports register
/// theirs through [`NetMsg::Register`] before any frame from that peer
/// arrives.  Runs until `rx` disconnects; used inline by the TCP worker
/// process and on a thread by [`spawn_server`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn server_loop(
    part_id: usize,
    feature_seed: u64,
    feat_dim: usize,
    chunk_rows: usize,
    part: Arc<Partition>,
    rx: Receiver<NetMsg>,
    prereg: Vec<(u32, Box<dyn FrameSender>)>,
    delay: WireDelay,
    fault: Option<FaultSpec>,
    trace: bool,
) -> (ServerStats, Vec<TraceEvent>) {
    let mut stats = ServerStats { part: part_id, ..ServerStats::default() };
    let mut tracer = Tracer::new(trace, Role::Server, super::id_u32(part_id));
    let shard = FeatureShard::build(&part, part_id, feature_seed, feat_dim, chunk_rows);
    let mut replies: FastMap<u32, Box<dyn FrameSender>> = FastMap::default();
    for (id, s) in prereg {
        replies.insert(id, wrap_fault(s, &fault, part_id, id));
    }
    loop {
        // Drain eagerly; on an empty inbox flush fault-held replies before
        // blocking, so an injected delay re-orders frames but can never
        // stall a trainer that is blocked waiting on the held response.
        let msg = match rx.try_recv() {
            Ok(m) => m,
            Err(TryRecvError::Disconnected) => break,
            Err(TryRecvError::Empty) => {
                for r in replies.values_mut() {
                    r.flush_pending();
                }
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            }
        };
        let bytes = match msg {
            NetMsg::Register(id, s) => {
                replies.insert(id, wrap_fault(s, &fault, part_id, id));
                continue;
            }
            NetMsg::Frame(bytes) => bytes,
        };
        stats.bytes_in += bytes.len() as u64;
        let (frame, _) = match Frame::decode(&bytes) {
            Ok(ok) => ok,
            Err(_) => {
                stats.bad_frames += 1;
                continue;
            }
        };
        let (req_id, from, served, encoded) = match frame {
            Frame::FetchReq { req_id, from, nodes } => {
                debug_assert!(
                    nodes.iter().all(|&n| part.owner_of(n) == part_id),
                    "fetch routed to non-owner partition {part_id}"
                );
                let mut feats = vec![0.0f32; nodes.len() * feat_dim];
                for (i, &n) in nodes.iter().enumerate() {
                    shard.fill(n, &mut feats[i * feat_dim..(i + 1) * feat_dim]);
                }
                let served = nodes.len() as u64;
                let resp =
                    Frame::FetchResp { req_id, feat_dim: super::id_u32(feat_dim), nodes, feats };
                (req_id, from, served, resp.encode())
            }
            Frame::ChunkReq { req_id, from, nodes, have } => {
                debug_assert!(
                    nodes.iter().all(|&n| part.owner_of(n) == part_id),
                    "chunk fetch routed to non-owner partition {part_id}"
                );
                let (refs, chunks, served) = shard.gather_chunks(&nodes, &have);
                let resp =
                    Frame::ChunkResp { req_id, feat_dim: super::id_u32(feat_dim), refs, chunks };
                (req_id, from, served, resp.encode())
            }
            _ => {
                stats.bad_frames += 1;
                continue;
            }
        };
        let out = match encoded {
            Ok(o) => o,
            Err(e) => {
                stats.bad_frames += 1;
                crate::log_info!("server {part_id}: reply encode failed: {e}");
                continue;
            }
        };
        let Some(reply) = replies.get_mut(&from) else {
            stats.bad_frames += 1;
            continue;
        };
        stats.requests += 1;
        stats.nodes_served += served;
        stats.bytes_out += out.len() as u64;
        tracer.emit(
            0.0,
            EventKind::FetchServe { req_id, from, nodes: served, bytes: out.len() as u64 },
        );
        delay.emulate(out.len());
        // Prefetcher gone (trainer already finished): drop reply.
        let _ = reply.send_frame(&out);
    }
    // Reply links drop here, flushing any fault-shim-held frames while the
    // peers' drain loops are still reading.
    (stats, tracer.finish())
}

/// Spawn [`server_loop`] on its own OS thread.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_server(
    part_id: usize,
    feature_seed: u64,
    feat_dim: usize,
    chunk_rows: usize,
    part: Arc<Partition>,
    rx: Receiver<NetMsg>,
    prereg: Vec<(u32, Box<dyn FrameSender>)>,
    delay: WireDelay,
    fault: Option<FaultSpec>,
    trace: bool,
) -> JoinHandle<(ServerStats, Vec<TraceEvent>)> {
    std::thread::Builder::new()
        .name(format!("rudder-server-{part_id}"))
        .spawn(move || {
            server_loop(
                part_id,
                feature_seed,
                feat_dim,
                chunk_rows,
                part,
                rx,
                prereg,
                delay,
                fault,
                trace,
            )
        })
        .expect("spawn feature-server thread")
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)] // test code: panics are the failure report

    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::net::NetParams;
    use crate::partition::{partition, Method};
    use crate::util::rng::Pcg32;
    use std::sync::mpsc;

    use crate::cluster::prefetch::PrefetchMsg;
    use crate::cluster::transport::{ChannelSender, LinkStatsHandle};

    #[test]
    fn serves_owned_nodes_with_correct_features() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 400,
                num_edges: 2400,
                permute: true,
            },
            &mut Pcg32::new(5),
        );
        let part = Arc::new(partition(&csr, 2, Method::MetisLike, 1));
        let (req_tx, req_rx) = mpsc::channel::<NetMsg>();
        let (rep_tx, rep_rx) = mpsc::channel::<PrefetchMsg>();
        let delay = WireDelay::from_net(&Network::new(NetParams::default(), 2), 0.0);
        let owned: Vec<u32> = part.local_nodes[0][..3].to_vec();
        let link = LinkStatsHandle::new("server:0");
        let prereg: Vec<(u32, Box<dyn FrameSender>)> = vec![(
            1,
            Box::new(ChannelSender::delivering(rep_tx, PrefetchMsg::Wire, link.clone())),
        )];
        let handle = spawn_server(0, 42, 4, 8, part.clone(), req_rx, prereg, delay, None, true);
        req_tx
            .send(NetMsg::Frame(
                Frame::FetchReq { req_id: 9, from: 1, nodes: owned.clone() }.encode().unwrap(),
            ))
            .unwrap();
        let PrefetchMsg::Wire(resp) = rep_rx.recv().unwrap() else {
            panic!("expected wire reply")
        };
        let (frame, _) = Frame::decode(&resp).unwrap();
        let Frame::FetchResp { req_id, feat_dim, nodes, feats } = frame else {
            panic!("expected FetchResp")
        };
        assert_eq!((req_id, feat_dim), (9, 4));
        assert_eq!(nodes, owned);
        let mut want = vec![0.0f32; 4];
        crate::graph::features::fill_features(42, owned[1], &mut want);
        assert_eq!(&feats[4..8], &want[..], "row 1 must be node {}'s features", owned[1]);
        drop(req_tx);
        let (stats, trace) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.nodes_served, 3);
        assert!(stats.bytes_out > stats.bytes_in);
        // One FetchServe event plus the terminal RoleEnd.
        assert_eq!(trace.len(), 2);
        assert!(matches!(
            trace[0].kind,
            EventKind::FetchServe { req_id: 9, from: 1, nodes: 3, .. }
        ));
        // Reply delivery counted as received on the trainer-side link.
        let snap = link.snapshot();
        assert_eq!(snap.frames_recv, 1);
    }

    #[test]
    fn feature_shard_serves_resident_copies() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 300,
                num_edges: 1800,
                permute: true,
            },
            &mut Pcg32::new(9),
        );
        let part = partition(&csr, 2, Method::MetisLike, 1);
        let shard = FeatureShard::build(&part, 0, 11, 4, 8);
        assert_eq!(shard.index.len(), part.local_nodes[0].len());
        let mut got = vec![0.0f32; 4];
        let mut want = vec![0.0f32; 4];
        // Resident row: a copy of the materialized tensor, bit-identical
        // to synthesis.
        let own = part.local_nodes[0][0];
        shard.fill(own, &mut got);
        fill_features(11, own, &mut want);
        assert_eq!(got, want);
        // Foreign node: synthesis fallback, same values.
        let foreign = part.local_nodes[1][0];
        shard.fill(foreign, &mut got);
        fill_features(11, foreign, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn chunk_requests_expand_and_elide_by_digest() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 300,
                num_edges: 1800,
                permute: true,
            },
            &mut Pcg32::new(3),
        );
        let part = partition(&csr, 2, Method::MetisLike, 1);
        let shard = FeatureShard::build(&part, 0, 11, 4, 2);
        let owned = &part.local_nodes[0];
        // owned[0], owned[1] share chunk 0; owned[2] lives in chunk 1.
        let (refs, chunks, served) = shard.gather_chunks(&[owned[0], owned[1], owned[2]], &[]);
        assert!(refs.is_empty());
        assert_eq!(chunks.len(), 2, "three nodes expand to two whole chunks");
        assert_eq!(served, 4);
        assert_eq!(chunks[0].nodes, vec![owned[0], owned[1]]);
        for c in &chunks {
            assert_eq!(c.feats.len(), c.nodes.len() * 4);
            assert_eq!(digest_f32(&c.feats), c.digest, "digest covers the row payload");
        }
        // Declaring chunk 0 held elides its payload: digest-only ref.
        let held = chunks[0].digest;
        let (refs2, chunks2, served2) =
            shard.gather_chunks(&[owned[0], owned[2]], &[held]);
        assert_eq!(refs2, vec![held]);
        assert_eq!(chunks2.len(), 1);
        assert_eq!(chunks2[0].nodes, vec![owned[2], owned[3]]);
        assert_eq!(served2, 2);
    }

    #[test]
    fn serves_chunk_requests_end_to_end() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 200,
                num_edges: 1200,
                permute: true,
            },
            &mut Pcg32::new(8),
        );
        let part = Arc::new(partition(&csr, 1, Method::MetisLike, 1));
        let (req_tx, req_rx) = mpsc::channel::<NetMsg>();
        let (rep_tx, rep_rx) = mpsc::channel::<PrefetchMsg>();
        let delay = WireDelay::from_net(&Network::new(NetParams::default(), 1), 0.0);
        let link = LinkStatsHandle::new("server:0");
        let prereg: Vec<(u32, Box<dyn FrameSender>)> =
            vec![(0, Box::new(ChannelSender::delivering(rep_tx, PrefetchMsg::Wire, link)))];
        let want_node = part.local_nodes[0][5];
        let handle = spawn_server(0, 42, 4, 4, part.clone(), req_rx, prereg, delay, None, false);
        req_tx
            .send(NetMsg::Frame(
                Frame::ChunkReq { req_id: 2, from: 0, nodes: vec![want_node], have: vec![] }
                    .encode()
                    .unwrap(),
            ))
            .unwrap();
        drop(req_tx);
        let PrefetchMsg::Wire(resp) = rep_rx.recv().unwrap() else {
            panic!("expected wire reply")
        };
        let (frame, _) = Frame::decode(&resp).unwrap();
        let Frame::ChunkResp { req_id, feat_dim, refs, chunks } = frame else {
            panic!("expected ChunkResp")
        };
        assert_eq!((req_id, feat_dim), (2, 4));
        assert!(refs.is_empty());
        assert_eq!(chunks.len(), 1);
        // The whole chunk comes back: rows 4..8 of the local order.
        assert_eq!(chunks[0].nodes, part.local_nodes[0][4..8].to_vec());
        assert_eq!(digest_f32(&chunks[0].feats), chunks[0].digest);
        let mut want = vec![0.0f32; 4];
        fill_features(42, want_node, &mut want);
        assert_eq!(&chunks[0].feats[4..8], &want[..], "row 1 is the requested node");
        let (stats, _) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.nodes_served, 4, "whole chunk counted");
    }

    #[test]
    fn faulted_reply_link_duplicates_responses() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 200,
                num_edges: 1200,
                permute: true,
            },
            &mut Pcg32::new(6),
        );
        let part = Arc::new(partition(&csr, 1, Method::MetisLike, 1));
        let (req_tx, req_rx) = mpsc::channel::<NetMsg>();
        let (rep_tx, rep_rx) = mpsc::channel::<PrefetchMsg>();
        let delay = WireDelay::from_net(&Network::new(NetParams::default(), 1), 0.0);
        let fault = FaultSpec { seed: 5, dup: 1.0, delay: 0.0, chop: 0 };
        let link = LinkStatsHandle::new("server:0");
        let prereg: Vec<(u32, Box<dyn FrameSender>)> = vec![(
            0,
            Box::new(ChannelSender::delivering(rep_tx, PrefetchMsg::Wire, link)),
        )];
        let owned: Vec<u32> = part.local_nodes[0][..2].to_vec();
        let handle = spawn_server(0, 1, 2, 8, part, req_rx, prereg, delay, Some(fault), false);
        req_tx
            .send(NetMsg::Frame(
                Frame::FetchReq { req_id: 0, from: 0, nodes: owned }.encode().unwrap(),
            ))
            .unwrap();
        drop(req_tx);
        let (stats, trace) = handle.join().unwrap();
        assert!(trace.is_empty(), "tracing disabled");
        assert_eq!(stats.requests, 1, "server serves each request once");
        let mut replies = 0;
        while let Ok(PrefetchMsg::Wire(_)) = rep_rx.recv() {
            replies += 1;
        }
        assert_eq!(replies, 2, "dup=1.0 must deliver every response twice");
    }
}
