//! Per-partition feature servers: the remote end of the fetch RPC.
//!
//! Each partition gets one OS thread owning its (synthesized) feature
//! shard.  It decodes [`Frame::FetchReq`] frames, materializes the
//! requested rows, optionally emulates the fabric's α–β transfer time at a
//! configurable wall-clock scale, and replies with a serialized
//! [`Frame::FetchResp`] routed to the requesting trainer's prefetcher.
//! The thread exits when every request sender has hung up.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::graph::features::fill_features;
use crate::net::Network;
use crate::partition::Partition;

use super::prefetch::PrefetchMsg;
use super::wire::Frame;

/// Traffic served by one feature server.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub part: usize,
    pub requests: u64,
    pub nodes_served: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Frames that failed to decode or had an unexpected kind.
    pub bad_frames: u64,
}

/// Wall-clock emulation of the RPC fabric, derived from the same α–β
/// [`crate::net::NetParams`] the virtual-time sim charges: each reply is
/// delayed by `scale × (α + β·bytes·contention)`.  `scale = 0` disables
/// emulation (as fast as the hardware allows).
#[derive(Debug, Clone, Copy)]
pub struct WireDelay {
    pub alpha: f64,
    pub beta_contended: f64,
    pub scale: f64,
}

impl WireDelay {
    pub fn from_net(net: &Network, scale: f64) -> WireDelay {
        WireDelay {
            alpha: net.params.alpha,
            beta_contended: net.params.beta * net.contention_factor(),
            scale,
        }
    }

    /// Sleep for the emulated transfer time of a `bytes`-sized payload.
    pub fn emulate(&self, bytes: usize) {
        if self.scale <= 0.0 {
            return;
        }
        let secs = self.scale * (self.alpha + self.beta_contended * bytes as f64);
        if secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

/// Spawn the feature server for partition `part_id`.  `replies[t]` routes
/// responses to trainer `t`'s prefetcher inbox.
pub(crate) fn spawn_server(
    part_id: usize,
    feature_seed: u64,
    feat_dim: usize,
    part: Arc<Partition>,
    rx: Receiver<Vec<u8>>,
    replies: Vec<Sender<PrefetchMsg>>,
    delay: WireDelay,
) -> JoinHandle<ServerStats> {
    std::thread::Builder::new()
        .name(format!("rudder-server-{part_id}"))
        .spawn(move || {
            let mut stats = ServerStats { part: part_id, ..ServerStats::default() };
            for bytes in rx.iter() {
                stats.bytes_in += bytes.len() as u64;
                let (frame, _) = match Frame::decode(&bytes) {
                    Ok(ok) => ok,
                    Err(_) => {
                        stats.bad_frames += 1;
                        continue;
                    }
                };
                let Frame::FetchReq { req_id, from, nodes } = frame else {
                    stats.bad_frames += 1;
                    continue;
                };
                if from as usize >= replies.len() {
                    stats.bad_frames += 1;
                    continue;
                }
                debug_assert!(
                    nodes.iter().all(|&n| part.owner_of(n) == part_id),
                    "fetch routed to non-owner partition {part_id}"
                );
                let mut feats = vec![0.0f32; nodes.len() * feat_dim];
                for (i, &n) in nodes.iter().enumerate() {
                    fill_features(feature_seed, n, &mut feats[i * feat_dim..(i + 1) * feat_dim]);
                }
                stats.requests += 1;
                stats.nodes_served += nodes.len() as u64;
                let out =
                    Frame::FetchResp { req_id, feat_dim: feat_dim as u32, nodes, feats }.encode();
                stats.bytes_out += out.len() as u64;
                delay.emulate(out.len());
                // Prefetcher gone (trainer already finished): drop reply.
                let _ = replies[from as usize].send(PrefetchMsg::Wire(out));
            }
            stats
        })
        .expect("spawn feature-server thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::net::NetParams;
    use crate::partition::{partition, Method};
    use crate::util::rng::Pcg32;
    use std::sync::mpsc;

    #[test]
    fn serves_owned_nodes_with_correct_features() {
        let csr = generate(
            &RmatParams {
                a: 0.57,
                b: 0.19,
                c: 0.19,
                num_nodes: 400,
                num_edges: 2400,
                permute: true,
            },
            &mut Pcg32::new(5),
        );
        let part = Arc::new(partition(&csr, 2, Method::MetisLike, 1));
        let (req_tx, req_rx) = mpsc::channel::<Vec<u8>>();
        let (rep_tx, rep_rx) = mpsc::channel::<PrefetchMsg>();
        let delay = WireDelay::from_net(&Network::new(NetParams::default(), 2), 0.0);
        let owned: Vec<u32> = part.local_nodes[0][..3].to_vec();
        let handle =
            spawn_server(0, 42, 4, part.clone(), req_rx, vec![rep_tx.clone(), rep_tx], delay);
        req_tx
            .send(Frame::FetchReq { req_id: 9, from: 1, nodes: owned.clone() }.encode())
            .unwrap();
        let PrefetchMsg::Wire(resp) = rep_rx.recv().unwrap() else {
            panic!("expected wire reply")
        };
        let (frame, _) = Frame::decode(&resp).unwrap();
        let Frame::FetchResp { req_id, feat_dim, nodes, feats } = frame else {
            panic!("expected FetchResp")
        };
        assert_eq!((req_id, feat_dim), (9, 4));
        assert_eq!(nodes, owned);
        let mut want = vec![0.0f32; 4];
        fill_features(42, owned[1], &mut want);
        assert_eq!(&feats[4..8], &want[..], "row 1 must be node {}'s features", owned[1]);
        drop(req_tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.nodes_served, 3);
        assert!(stats.bytes_out > stats.bytes_in);
    }
}
